#!/usr/bin/env python
"""GNN minibatch training with the REAL neighbour sampler (the minibatch_lg
shape's data path): CSR graph -> fanout-sampled padded subgraphs -> GraphCast
processor -> regression loss on seed nodes.

    PYTHONPATH=src python examples/train_gnn_minibatch.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import CSRGraph, sample_subgraph
from repro.data.synthetic import random_graph
from repro.distributed.optimizer import adamw
from repro.models import gnn

# ---- a 20k-node power-law graph with learnable node targets ---------------
N_NODES, N_EDGES, D_FEAT, D_OUT = 20_000, 120_000, 32, 8
g = random_graph(N_NODES, N_EDGES, D_FEAT, D_OUT, seed=0)
csr = CSRGraph.from_edges(g["edges"], N_NODES)
print(f"graph: {N_NODES} nodes, {N_EDGES} edges (CSR built)")

SEEDS, FANOUTS = 256, [10, 5]
PAD_N = SEEDS * (1 + FANOUTS[0] + FANOUTS[0] * FANOUTS[1])
PAD_E = SEEDS * (FANOUTS[0] + FANOUTS[0] * FANOUTS[1])

cfg = gnn.GNNConfig(n_layers=3, d_hidden=64, d_in=D_FEAT, d_out=D_OUT, remat=False)
params = gnn.init_params(jax.random.PRNGKey(0), cfg)
optimizer = adamw(lr=1e-3)
opt_state = optimizer.init(params)


@jax.jit
def train_step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(gnn.loss_fn)(params, batch, cfg)
    params, opt_state = optimizer.update(grads, opt_state, params)
    return params, opt_state, loss


rng = np.random.default_rng(1)
t0 = time.time()
for step in range(1, 41):
    seeds = rng.choice(N_NODES, SEEDS, replace=False)
    sub = sample_subgraph(
        csr, g["nodes"], g["targets"], seeds, FANOUTS,
        pad_nodes=PAD_N, pad_edges=PAD_E, seed=step,
    )
    batch = {k: jnp.asarray(v) for k, v in sub.items() if k != "n_real_nodes"}
    params, opt_state, loss = train_step(params, opt_state, batch)
    if step % 10 == 0:
        print(f"step {step:3d}  seed-node MSE {float(loss):.4f}  "
              f"({sub['n_real_nodes']} real nodes in the padded subgraph)")
print(f"done in {time.time()-t0:.1f}s — loss should fall toward the noise floor")
