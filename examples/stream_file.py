#!/usr/bin/env python
"""Compress a file the process never fully loads (streaming sessions).

    PYTHONPATH=src python examples/stream_file.py [path]

Without an argument, a ~32 MiB synthetic log corpus is generated on disk
first.  The file then streams through a long-lived ``CompressorSession``:
chunks are read lazily, encoded in parallel, and written to the container
incrementally — peak memory is ~window × chunk_bytes, independent of the
file size.  Decompression streams the same way, and the roundtrip is
verified with a running comparison, also without loading either file whole.
"""
from __future__ import annotations

import filecmp
import os
import sys
import tempfile
import time

from repro.codecs import text_profile
from repro.core import CompressorSession, DecompressorSession, stream_io

CHUNK_BYTES = 2 << 20
WINDOW = 4


def make_corpus(path: str, mib: int = 32) -> None:
    """Write a synthetic log corpus in pieces (the generator never holds it)."""
    line = b"2026-07-30T12:%02d:%06.3fZ INFO ingest req=%016x flushed in %dus\n"
    with open(path, "wb") as f:
        n = i = 0
        while n < mib << 20:
            chunk = b"".join(
                line % (i % 60, (i * 7919 % 60000) / 1000, i * 2654435761, i % 9999)
                for i in range(i, i + 4096)
            )
            f.write(chunk)
            n += len(chunk)
            i += 4096


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="ozl_stream_")
    if len(sys.argv) > 1:
        src = sys.argv[1]
    else:
        src = os.path.join(tmp, "corpus.log")
        print("generating ~32 MiB synthetic corpus ...")
        make_corpus(src)
    dst = os.path.join(tmp, "corpus.ozl")
    rt = os.path.join(tmp, "roundtrip.log")

    plan = text_profile()
    with CompressorSession(plan, chunk_bytes=CHUNK_BYTES, window=WINDOW) as sess:
        t0 = time.time()
        stats = stream_io.compress_file(
            src, dst, plan, chunk_bytes=CHUNK_BYTES, session=sess
        )
        dt = time.time() - t0
    print(
        f"compressed {stats['bytes_in']:,} -> {stats['bytes_out']:,} bytes"
        f" (x{stats['bytes_in']/max(stats['bytes_out'],1):.2f})"
        f" in {dt:.2f}s, {stats['chunks']} chunks,"
        f" <= {sess.stats['max_inflight']} in flight"
        f" (~{sess.stats['max_inflight']*CHUNK_BYTES>>20} MiB held)"
    )

    with DecompressorSession(window=WINDOW) as dsess:
        t0 = time.time()
        dstats = stream_io.decompress_file(dst, rt, session=dsess)
        dt = time.time() - t0
    print(f"decompressed back to {dstats['bytes_out']:,} bytes in {dt:.2f}s")

    ok = filecmp.cmp(src, rt, shallow=False)
    print("roundtrip:", "bit-exact" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
