#!/usr/bin/env python
"""End-to-end driver: train a ~100M-param llama3.2-1b-family model for a few
hundred steps on CPU with the OpenZL integrations live on every I/O path
(paper §VIII): compressed training-data shards, compressed checkpoints,
crash + auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-width]

Defaults to a width-reduced model so a few hundred steps finish on one CPU
core; --full-width uses d_model=768 (~100M params) and fewer steps.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    ap.add_argument("--data-dir", default="/tmp/repro_example_data")
    args = ap.parse_args()

    argv = [
        "--arch", "llama3.2-1b",
        "--steps", str(args.steps),
        "--ckpt-dir", args.ckpt_dir,
        "--data-dir", args.data_dir,
        "--save-interval", "100",
        "--batch", "8",
        "--seq", "64",
        "--log-every", "25",
    ]
    if not args.full_width:
        argv.append("--reduced")
    return train_mod.main(argv)


if __name__ == "__main__":
    sys.exit(main())
