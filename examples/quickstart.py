#!/usr/bin/env python
"""Quickstart: the graph model of compression in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Fig. 2 compressor (tokenize -> per-output backends),
compresses data, decodes it with the UNIVERSAL decoder (no plan needed),
and round-trips a serialized compressor config (paper §V-D).
"""
import numpy as np

from repro.core import Compressor, GraphBuilder, decompress, numeric

# ---- data: low-cardinality u32 sensor readings -----------------------------
rng = np.random.default_rng(0)
values = rng.choice([17, 42, 99, 1234, 77777], size=100_000, p=[0.4, 0.3, 0.2, 0.05, 0.05])
stream = numeric(values.astype(np.uint32))
print(f"raw: {stream.nbytes} bytes")

# ---- the paper's Fig. 2 graph: tokenize feeds two separate backends --------
g = GraphBuilder(n_inputs=1)
alphabet, indices = g.add("tokenize", g.input(0))
g.add("transpose", alphabet)               # sparse dictionary -> byte planes
idx_planes = g.add("transpose", indices)   # u32 indices -> byte planes ...
g.add("huffman", idx_planes)               # ... -> entropy coder
compressor = Compressor(g.build("fig2"), name="quickstart")

frame = compressor.compress(stream)
print(f"compressed: {len(frame)} bytes ({stream.nbytes/len(frame):.1f}x)")

# ---- universal decode: ANY frame, ONE function, no configuration -----------
(restored,) = decompress(frame)
assert restored.content_bytes() == stream.content_bytes()
print("universal decoder: roundtrip OK")

# ---- serialized compressors deploy like config files (paper §V-D) ----------
blob = compressor.serialize()
clone = Compressor.deserialize(blob)
assert clone.compress(stream) == frame
print(f"serialized compressor: {len(blob)} bytes (<2KB, paper §V-D)")

# ---- or skip graph authoring entirely: the trial selector -------------------
from repro.codecs import generic_profile

auto = Compressor(generic_profile())
auto_frame = auto.compress(stream)
print(f"generic_auto selector: {len(auto_frame)} bytes ({stream.nbytes/len(auto_frame):.1f}x)")
