#!/usr/bin/env python
"""The device-side codec path: Pallas TPU kernels chained INSIDE jit —
float_split -> (exponent histogram for table stats) + fused delta+bitpack on
sorted index streams.  This is the layer that makes §VIII-style compression
run on the accelerator instead of the host (interpret mode on CPU).

    PYTHONPATH=src python examples/device_codec.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

rng = np.random.default_rng(0)

# ---- checkpoint-style payload: a bf16-ish f32 weight tensor ----------------
w = (rng.normal(size=(1 << 16,)) * 0.02).astype(np.float32)
u = jnp.asarray(w.view(np.uint32))

sign, exp, man = ops.float_split(u, 8, 23)  # one HBM pass, 3 planes
counts = ops.histogram(exp.astype(jnp.uint8))  # one-hot MXU contraction
probs = np.asarray(counts, np.float64)
probs = probs[probs > 0] / probs.sum()
H = float(-(probs * np.log2(probs)).sum())
print(f"float_split: sign/exp/mantissa planes on device")
print(f"exponent entropy: {H:.2f} bits/value (vs 8 raw) -> "
      f"{(8-H)/32*100:.1f}% of the f32 tensor is free to entropy coding")
back = ops.float_merge(sign, exp, man, 8, 23)
assert bool(jnp.all(back == u)), "bit-exact merge"
print("merge: bit-exact roundtrip OK")

# ---- offset-table payload: sorted indices, fused delta+bitpack -------------
offs = jnp.asarray(np.cumsum(rng.integers(0, 200, 1 << 16)).astype(np.uint32))
bits = 8
assert bool(ops.fused_delta_bitpack_fits(offs, bits))
packed = ops.fused_delta_bitpack(offs, bits)  # ONE pass vs two codecs
restored = ops.fused_delta_bitpack_decode(packed, bits, offs.shape[0])
assert bool(jnp.all(restored == offs))
print(f"fused delta+bitpack: {offs.nbytes} B -> {packed.nbytes} B "
      f"({offs.nbytes/packed.nbytes:.1f}x), single-pass, bit-exact")
print("HBM traffic model (EXPERIMENTS.md §Perf/K1): 13 B/elt unfused -> 5 B/elt fused (2.6x)")

# ---- byte-plane shuffle for struct data ------------------------------------
recs = jnp.asarray(rng.integers(0, 256, (1 << 14, 4)), jnp.uint8)
planes = ops.byteshuffle(recs)
assert bool(jnp.all(ops.byteunshuffle(planes) == recs))
print(f"byteshuffle: (n,4) records -> 4 byte planes, roundtrip OK")
print("\nall kernels ran under jit (Pallas interpret mode on CPU; Mosaic on TPU)")
