#!/usr/bin/env python
"""The device-side codec path: Pallas TPU kernels chained INSIDE jit —
float_split -> (exponent histogram for table stats) + fused delta+bitpack on
sorted index streams.  This is the layer that makes §VIII-style compression
run on the accelerator instead of the host (interpret mode on CPU).

    PYTHONPATH=src python examples/device_codec.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

rng = np.random.default_rng(0)

# ---- checkpoint-style payload: a bf16-ish f32 weight tensor ----------------
w = (rng.normal(size=(1 << 16,)) * 0.02).astype(np.float32)
u = jnp.asarray(w.view(np.uint32))

sign, exp, man = ops.float_split(u, 8, 23)  # one HBM pass, 3 planes
counts = ops.histogram(exp.astype(jnp.uint8))  # one-hot MXU contraction
probs = np.asarray(counts, np.float64)
probs = probs[probs > 0] / probs.sum()
H = float(-(probs * np.log2(probs)).sum())
print(f"float_split: sign/exp/mantissa planes on device")
print(f"exponent entropy: {H:.2f} bits/value (vs 8 raw) -> "
      f"{(8-H)/32*100:.1f}% of the f32 tensor is free to entropy coding")
back = ops.float_merge(sign, exp, man, 8, 23)
assert bool(jnp.all(back == u)), "bit-exact merge"
print("merge: bit-exact roundtrip OK")

# ---- offset-table payload: sorted indices, fused delta+bitpack -------------
offs = jnp.asarray(np.cumsum(rng.integers(0, 200, 1 << 16)).astype(np.uint32))
bits = 8
assert bool(ops.fused_delta_bitpack_fits(offs, bits))
packed = ops.fused_delta_bitpack(offs, bits)  # ONE pass vs two codecs
restored = ops.fused_delta_bitpack_decode(packed, bits, offs.shape[0])
assert bool(jnp.all(restored == offs))
print(f"fused delta+bitpack: {offs.nbytes} B -> {packed.nbytes} B "
      f"({offs.nbytes/packed.nbytes:.1f}x), single-pass, bit-exact")
print("HBM traffic model (EXPERIMENTS.md §Perf/K1): 13 B/elt unfused -> 5 B/elt fused (2.6x)")

# ---- byte-plane shuffle for struct data ------------------------------------
recs = jnp.asarray(rng.integers(0, 256, (1 << 14, 4)), jnp.uint8)
planes = ops.byteshuffle(recs)
assert bool(jnp.all(ops.byteunshuffle(planes) == recs))
print(f"byteshuffle: (n,4) records -> 4 byte planes, roundtrip OK")
print("\nall kernels ran under jit (Pallas interpret mode on CPU; Mosaic on TPU)")

# ---- the engine-level device backend ---------------------------------------
# The same kernels drive real compression: resolve once, execute per call
# with backend="device", fusing adjacent delta+bitpack into one kernel pass.
from repro.core import compress, decompress, numeric, pipeline
from repro.core.wire import is_container, read_frame

offsets = numeric(np.cumsum(rng.integers(0, 200, 1 << 16)).astype(np.uint32))
plan = pipeline("delta", "bitpack")
frame_host = compress(plan, offsets, backend="host")
frame_dev = compress(plan, offsets, backend="device")
_, _, nodes, _ = read_frame(frame_dev)
assert decompress(frame_dev)[0].content_bytes() == offsets.content_bytes()
print(f"\nengine backend=device: delta+bitpack fused into "
      f"{len(nodes)} wire node (codec id {nodes[0].codec_id}), "
      f"{offsets.nbytes} B -> {len(frame_dev)} B, universal decode bit-exact")
assert len(frame_dev) <= len(frame_host)

chunked = compress(plan, offsets, chunk_bytes=1 << 16, backend="device")
assert is_container(chunked)
assert decompress(chunked)[0].content_bytes() == offsets.content_bytes()
print(f"chunked container frame: {len(chunked)} B across "
      f"{(offsets.nbytes + (1 << 16) - 1) >> 16} chunks, decodes bit-exact")
