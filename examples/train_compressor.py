#!/usr/bin/env python
"""End-to-end compressor training (the paper's §VI-C zli-train workflow):
parse -> cluster -> parallel NSGA-II backend search -> Pareto tradeoff
points -> serialized deployable compressors.

Candidate evaluation fans out over a session-backed worker pool
(``workers=``); training is deterministic — the same seed yields
byte-identical plans for any worker count.  The shell equivalent is
``python -m repro train SAMPLES... --out plan.ozp``.

    PYTHONPATH=src python examples/train_compressor.py
"""
import os
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.datasets import make_tlc_columns  # noqa: E402
from repro.core import Compressor  # noqa: E402
from repro.training import MultiStreamFrontend, train  # noqa: E402

# taxi-trip-like columnar data (paper's TLC dataset family)
train_cols = make_tlc_columns(20_000, seed=1)
test_cols = make_tlc_columns(60_000, seed=2)
raw = sum(s.nbytes for s in test_cols)
print(f"columns: {len(train_cols)}, test data: {raw/(1<<20):.2f} MiB")

t0 = time.time()
tc = train(
    [train_cols],
    MultiStreamFrontend(k=len(train_cols)),
    pop_size=12,
    generations=4,
    seed=0,
    workers=os.cpu_count(),
    verbose=True,
)
print(f"\ntraining took {time.time()-t0:.1f}s; stats: "
      f"{tc.stats['train_speed_mib_min']:.2f} MiB/min, "
      f"{int(tc.stats['n_clusters'])} clusters from {int(tc.stats['n_streams'])} streams, "
      f"{int(tc.stats['evaluations'])} candidate evals on {int(tc.stats['workers'])} workers "
      f"({tc.stats['eval_wall_seconds']:.1f}s encode time)")

print("\nPareto tradeoff points (size estimate vs encode-time estimate):")
for plan, sz, tm in tc.pareto_plans():
    print(f"  {sz:>10.0f} B  {tm*1e3:>8.2f} ms  ({len(plan.nodes)} codec nodes)")

best = Compressor(tc.best_ratio_plan())
frame = best.compress(list(test_cols))
assert best.roundtrip_check(list(test_cols))
import zlib

zsize = len(zlib.compress(b"".join(s.content_bytes() for s in test_cols), 6))
print(f"\nheld-out test: OpenZL {len(frame)} B ({raw/len(frame):.2f}x)"
      f" vs zlib-6 {zsize} B ({raw/zsize:.2f}x)")
blob = best.serialize()
print(f"deployable serialized compressor: {len(blob)} bytes")
clone = Compressor.deserialize(blob)
assert clone.roundtrip_check(list(test_cols))
print("deserialized clone verified lossless — ship it (paper §V-D)")
