#!/usr/bin/env python
"""The paper's §IV worked example: a hand-built compressor for the SAO star
catalogue, reproducing the Table I comparison.

    PYTHONPATH=src python examples/sao_profile.py
"""
import sys
import time
import zlib
import lzma
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))

import numpy as np

from repro.codecs import sao_profile
from repro.core import Compressor

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.datasets import make_sao  # noqa: E402

data = make_sao(50_000)
print(f"SAO: {len(data)} bytes ({len(data)/(1<<20):.2f} MiB), 28-byte star records")

rows = []
for name, enc in [
    ("zlib-6", lambda d: zlib.compress(d, 6)),
    ("xz-9", lambda d: lzma.compress(d, preset=9)),
]:
    t0 = time.perf_counter()
    blob = enc(data)
    dt = time.perf_counter() - t0
    rows.append((name, len(blob), len(data) / len(blob), dt))

c = Compressor(sao_profile())
t0 = time.perf_counter()
frame = c.compress(data)
dt = time.perf_counter() - t0
assert c.roundtrip_check(data), "lossless check failed"
rows.append(("OpenZL (sao graph)", len(frame), len(data) / len(frame), dt))

print(f"{'compressor':22s} {'size':>10s} {'ratio':>7s} {'seconds':>8s}")
for name, size, ratio, dt in rows:
    print(f"{name:22s} {size:>10d} {ratio:>7.2f} {dt:>8.2f}")
print(
    "\npaper Table I (real SAO, C impl): zstd-3 1.31x | xz-9 1.64x | OpenZL 2.06x"
    "\nthe graph (field_split + delta/transpose/tokenize per field, §IV) wins on"
    "\nratio here too; absolute speeds differ (numpy host kernels vs optimized C)."
)
print(f"\nserialized compressor: {len(c.serialize())} bytes")
