#!/usr/bin/env python
"""Batched LM serving with a KV cache (prefill + incremental decode),
optionally restoring an OpenZL-compressed checkpoint written by train_lm.py.

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-3-4b]

Try the SWA arch to see the ring-buffer cache: generation length can exceed
the window with CONSTANT cache memory (the long_500k serving story).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    return serve_mod.main(
        [
            "--arch", args.arch,
            "--reduced",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen),
            "--ckpt-dir", args.ckpt_dir if Path(args.ckpt_dir).exists() else "",
        ]
    )


if __name__ == "__main__":
    sys.exit(main())
