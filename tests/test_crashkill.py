"""Crash-kill fuzzing sweep (repro.reliability.crashkill).

Forks a real victim subprocess per enumerated crash point, SIGKILLs it
mid-operation, and asserts the durability invariants over the remains.
This is the slow tier of the reliability suite — the full sweep spawns one
process per kill site (60+), so it lives behind its own test and parallel
workers, not inside the unit-test fast path.
"""
from repro.reliability import crashkill as ck


def test_kill_sweep_all_scenarios(tmp_path):
    summary = ck.kill_sweep(tmp_path)
    assert summary["total_sites"] >= 50
    for name in ck.SCENARIOS:
        info = summary["scenarios"][name]
        assert info["sites"] > 0
        # every kill run left *some* byte-exact consistent version behind
        assert sum(info["survivor_versions"].values()) == info["sites"]


def test_record_run_enumerates_the_interesting_sites(tmp_path):
    sites = ck.enumerate_sites("shard_rewrite", tmp_path / "rec")
    names = {name for name, _occ in sites}
    # the windows where torn state is most likely must each be a kill site
    assert {"shard.aside.before", "shard.aside.after", "shard.swap.after"} <= names
    ck.check_invariants("shard_rewrite", tmp_path / "rec")


def test_single_kill_is_a_real_sigkill(tmp_path):
    import signal

    rc = ck.run_kill("atomic_sink", tmp_path / "k", "io.sink.write", 1)
    assert rc == -signal.SIGKILL
    verdict = ck.check_invariants("atomic_sink", tmp_path / "k")
    assert verdict["version"] == 0  # the old output survived untouched
