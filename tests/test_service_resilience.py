"""Daemon degradation under faults and overload.

The service keeps its byte-identity guarantee while degrading *gracefully*:
saturated pools shed with a structured retry-after signal instead of
stalling the accept loop, a plan that keeps poisoning sessions trips a
per-digest breaker without touching its neighbours, device-kernel failures
fail over to host re-execution transparently, and the client's bounded
jittered retries turn transient shedding into eventual success.
"""
import random
import threading
import time

import numpy as np
import pytest

from repro.reliability import FaultPlan
from repro.service import (
    CompressionServer,
    PlanRegistry,
    ServiceClient,
    ServiceUnavailable,
)

DATA = b"req=deadbeef level=INFO svc=auth handled in 42us\n" * 800
CHUNK = 8 << 10


def _server(tmp_path, **kw):
    registry = PlanRegistry()
    registry.register_profile("text")
    registry.register_profile("struct:3,5")
    return CompressionServer(
        registry,
        socket_path=str(tmp_path / "ozl.sock"),
        max_clients=8,
        sessions_per_plan=1,
        request_timeout=20.0,
        **kw,
    )


# ------------------------------------------------------------------ shedding
def test_overload_sheds_with_retry_after(tmp_path):
    with _server(tmp_path, admission_timeout=0.05) as srv:
        with ServiceClient(srv.address) as c:
            ref, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            digest = srv.registry.resolve("text").digest
            lease = srv.pool.acquire(digest)  # hold the only session hostage
            lease.__enter__()
            try:
                with pytest.raises(ServiceUnavailable) as ei:
                    c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            finally:
                lease.__exit__(None, None, None)
            assert ei.value.kind == "overloaded"
            assert ei.value.retry_after and ei.value.retry_after > 0
            # shedding is per-request, not per-connection: the same client
            # succeeds once capacity frees, with byte-identical output
            frame, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            assert frame == ref
        assert srv.stats()["shed"] >= 1


def test_blocking_admission_is_the_default(tmp_path):
    # admission_timeout=None keeps the historical behavior: waiters block
    # (bounded by request_timeout) instead of shedding
    with _server(tmp_path) as srv:
        with ServiceClient(srv.address) as c:
            ref, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            digest = srv.registry.resolve("text").digest
            lease = srv.pool.acquire(digest)
            lease.__enter__()
            timer = threading.Timer(0.2, lease.__exit__, (None, None, None))
            timer.start()
            try:
                frame, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            finally:
                timer.join()
            assert frame == ref
        assert srv.stats()["shed"] == 0


def test_client_retries_through_transient_overload(tmp_path):
    with _server(tmp_path, admission_timeout=0.05) as srv:
        with ServiceClient(
            srv.address, retries=8, backoff_base=0.05, rng=random.Random(0)
        ) as c:
            ref, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            digest = srv.registry.resolve("text").digest
            lease = srv.pool.acquire(digest)
            lease.__enter__()
            timer = threading.Timer(0.25, lease.__exit__, (None, None, None))
            timer.start()
            try:
                # sheds a few times, backs off with jitter, then lands
                frame, _ = c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            finally:
                timer.join()
            assert frame == ref
        assert srv.stats()["shed"] >= 1


def test_client_rejects_negative_retries():
    with pytest.raises(ValueError):
        ServiceClient("/nonexistent.sock", retries=-1)


# ---------------------------------------------------------------- quarantine
def test_poison_plan_trips_breaker_without_hurting_neighbours(tmp_path):
    with _server(
        tmp_path, quarantine_threshold=3, quarantine_cooldown_s=0.2
    ) as srv:
        with ServiceClient(srv.address) as c:
            bad = b"x" * 1001  # not a whole number of 8-byte records
            for _ in range(3):
                with pytest.raises(RuntimeError, match="whole number of records"):
                    c.compress_bytes(bad, plan="struct:3,5", chunk_bytes=0)
            with pytest.raises(ServiceUnavailable) as ei:
                c.compress_bytes(bad, plan="struct:3,5", chunk_bytes=0)
            assert ei.value.kind == "plan_quarantined"
            assert ei.value.retry_after and ei.value.retry_after > 0
            # the breaker is per plan digest: a healthy neighbour still serves
            c.compress_bytes(DATA, plan="text", chunk_bytes=CHUNK)
            digest = srv.registry.resolve("struct:3,5").digest
            q = srv.stats()["quarantine"][digest]
            assert q["quarantined"] and q["trips"] == 1
            # cooldown expiry admits a probe; a well-formed request clears it
            time.sleep(0.25)
            c.compress_bytes(b"x" * 1000, plan="struct:3,5", chunk_bytes=0)
            assert not srv.stats()["quarantine"][digest]["quarantined"]


# ------------------------------------------------------------ device failover
def test_device_fault_fails_over_to_byte_identical_host_frames(tmp_path):
    payload = np.arange(8192, dtype=np.uint32).tobytes()
    kw = dict(max_clients=4, sessions_per_plan=1, request_timeout=20.0)
    host_reg = PlanRegistry()
    host_reg.register_profile("struct:4,4")
    dev_reg = PlanRegistry()
    dev_reg.register_profile("struct:4,4")
    with CompressionServer(
        host_reg, socket_path=str(tmp_path / "host.sock"), backend="host", **kw
    ) as host_srv, CompressionServer(
        dev_reg, socket_path=str(tmp_path / "dev.sock"), backend="device", **kw
    ) as dev_srv:
        with ServiceClient(host_srv.address) as c:
            host_frame, _ = c.compress_bytes(
                payload, plan="struct:4,4", chunk_bytes=CHUNK
            )
        # every device kernel invocation fails for the rest of the block:
        # the device server must keep serving via transparent host retries
        with FaultPlan().at("device.encode.device.*", times=10**6).arm(
            all_threads=True
        ):
            with ServiceClient(dev_srv.address) as c:
                f1, _ = c.compress_bytes(payload, plan="struct:4,4", chunk_bytes=CHUNK)
                f2, _ = c.compress_bytes(payload, plan="struct:4,4", chunk_bytes=CHUNK)
        assert f1 == host_frame and f2 == host_frame
        health = dev_srv.stats()["backend_health"]["device"]
        assert health["failovers"] >= 1 and health["quarantined"]
        assert host_srv.stats()["backend_health"] == {}  # host server untouched
