"""Adversarial tests for the lz77 decode overlapping-copy path.

When a match's distance is smaller than its length the copy source includes
bytes the copy itself produces — the decoder must replicate the period, and
must do so for *every* small distance (the batched decode rewrite replays
matches through bytearray slices, where this is the easy path to get wrong).
"""
import numpy as np
import pytest

from repro.codecs import lz
from repro.core.codec import get_codec
from repro.core.message import Stream, SType, serial
from repro.codecs._util import numeric_stream


def _roundtrip(data: bytes) -> None:
    spec = get_codec("lz77")
    outs, header = spec.run_encode([serial(data)], {})
    (back,) = spec.run_decode(outs, header)
    assert back.content_bytes() == data


@pytest.mark.parametrize("dist", range(1, 9))
def test_self_referencing_runs_every_distance(dist):
    """A period-`dist` run long enough to force dist < L overlapping copies."""
    seed = bytes(range(65, 65 + dist))
    data = seed * (4000 // dist)
    # verify the encoder actually produced an overlapping match
    outs, header = get_codec("lz77").run_encode([serial(data)], {})
    mls = outs[2].data.astype(np.int64)
    offs = outs[3].data.astype(np.int64)
    assert ((offs < mls) & (offs == dist)).any(), "no overlapping match emitted"
    _roundtrip(data)


@pytest.mark.parametrize("dist", range(1, 9))
def test_self_referencing_with_prefix_and_tail(dist):
    prefix = b"QXZW-unique-prefix-" + bytes([200 + dist])
    data = prefix + bytes(range(dist)) * 700 + b"#tail-bytes"
    _roundtrip(data)


def test_overlap_lengths_non_multiple_of_period():
    """Copy lengths that are not multiples of the period exercise the
    truncated final repetition."""
    for dist in range(1, 9):
        for extra in range(dist):
            data = b"HDR!" + bytes(range(dist)) * 300 + bytes(range(dist))[:extra]
            _roundtrip(data)


def test_handcrafted_overlap_tokens_decode():
    """Drive _lz77_dec directly with tokens forcing dist < L at every
    distance 1..8 (independent of what the encoder chooses to emit)."""
    for dist in range(1, 9):
        literals = bytes(range(100, 100 + dist))
        L = 57  # deliberately not a multiple of any dist <= 8
        n = dist + L
        header = (
            lz.HeaderWriter().u8(int(SType.SERIAL)).varint(1).varint(n).done()
        )
        outs = [
            Stream(np.frombuffer(literals, np.uint8), SType.SERIAL, 1),
            numeric_stream(np.array([dist, 0], np.uint32)),  # lit runs
            numeric_stream(np.array([L], np.uint32)),  # match lens
            numeric_stream(np.array([dist], np.uint32)),  # offsets
        ]
        (back,) = lz._lz77_dec(outs, header)
        expect = (literals * (L // dist + 2))[:n]
        assert back.content_bytes() == expect, f"dist={dist}"


def test_corrupt_tokens_raise():
    header = lz.HeaderWriter().u8(int(SType.SERIAL)).varint(1).varint(10).done()

    def mk(lits, runs, mls, offs):
        return [
            Stream(np.frombuffer(lits, np.uint8), SType.SERIAL, 1),
            numeric_stream(np.asarray(runs, np.uint32)),
            numeric_stream(np.asarray(mls, np.uint32)),
            numeric_stream(np.asarray(offs, np.uint32)),
        ]

    with pytest.raises(ValueError):  # totals don't reach n
        lz._lz77_dec(mk(b"ab", [2, 0], [4], [1]), header)
    with pytest.raises(ValueError):  # offset reaches before the start
        lz._lz77_dec(mk(b"ab", [2, 0], [8], [5]), header)
    with pytest.raises(ValueError):  # zero offset
        lz._lz77_dec(mk(b"ab", [2, 0], [8], [0]), header)


def test_max_match_cap_roundtrip():
    """Runs longer than MAX_MATCH split into capped tokens and still decode."""
    data = b"\xaa" * (lz.MAX_MATCH * 2 + 12345)
    outs, header = get_codec("lz77").run_encode([serial(data)], {})
    mls = outs[2].data.astype(np.int64)
    assert mls.max() <= lz.MAX_MATCH
    (back,) = get_codec("lz77").run_decode(outs, header)
    assert back.content_bytes() == data
