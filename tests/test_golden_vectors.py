"""Golden wire-format conformance tests (see tests/_golden.py).

Two frozen-corpus invariants, vector by vector:

  * the universal decoder reproduces the stored payload bytes for every
    frozen frame (decode stability: frames outlive library versions);
  * the current encoder still emits the byte-identical frame for the pinned
    (plan, input, format_version, chunking) — encode drift fails fast.

Plus structural coverage checks: every registered codec id, every supported
format version, and both container shapes must appear in the corpus — so
adding a codec or bumping the format version *requires* freezing new vectors
(REPRO_REGEN_GOLDEN=1 python tests/_golden.py, a reviewed decision).
"""
import pytest
from _golden import (
    GOLDEN_DIR,
    MANIFEST,
    encode_vector,
    load_manifest,
    stream_from_entry,
)

from repro.core import decompress, wire
from repro.core.codec import all_codecs
from repro.core.message import SType
from repro.core.serialize import deserialize_plan
from repro.core.versioning import CURRENT_FORMAT_VERSION, MIN_FORMAT_VERSION

import numpy as np

MANIFEST_ENTRIES = load_manifest() if MANIFEST.exists() else {}
NAMES = sorted(MANIFEST_ENTRIES)

pytestmark = pytest.mark.skipif(
    not MANIFEST_ENTRIES, reason="golden corpus missing (tests/golden/)"
)


def _frame(name: str) -> bytes:
    return (GOLDEN_DIR / f"{name}.ozl").read_bytes()


def _input_stream(name: str):
    payload = (GOLDEN_DIR / f"{name}.in").read_bytes()
    return stream_from_entry(MANIFEST_ENTRIES[name], payload)


def _frame_codec_ids(frame: bytes) -> set:
    ids = set()
    if wire.is_container(frame):
        _version, sub_frames = wire.read_container(frame)
    else:
        sub_frames = [frame]
    for sub in sub_frames:
        _v, _n, nodes, _stored = wire.read_frame(sub)
        ids.update(node.codec_id for node in nodes)
    return ids


@pytest.mark.parametrize("name", NAMES)
def test_universal_decode_reproduces_payload(name):
    entry = MANIFEST_ENTRIES[name]
    expected = _input_stream(name)
    (out,) = decompress(_frame(name))
    assert out.content_bytes() == expected.content_bytes(), name
    assert out.stype == expected.stype and out.width == expected.width, name
    if expected.stype == SType.STRING:
        assert np.array_equal(out.lengths, expected.lengths), name
    assert entry["frame_bytes"] == len(_frame(name))


@pytest.mark.parametrize("name", NAMES)
def test_encoder_emits_frozen_frame(name):
    entry = MANIFEST_ENTRIES[name]
    plan, _meta = deserialize_plan((GOLDEN_DIR / f"{name}.ozp").read_bytes())
    frame = encode_vector(entry, plan, _input_stream(name))
    assert frame == _frame(name), (
        f"{name}: encoder output drifted from the frozen frame"
        f" ({len(frame)}B vs {entry['frame_bytes']}B) — if this change is"
        f" intentional, regenerate the corpus (REPRO_REGEN_GOLDEN=1) and"
        f" say so in the PR"
    )


def test_every_registered_codec_id_is_covered():
    covered = set()
    for name in NAMES:
        covered |= _frame_codec_ids(_frame(name))
    registered = {spec.codec_id for spec in all_codecs().values()}
    missing = registered - covered
    assert not missing, (
        f"codec ids {sorted(missing)} have no golden vector — freeze one in"
        f" tests/_golden.py (new codecs must pin their wire format)"
    )


def test_graph_codec_ids_have_vectors():
    """PR 9's graph family pinned explicitly: edge_list (27), adj_gap (28),
    edge_list_bin (29) each appear inside a frozen frame, and the csv_split
    extension-header cases (multi-byte separator, CRLF) stay in the corpus."""
    covered = set()
    for name in NAMES:
        covered |= _frame_codec_ids(_frame(name))
    assert {27, 28, 29} <= covered
    assert "codec_csv_split_multisep" in NAMES
    assert "codec_csv_split_crlf" in NAMES


def test_every_format_version_is_covered():
    versions = {MANIFEST_ENTRIES[n]["format_version"] for n in NAMES}
    expected = set(range(MIN_FORMAT_VERSION, CURRENT_FORMAT_VERSION + 1))
    missing = expected - versions
    assert not missing, f"format versions {sorted(missing)} lack golden vectors"


def test_both_container_shapes_are_covered():
    shapes = {wire.is_container(_frame(n)) for n in NAMES}
    assert shapes == {True, False}, "need both chunked and unchunked vectors"


def test_corpus_includes_a_trained_plan():
    assert any(n.startswith("trained_") for n in NAMES)
