"""Model-level invariants: incremental decode == full forward (dense, SWA,
MoE), ring-buffer cache semantics, GQA repeat equivalence, GNN permutation
invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, transformer as T


def _decode_matches_forward(cfg, atol=3e-4):
    p = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    full = T.forward(p, toks, cfg)
    cache = T.init_kv_cache(cfg, 2, 4096)
    step = jax.jit(lambda pr, c, t, pos: T.decode_step(pr, c, t, pos, cfg))
    outs = []
    for t in range(16):
        lg, cache = step(p, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(dec - full).max())
    assert err < atol, err


def test_decode_matches_forward_dense():
    _decode_matches_forward(
        T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab=97, remat=False)
    )


def test_decode_matches_forward_swa_ring_buffer():
    """SWA cache shorter than the sequence: ring buffer must still match the
    windowed full forward exactly."""
    cfg = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=97, sliding_window=8, remat=False)
    p = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, 97)
    full = T.forward(p, toks, cfg)
    cache = T.init_kv_cache(cfg, 2, 4096)
    assert cache["k"].shape[2] == 8  # ring = window size
    outs = []
    for t in range(20):
        lg, cache = T.decode_step(p, cache, toks[:, t : t + 1], jnp.int32(t), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.abs(dec - full).max()) < 3e-4


def test_decode_matches_forward_moe():
    _decode_matches_forward(
        T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                            d_ff=96, vocab=97, n_experts=8, top_k=2,
                            capacity_factor=4.0, remat=False),
        atol=2e-3,  # decode re-dispatches one token: capacity never drops it
    )


def test_tied_embeddings_share_weights():
    cfg = T.TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                              d_ff=64, vocab=50, tie_embeddings=True, remat=False)
    p = T.init_params(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in p


def test_remat_equals_no_remat():
    base = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                               d_ff=128, vocab=97, remat=False)
    import dataclasses

    rem = dataclasses.replace(base, remat=True)
    p = T.init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    batch = {"tokens": toks, "labels": toks}
    l1, g1 = jax.value_and_grad(T.loss_fn)(p, batch, base)
    l2, g2 = jax.value_and_grad(T.loss_fn)(p, batch, rem)
    assert abs(float(l1) - float(l2)) < 1e-6
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_gnn_node_permutation_equivariance():
    """Relabeling nodes permutes outputs identically (message passing is
    permutation-equivariant) — validates the segment_sum wiring."""
    cfg = gnn.GNNConfig(n_layers=2, d_hidden=32, d_in=8, d_out=4, remat=False)
    p = gnn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    N, E = 30, 80
    nodes = jnp.asarray(rng.normal(size=(N, 8)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, N, (E, 2)), jnp.int32)
    efe = jnp.asarray(rng.normal(size=(E, 4)), jnp.float32)
    out = gnn.forward(p, nodes, edges, efe, cfg)
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    out_p = gnn.forward(p, nodes[perm], jnp.asarray(inv)[edges], efe, cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[perm], atol=2e-4)


def test_gnn_edge_mask_zeroes_messages():
    cfg = gnn.GNNConfig(n_layers=1, d_hidden=16, d_in=4, d_out=2, remat=False)
    p = gnn.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    nodes = jnp.asarray(rng.normal(size=(10, 4)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, 10, (20, 2)), jnp.int32)
    efe = jnp.zeros((20, 4), jnp.float32)
    masked = gnn.forward(p, nodes, edges, efe, cfg, edge_mask=jnp.zeros(20))
    no_edges = gnn.forward(
        p, nodes, jnp.zeros((0, 2), jnp.int32), jnp.zeros((0, 4), jnp.float32), cfg
    )
    np.testing.assert_allclose(np.asarray(masked), np.asarray(no_edges), atol=1e-5)
