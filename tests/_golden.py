"""Golden wire-format conformance corpus: registry + regeneration helper.

``tests/golden/`` holds *frozen* wire frames: for every vector a payload
(``<name>.in``), the pinned plan (``<name>.ozp``), and the frame the current
encoder emitted when the vector was frozen (``<name>.ozl``), indexed by
``manifest.json``.  ``tests/test_golden_vectors.py`` asserts two invariants
against them:

  * **universal decode** — every stored frame decodes to its stored payload,
    byte for byte, forever (the §III-D guarantee across library versions);
  * **encoder stability** — re-encoding the pinned (plan, input, version,
    chunking) quadruple still produces the frozen frame byte-for-byte, so
    *any* wire-format drift fails CI before it ships.

The corpus covers format versions 1-4, every registered codec id (enforced
by a coverage test — registering a codec without freezing a vector for it is
a test failure), chunked and unchunked containers, every shipped profile
family, and a trained plan from ``results/trained/``.

Regeneration is deliberately awkward: it only runs with
``REPRO_REGEN_GOLDEN=1`` set, because regenerating *is* a format change and
must be a reviewed decision, not a test-fixing reflex:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python tests/_golden.py

Adding a codec requires *adding* vectors without touching any frozen frame
(the ROADMAP conformance policy).  ``REPRO_REGEN_GOLDEN=new`` does exactly
that: it freezes only vectors absent from ``manifest.json`` and leaves every
existing file byte-identical:

    REPRO_REGEN_GOLDEN=new PYTHONPATH=src python tests/_golden.py

Vector inputs are seeded ``np.random.default_rng`` draws (bit-stable across
platforms), so regeneration itself is reproducible.
"""
from __future__ import annotations

import json
import os
import sys
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import CompressionCtx, compress  # noqa: E402
from repro.core.graph import GraphBuilder, Plan, pipeline  # noqa: E402
from repro.core.message import Stream, SType, serial, strings  # noqa: E402
from repro.core.serialize import deserialize_plan, serialize_plan  # noqa: E402

GOLDEN_DIR = Path(__file__).resolve().parent / "golden"
MANIFEST = GOLDEN_DIR / "manifest.json"
REGEN_ENV = "REPRO_REGEN_GOLDEN"
LEVEL = 5  # every vector is frozen at the default effort level

TRAINED_SOURCE = (
    Path(__file__).resolve().parents[1] / "results" / "trained" / "era5_flux_0.ozp"
)


def _rng(name: str) -> np.random.Generator:
    return np.random.default_rng(zlib.crc32(name.encode()))


# ------------------------------------------------------------ input builders
def _text(name: str, n: int = 4096) -> Stream:
    rng = _rng(name)
    words = [b"graph", b"codec", b"stream", b"frame", b"openzl", b"wire", b"the"]
    picks = rng.integers(0, len(words), n // 5)
    return serial(b" ".join(words[i] for i in picks)[:n])


def _smooth_u32(name: str, n: int = 1024) -> Stream:
    rng = _rng(name)
    walk = np.cumsum(rng.integers(0, 50, n, dtype=np.int64))
    return Stream((walk % (1 << 22)).astype(np.uint32), SType.NUMERIC, 4)


def _bounded_u32(name: str, n: int = 1024, hi: int = 1000) -> Stream:
    return Stream(
        _rng(name).integers(0, hi, n).astype(np.uint32), SType.NUMERIC, 4
    )


def _runs_u32(name: str, n: int = 1024) -> Stream:
    rng = _rng(name)
    vals = np.repeat(
        rng.integers(0, 9, n // 8).astype(np.uint32), rng.integers(2, 16, n // 8)
    )[:n]
    return Stream(np.ascontiguousarray(vals), SType.NUMERIC, 4)


def _signed_wiggle(name: str, n: int = 1024) -> Stream:
    rng = _rng(name)
    return Stream(
        rng.integers(-60, 60, n).astype(np.int32), SType.NUMERIC, 4
    )


def _struct_rec(name: str, width: int, n: int = 512) -> Stream:
    rng = _rng(name)
    rec = np.empty((n, width), np.uint8)
    rec[:, : width // 2] = rng.integers(0, 256, (n, width // 2))
    rec[:, width // 2 :] = rng.integers(0, 4, (n, width - width // 2))
    return Stream(rec.reshape(-1), SType.STRUCT, width)


def _float32(name: str, n: int = 1024) -> Stream:
    rng = _rng(name)
    vals = (np.sin(np.linspace(0, 20, n)) * 100 + rng.normal(0, 0.3, n)).astype(
        np.float32
    )
    return Stream(vals.view(np.uint32), SType.NUMERIC, 4)


def _float64(name: str, n: int = 512) -> Stream:
    rng = _rng(name)
    vals = np.cumsum(rng.normal(0, 1, n)).astype(np.float64)
    return Stream(vals.view(np.uint64), SType.NUMERIC, 8)


def _bf16(name: str, n: int = 1024) -> Stream:
    # bf16 bit patterns: f32 rounded by truncation to the top 16 bits
    f32 = _float32(name).data
    return Stream((f32 >> np.uint32(16)).astype(np.uint16), SType.NUMERIC, 2)


def _csv(
    name: str, n_rows: int = 400, sep: bytes = b",", eol: bytes = b"\n"
) -> Stream:
    rng = _rng(name)
    animals = [b"cat", b"dog", b"emu"]
    rows = [
        sep.join(
            (b"%d" % (i * 3), animals[int(rng.integers(3))],
             b"%d" % int(rng.integers(0, 50)))
        )
        for i in range(n_rows)
    ]
    return serial(eol.join(rows) + eol)


def _edges_text(name: str, n_nodes: int = 300, max_deg: int = 16) -> Stream:
    """SNAP-style text edge list: # comment header + sorted u<TAB>v lines."""
    rng = _rng(name)
    lines = [b"# SNAP-style golden edge list", b"# FromNodeId\tToNodeId"]
    for u in range(n_nodes):
        for v in np.unique(rng.integers(0, n_nodes, int(rng.integers(1, max_deg)))):
            lines.append(b"%d\t%d" % (u, v))
    return serial(b"\n".join(lines) + b"\n")


def _edges_bin(name: str, n_nodes: int = 300, max_deg: int = 16) -> Stream:
    """The CSR/binary twin: interleaved little-endian u32 (src, dst) pairs."""
    rng = _rng(name)
    src: List[int] = []
    dst: List[int] = []
    for u in range(n_nodes):
        for v in np.unique(rng.integers(0, n_nodes, int(rng.integers(1, max_deg)))):
            src.append(u)
            dst.append(int(v))
    pairs = np.stack(
        [np.asarray(src, np.uint32), np.asarray(dst, np.uint32)], axis=1
    )
    return serial(pairs.tobytes())


def _strings_ints(name: str, n: int = 400) -> Stream:
    rng = _rng(name)
    items = []
    for i in range(n):
        if rng.random() < 0.8:
            items.append(b"%d" % int(rng.integers(-5000, 5000)))
        else:
            items.append(b"n/a")
    return strings(items)


def _strings_mixed(name: str, n: int = 300) -> Stream:
    rng = _rng(name)
    words = [b"alpha", b"beta", b"gamma", b"", b"x" * 40]
    return strings([words[int(rng.integers(len(words)))] for _ in range(n)])


def _sao_like(name: str, n: int = 256) -> Stream:
    """28-byte header + n 28-byte records shaped like the §IV SAO catalog."""
    rng = _rng(name)
    sra0 = np.sort(rng.integers(0, 1 << 40, n).astype(np.uint64))
    sdec0 = rng.integers(0, 1 << 30, n).astype(np.uint64)
    is_f = rng.integers(0, 4, n).astype(np.uint16)
    mag = rng.integers(0, 1500, n).astype(np.uint16)
    xrpm = rng.integers(0, 1 << 16, n).astype(np.uint32)
    xdpm = rng.integers(0, 1 << 16, n).astype(np.uint32)
    rec = np.zeros((n, 28), np.uint8)
    rec[:, 0:8] = sra0.view(np.uint8).reshape(n, 8)
    rec[:, 8:16] = sdec0.view(np.uint8).reshape(n, 8)
    rec[:, 16:18] = is_f.view(np.uint8).reshape(n, 2)
    rec[:, 18:20] = mag.view(np.uint8).reshape(n, 2)
    rec[:, 20:24] = xrpm.view(np.uint8).reshape(n, 4)
    rec[:, 24:28] = xdpm.view(np.uint8).reshape(n, 4)
    header = np.frombuffer(b"SAO golden header 28 bytes!!", np.uint8)
    return serial(np.concatenate([header, rec.reshape(-1)]).tobytes())


# ------------------------------------------------------------- plan builders
def _single(codec: str, **params) -> Plan:
    return pipeline((codec, params) if params else codec, name=f"unit_{codec}")


def _fanout(codec: str, n_out: int, **params) -> Plan:
    g = GraphBuilder(1)
    g.add(codec, g.input(0), n_out=n_out, **params)
    return g.build(f"unit_{codec}")


@dataclass(frozen=True)
class GoldenVector:
    name: str
    format_version: int
    make_plan: Callable[[], Plan]
    make_input: Callable[[], Stream]
    chunk_bytes: int = 0  # 0 = unchunked


def vectors() -> List[GoldenVector]:
    from repro.codecs import profiles as P

    out: List[GoldenVector] = []

    def add(name, fv, make_plan, make_input, chunk_bytes=0):
        out.append(GoldenVector(name, fv, make_plan, make_input, chunk_bytes))

    # --- codec unit vectors, each pinned at the codec's min_version --------
    add("codec_store", 1, lambda: _single("store"),
        lambda: _text("codec_store"))
    add("codec_dup", 1, lambda: _fanout("dup", 2),
        lambda: _bounded_u32("codec_dup"))
    add("codec_delta", 1, lambda: _single("delta"),
        lambda: _smooth_u32("codec_delta"))
    add("codec_zigzag", 1, lambda: _single("zigzag"),
        lambda: _signed_wiggle("codec_zigzag"))
    add("codec_transpose", 1, lambda: _single("transpose"),
        lambda: _bounded_u32("codec_transpose"))
    add("codec_bitpack", 1, lambda: _single("bitpack"),
        lambda: _bounded_u32("codec_bitpack"))
    add("codec_rle", 1, lambda: _fanout("rle", 2),
        lambda: _runs_u32("codec_rle"))
    add("codec_constant", 1, lambda: _fanout("constant", 0),
        lambda: Stream(np.full(777, 42, np.uint32), SType.NUMERIC, 4))
    add("codec_tokenize", 2, lambda: _fanout("tokenize", 2),
        lambda: _bounded_u32("codec_tokenize", hi=17))
    add("codec_field_split", 1, lambda: _fanout("field_split", 2, widths=[2, 4]),
        lambda: _struct_rec("codec_field_split", 6))
    add("codec_split_n", 1, lambda: _fanout("split_n", 2, sizes=[100, -1]),
        lambda: _text("codec_split_n"))

    def concat_plan() -> Plan:
        g = GraphBuilder(1)
        a, b = g.add("split_n", g.input(0), n_out=2, sizes=[700, -1])
        g.add("concat", a, b)
        return g.build("unit_concat")

    add("codec_concat", 1, concat_plan, lambda: _text("codec_concat"))
    add("codec_range_pack", 1, lambda: _single("range_pack"),
        lambda: _bounded_u32("codec_range_pack"))
    add("codec_huffman", 2, lambda: _fanout("huffman", 2),
        lambda: _text("codec_huffman"))
    add("codec_fse", 2, lambda: _fanout("fse", 2),
        lambda: _text("codec_fse"))
    add("codec_lz77", 2, lambda: _fanout("lz77", 4),
        lambda: _text("codec_lz77", 8192))
    add("codec_zlib_backend", 3, lambda: _single("zlib_backend", level=6),
        lambda: _text("codec_zlib_backend"))
    add("codec_float_split", 3, lambda: _fanout("float_split", 3, fmt=2),
        lambda: _float32("codec_float_split"))
    add("codec_parse_numeric", 2, lambda: _fanout("parse_numeric", 3),
        lambda: _strings_ints("codec_parse_numeric"))
    add("codec_csv_split", 2, lambda: _fanout("csv_split", 3, sep=","),
        lambda: _csv("codec_csv_split"))
    add("codec_string_split", 1, lambda: _fanout("string_split", 2),
        lambda: _strings_mixed("codec_string_split"))
    add("codec_transpose_split", 1, lambda: _fanout("transpose_split", 4),
        lambda: _bounded_u32("codec_transpose_split"))
    add("codec_interpret_numeric", 1,
        lambda: _single("interpret_numeric", width=4),
        lambda: _struct_rec("codec_interpret_numeric", 4))
    add("codec_lzma_backend", 3, lambda: _single("lzma_backend", preset=6),
        lambda: _text("codec_lzma_backend"))
    add("codec_bz2_backend", 3, lambda: _single("bz2_backend", level=9),
        lambda: _text("codec_bz2_backend"))
    # explicit bits: dynamic selection only fuses exact power widths, and the
    # coverage test needs codec id 26 *in* the frame, not its lowered form
    add("codec_fused_delta_bitpack", 4,
        lambda: _single("fused_delta_bitpack", bits=8),
        lambda: _smooth_u32("codec_fused_delta_bitpack"))
    # multi-byte separator and CRLF pin csv_split's extension header byte
    # (flags + separator tail) — the layout the multi-byte-sep bugfix added
    add("codec_csv_split_multisep", 2,
        lambda: _fanout("csv_split", 3, sep="::"),
        lambda: _csv("codec_csv_split_multisep", sep=b"::"))
    add("codec_csv_split_crlf", 2, lambda: _fanout("csv_split", 3, sep=","),
        lambda: _csv("codec_csv_split_crlf", eol=b"\r\n"))
    add("codec_edge_list", 4, lambda: _fanout("edge_list", 4, sep="\t"),
        lambda: _edges_text("codec_edge_list"))

    def adj_gap_plan() -> Plan:
        g = GraphBuilder(1)
        src, dst, _bitmap, _exc = g.add("edge_list", g.input(0), sep="\t")
        g.add("adj_gap", src, dst, window=8)
        return g.build("unit_adj_gap")

    add("codec_adj_gap", 4, adj_gap_plan,
        lambda: _edges_text("codec_adj_gap"))
    add("codec_edge_list_bin", 4, lambda: _fanout("edge_list_bin", 2, width=4),
        lambda: _edges_bin("codec_edge_list_bin"))

    # --- profile families at the current version ---------------------------
    add("profile_generic_numeric", 4, P.generic_profile,
        lambda: _smooth_u32("profile_generic_numeric"))
    add("profile_generic_text", 4, P.generic_profile,
        lambda: _text("profile_generic_text"))
    add("profile_numeric", 4, P.numeric_profile,
        lambda: _bounded_u32("profile_numeric"))
    add("profile_text", 4, P.text_profile,
        lambda: _text("profile_text"))
    add("profile_float32", 4, P.float32_profile,
        lambda: _float32("profile_float32"))
    add("profile_bfloat16", 4, P.bfloat16_profile,
        lambda: _bf16("profile_bfloat16"))
    add("profile_float64", 4, P.float64_profile,
        lambda: _float64("profile_float64"))
    add("profile_sao", 4, P.sao_profile, lambda: _sao_like("profile_sao"))
    add("profile_csv3", 4, lambda: P.csv_profile(3),
        lambda: _csv("profile_csv3"))
    add("profile_struct44", 4, lambda: P.struct_profile([4, 4]),
        lambda: _struct_rec("profile_struct44", 8))
    add("profile_graph", 4, P.graph_profile,
        lambda: _edges_text("profile_graph"))
    add("profile_graph_bin", 4, lambda: P.graph_bin_profile(4),
        lambda: _edges_bin("profile_graph_bin"))

    # --- one generic vector per supported format version (drift canary) ----
    for fv in (1, 2, 3, 4):
        add(f"version_v{fv}_generic", fv, P.generic_profile,
            lambda: _smooth_u32("version_generic"))

    # --- chunked containers (format v4 OZLC record) ------------------------
    add("container_text", 4, P.text_profile,
        lambda: _text("container_text", 10240), chunk_bytes=2048)
    add("container_numeric", 4, P.numeric_profile,
        lambda: _smooth_u32("container_numeric", 4096), chunk_bytes=4096)

    # --- a trained plan from results/trained (the §VI-C deploy loop) -------
    def trained_plan() -> Plan:
        plan, _meta = deserialize_plan(TRAINED_SOURCE.read_bytes())
        return plan

    def trained_input() -> Stream:
        # era5_flux_0 starts with interpret_numeric: serial bytes whose
        # length divides its width
        plan, _meta = deserialize_plan(TRAINED_SOURCE.read_bytes())
        width = plan.nodes[0].param_dict().get("width", 4)
        raw = _smooth_u32("trained_era5", 1024).data.tobytes()
        return serial(raw[: len(raw) - len(raw) % width])

    add("trained_era5_flux", 4, trained_plan, trained_input)
    return out


# ------------------------------------------------------------- (de)hydration
def stream_to_entry(s: Stream) -> Dict:
    entry = {"stype": int(s.stype), "width": int(s.width)}
    if s.stype == SType.STRING and s.lengths is not None:
        entry["lengths"] = [int(x) for x in s.lengths.tolist()]
    return entry


def stream_from_entry(entry: Dict, payload: bytes) -> Stream:
    stype = SType(entry["stype"])
    width = int(entry["width"])
    if stype == SType.NUMERIC:
        dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[width]
        return Stream(np.frombuffer(payload, dtype=dtype), stype, width).validate()
    lengths = None
    if stype == SType.STRING:
        lengths = np.asarray(entry.get("lengths", []), dtype=np.uint32)
    return Stream(
        np.frombuffer(payload, dtype=np.uint8), stype, width, lengths
    ).validate()


def encode_vector(v_entry: Dict, plan: Plan, stream: Stream) -> bytes:
    """The one pinned encode path both regeneration and the tests use.

    The resolve cache is bypassed: it is keyed on stream *shape*, so a warm
    cache could replay a selector choice made on some other vector's data —
    frozen frames must depend only on (plan, input, version, chunking).
    """
    return compress(
        plan,
        [stream],
        ctx=CompressionCtx(v_entry["format_version"], LEVEL),
        chunk_bytes=v_entry["chunk_bytes"] or None,
        use_resolve_cache=False,
    )


def load_manifest() -> Dict[str, Dict]:
    return json.loads(MANIFEST.read_text())


# -------------------------------------------------------------- regeneration
def regenerate() -> None:
    mode = os.environ.get(REGEN_ENV)
    if mode not in ("1", "new"):
        raise SystemExit(
            f"refusing to regenerate the conformance corpus without"
            f" {REGEN_ENV}=1 (full rewrite — a reviewed format change) or"
            f" {REGEN_ENV}=new (freeze only vectors missing from the"
            f" manifest; existing frames stay byte-identical) — frozen"
            f" frames define the wire format (see ROADMAP.md)"
        )
    additive = mode == "new"
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    manifest: Dict[str, Dict] = (
        load_manifest() if additive and MANIFEST.exists() else {}
    )
    for v in vectors():
        if additive and v.name in manifest:
            continue
        plan = v.make_plan().validate()
        stream = v.make_input().validate()
        entry = {
            "format_version": v.format_version,
            "chunk_bytes": v.chunk_bytes,
            "level": LEVEL,
            **stream_to_entry(stream),
        }
        frame = encode_vector(entry, plan, stream)
        (GOLDEN_DIR / f"{v.name}.in").write_bytes(stream.content_bytes())
        (GOLDEN_DIR / f"{v.name}.ozl").write_bytes(frame)
        (GOLDEN_DIR / f"{v.name}.ozp").write_bytes(
            serialize_plan(plan, name=v.name, format_version=v.format_version,
                           level=LEVEL)
        )
        entry["frame_bytes"] = len(frame)
        manifest[v.name] = entry
        print(f"froze {v.name}: {stream.nbytes}B -> {len(frame)}B (v{v.format_version})")
    MANIFEST.write_text(json.dumps(manifest, indent=1, sort_keys=True) + "\n")
    print(f"{len(manifest)} vectors -> {GOLDEN_DIR}")


if __name__ == "__main__":
    regenerate()
