"""The two-phase engine: resolve caching, backend dispatch + bit-exactness,
the delta+bitpack fusion rewrite, and multi-chunk container frames."""
import numpy as np
import pytest

from repro.core import (
    CompressionCtx,
    Compressor,
    GraphBuilder,
    StreamMeta,
    available_backends,
    compress,
    decompress,
    decompress_bytes,
    execute,
    fuse_resolved,
    numeric,
    pipeline,
    resolve,
    resolve_cache_clear,
    resolve_cache_info,
    serial,
    stream_meta,
    strings,
)
from repro.core.wire import FrameError, is_container, read_container, read_frame

rng = np.random.default_rng(0)


def sorted_u32(n=2000, step=200):
    return numeric(np.cumsum(rng.integers(0, step, n)).astype(np.uint32))


# ------------------------------------------------------------------ resolve
def test_resolve_is_selector_free():
    from repro.codecs import generic_profile

    r = resolve(generic_profile(), numeric(np.arange(5000, dtype=np.uint32)))
    assert r.steps, "resolution produced an empty program"
    from repro.core.codec import get_codec

    for step in r.steps:
        assert get_codec(step.name).codec_id == step.codec_id


def test_resolve_cache_hit_on_same_meta():
    from repro.codecs import generic_profile

    resolve_cache_clear()
    plan = generic_profile()
    x1 = numeric(np.arange(4096, dtype=np.uint32))
    x2 = numeric(np.arange(4096, dtype=np.uint32) * 3)  # same meta, new data
    r1 = resolve(plan, x1)
    misses_after_first = resolve_cache_info()["misses"]
    r2 = resolve(plan, x2)
    info = resolve_cache_info()
    assert r2 is r1, "same stream meta must reuse the cached ResolvedPlan"
    assert info["hits"] >= 1
    assert info["misses"] == misses_after_first


def test_resolve_cache_miss_on_level_change():
    from repro.codecs import generic_profile

    resolve_cache_clear()
    plan = generic_profile()
    x = numeric(np.arange(4096, dtype=np.uint32))
    r5 = resolve(plan, x, CompressionCtx(level=5))
    before = resolve_cache_info()["misses"]
    r9 = resolve(plan, x, CompressionCtx(level=9))
    assert resolve_cache_info()["misses"] > before, "level is part of the key"
    assert r9 is not r5


def test_resolve_cache_miss_on_meta_change():
    resolve_cache_clear()
    plan = pipeline("delta", "range_pack")
    resolve(plan, numeric(np.arange(100, dtype=np.uint32)))
    before = resolve_cache_info()["misses"]
    resolve(plan, numeric(np.arange(100, dtype=np.uint16)))  # width changed
    assert resolve_cache_info()["misses"] > before


def test_resolve_from_metas_only_static_plan():
    plan = pipeline("delta", "range_pack")
    x = numeric(np.arange(100, dtype=np.uint32))
    r = resolve(plan, [stream_meta(x)])
    frame = execute(r, x)
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_resolve_from_metas_only_dynamic_plan_rejected():
    from repro.codecs import generic_profile

    meta = StreamMeta(numeric(np.arange(4, dtype=np.uint32)).stype, 4, 3)
    with pytest.raises(ValueError, match="concrete streams"):
        resolve(generic_profile(), [meta], use_cache=False)


def test_resolve_rejects_wrong_input_count():
    plan = pipeline("delta", "bitpack")  # 1-input plan
    a = numeric(np.arange(10, dtype=np.uint32))
    with pytest.raises(ValueError, match="wants 1 inputs"):
        resolve(plan, [a, a], use_cache=False)
    g = GraphBuilder(2)
    g.add("concat", g.input(0), g.input(1))
    with pytest.raises(ValueError, match="wants 2 inputs"):
        resolve(g.build(), [a], use_cache=False)


def test_cached_resolution_falls_back_on_inapplicable_values():
    """Same stream meta, but values that break the cached selector choice:
    compress() must re-expand instead of propagating the codec refusal."""
    from repro.codecs import generic_profile

    resolve_cache_clear()
    plan = generic_profile()
    n = 4096
    small = numeric(np.arange(n, dtype=np.uint64))  # tiny range: range_pack wins
    frame1 = compress(plan, small)
    assert decompress(frame1)[0].content_bytes() == small.content_bytes()
    # same meta (u64, same size bucket), range needs > 57 bits -> cached
    # range_pack plan is inapplicable to these values
    wide = numeric(
        np.linspace(0, (1 << 63) - 1, n, dtype=np.uint64) + np.arange(n, dtype=np.uint64)
    )
    frame2 = compress(plan, wide)
    assert decompress(frame2)[0].content_bytes() == wide.content_bytes()


def test_compressor_chunking_disable_override():
    x = np.arange(50_000, dtype=np.uint32).tobytes()
    c = Compressor(pipeline("huffman"), chunk_bytes=1 << 14)
    assert is_container(c.compress(x))
    assert not is_container(c.compress(x, chunk_bytes=0)), "0 forces a plain frame"


def test_execute_rejects_unknown_backend():
    x = numeric(np.arange(10, dtype=np.uint32))
    r = resolve(pipeline("store"), x)
    with pytest.raises(ValueError, match="unknown backend"):
        execute(r, x, backend="quantum")


# ------------------------------------------------------------------ backends
def _routed_cases():
    f32 = (rng.normal(size=300) * 0.1).astype(np.float32)
    g = GraphBuilder(1)
    g.add("transpose_split", g.input(0), n_out=4)
    tsplit = g.build("tsplit")
    return [
        ("delta_u8", pipeline("delta"), numeric(np.arange(777, dtype=np.uint8))),
        ("delta_u16", pipeline("delta"), numeric(np.arange(777, dtype=np.uint16))),
        ("delta_u32", pipeline("delta"), numeric(np.arange(777, dtype=np.uint32))),
        ("delta_u64_fallback", pipeline("delta"), numeric(np.arange(77, dtype=np.uint64))),
        (
            "bitpack_8",
            pipeline("bitpack"),
            numeric(rng.integers(0, 200, 500).astype(np.uint32)),
        ),
        (
            "bitpack_13_fallback",
            pipeline("bitpack"),
            numeric(rng.integers(0, 5000, 500).astype(np.uint32)),
        ),
        ("transpose", pipeline("transpose"), numeric(rng.integers(0, 1 << 30, 400).astype(np.uint32))),
        ("transpose_split", tsplit, numeric(rng.integers(0, 1 << 30, 400).astype(np.uint32))),
        ("float_split", pipeline(("float_split", {"fmt": 2})), numeric(f32)),
        ("float_split_f64_fallback", pipeline(("float_split", {"fmt": 3})), numeric(rng.integers(0, 1 << 60, 100).astype(np.uint64))),
        ("fused", pipeline("fused_delta_bitpack"), sorted_u32()),
        ("empty", pipeline("delta"), numeric(np.zeros(0, dtype=np.uint32))),
    ]


@pytest.mark.parametrize("name,plan,stream", _routed_cases(), ids=lambda c: c if isinstance(c, str) else "")
def test_host_device_frames_byte_identical(name, plan, stream):
    assert "device" in available_backends()
    fh = compress(plan, stream, backend="host")
    fd = compress(plan, stream, backend="device", )
    assert fh == fd, f"{name}: device frame differs from host frame"
    assert decompress(fd)[0].content_bytes() == stream.content_bytes()


# -------------------------------------------------------------------- fusion
def test_fusion_rewrites_adjacent_delta_bitpack():
    x = sorted_u32()
    frame = compress(pipeline("delta", "bitpack"), x, backend="device")
    _, _, nodes, _ = read_frame(frame)
    assert [n.codec_id for n in nodes] == [26], "expected one fused node"
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_fusion_falls_back_when_precondition_fails():
    # wide wrapped deltas: the 32-bit-word kernel can't pack these profitably
    x = numeric(rng.integers(0, 1 << 31, 2000).astype(np.uint32))
    frame = compress(pipeline("delta", "bitpack"), x, backend="device")
    _, _, nodes, _ = read_frame(frame)
    assert [n.codec_id for n in nodes] == [3, 6], "must lower to delta+bitpack"
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_fusion_is_version_gated():
    x = sorted_u32()
    r = resolve(pipeline("delta", "bitpack"), x, CompressionCtx(format_version=3))
    assert fuse_resolved(r) is r, "no fusion below wire format v4"
    frame = execute(r, x, backend="device")
    _, _, nodes, _ = read_frame(frame)
    assert [n.codec_id for n in nodes] == [3, 6]


def test_fusion_preserves_downstream_wiring():
    # delta+bitpack followed by more nodes: edge renumbering must hold up
    g = GraphBuilder(1)
    a, b = g.add("dup", g.input(0))
    d = g.add("delta", a)
    g.add("bitpack", d)
    g.add("transpose", b)
    plan = g.build("fuse_mid")
    x = sorted_u32(1000)
    fd = compress(plan, x, backend="device")
    _, _, nodes, _ = read_frame(fd)
    assert 26 in [n.codec_id for n in nodes]
    assert decompress(fd)[0].content_bytes() == x.content_bytes()
    assert decompress(compress(plan, x, backend="host"))[0].content_bytes() == x.content_bytes()


def test_fused_decode_matches_host_chain():
    """decompress() is backend-free: both frame shapes regenerate the input."""
    x = sorted_u32()
    fh = compress(pipeline("delta", "bitpack"), x, backend="host")
    fd = compress(pipeline("delta", "bitpack"), x, backend="device")
    assert decompress(fh)[0].content_bytes() == decompress(fd)[0].content_bytes()
    assert len(fd) <= len(fh), "fusion must not grow the frame"


def test_fusion_declines_inexact_widths():
    """Dynamic fusion only fires when the packing width is exact — rounding
    3-bit deltas up to 4 would inflate the frame vs separate delta+bitpack."""
    x = numeric(np.cumsum(rng.integers(0, 8, 2000)).astype(np.uint32))  # 3-bit
    fh = compress(pipeline("delta", "bitpack"), x, backend="host")
    fd = compress(pipeline("delta", "bitpack"), x, backend="device")
    _, _, nodes, _ = read_frame(fd)
    assert [n.codec_id for n in nodes] == [3, 6], "inexact width must not fuse"
    assert fd == fh, "declined fusion falls back to the bit-identical pair"


def test_resolve_cache_bypass():
    from repro.codecs import generic_profile

    resolve_cache_clear()
    plan = generic_profile()
    x = numeric(np.arange(4096, dtype=np.uint32))
    r1 = resolve(plan, x)
    assert resolve(plan, x) is r1, "cached path returns the memoized object"
    r3 = resolve(plan, x, use_cache=False)
    assert r3 is not r1, "bypass must re-expand"
    assert r3.steps == r1.steps, "same data -> same expansion"


# ------------------------------------------------------------------ chunking
CHUNK_PLAN = pipeline("delta", "range_pack")


def test_chunked_roundtrip_numeric():
    x = numeric(np.arange(100_000, dtype=np.uint32))
    frame = compress(CHUNK_PLAN, x, chunk_bytes=1 << 15)
    assert is_container(frame)
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_chunked_at_one_byte_granularity():
    x = numeric(np.arange(257, dtype=np.uint32))
    frame = compress(CHUNK_PLAN, x, chunk_bytes=1)
    assert is_container(frame)
    version, chunks = read_container(frame)
    assert len(chunks) == 257, "element-aligned: one u32 per chunk"
    (out,) = decompress(frame)
    assert out.content_bytes() == x.content_bytes()
    assert out.stype == x.stype and out.width == x.width


def test_chunked_roundtrip_serial_and_strings():
    blob = b"the quick brown fox " * 4096
    frame = compress(pipeline("huffman"), serial(blob), chunk_bytes=10_000)
    assert is_container(frame)
    assert decompress_bytes(frame) == blob

    ss = strings([b"alpha", b"", b"gamma" * 10, b"x", b"y" * 100])
    sf = compress(pipeline("store"), ss, chunk_bytes=8)
    assert is_container(sf)
    (out,) = decompress(sf)
    assert out.to_strings() == ss.to_strings()
    assert np.array_equal(out.lengths, ss.lengths)


def test_chunked_device_backend():
    x = sorted_u32(50_000)
    frame = compress(pipeline("delta", "bitpack"), x, chunk_bytes=1 << 15, backend="device")
    assert is_container(frame)
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_small_input_stays_single_frame():
    x = numeric(np.arange(100, dtype=np.uint32))
    frame = compress(CHUNK_PLAN, x, chunk_bytes=1 << 20)
    assert not is_container(frame)
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_chunked_with_selector_profile():
    from repro.codecs import generic_profile

    x = numeric(np.cumsum(rng.integers(0, 9, 60_000)).astype(np.uint32))
    frame = compress(generic_profile(), x, chunk_bytes=1 << 16)
    assert is_container(frame)
    assert decompress(frame)[0].content_bytes() == x.content_bytes()


def test_chunking_requires_v4():
    x = numeric(np.arange(1000, dtype=np.uint32))
    with pytest.raises(ValueError, match="format version"):
        compress(CHUNK_PLAN, x, ctx=CompressionCtx(format_version=3), chunk_bytes=16)


def test_chunking_rejects_multi_input():
    g = GraphBuilder(2)
    g.add("concat", g.input(0), g.input(1))
    plan = g.build()
    a, b = serial(b"x" * 100), serial(b"y" * 100)
    with pytest.raises(ValueError, match="one input"):
        compress(plan, [a, b], chunk_bytes=16)


def test_container_corruption_fails_closed():
    x = numeric(np.arange(10_000, dtype=np.uint32))
    frame = bytearray(compress(CHUNK_PLAN, x, chunk_bytes=1 << 12))
    frame[len(frame) // 2] ^= 0xFF
    with pytest.raises((FrameError, ValueError)):
        decompress(bytes(frame))


def test_container_truncation_fails_closed():
    x = numeric(np.arange(10_000, dtype=np.uint32))
    frame = compress(CHUNK_PLAN, x, chunk_bytes=1 << 12)
    for cut in range(0, len(frame) - 1, max(len(frame) // 53, 1)):
        with pytest.raises((FrameError, ValueError, KeyError, IndexError)):
            decompress(frame[:cut])


def test_container_decode_in_fresh_process():
    """Regression: parallel chunk decode in a process that never compressed
    must not race the lazy codec-registry load (flag set before import done)."""
    import subprocess
    import sys
    import tempfile
    from pathlib import Path

    x = numeric(np.arange(80_000, dtype=np.uint32))
    frame = compress(CHUNK_PLAN, x, chunk_bytes=1 << 13)
    assert is_container(frame)
    with tempfile.TemporaryDirectory() as td:
        p = Path(td) / "frame.bin"
        p.write_bytes(frame)
        src = Path(__file__).resolve().parents[1] / "src"
        out = subprocess.run(
            [
                sys.executable,
                "-c",
                "import sys; sys.path.insert(0, sys.argv[1])\n"
                "from repro.core import decompress\n"
                "(s,) = decompress(open(sys.argv[2], 'rb').read())\n"
                "print('DECODED', s.nbytes)",
                str(src),
                str(p),
            ],
            capture_output=True,
            text=True,
            timeout=240,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "DECODED 320000" in out.stdout


def test_compressor_chunking_facade():
    x = np.arange(50_000, dtype=np.uint32).tobytes()
    c = Compressor(pipeline("huffman"), chunk_bytes=1 << 14)
    assert c.roundtrip_check(x)
    assert is_container(c.compress(x))


# ----------------------------------------------------- serialized compressors
def test_deserialize_preserves_version_and_level():
    c = Compressor(CHUNK_PLAN, format_version=3, level=8, name="deployed")
    c2 = Compressor.deserialize(c.serialize())
    # the blob's single name field becomes both plan and compressor name on
    # reload (longstanding wire shape), so compare plan structure
    assert c2.plan.nodes == c.plan.nodes and c2.plan.n_inputs == c.plan.n_inputs
    assert c2.format_version == 3, "format_version must survive deployment"
    assert c2.level == 8, "level must survive deployment"
    assert c2.name == "deployed"


def test_deserialize_legacy_blob_defaults():
    """Blobs written before the fix carry no knobs -> current defaults."""
    from repro.core.serialize import deserialize_plan, serialize_plan
    from repro.core.versioning import CURRENT_FORMAT_VERSION

    blob = serialize_plan(CHUNK_PLAN, name="old")  # no knobs, legacy shape
    plan, meta = deserialize_plan(blob)
    assert "format_version" not in meta and "level" not in meta
    c = Compressor.deserialize(blob)
    assert c.format_version == CURRENT_FORMAT_VERSION and c.level == 5
