"""Static plan analysis: signature coverage, the type-checker's diagnostic
catalogue, signature/encode conformance, and fail-closed integration at every
plan entry point (registry, CLI lint, trainer pruning, resolve debug mode).

The analyzer's soundness contract is load-bearing: an *error* diagnostic may
only fire on plans that definitely fail at encode time.  That is what lets
the trainer prune ill-typed genomes statically and still emit byte-identical
Pareto fronts (pruned genomes would have scored INVALID anyway) — asserted
end-to-end below.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    PlanTypeError,
    annotate_resolved_nodes,
    check_plan,
    fmt_atoms,
)
from repro.core import Compressor, compress
from repro.core.codec import all_codecs
from repro.core.graph import GraphBuilder, Plan, PlanNode, KIND_CODEC, pipeline
from repro.core.message import SType, numeric as _numeric, serial, strings, struct
from repro.core.selector import all_selectors
from repro.core.serialize import deserialize_plan, serialize_plan

S, T, N, G = (int(SType.SERIAL), int(SType.STRUCT),
              int(SType.NUMERIC), int(SType.STRING))
_DT = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}

REPO = Path(__file__).resolve().parents[1]
ILLTYPED = Path(__file__).resolve().parent / "illtyped"
GOLDEN = Path(__file__).resolve().parent / "golden"


def numeric(vals, w):
    return _numeric(np.asarray(vals, dtype=_DT[w]))


# ------------------------------------------------------------- coverage
def test_every_codec_declares_a_signature():
    missing = [n for n, s in all_codecs().items() if s.sig is None]
    assert not missing, (
        f"codecs without a stream-type signature: {missing} — the ROADMAP"
        " policy requires every codec to ship one"
    )


def test_every_selector_declares_a_signature():
    missing = [n for n, s in all_selectors().items() if s.sig is None]
    assert not missing, f"selectors without a signature: {missing}"


def test_signature_ports_cover_declared_arity():
    for name, spec in all_codecs().items():
        assert spec.sig.inputs or spec.n_inputs == 0, name
        if spec.n_inputs > 1:
            assert len(spec.sig.inputs) in (1, spec.n_inputs), name


# ------------------------------------------------- diagnostic catalogue
def _codes(report):
    return {d.code for d in report.diagnostics}


def test_e_type_fires_on_stype_mismatch():
    g = GraphBuilder(1)
    lit, lens = g.add("huffman", g.input(0), n_out=2)
    g.add("delta", lit)  # delta wants numeric, huffman emits serial
    report = check_plan(g.build())
    assert not report.ok
    assert any(d.code == "E_TYPE" and d.node == 1 for d in report.errors)


def test_e_width_fires_on_width_mismatch():
    g = GraphBuilder(1)
    n4 = g.add("interpret_numeric", g.input(0), width=4)
    g.add("huffman", n4, n_out=2)  # huffman: byte alphabet only
    report = check_plan(g.build())
    assert any(d.code == "E_WIDTH" for d in report.errors)


def test_e_params_fires_on_transfer_conflict():
    g = GraphBuilder(1)
    n4 = g.add("interpret_numeric", g.input(0), width=4)
    g.add("float_split", n4, n_out=3, fmt=3)  # fmt=float64 wants width 8
    report = check_plan(g.build())
    assert any(d.code == "E_PARAMS" for d in report.errors)


def test_e_version_fires_on_min_version_conflict():
    report = check_plan(
        pipeline("delta", "fused_delta_bitpack"), format_version=2,
        input_atoms=[(N, 4)],
    )
    assert any(d.code == "E_VERSION" for d in report.errors)
    # ... and is absent when the plan format is new enough
    assert check_plan(
        pipeline("delta", "fused_delta_bitpack"), format_version=4,
        input_atoms=[(N, 4)],
    ).ok


def test_e_struct_fires_on_invalid_topology():
    plan = Plan(1, (PlanNode(KIND_CODEC, "delta", (7,), 1),))  # edge 7 undefined
    report = check_plan(plan)
    assert any(d.code == "E_STRUCT" for d in report.errors)


def test_e_unknown_fires_on_unknown_codec():
    plan = Plan(1, (PlanNode(KIND_CODEC, "no_such_codec", (0,), 1),))
    report = check_plan(plan)
    assert any(d.code == "E_UNKNOWN" for d in report.errors)


def test_w_selector_is_warning_not_error():
    g = GraphBuilder(1)
    g.select("numeric_auto", g.input(0))
    report = check_plan(g.build(), input_atoms=[(G, 1)])  # strings in
    assert report.ok  # selectors degrade to store: never a hard error
    assert any(d.code == "W_SELECTOR" for d in report.warnings)


def test_w_packed_fires_on_recoding_entropy_output():
    g = GraphBuilder(1)
    packed = g.add("bitpack", g.input(0))
    g.add("huffman", packed, n_out=2)
    report = check_plan(g.build(), input_atoms=[(N, 4)])
    assert report.ok
    assert any(d.code == "W_PACKED" for d in report.warnings)


def test_w_dead_fires_on_identity_store():
    report = check_plan(pipeline("store"))
    assert report.ok
    assert any(d.code == "W_DEAD" for d in report.warnings)


def test_i_expand_reports_terminal_bound():
    report = check_plan(pipeline("delta", "range_pack"), input_atoms=[(N, 4)])
    infos = [d for d in report.infos if d.code == "I_EXPAND"]
    assert infos, "every terminal edge gets a worst-case expansion bound"


def test_input_atoms_narrow_the_walk():
    # delta on strings is definitely ill-typed once the input is pinned ...
    assert not check_plan(pipeline("delta"), input_atoms=[(G, 1)]).ok
    # ... but fine at lattice top (some concrete typing exists)
    assert check_plan(pipeline("delta")).ok


def test_fmt_atoms_renders_stably():
    assert fmt_atoms([(N, w) for w in (1, 2, 4, 8)]) == "numeric(*)"
    assert fmt_atoms([(S, 1)]) == "serial"
    assert fmt_atoms([]) == "none"


# ------------------------------------------- signature/encode conformance
def _sample(atom, codec):
    """A stream of type `atom` honoring `codec`'s value-level preconditions."""
    st, w = atom
    if st == S:
        if codec == "csv_split":
            return serial(b"1,2\n3,4\n5,6\n" * 4)
        if codec == "edge_list":
            return serial(b"0 1\n0 2\n1 2\n2 3\n")
        if codec == "edge_list_bin":
            import struct as _s
            return serial(
                b"".join(_s.pack("<II", a, b) for a, b in [(0, 1), (0, 2), (1, 2)])
            )
        if codec == "constant":
            return serial(b"\x07" * 32)
        return serial(bytes(range(16)) * 4)
    if st == G:
        return strings([b"alpha", b"beta", b"gamma", b"alpha"] * 4)
    if st == T:
        if codec == "constant":
            return struct(b"abc" * 16, 3)
        return struct(bytes(range(48)), 3)
    if codec == "constant":
        return numeric([5] * 16, w)
    return numeric(list(range(16)), w)


def _params_for(codec, strm, atom):
    if codec == "split_n":
        return {"sizes": [strm.n_elts // 2, strm.n_elts - strm.n_elts // 2]}
    if codec == "field_split":
        return {"widths": [1, 2]} if atom[0] == T else {"widths": [1]}
    if codec == "interpret_numeric":
        return {"width": 2}
    if codec == "float_split":
        return {"fmt": {2: 0, 4: 2, 8: 3}.get(atom[1], 2)}
    if codec == "edge_list_bin":
        return {"width": 4}
    return {}


CONCRETE_ATOMS = [(S, 1), (G, 1), (T, 3), (N, 1), (N, 2), (N, 4), (N, 8)]


@pytest.mark.parametrize(
    "name", sorted(n for n, s in all_codecs().items() if s.n_inputs == 1)
)
def test_signature_matches_encode_reality(name):
    """For every single-input codec and every concrete stream shape:
    signature-accepted => encode succeeds; signature-rejected => encode
    raises AND the checker statically rejects the wiring."""
    spec = all_codecs()[name]
    port = spec.sig.inputs[0]
    for atom in CONCRETE_ATOMS:
        strm = _sample(atom, name)
        params = _params_for(name, strm, atom)
        raised = None
        try:
            spec.run_encode([strm], params)
        except Exception as err:  # noqa: BLE001 - conformance probe
            raised = err
        if port.accepts(atom):
            assert raised is None, (
                f"{name} declares it accepts {atom} but encode raised: {raised}"
            )
        else:
            assert raised is not None, (
                f"{name} declares it rejects {atom} but encode succeeded —"
                " the signature is too narrow (unsound for trainer pruning)"
            )
            # and the checker flags the same wiring statically
            g = GraphBuilder(1)
            n_out = spec.n_outputs if spec.n_outputs >= 0 else 2
            g.add(name, g.input(0), n_out=n_out, **params)
            report = check_plan(g.build(), input_atoms=[atom])
            assert not report.ok, f"{name} on {atom}: encode fails but checker passes"


# ------------------------------------------------- corpus: well-typed side
def test_all_golden_plans_typecheck_clean():
    assert GOLDEN.is_dir()
    checked = 0
    for path in sorted(GOLDEN.glob("*.ozp")):
        plan, meta = deserialize_plan(path.read_bytes())
        report = check_plan(plan, format_version=meta.get("format_version"))
        assert report.ok, f"{path.name}: {[str(d) for d in report.errors]}"
        checked += 1
    assert checked >= 40


def test_all_named_profiles_typecheck_clean():
    from repro.codecs.profiles import named_profiles, resolve_profile_spec

    specs = sorted(named_profiles()) + ["struct:2,4", "csv:3", "graph:bin:4"]
    for spec in specs:
        report = check_plan(resolve_profile_spec(spec))
        assert report.ok, f"profile {spec}: {[str(d) for d in report.errors]}"


# ----------------------------------------------- corpus: ill-typed side
def _illtyped_cases():
    manifest = json.loads((ILLTYPED / "manifest.json").read_text())
    return sorted(manifest.items())


@pytest.mark.parametrize("fname,want", _illtyped_cases())
def test_illtyped_corpus_rejected_by_checker(fname, want):
    plan, meta = deserialize_plan((ILLTYPED / fname).read_bytes())
    report = check_plan(plan, format_version=meta.get("format_version"))
    assert not report.ok
    assert want["expect"] in _codes(report), (
        f"{fname}: expected {want['expect']}, got {sorted(_codes(report))}"
    )


@pytest.mark.parametrize("fname,want", _illtyped_cases())
def test_illtyped_corpus_rejected_at_registry(fname, want):
    from repro.service.registry import PlanRegistry

    reg = PlanRegistry()
    with pytest.raises(PlanTypeError) as exc:
        reg.register_file(ILLTYPED / fname)
    err = exc.value
    # structured error surface for the service frame (additive header key)
    assert err.extra["error_kind"] == "ill_typed_plan"
    assert any(d["code"] == want["expect"] for d in err.extra["diagnostics"])
    assert len(reg) == 0, "fail closed: nothing registered"


@pytest.mark.parametrize("fname,want", _illtyped_cases())
def test_illtyped_corpus_rejected_by_cli_lint(fname, want):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.cli", "lint", "--json", str(ILLTYPED / fname)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert out["errors"] >= 1
    codes = {d["code"] for t in out["targets"] for d in t["diagnostics"]}
    assert want["expect"] in codes


def test_illtyped_corpus_pruned_by_trainer():
    from repro.training.trainer import TrainerService

    svc = TrainerService(workers=1)
    try:
        for fname, _want in _illtyped_cases():
            plan, _meta = deserialize_plan((ILLTYPED / fname).read_bytes())
            # version conflicts are deploy-time, not encode-time: the trainer
            # gate is the typing itself
            if check_plan(plan).ok:
                continue
            assert svc._statically_rejected(plan, (None, None))
    finally:
        svc.close()


def test_cli_lint_clean_on_golden_and_profiles():
    targets = [str(p) for p in sorted(GOLDEN.glob("*.ozp"))] + ["generic", "text"]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.cli", "lint"] + targets,
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


# -------------------------------------------------- registry stays usable
def test_registry_accepts_well_typed_plans():
    from repro.service.registry import PlanRegistry

    reg = PlanRegistry()
    entry = reg.register_profile("numeric")
    assert entry.plan_id == "numeric"
    assert len(reg) == 1


# -------------------------------------------- wire-frame type annotation
def test_annotate_resolved_nodes_renders_types():
    from repro.core import wire

    frame = compress(
        pipeline("delta", "range_pack"), numeric(list(range(64)), 4)
    )
    version, n_inputs, nodes, _stored = wire.read_frame(frame)
    node_types, report = annotate_resolved_nodes(
        n_inputs, nodes, format_version=version
    )
    assert len(node_types) == len(nodes)
    assert report.ok
    ins, outs = node_types[0]  # delta: graph input starts at lattice top
    assert ins == "any" and "numeric" in outs
    _, pk_out = node_types[1]  # range_pack emits packed serial
    assert pk_out == "serial"


# ------------------------------------------------ resolve debug assertion
def test_resolve_check_mode_rejects_ill_typed_plan():
    from repro.core import resolve_cache_clear, set_resolve_check

    g = GraphBuilder(1)
    lit, lens = g.add("huffman", g.input(0), n_out=2)
    g.add("delta", lit)
    bad = g.build("bad")
    data = serial(b"abcd" * 64)
    set_resolve_check(True)
    try:
        resolve_cache_clear()
        with pytest.raises(PlanTypeError):
            compress(bad, data)
        # well-typed plans pass untouched under the same mode
        out = compress(pipeline("delta", "range_pack"), numeric(range(64), 4))
        assert out
    finally:
        set_resolve_check(False)
        resolve_cache_clear()


# --------------------------------- trainer: static pruning is behaviorless
def test_static_pruning_is_byte_identical_and_counts():
    from repro.training import CsvFrontend, train

    rows = b"".join(
        b"%d,%d,%d\n" % (i, i * 7 % 97, 1000 - i) for i in range(200)
    )
    samples = [[serial(rows)]]

    kw = dict(pop_size=8, generations=2, n_points=4, seed=3, workers=2)
    on = train(samples, CsvFrontend(n_cols=3), static_prune=True, **kw)
    off = train(samples, CsvFrontend(n_cols=3), static_prune=False, **kw)

    # identical search trajectory: pruning replaces trial compressions only
    assert on.stats["evaluations"] == off.stats["evaluations"]
    assert on.stats["invalid_evaluations"] == off.stats["invalid_evaluations"]
    assert on.stats["pruned_static"] > 0
    assert off.stats["pruned_static"] == 0

    blobs_on = sorted(serialize_plan(p, p.name) for p, _sz, _t in on.pareto_plans())
    blobs_off = sorted(serialize_plan(p, p.name) for p, _sz, _t in off.pareto_plans())
    assert blobs_on == blobs_off, (
        "static pruning changed the Pareto front — the analyzer rejected a"
        " genome that would have encoded (soundness violation)"
    )


def test_trained_output_registers_cleanly():
    """The trainer never emits a plan the registry would bounce."""
    from repro.service.registry import PlanRegistry
    from repro.training import NumericFrontend, train

    data = np.cumsum(np.random.default_rng(5).integers(0, 9, 400)).astype(np.uint32)
    comp = train(
        [[_numeric(data)]], NumericFrontend(),
        pop_size=6, generations=1, n_points=4, seed=1, workers=1,
    )
    reg = PlanRegistry()
    blob = Compressor(comp.best_ratio_plan()).serialize()
    entry = reg.register_compressor(Compressor.deserialize(blob), "trained")
    assert entry.plan_id == "trained"
