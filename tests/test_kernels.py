"""Per-kernel validation: sweep shapes/dtypes, assert bit-exact match between
the Pallas kernel (interpret=True on CPU) and the ref.py pure-jnp oracle,
plus cross-checks against the numpy host codecs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

SIZES = [0, 1, 7, 128, 2048, 2049, 5000, 16384]
rng = np.random.default_rng(42)


def _u32(n, hi=None):
    return rng.integers(0, hi if hi is not None else (1 << 32), size=n, dtype=np.uint64).astype(np.uint32)


# --------------------------------------------------------------------- delta
@pytest.mark.parametrize("n", SIZES)
def test_delta_encode_matches_ref(n):
    x = _u32(n)
    got = np.asarray(ops.delta_encode(jnp.asarray(x)))
    want = np.asarray(ref.delta_encode(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", SIZES)
def test_delta_roundtrip_kernel(n):
    x = _u32(n)
    d = ops.delta_encode(jnp.asarray(x))
    back = np.asarray(ops.delta_decode(d))
    np.testing.assert_array_equal(back, x)


def test_delta_matches_host_codec():
    """Device kernel and numpy wire codec agree bit-for-bit."""
    from repro.core import numeric
    from repro.core.codec import get_codec

    x = _u32(4999)
    (host_out,), _ = get_codec("delta").run_encode([numeric(x)], {})
    dev_out = np.asarray(ops.delta_encode(jnp.asarray(x)))
    np.testing.assert_array_equal(host_out.data, dev_out)


# --------------------------------------------------------------- byteshuffle
@pytest.mark.parametrize("n", [0, 1, 100, 2048, 4097])
@pytest.mark.parametrize("w", [2, 4, 8])
def test_byteshuffle_matches_ref(n, w):
    x = rng.integers(0, 256, size=(n, w), dtype=np.uint8)
    got = np.asarray(ops.byteshuffle(jnp.asarray(x)))
    want = np.asarray(ref.byteshuffle_encode(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)
    back = np.asarray(ops.byteunshuffle(jnp.asarray(got)))
    np.testing.assert_array_equal(back, x)


# ------------------------------------------------------------------- bitpack
@pytest.mark.parametrize("bits", [1, 2, 4, 8, 16, 32])
@pytest.mark.parametrize("n", [0, 1, 31, 32, 1000, 8192])
def test_bitpack_roundtrip_and_ref(bits, n):
    x = _u32(n, hi=1 << bits)
    packed = ops.bitpack(jnp.asarray(x), bits)
    per = 32 // bits
    want = np.asarray(ref.bitpack_encode(jnp.asarray(np.pad(x, (0, (-n) % per))), bits))[
        : -(-n // per) if n else 0
    ]
    np.testing.assert_array_equal(np.asarray(packed), want)
    back = np.asarray(ops.bitunpack(packed, bits, n))
    np.testing.assert_array_equal(back, x)


# ----------------------------------------------------------------- histogram
@pytest.mark.parametrize("n", [0, 1, 4096, 5000, 65536])
def test_histogram_matches_numpy(n):
    x = rng.integers(0, 256, size=n, dtype=np.uint8)
    got = np.asarray(ops.histogram(jnp.asarray(x)))
    want = np.bincount(x, minlength=256).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_histogram_matches_ref():
    x = rng.integers(0, 256, size=4096, dtype=np.uint8)
    got = np.asarray(ops.histogram(jnp.asarray(x)))
    want = np.asarray(ref.histogram(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------- float_split
@pytest.mark.parametrize("n", [0, 1, 2048, 3000])
@pytest.mark.parametrize("fmt", [(8, 23), (8, 7), (5, 10)])  # f32, bf16, f16
def test_float_split_roundtrip_and_ref(n, fmt):
    exp_bits, man_bits = fmt
    width_bits = 1 + exp_bits + man_bits
    u = _u32(n, hi=1 << min(width_bits, 32))
    sign, exp, man = ops.float_split(jnp.asarray(u), exp_bits, man_bits)
    rs, re, rm = ref.float_split_encode(jnp.asarray(u), exp_bits, man_bits)
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(exp), np.asarray(re))
    np.testing.assert_array_equal(np.asarray(man), np.asarray(rm))
    back = np.asarray(ops.float_merge(sign, exp, man, exp_bits, man_bits))
    np.testing.assert_array_equal(back, u)


def test_float_split_matches_host_codec():
    from repro.core import numeric
    from repro.core.codec import get_codec

    f = rng.normal(size=5000).astype(np.float32)
    outs, _ = get_codec("float_split").run_encode([numeric(f)], {"fmt": 2})
    u = f.view(np.uint32)
    sign, exp, man = ops.float_split(jnp.asarray(u), 8, 23)
    np.testing.assert_array_equal(np.unpackbits(outs[0].data)[: f.size], np.asarray(sign))
    np.testing.assert_array_equal(outs[1].data, np.asarray(exp).astype(np.uint8))
    np.testing.assert_array_equal(outs[2].data, np.asarray(man))


# ------------------------------------------------- fused delta+bitpack (K1)
@pytest.mark.parametrize("bits", [4, 8, 16])
@pytest.mark.parametrize("n", [0, 1, 100, 8192, 10000])
def test_fused_delta_bitpack_roundtrip(bits, n):
    # monotone stream with deltas < 2^bits: the documented lossless domain
    steps = rng.integers(0, 1 << bits, size=n).astype(np.uint32)
    x = np.cumsum(steps, dtype=np.uint32)
    assert bool(ops.fused_delta_bitpack_fits(jnp.asarray(x), bits)) or n == 0
    packed = ops.fused_delta_bitpack(jnp.asarray(x), bits)
    want = np.asarray(
        ref.fused_delta_bitpack_encode(
            jnp.asarray(np.pad(x, (0, (-n) % (32 // bits)), mode="edge" if n else "constant")), bits
        )
    )
    np.testing.assert_array_equal(np.asarray(packed), want[: packed.shape[0]])
    back = np.asarray(ops.fused_delta_bitpack_decode(packed, bits, n))
    np.testing.assert_array_equal(back, x)


def test_fused_equals_unfused_composition():
    """K1 invariant: fused kernel == delta ∘ bitpack composition."""
    bits = 8
    x = np.cumsum(rng.integers(0, 200, size=7000).astype(np.uint32), dtype=np.uint32)
    fused = np.asarray(ops.fused_delta_bitpack(jnp.asarray(x), bits))
    d = ops.delta_encode(jnp.asarray(x))
    unfused = np.asarray(ops.bitpack(d, bits))
    np.testing.assert_array_equal(fused, unfused)


# --------------------------------------------------------------- lane refill
@pytest.mark.parametrize("n_lanes", [0, 1, 7, 256, 300])
def test_lane_refill_matches_ref_and_host(n_lanes):
    """Pallas refill == jnp oracle == the numpy sliding-window gather that
    the entropy lane decoders use (truncated to the device's 32-bit window)."""
    buf = rng.integers(0, 256, 4096, dtype=np.int64).astype(np.uint8)
    bufp = np.concatenate([buf, np.zeros(8, np.uint8)])
    pos = rng.integers(0, buf.size * 8 - 40, size=n_lanes).astype(np.int32)
    got_pl = np.asarray(ops.lane_refill(jnp.asarray(bufp), jnp.asarray(pos)))
    got_ref = np.asarray(
        ops.lane_refill(jnp.asarray(bufp), jnp.asarray(pos), use_pallas=False)
    )
    sw = np.lib.stride_tricks.sliding_window_view(bufp, 8)
    w64 = sw[pos >> 3].copy().view("<u8")[:, 0] >> (pos & 7).astype(np.uint64)
    want = (w64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_pl, want)


def test_lane_refill_feeds_huffman_window():
    """The refilled window's low 15 bits are exactly the Huffman LUT index
    the host decoder derives for the same cursor."""
    from repro.core.codec import get_codec
    from repro.core.message import serial

    data = bytes(rng.integers(97, 123, 20000, dtype=np.int64).astype(np.uint8))
    outs, header = get_codec("huffman").run_encode([serial(data)], {})
    bitstream = outs[0].data
    offs = outs[1].data.astype(np.int64)
    bufp = np.concatenate([bitstream, np.zeros(16, np.uint8)])
    pos = offs.astype(np.int32)
    win = np.asarray(ops.lane_refill(jnp.asarray(bufp), jnp.asarray(pos)))
    sw = np.lib.stride_tricks.sliding_window_view(bufp, 8)
    w64 = sw[pos >> 3].copy().view("<u8")[:, 0] >> (pos & 7).astype(np.uint64)
    np.testing.assert_array_equal(
        win & np.uint32(0x7FFF), (w64 & np.uint64(0x7FFF)).astype(np.uint32)
    )


# ------------------------------------------------- entropy: huffman (device)
def _skewed(n, seed=7):
    r = np.random.default_rng(seed)
    return (r.zipf(1.4, n) % 256).astype(np.uint8)


@pytest.mark.parametrize("n", [1, 100, 4096, 50000])
def test_huffman_map_pack_matches_host_encoder(n):
    """Device map + scatter-add packer == the host bit-matrix writer, byte
    for byte (and the pallas map == the jnp oracle)."""
    from repro.codecs import entropy as E

    data = _skewed(n)
    lens = E._huffman_code_lengths(E._hist_u8(data))
    codes = E._canonical_codes(lens)
    host_packed, host_offs = E._write_bits_blocked(
        codes[data], lens[data].astype(np.int64), 1 << E.BLOCK_LOG
    )
    for up in (True, False):
        code, nb, offs = ops.huffman_map(
            jnp.asarray(data),
            jnp.asarray(codes),
            jnp.asarray(lens.astype(np.int32)),
            use_pallas=up,
        )
        np.testing.assert_array_equal(np.asarray(offs), host_offs)
        total_bytes = (int(offs[-1]) + 7) >> 3
        packed = np.asarray(ops.pack_bits(code, offs[:-1], 1 << 17))[:total_bytes]
        assert packed.tobytes() == host_packed.tobytes()


@pytest.mark.parametrize("n", [1, 100, 4097, 50000])
def test_huffman_decode_kernel_roundtrip(n):
    """Device lane decode of a host-encoded bitstream recovers the input,
    pallas and oracle paths identical."""
    from repro.codecs import entropy as E

    data = _skewed(n, seed=n)
    lens = E._huffman_code_lengths(E._hist_u8(data))
    codes = E._canonical_codes(lens)
    packed, offs = E._write_bits_blocked(
        codes[data], lens[data].astype(np.int64), 1 << E.BLOCK_LOG
    )
    lut_sym, lut_len = E._huffman_decode_lut(lens)
    block = 1 << E.BLOCK_LOG
    n_blocks = (n + block - 1) // block
    rem = np.minimum(n - np.arange(n_blocks) * block, block)
    max_rem = int(rem.max())
    pad = 16 + ((E.MAX_CODE_LEN * max_rem + 7) >> 3)
    buf = np.zeros(packed.size + pad, np.uint8)
    buf[: packed.size] = packed
    results = []
    for up in (True, False):
        out = np.asarray(
            ops.huffman_decode(
                jnp.asarray(buf),
                jnp.asarray(offs[:-1:block].astype(np.int32)),
                jnp.asarray(lut_sym.astype(np.int32)),
                jnp.asarray(lut_len.astype(np.int32)),
                max_rem,
                use_pallas=up,
            )
        )
        lanes = out.T
        results.append(
            np.concatenate([lanes[:-1].reshape(-1), lanes[-1, : rem[-1]]])
        )
    np.testing.assert_array_equal(results[0], data)
    np.testing.assert_array_equal(results[1], data)


# ----------------------------------------------------- entropy: fse (device)
def _fse_fixture(n, table_log=11, seed=3):
    from repro.codecs import entropy as E

    data = _skewed(n, seed=seed)
    norm = E._normalize_counts(E._hist_u8(data), table_log)
    tabs = E._build_tables(norm, table_log)
    return data, norm, tabs


@pytest.mark.parametrize("n", [1, 100, 1025, 50000])
def test_fse_encode_kernel_matches_host_encoder(n):
    """Device backward scan + packer == the host tANS encoder's bitstream
    and (bit length, final state) meta, byte for byte."""
    from repro.codecs import entropy as E
    from repro.core.message import Stream, SType

    table_log = 11
    data, norm, _ = _fse_fixture(n, table_log)
    _ds, _dn, _db, enc_table, nb0t, thrt, st0t = E._fse_tables_cached(
        norm, table_log
    )
    total = 1 << table_log
    width = enc_table.shape[1]
    block = 1 << E.FSE_BLOCK_LOG
    n_blocks = (n + block - 1) // block
    padded = np.zeros(n_blocks * block, np.uint8)
    padded[:n] = data
    lanesT = padded.reshape(n_blocks, block).T
    rem = np.minimum(n - np.arange(n_blocks) * block, block).astype(np.int32)
    host_outs, _ = E._fse_enc([Stream(data, SType.SERIAL, 1)], {})
    for up in (True, False):
        vals, goffs, state, bitpos, byte_off = ops.fse_encode(
            jnp.asarray(lanesT),
            jnp.asarray(rem),
            jnp.asarray(nb0t.astype(np.int32)),
            jnp.asarray(thrt.astype(np.int32)),
            jnp.asarray(st0t.astype(np.int32)),
            jnp.asarray(norm.astype(np.int32)),
            jnp.asarray(enc_table.reshape(-1)),
            width,
            total,
            use_pallas=up,
        )
        tb = int(byte_off[-1])
        stream = np.asarray(
            ops.pack_bits(vals.reshape(-1), goffs.reshape(-1), 1 << 17)
        )[:tb]
        assert stream.tobytes() == host_outs[0].content_bytes()
        meta = np.empty(n_blocks * 2, np.uint32)
        meta[0::2] = np.asarray(bitpos).astype(np.uint32)
        meta[1::2] = np.asarray(state).astype(np.uint32)
        assert meta.tobytes() == host_outs[1].content_bytes()


@pytest.mark.parametrize("n", [1, 100, 1025, 50000])
def test_fse_decode_kernel_roundtrip(n):
    """Device forward walk over host-encoded lanes recovers the input."""
    from repro.codecs import entropy as E
    from repro.core.message import Stream, SType

    table_log = 11
    data, norm, (dec_sym, dec_nb, dec_base, _enc) = _fse_fixture(n, table_log)
    host_outs, _ = E._fse_enc([Stream(data, SType.SERIAL, 1)], {})
    meta = np.frombuffer(host_outs[1].content_bytes(), np.uint32)
    bitlen = meta[0::2].astype(np.int64)
    n_blocks = bitlen.size
    block = 1 << E.FSE_BLOCK_LOG
    nbytes = (bitlen + 7) // 8
    offsets = np.zeros(n_blocks + 1, np.int64)
    np.cumsum(nbytes, out=offsets[1:])
    cap = int(nbytes.max()) + 16
    flat = np.zeros(n_blocks * cap, np.uint8)
    lane_base = np.arange(n_blocks, dtype=np.int64) * cap
    intra = np.arange(int(offsets[-1]), dtype=np.int64) - np.repeat(
        offsets[:-1], nbytes
    )
    flat[np.repeat(lane_base, nbytes) + intra] = np.frombuffer(
        host_outs[0].content_bytes(), np.uint8
    )
    rem = np.minimum(n - np.arange(n_blocks) * block, block)
    for up in (True, False):
        out = np.asarray(
            ops.fse_decode(
                jnp.asarray(flat),
                jnp.asarray(lane_base.astype(np.int32)),
                jnp.asarray(bitlen.astype(np.int32)),
                jnp.asarray(meta[1::2].astype(np.int32)),
                jnp.asarray(dec_sym.astype(np.int32)),
                jnp.asarray(dec_nb),
                jnp.asarray(dec_base),
                int(rem.max()),
                use_pallas=up,
            )
        )
        lanes = out.T
        result = np.concatenate([lanes[:-1].reshape(-1), lanes[-1, : rem[-1]]])
        np.testing.assert_array_equal(result, data)


def test_histogram_exact_is_exact():
    x = _skewed(200000)
    np.testing.assert_array_equal(
        np.asarray(ops.histogram_exact(jnp.asarray(x))),
        np.bincount(x, minlength=256).astype(np.int32),
    )
