"""Hypothesis, or inert stand-ins when the dependency is missing.

Modules that mix property tests with deterministic tests import from here so
they still *collect* without hypothesis: each ``@given`` test then guards
itself with ``pytest.importorskip("hypothesis")`` at call time (a clean skip),
while the deterministic tests in the same module keep running.  Install
``requirements-dev.txt`` to run the full property suite.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Absorbs any strategy-building expression at module import time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _Strategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stub: pytest must not mistake the property's value
            # parameters for fixtures
            def skipper():
                pytest.importorskip("hypothesis")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
