"""The compression daemon end to end: registry, session pool, byte-identity.

The deployment claim under test (paper §VIII): a long-lived service holding
registered plans serves many concurrent clients and emits frames
**byte-identical** to the offline CLI for the same plan and chunk settings —
sessions change *when* work happens, never the wire bytes.
"""
import threading

import numpy as np
import pytest

from repro.codecs import profiles as P
from repro.core import (
    Compressor,
    CompressorSession,
    SessionPool,
    compress,
    decompress_bytes,
    pipeline,
    serial,
)
from repro.core.serialize import plan_digest
from repro.service import (
    CompressionServer,
    PlanRegistry,
    ServiceClient,
)

DATA = (b"req=deadbeef level=INFO svc=auth handled in 42us\n" * 800)  # ~39 KB
CHUNK = 8 << 10


@pytest.fixture()
def server(tmp_path):
    registry = PlanRegistry()
    registry.register_profile("text")
    registry.register_profile("generic")
    srv = CompressionServer(
        registry,
        socket_path=str(tmp_path / "ozl.sock"),
        max_clients=8,
        sessions_per_plan=2,
        request_timeout=20.0,
    )
    with srv:
        yield srv


# ---------------------------------------------------------------- registry
def test_registry_addressing(tmp_path):
    reg = PlanRegistry()
    entry = reg.register_profile("text")
    assert reg.resolve("text") is entry
    assert reg.resolve(entry.digest) is entry
    assert reg.resolve(entry.digest[:12]) is entry  # unique prefix
    with pytest.raises(KeyError):
        reg.resolve("nope")
    with pytest.raises(KeyError):
        reg.resolve(entry.digest[:4])  # prefix too short to be an address
    # idempotent re-registration; conflicting id rejected
    assert reg.register_profile("text") is entry
    with pytest.raises(ValueError):
        reg.register_profile("generic", plan_id="text")
    assert "text" in reg and entry.digest in reg and len(reg) == 1


def test_registry_file_roundtrip(tmp_path):
    comp = Compressor(P.numeric_profile(), level=7, name="nums")
    path = tmp_path / "nums.ozp"
    path.write_bytes(comp.serialize())
    reg = PlanRegistry()
    entry = reg.register_file(path)
    assert entry.plan_id == "nums"
    assert entry.compressor.level == 7
    assert entry.digest == plan_digest(comp.plan, format_version=comp.format_version, level=comp.level)
    assert entry.describe()["source"] == f"file:{path}"


def test_registry_digest_tracks_output_knobs():
    """Same topology, different level -> different content address."""
    plan = P.text_profile()
    a = plan_digest(plan, format_version=4, level=5)
    b = plan_digest(plan, format_version=4, level=9)
    c = plan_digest(plan, format_version=3, level=5)
    assert len({a, b, c}) == 3


# ------------------------------------------------------------- session pool
def test_session_pool_checkout_and_reuse():
    plan = pipeline("zlib_backend")
    with SessionPool(max_per_key=2) as pool:
        pool.register("k", lambda: CompressorSession(plan))
        with pool.acquire("k") as s1:
            frame = s1.compress(serial(b"hello"), chunk_bytes=0)
            assert decompress_bytes(frame) == b"hello"
        with pool.acquire("k") as s2:
            assert s2 is s1  # returned sessions are reused, not rebuilt
        st = pool.stats()["k"]
        assert st == {
            "created": 1, "idle": 1, "in_use": 0,
            "acquires": 2, "creates": 1, "waits": 0, "drops": 0,
        }


def test_session_pool_blocks_at_capacity_and_unblocks():
    plan = pipeline("zlib_backend")
    with SessionPool(max_per_key=1) as pool:
        pool.register("k", lambda: CompressorSession(plan))
        release = threading.Event()
        acquired = threading.Event()

        def hold():
            with pool.acquire("k"):
                acquired.set()
                release.wait(5)

        t = threading.Thread(target=hold)
        t.start()
        assert acquired.wait(5)
        with pytest.raises(TimeoutError):
            with pool.acquire("k", timeout=0.05):
                pass
        release.set()
        t.join(5)
        with pool.acquire("k", timeout=5):
            pass  # freed capacity is observable
        assert pool.stats()["k"]["waits"] >= 1


def test_session_pool_drops_poisoned_sessions():
    plan = pipeline("zlib_backend")
    with SessionPool(max_per_key=1) as pool:
        pool.register("k", lambda: CompressorSession(plan))
        with pytest.raises(RuntimeError):
            with pool.acquire("k"):
                raise RuntimeError("request blew up mid-session")
        st = pool.stats()["k"]
        assert st["created"] == 0 and st["drops"] == 1
        with pool.acquire("k") as s:  # a fresh session takes its place
            assert s.compress(serial(b"x"), chunk_bytes=0)


def test_session_pool_unknown_key():
    with SessionPool() as pool:
        with pytest.raises(KeyError):
            with pool.acquire("ghost"):
                pass


def test_session_pool_close_unblocks_waiter():
    """close() must wake a blocked acquire with a clean KeyError, not wedge
    it or crash it with an internal lookup error."""
    plan = pipeline("zlib_backend")
    pool = SessionPool(max_per_key=1)
    pool.register("k", lambda: CompressorSession(plan))
    holding = threading.Event()
    release = threading.Event()
    waiter_result = {}

    def holder():
        with pool.acquire("k"):
            holding.set()
            release.wait(5)

    def waiter():
        try:
            with pool.acquire("k", timeout=10):
                waiter_result["outcome"] = "acquired"
        except KeyError as err:
            waiter_result["outcome"] = f"KeyError: {err}"

    t1 = threading.Thread(target=holder)
    t2 = threading.Thread(target=waiter)
    t1.start()
    assert holding.wait(5)
    t2.start()
    while pool.stats().get("k", {}).get("waits", 0) == 0:
        pass  # the waiter is provably blocked before we close
    pool.close()
    release.set()
    t1.join(5)
    t2.join(5)
    assert "KeyError" in waiter_result["outcome"]


def test_registry_bad_profile_spec_raises_value_error():
    reg = PlanRegistry()
    with pytest.raises(ValueError, match="unknown profile"):
        reg.register_profile("not-a-profile")


# ----------------------------------------------------------- service e2e
def _offline(profile_factory, data: bytes, chunk: int) -> bytes:
    return compress(profile_factory(), serial(data), chunk_bytes=chunk or None)


@pytest.mark.parametrize("chunk", [0, CHUNK], ids=["single", "chunked"])
def test_service_byte_identical_to_offline(server, chunk):
    with ServiceClient(server.address) as c:
        frame, info = c.compress_bytes(DATA, "text", chunk_bytes=chunk)
        assert frame == _offline(P.text_profile, DATA, chunk)
        assert info["bytes_in"] == len(DATA)
        assert info["container"] == bool(chunk)
        back, dinfo = c.decompress_bytes(frame)
        assert back == DATA
        assert dinfo["bytes_out"] == len(DATA)


def test_service_plan_by_digest(server):
    entry = server.registry.resolve("generic")
    with ServiceClient(server.address) as c:
        frame, info = c.compress_bytes(DATA, entry.digest, chunk_bytes=CHUNK)
        assert info["plan_id"] == "generic"
        assert frame == _offline(P.generic_profile, DATA, CHUNK)


def test_service_file_paths_and_in_place(server, tmp_path):
    src = tmp_path / "corpus.bin"
    src.write_bytes(DATA)
    dst = tmp_path / "corpus.ozl"
    with ServiceClient(server.address) as c:
        stats = c.compress_file(src, dst, "text", chunk_bytes=CHUNK)
        assert stats["chunks"] == -(-len(DATA) // CHUNK)
        assert dst.read_bytes() == _offline(P.text_profile, DATA, CHUNK)
        # in-place through the service client: no data loss either
        c.compress_file(src, src, "text", chunk_bytes=CHUNK)
        assert src.read_bytes() == dst.read_bytes()
        c.decompress_file(src, src)
        assert src.read_bytes() == DATA


def test_service_compress_without_size_header(server, tmp_path):
    """A file-object source sends no 'size' header: the server must take the
    unknown-length path (no AttributeError on the minimal body reader) and
    still produce a decodable, lossless frame."""
    import io as _io

    with ServiceClient(server.address) as c:
        for chunk in (0, CHUNK):
            dst = tmp_path / f"nosize{chunk}.ozl"
            stats = c.compress_file(
                _io.BytesIO(DATA), dst, "text", chunk_bytes=chunk
            )
            assert stats["bytes_in"] == len(DATA)
            back, _ = c.decompress_bytes(dst.read_bytes())
            assert back == DATA


def test_service_concurrent_clients_byte_identical(server):
    """8 concurrent clients, interleaved plans: every frame matches offline."""
    want = {
        "text": _offline(P.text_profile, DATA, CHUNK),
        "generic": _offline(P.generic_profile, DATA, CHUNK),
    }
    results = [None] * 8
    errors = []

    def worker(i):
        plan = "text" if i % 2 == 0 else "generic"
        try:
            with ServiceClient(server.address) as c:
                for _ in range(3):  # several requests per connection
                    frame, _ = c.compress_bytes(DATA, plan, chunk_bytes=CHUNK)
                    assert frame == want[plan]
                    back, _ = c.decompress_bytes(frame)
                    assert back == DATA
            results[i] = True
        except Exception as err:  # pragma: no cover - failure reporting
            errors.append((i, err))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errors, errors
    assert all(results)
    st = server.stats()
    assert st["requests"]["compress"] == 24
    assert st["errors"] == 0
    for key_stats in st["sessions"].values():
        assert key_stats["in_use"] == 0  # every session returned to the pool
        assert key_stats["created"] <= server.pool.max_per_key


def test_service_trained_plan_deploys(tmp_path):
    """A serialized .ozp plan registered at serve time compresses identically
    to `compress --plan` offline."""
    comp = Compressor(
        pipeline(("zlib_backend", {"level": 6})), name="trained", level=6
    )
    ozp = tmp_path / "trained.ozp"
    ozp.write_bytes(comp.serialize())
    payload = np.cumsum(
        np.random.default_rng(3).integers(0, 9, 40_000)
    ).astype(np.uint32).tobytes()
    registry = PlanRegistry()
    registry.register_file(ozp)
    with CompressionServer(
        registry, socket_path=str(tmp_path / "t.sock")
    ) as srv:
        with ServiceClient(srv.address) as c:
            frame, info = c.compress_bytes(payload, "trained", chunk_bytes=CHUNK)
    reloaded = Compressor.deserialize(ozp.read_bytes())
    assert frame == reloaded.compress(serial(payload), chunk_bytes=CHUNK)
    assert decompress_bytes(frame) == payload


# ------------------------------------------------------------ error handling
def test_service_unknown_plan_keeps_connection(server):
    with ServiceClient(server.address) as c:
        with pytest.raises(RuntimeError, match="unknown plan"):
            c.compress_bytes(DATA, "no-such-plan")
        # the same connection still serves the next request
        frame, _ = c.compress_bytes(DATA, "text", chunk_bytes=CHUNK)
        assert frame == _offline(P.text_profile, DATA, CHUNK)
    assert server.stats()["errors"] == 1


def _hostile_compress(c, header):
    """Send a size-lying request; return the error response header, or None
    when the server dropped the connection instead (an equally valid
    rejection — and a race the client must tolerate: a fast-failing server
    may slam the door while our body is still in flight, surfacing as
    EPIPE/ECONNRESET on the *write* side)."""
    import repro.service.protocol as P_

    try:
        P_.write_request(c._w, P_.VERB_COMPRESS, header, P_.iter_body_blocks(DATA))
    except (BrokenPipeError, ConnectionResetError):
        return None
    try:
        got = P_.read_response_or_eof(c._r)
    except (BrokenPipeError, ConnectionResetError):
        return None
    if got is None:
        return None
    status, resp, body = got
    body.drain()
    assert status == P_.STATUS_ERROR
    return resp


def test_service_size_lies_rejected(server):
    """A declared size that disagrees with the body must fail, not silently
    compress a truncated or padded payload."""
    with ServiceClient(server.address) as c:
        # understate: extra bytes beyond the declared size
        _hostile_compress(c, {"plan": "text", "size": 10, "chunk_bytes": 0})
    with ServiceClient(server.address) as c:
        # overstate: body ends before the declared size
        _hostile_compress(
            c, {"plan": "text", "size": len(DATA) * 2, "chunk_bytes": CHUNK}
        )
    with ServiceClient(server.address) as c:
        # overstate by so little that the promised chunk count still matches:
        # only true byte accounting (not the chunk-count check) catches this
        assert len(DATA) % CHUNK != 0
        resp = _hostile_compress(
            c, {"plan": "text", "size": len(DATA) + 1, "chunk_bytes": CHUNK}
        )
        if resp is not None:
            assert "declared size" in resp.get("error", "")
    # the daemon is still healthy
    with ServiceClient(server.address) as c:
        assert c.ping()["ok"]


def test_service_multibyte_memoryview_payload(server):
    """len(memoryview) counts elements, not bytes, for itemsize > 1 — the
    declared size and block slicing must use byte counts (regression: an
    int64 view declared 1/8th of its bytes and tripped the body limit)."""
    arr = np.arange(1000, dtype=np.int64)
    with ServiceClient(server.address) as c:
        frame, info = c.compress_bytes(memoryview(arr), "generic", chunk_bytes=CHUNK)
        assert info["bytes_in"] == arr.nbytes
        back, _ = c.decompress_bytes(frame)
        assert back == arr.tobytes()


def test_idle_client_reconnects_transparently(tmp_path):
    """The server drops connections idle past idle_timeout (a *separate*,
    longer knob than request_timeout); a persistent client's next call must
    succeed anyway via transparent reconnect — for in-memory and (seekable)
    file bodies alike — instead of dying on 'connection closed mid-message'."""
    import time

    registry = PlanRegistry()
    registry.register_profile("generic")
    srv = CompressionServer(
        registry,
        socket_path=str(tmp_path / "idle.sock"),
        request_timeout=20.0,
        idle_timeout=0.3,
    )
    with srv:
        src = tmp_path / "in.bin"
        src.write_bytes(DATA)
        with ServiceClient(srv.address, timeout=10.0) as c:
            frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=CHUNK)
            time.sleep(1.0)  # provably past the idle cutoff
            frame2, _ = c.compress_bytes(DATA, "generic", chunk_bytes=CHUNK)
            assert frame2 == frame
            time.sleep(1.0)
            dst = tmp_path / "out.ozl"
            c.compress_file(src, dst, "generic", chunk_bytes=CHUNK)
            assert dst.read_bytes() == frame
        # each idle drop forced a fresh connection
        assert srv.stats()["connections"] >= 3


def test_service_decompress_garbage_rejected(server):
    with ServiceClient(server.address) as c:
        with pytest.raises(RuntimeError):
            c.decompress_bytes(b"OZLJ this is not a real frame")
        assert c.ping()["ok"]


def test_service_stats_shape(server):
    with ServiceClient(server.address) as c:
        c.compress_bytes(DATA, "text", chunk_bytes=CHUNK)
        st = c.stats()
    assert st["protocol_version"] == 1
    assert st["requests"]["compress"] == 1
    assert {e["plan_id"] for e in st["registry"]} == {"text", "generic"}
    for e in st["registry"]:
        assert len(e["digest"]) == 64
    assert st["bytes_in"] == len(DATA)


def test_service_stats_expose_cache_counters(tmp_path):
    """The stats verb surfaces resolve-cache and coder-table-cache hit/miss
    counters, and repeated same-shape requests actually hit both caches."""
    comp = Compressor(pipeline("huffman", "fse"), name="entropy")
    ozp = tmp_path / "entropy.ozp"
    ozp.write_bytes(comp.serialize())
    registry = PlanRegistry()
    registry.register_file(ozp)
    with CompressionServer(
        registry, socket_path=str(tmp_path / "ozl.sock")
    ) as srv:
        with ServiceClient(srv.address) as c:
            c.compress_bytes(DATA, "entropy")
            cold = c.stats()
            c.compress_bytes(DATA, "entropy")
            warm = c.stats()
    for st in (cold, warm):
        for key in ("resolve_cache", "coder_cache"):
            assert {"hits", "misses"} <= set(st[key]), st[key]
    # the second identical request re-uses the first one's resolution and
    # coder tables: both hit counters must move
    assert warm["resolve_cache"]["hits"] > cold["resolve_cache"]["hits"]
    assert warm["coder_cache"]["hits"] > cold["coder_cache"]["hits"]


def test_service_tcp_transport(tmp_path):
    registry = PlanRegistry()
    registry.register_profile("generic")
    with CompressionServer(registry, host="127.0.0.1", port=0) as srv:
        assert ":" in srv.address
        with ServiceClient(srv.address) as c:
            frame, _ = c.compress_bytes(b"tcp payload " * 100, "generic")
            back, _ = c.decompress_bytes(frame)
            assert back == b"tcp payload " * 100
