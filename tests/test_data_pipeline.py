"""Data pipeline: compressed shards, straggler-tolerant prefetch, resume,
GNN neighbour sampler."""
import time

import numpy as np
import pytest

from repro.data import CompressedShardStore, CSRGraph, Prefetcher, Straggler, sample_subgraph
from repro.data.synthetic import random_graph, zipf_tokens

rng = np.random.default_rng(0)


def test_shard_store_roundtrip_and_ratio(tmp_path):
    store = CompressedShardStore(tmp_path)
    toks = zipf_tokens(100_000, vocab=32000, seed=1)
    meta = store.write_shard(0, {"tokens": toks})
    assert meta["compressed_bytes"] < meta["raw_bytes"] * 0.7  # zipf compresses
    back = store.read_shard(0)
    assert np.array_equal(back["tokens"], toks)
    assert store.stats()["ratio"] > 1.4


def test_shard_store_corruption_detected(tmp_path):
    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"x": np.arange(1000, dtype=np.int64)})
    f = next((tmp_path / "shard_000000").glob("x.ozl"))
    blob = bytearray(f.read_bytes())
    blob[10] ^= 0xFF
    f.write_bytes(bytes(blob))
    with pytest.raises((IOError, ValueError)):
        store.read_shard(0)


def test_prefetcher_orders_and_resumes(tmp_path):
    store = CompressedShardStore(tmp_path)
    for i in range(4):
        store.write_shard(i, {"x": np.full(10, i, np.int64)})
    pf = Prefetcher(store.read_shard, store.shard_ids(), start_cursor=2)
    try:
        first = pf.next(timeout=10)
        assert first["shard"] == 2  # resumed at the checkpointed cursor
        second = pf.next(timeout=10)
        assert second["shard"] == 3
        third = pf.next(timeout=10)
        assert third["shard"] == 0  # wraps to next epoch
    finally:
        pf.stop()


def test_prefetcher_straggler_timeout():
    def slow_load(idx):
        time.sleep(5.0)
        return idx

    pf = Prefetcher(slow_load, [0, 1], depth=1)
    try:
        with pytest.raises(Straggler):
            pf.next(timeout=0.2)
    finally:
        pf.stop()


def test_prefetcher_skips_damaged_shard():
    def load(idx):
        if idx == 1:
            raise IOError("corrupt")
        return idx

    pf = Prefetcher(load, [0, 1, 2])
    try:
        got = [pf.next(timeout=10)["shard"] for _ in range(3)]
        assert 1 not in got[:2]
        assert 1 in pf.state()["skipped"]
    finally:
        pf.stop()


# --------------------------------------------------------------- GNN sampler
def test_neighbor_sampler_shapes_and_validity():
    g = random_graph(5000, 40000, d_feat=8, d_out=4, seed=0)
    csr = CSRGraph.from_edges(g["edges"], 5000)
    seeds = rng.choice(5000, 64, replace=False)
    sub = sample_subgraph(
        csr, g["nodes"], g["targets"], seeds, [5, 3],
        pad_nodes=64 + 64 * 5 + 64 * 15, pad_edges=64 * 5 + 64 * 15,
    )
    assert sub["nodes"].shape[0] == 64 + 64 * 5 + 64 * 15
    assert sub["edges"].max() < sub["nodes"].shape[0]
    # seeds occupy local ids [0, 64) and carry the loss mask
    assert sub["node_mask"][:64].all() and not sub["node_mask"][64:].any()
    np.testing.assert_allclose(sub["nodes"][:64], g["nodes"][seeds])
    # every valid edge's dst features match the global graph
    valid = sub["edge_mask"] > 0
    assert valid.sum() > 0


def test_sampler_respects_fanout_budget():
    g = random_graph(1000, 8000, d_feat=4, d_out=2, seed=1)
    csr = CSRGraph.from_edges(g["edges"], 1000)
    seeds = np.arange(16)
    sub = sample_subgraph(
        csr, g["nodes"], g["targets"], seeds, [15, 10],
        pad_nodes=16 + 16 * 15 + 16 * 150, pad_edges=16 * 15 + 16 * 150,
    )
    assert (sub["edge_mask"].sum()) <= 16 * 15 + 16 * 150
