"""Data pipeline: compressed shards, straggler-tolerant prefetch, resume,
GNN neighbour sampler."""
import time

import numpy as np
import pytest

from repro.data import CompressedShardStore, CSRGraph, Prefetcher, Straggler, sample_subgraph
from repro.data.synthetic import random_graph, zipf_tokens

rng = np.random.default_rng(0)


def test_shard_store_roundtrip_and_ratio(tmp_path):
    store = CompressedShardStore(tmp_path)
    toks = zipf_tokens(100_000, vocab=32000, seed=1)
    meta = store.write_shard(0, {"tokens": toks})
    assert meta["compressed_bytes"] < meta["raw_bytes"] * 0.7  # zipf compresses
    back = store.read_shard(0)
    assert np.array_equal(back["tokens"], toks)
    assert store.stats()["ratio"] > 1.4


def test_shard_store_corruption_detected(tmp_path):
    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"x": np.arange(1000, dtype=np.int64)})
    f = next((tmp_path / "shard_000000").glob("x.ozl"))
    blob = bytearray(f.read_bytes())
    blob[10] ^= 0xFF
    f.write_bytes(bytes(blob))
    with pytest.raises((IOError, ValueError)):
        store.read_shard(0)


def test_shard_store_rewrite_atomic(tmp_path):
    """Rewriting an existing shard idx must replace it, not crash.

    Regression: the old fixed-name tmp dir was ``os.replace``d onto an
    existing non-empty shard dir -> ``OSError: Directory not empty``.
    """
    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"x": np.arange(100, dtype=np.int64)})
    meta = store.write_shard(
        0, {"y": np.arange(50, dtype=np.int64), "z": np.ones(8, np.float32)}
    )
    assert [e["name"] for e in meta["entries"]] == ["y", "z"]
    back = store.read_shard(0)
    assert set(back) == {"y", "z"}
    assert np.array_equal(back["y"], np.arange(50, dtype=np.int64))
    # the old entry's payload is gone from disk, not just from meta.json
    assert not (tmp_path / "shard_000000" / "x.ozl").exists()
    assert store.shard_ids() == [0]
    assert not list(tmp_path.glob("*.tmp"))


def test_shard_store_stale_tmp_recovery(tmp_path):
    """A crashed writer's leftover tmp dir must neither leak its orphan
    entries into the next write nor survive it — while a *live* concurrent
    writer's fresh staging dir must be left alone (age-gated sweep)."""
    import os

    store = CompressedShardStore(tmp_path)
    # simulate both tmp generations a crash can leave behind, aged past the
    # staleness cutoff (crashed writers stop touching their dirs)
    old = time.time() - store.STALE_TMP_SECONDS - 60
    legacy = tmp_path / "shard_000000.tmp"
    legacy.mkdir()
    (legacy / "orphan.ozl").write_bytes(b"stale bytes from a dead writer")
    stale = tmp_path / "shard_000000.abc123.tmp"
    stale.mkdir()
    (stale / "meta.json").write_text("{}")
    for d in (legacy, stale):
        os.utime(d, (old, old))
    live = tmp_path / "shard_000000.def456.tmp"  # a concurrent writer, now
    live.mkdir()
    meta = store.write_shard(0, {"tokens": np.arange(64, dtype=np.int64)})
    assert [e["name"] for e in meta["entries"]] == ["tokens"]
    back = store.read_shard(0)
    assert set(back) == {"tokens"}  # orphans never surface through read_shard
    assert not (tmp_path / "shard_000000" / "orphan.ozl").exists()
    assert not legacy.exists() and not stale.exists()
    assert live.exists()  # in-flight staging of another writer untouched
    # tmp dirs never show up as shards, before or after cleanup
    assert store.shard_ids() == [0]


def test_shard_store_crash_between_renames_recovers(tmp_path):
    """A crash in the rewrite's rename-aside window leaves only the aside
    copy; reads and writes must promote it back, and the sweep must never
    delete it while the canonical dir is missing."""
    import os

    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"a": np.arange(20, dtype=np.int64)})
    final = tmp_path / "shard_000000"
    aside = tmp_path / "shard_000000.old.crash.tmp"
    os.replace(final, aside)  # simulate: crashed after rename-aside
    # even an old aside is protected while the canonical dir is missing
    old = time.time() - store.STALE_TMP_SECONDS - 60
    os.utime(aside, (old, old))
    assert store._stale_tmps(0) == []
    back = store.read_shard(0)  # read self-heals from the aside
    assert np.array_equal(back["a"], np.arange(20, dtype=np.int64))
    assert final.exists() and not aside.exists()


def test_shard_store_recovery_tolerates_vanishing_asides(tmp_path, monkeypatch):
    """An aside dir a concurrent process promotes or sweeps between glob and
    stat must be skipped, not crash recovery (regression: the mtime sort
    raised OSError on the vanished entry)."""
    import os
    from pathlib import Path

    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"a": np.arange(20, dtype=np.int64)})
    final = tmp_path / "shard_000000"
    keep = tmp_path / "shard_000000.old.keep.tmp"
    os.replace(final, keep)  # crash-after-rename-aside, as in the test above
    ghost = tmp_path / "shard_000000.old.ghost.tmp"
    ghost.mkdir()
    now = time.time()
    os.utime(ghost, (now - 100, now - 100))  # keep is newest: it must win

    real_stat = Path.stat
    calls = {"n": 0}

    def flaky_stat(self, *a, **kw):
        if self.name == ghost.name:
            calls["n"] += 1
            if calls["n"] > 1:  # is_dir()'s stat sees it; the mtime stat doesn't
                raise FileNotFoundError(str(self))
        return real_stat(self, *a, **kw)

    monkeypatch.setattr(Path, "stat", flaky_stat)
    back = store.read_shard(0)  # recovery skips the ghost, promotes keep
    assert np.array_equal(back["a"], np.arange(20, dtype=np.int64))
    assert final.exists() and not keep.exists()
    assert calls["n"] > 1  # the vanish was actually exercised


def test_shard_store_rewrite_survives_reader_promoting_aside(tmp_path, monkeypatch):
    """A reader whose _recover_aside promotes the aside back *into* the
    rewrite's rename gap must not crash the writer or lose the staged data
    (regression: os.replace onto the refilled dir raised ENOTEMPTY and the
    cleanup deleted the new shard) — the writer re-renames and retries."""
    import os

    store = CompressedShardStore(tmp_path)
    store.write_shard(0, {"a": np.arange(20, dtype=np.int64)})
    final = tmp_path / "shard_000000"

    real_replace = os.replace
    raced = {"n": 0}

    def racy_replace(src, dst, *a, **kw):
        # first tmp -> final swap of the rewrite: simulate a concurrent
        # reader promoting the aside back just before it lands
        if (
            str(dst) == str(final)
            and str(src).endswith(".tmp")
            and ".old." not in str(src)
            and raced["n"] == 0
        ):
            raced["n"] = 1
            aside = next(tmp_path.glob("shard_000000.old.*.tmp"))
            real_replace(aside, final)
        return real_replace(src, dst, *a, **kw)

    monkeypatch.setattr(os, "replace", racy_replace)
    meta = store.write_shard(0, {"b": np.arange(7, dtype=np.int64)})
    assert raced["n"] == 1  # the race was actually injected
    assert [e["name"] for e in meta["entries"]] == ["b"]
    back = store.read_shard(0)  # the writer's new data won
    assert set(back) == {"b"}
    assert not list(tmp_path.glob("*.tmp"))  # no aside or staging left behind


def test_shard_store_read_ignores_orphan_entries(tmp_path):
    """read_shard trusts meta.json, not the directory listing."""
    store = CompressedShardStore(tmp_path)
    store.write_shard(3, {"a": np.arange(10, dtype=np.int64)})
    (tmp_path / "shard_000003" / "rogue.ozl").write_bytes(b"not in meta")
    back = store.read_shard(3)
    assert set(back) == {"a"}
    stats = store.stats()
    assert stats["raw_bytes"] == 80  # rogue bytes not accounted


def test_prefetcher_orders_and_resumes(tmp_path):
    store = CompressedShardStore(tmp_path)
    for i in range(4):
        store.write_shard(i, {"x": np.full(10, i, np.int64)})
    pf = Prefetcher(store.read_shard, store.shard_ids(), start_cursor=2)
    try:
        first = pf.next(timeout=10)
        assert first["shard"] == 2  # resumed at the checkpointed cursor
        second = pf.next(timeout=10)
        assert second["shard"] == 3
        third = pf.next(timeout=10)
        assert third["shard"] == 0  # wraps to next epoch
    finally:
        pf.stop()


def test_prefetcher_straggler_timeout():
    def slow_load(idx):
        time.sleep(5.0)
        return idx

    pf = Prefetcher(slow_load, [0, 1], depth=1)
    try:
        with pytest.raises(Straggler):
            pf.next(timeout=0.2)
    finally:
        pf.stop()


def test_prefetcher_skips_damaged_shard():
    def load(idx):
        if idx == 1:
            raise IOError("corrupt")
        return idx

    pf = Prefetcher(load, [0, 1, 2])
    try:
        got = [pf.next(timeout=10)["shard"] for _ in range(3)]
        assert 1 not in got[:2]
        assert 1 in pf.state()["skipped"]
    finally:
        pf.stop()


# --------------------------------------------------------------- GNN sampler
def test_neighbor_sampler_shapes_and_validity():
    g = random_graph(5000, 40000, d_feat=8, d_out=4, seed=0)
    csr = CSRGraph.from_edges(g["edges"], 5000)
    seeds = rng.choice(5000, 64, replace=False)
    sub = sample_subgraph(
        csr, g["nodes"], g["targets"], seeds, [5, 3],
        pad_nodes=64 + 64 * 5 + 64 * 15, pad_edges=64 * 5 + 64 * 15,
    )
    assert sub["nodes"].shape[0] == 64 + 64 * 5 + 64 * 15
    assert sub["edges"].max() < sub["nodes"].shape[0]
    # seeds occupy local ids [0, 64) and carry the loss mask
    assert sub["node_mask"][:64].all() and not sub["node_mask"][64:].any()
    np.testing.assert_allclose(sub["nodes"][:64], g["nodes"][seeds])
    # every valid edge's dst features match the global graph
    valid = sub["edge_mask"] > 0
    assert valid.sum() > 0


def test_sampler_respects_fanout_budget():
    g = random_graph(1000, 8000, d_feat=4, d_out=2, seed=1)
    csr = CSRGraph.from_edges(g["edges"], 1000)
    seeds = np.arange(16)
    sub = sample_subgraph(
        csr, g["nodes"], g["targets"], seeds, [15, 10],
        pad_nodes=16 + 16 * 15 + 16 * 150, pad_edges=16 * 15 + 16 * 150,
    )
    assert (sub["edge_mask"].sum()) <= 16 * 15 + 16 * 150
