"""Streaming-session tests (ISSUE 3 tentpole + satellites).

Invariants:
  * ``CompressorSession`` output is byte-identical to the one-shot
    ``compress()`` for chunked and unchunked inputs, warm or cold.
  * Session roundtrips cross chunk boundaries for NUMERIC/STRUCT/STRING.
  * The vectorized STRING ``_split_chunks`` matches the scalar reference on
    ragged inputs (zero-length strings, oversize strings, exact boundaries).
  * ``stream_io.compress_file`` never loads the input whole, produces the
    same bytes as the in-memory path, and the in-flight window bounds
    concurrency.
  * The ``python -m repro`` CLI compresses/inspects/decompresses end to end.
"""
import io

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.codecs import generic_profile, text_profile
from repro.core import (
    Compressor,
    CompressorSession,
    DecompressorSession,
    compress,
    decompress,
    numeric,
    pipeline,
    serial,
    strings,
    struct,
)
from repro.core import stream_io
from repro.core.engine import _split_chunks
from repro.core.message import Stream, SType


def _scalar_split_strings(s: Stream, chunk_bytes: int):
    """The pre-vectorization per-string loop, kept as the reference."""
    out = []
    lens = s.lengths if s.lengths is not None else np.zeros(0, np.uint32)
    i, off = 0, 0
    while i < lens.size:
        j, nb = i, 0
        while j < lens.size and (j == i or nb + int(lens[j]) <= chunk_bytes):
            nb += int(lens[j])
            j += 1
        out.append(Stream(s.data[off : off + nb], SType.STRING, 1, lens[i:j]))
        i, off = j, off + nb
    return out or [s]


# ----------------------------------------------------------- split equivalence
def _assert_same_split(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert np.array_equal(x.data, y.data)
        assert np.array_equal(x.lengths, y.lengths)


@pytest.mark.parametrize(
    "lens,chunk_bytes",
    [
        ([], 8),
        ([0, 0, 0], 4),
        ([5], 3),  # single oversize string
        ([10, 1, 1], 10),  # exact boundary then spill
        ([3, 3, 3, 3], 6),  # clean pairs
        ([0, 7, 0, 0, 2, 9, 0], 9),  # zeros around boundaries
        ([1] * 100, 1),  # one string per chunk
    ],
)
def test_split_chunks_string_matches_scalar_reference(lens, chunk_bytes):
    rng = np.random.default_rng(0)
    s = strings([bytes(rng.integers(0, 256, l, dtype=np.uint8)) for l in lens])
    _assert_same_split(
        _split_chunks(s, chunk_bytes), _scalar_split_strings(s, chunk_bytes)
    )


@given(st.data())
@settings(max_examples=120, deadline=None)
def test_split_chunks_string_matches_scalar_reference_fuzz(data):
    lens = data.draw(st.lists(st.integers(0, 33), max_size=60))
    chunk_bytes = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(1)
    s = strings([bytes(rng.integers(0, 256, l, dtype=np.uint8)) for l in lens])
    _assert_same_split(
        _split_chunks(s, chunk_bytes), _scalar_split_strings(s, chunk_bytes)
    )


# ------------------------------------------------------- session byte-identity
def test_session_byte_identical_to_oneshot_chunked():
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(60000, dtype=np.uint32))
    oneshot = compress(plan, data, chunk_bytes=4096)
    with CompressorSession(plan, chunk_bytes=4096) as sess:
        cold = sess.compress(data)
        warm = sess.compress(data)
        buf = io.BytesIO()
        n = sess.compress_to(data, buf)
    assert cold == oneshot and warm == oneshot
    assert buf.getvalue() == oneshot and n == len(oneshot)


def test_session_byte_identical_to_oneshot_unchunked():
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(5000, dtype=np.uint32))
    with CompressorSession(plan) as sess:
        assert sess.compress(data) == compress(plan, data)


def test_session_byte_identical_selector_profile():
    """Dynamic plans: selector expansion happens once per shape per session,
    yet every call's wire output matches the throwaway path."""
    prof = generic_profile()
    rng = np.random.default_rng(3)
    data = numeric(rng.integers(0, 40, 1 << 15, dtype=np.int64).cumsum().astype(np.uint32))
    oneshot = compress(prof, data, chunk_bytes=8192)
    with CompressorSession(prof, chunk_bytes=8192) as sess:
        assert sess.compress(data) == oneshot
        assert sess.compress(data) == oneshot


def test_decompressor_session_matches_module_decompress():
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(30000, dtype=np.uint32))
    frame = compress(plan, data, chunk_bytes=4096)
    with DecompressorSession() as sess:
        for _ in range(2):  # warm reuse
            (out,) = sess.decompress(frame)
            assert out.content_bytes() == data.content_bytes()
        (via_reader,) = sess.decompress_from(io.BytesIO(frame))
        assert via_reader.content_bytes() == data.content_bytes()


# ------------------------------------------- roundtrips across chunk boundaries
@pytest.mark.parametrize("chunk_bytes", [256, 1000, 4096])
def test_session_roundtrip_numeric_across_boundaries(chunk_bytes):
    rng = np.random.default_rng(5)
    data = numeric(rng.integers(0, 9999, 4001, dtype=np.uint16))
    with CompressorSession(generic_profile(), chunk_bytes=chunk_bytes) as sess:
        frame = sess.compress(data)
    (back,) = decompress(frame)
    assert back.stype == SType.NUMERIC and back.width == 2
    assert back.content_bytes() == data.content_bytes()


@pytest.mark.parametrize("chunk_bytes", [128, 777])
def test_session_roundtrip_struct_across_boundaries(chunk_bytes):
    rng = np.random.default_rng(6)
    data = struct(rng.integers(0, 256, 12 * 500, dtype=np.uint8).tobytes(), 12)
    with CompressorSession(generic_profile(), chunk_bytes=chunk_bytes) as sess:
        frame = sess.compress(data)
    (back,) = decompress(frame)
    assert back.stype == SType.STRUCT and back.width == 12
    assert back.content_bytes() == data.content_bytes()


@pytest.mark.parametrize("chunk_bytes", [64, 512])
def test_session_roundtrip_string_across_boundaries(chunk_bytes):
    rng = np.random.default_rng(7)
    items = [
        bytes(rng.integers(97, 123, int(l), dtype=np.uint8))
        for l in rng.integers(0, 40, 300)
    ]
    data = strings(items)
    with CompressorSession(generic_profile(), chunk_bytes=chunk_bytes) as sess:
        frame = sess.compress(data)
    (back,) = decompress(frame)
    assert back.stype == SType.STRING
    assert back.content_bytes() == data.content_bytes()
    assert np.array_equal(back.lengths, data.lengths)
    # and through the streaming reader
    with DecompressorSession() as dsess:
        (srt,) = dsess.decompress_from(io.BytesIO(frame))
    assert srt.content_bytes() == data.content_bytes()
    assert np.array_equal(srt.lengths, data.lengths)


# ------------------------------------------------------------- bounded window
def test_window_bounds_inflight_chunks():
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(100000, dtype=np.uint32))
    with CompressorSession(plan, chunk_bytes=1024, window=3) as sess:
        frame = sess.compress(data)
        assert sess.stats["chunks"] > 20
        assert 1 <= sess.stats["max_inflight"] <= 3
    assert frame == compress(plan, data, chunk_bytes=1024)


def test_decode_window_bounds_inflight_chunks():
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(100000, dtype=np.uint32))
    frame = compress(plan, data, chunk_bytes=1024)
    with DecompressorSession(window=2) as sess:
        (out,) = sess.decompress_from(io.BytesIO(frame))
        assert sess.stats["max_inflight"] <= 2
    assert out.content_bytes() == data.content_bytes()


# ------------------------------------------------------------------ stream_io
def test_compress_file_byte_identical_and_lazy(tmp_path):
    rng = np.random.default_rng(8)
    data = b"repeat me " * 30000 + bytes(rng.integers(0, 256, 10000, dtype=np.uint8))
    src = tmp_path / "in.bin"
    dst = tmp_path / "out.ozl"
    src.write_bytes(data)

    stats = stream_io.compress_file(src, dst, text_profile(), chunk_bytes=16384)
    assert stats["container"] and stats["chunks"] == -(-len(data) // 16384)
    assert dst.read_bytes() == compress(text_profile(), serial(data), chunk_bytes=16384)

    rt = tmp_path / "rt.bin"
    dstats = stream_io.decompress_file(dst, rt)
    assert rt.read_bytes() == data
    assert dstats["chunks"] == stats["chunks"]


def test_compress_file_small_input_bare_frame(tmp_path):
    data = b"tiny payload"
    src = tmp_path / "in.bin"
    dst = tmp_path / "out.ozl"
    src.write_bytes(data)
    stats = stream_io.compress_file(src, dst, text_profile(), chunk_bytes=1 << 20)
    assert not stats["container"]
    assert dst.read_bytes() == compress(text_profile(), serial(data))
    rt = tmp_path / "rt.bin"
    stream_io.decompress_file(dst, rt)
    assert rt.read_bytes() == data


def test_compress_file_unknown_length_source(tmp_path):
    """Non-seekable sources stream through the backpatching container mode;
    the result decodes identically (bytes differ only at the count field)."""

    class NoSeek:
        def __init__(self, b):
            self._f = io.BytesIO(b)

        def read(self, n=-1):
            return self._f.read(n)

        def seekable(self):
            return False

    data = b"0123456789abcdef" * 8192
    dst = tmp_path / "out.ozl"
    stats = stream_io.compress_file(
        NoSeek(data), dst, text_profile(), chunk_bytes=16384
    )
    assert stats["container"] and stats["bytes_in"] == len(data)
    rt = tmp_path / "rt.bin"
    stream_io.decompress_file(dst, rt)
    assert rt.read_bytes() == data


def test_session_reuse_across_files(tmp_path):
    """One long-lived session serving many files (the serve.py shape)."""
    plan = text_profile()
    with CompressorSession(plan, chunk_bytes=4096) as sess, DecompressorSession() as dsess:
        for i in range(3):
            data = (b"payload %d " % i) * 5000
            src = tmp_path / f"in{i}.bin"
            dst = tmp_path / f"out{i}.ozl"
            rt = tmp_path / f"rt{i}.bin"
            src.write_bytes(data)
            stream_io.compress_file(src, dst, plan, session=sess)
            stream_io.decompress_file(dst, rt, session=dsess)
            assert rt.read_bytes() == data
        assert sess.stats["calls"] == 3
        assert dsess.stats["chunks"] >= 3


def test_compress_file_rejects_mismatched_session_plan(tmp_path):
    from repro.codecs import numeric_profile

    src = tmp_path / "in.bin"
    src.write_bytes(b"x" * 100)
    with CompressorSession(text_profile(), chunk_bytes=64) as sess:
        with pytest.raises(ValueError, match="does not match"):
            stream_io.compress_file(src, tmp_path / "o", numeric_profile(), session=sess)


def test_compress_to_mirrors_compress_errors():
    data = numeric(np.arange(100, dtype=np.uint32))
    with CompressorSession(pipeline("delta", "range_pack"), chunk_bytes=64) as sess:
        with pytest.raises(ValueError, match="exactly one input"):
            sess.compress([data, data])
        with pytest.raises(ValueError, match="exactly one input"):
            sess.compress_to([data, data], io.BytesIO())


def test_compressor_session_helper():
    comp = Compressor(pipeline("delta", "range_pack"), chunk_bytes=2048, level=7)
    data = numeric(np.arange(20000, dtype=np.uint32))
    with comp.session() as sess:
        assert sess.compress(data) == comp.compress(data)
        assert sess.ctx.level == 7


# ------------------------------------------------------------------------ CLI
def test_cli_end_to_end(tmp_path, capsys):
    from repro.cli import main

    data = b"level=INFO svc=auth msg=handled in 42us\n" * 5000
    src = tmp_path / "corpus.bin"
    frame = tmp_path / "corpus.ozl"
    rt = tmp_path / "corpus.rt"
    src.write_bytes(data)

    assert main(
        ["compress", str(src), "-o", str(frame), "--profile", "text",
         "--chunk-bytes", "32KiB"]
    ) == 0
    assert main(["inspect", str(frame)]) == 0
    out = capsys.readouterr().out
    assert "container" in out and "zlib_backend" in out
    assert main(["decompress", str(frame), "-o", str(rt)]) == 0
    assert rt.read_bytes() == data


def test_cli_plan_roundtrip(tmp_path):
    from repro.cli import main

    plan_file = tmp_path / "trained.ozp"
    plan_file.write_bytes(Compressor(text_profile(), name="t").serialize())
    data = b"x,y,z\n1,2,3\n" * 2000
    src = tmp_path / "in.csv"
    frame = tmp_path / "in.ozl"
    rt = tmp_path / "in.rt"
    src.write_bytes(data)
    assert main(["compress", str(src), "-o", str(frame), "--plan", str(plan_file)]) == 0
    assert main(["decompress", str(frame), "-o", str(rt)]) == 0
    assert rt.read_bytes() == data


def test_cli_profiles_and_errors(tmp_path, capsys):
    from repro.cli import main

    assert main(["profiles"]) == 0
    assert "generic" in capsys.readouterr().out
    bad = tmp_path / "bad.ozl"
    bad.write_bytes(b"definitely not a frame")
    assert main(["decompress", str(bad), "-o", str(tmp_path / "x")]) == 2
    assert main(["inspect", str(bad)]) == 2


def test_session_pipeline_overlap_stats_and_prefetch_knob():
    """The double-buffered window reports overlap accounting, and disabling
    prefetch changes scheduling only — frames stay byte-identical."""
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(120000, dtype=np.uint32))
    oneshot = compress(plan, data, chunk_bytes=4096)
    with CompressorSession(plan, chunk_bytes=4096, n_workers=2) as sess:
        assert sess.compress(data) == oneshot
        st = sess.stats
        assert st["prefetch_hits"] + st["prefetch_misses"] > 0
        assert st["draw_wait_s"] >= 0.0 and st["encode_wait_s"] >= 0.0
        assert st["max_inflight"] >= 1
    with CompressorSession(
        plan, chunk_bytes=4096, n_workers=2, prefetch=False
    ) as sess:
        assert sess.compress(data) == oneshot
        st = sess.stats
        assert st["prefetch_hits"] == 0 and st["prefetch_misses"] == 0


def test_session_pipeline_prefetch_draws_overlap_lazy_source():
    """A lazy chunk source is drawn on the draw thread while encodes run;
    the in-order container output is unaffected."""
    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(120000, dtype=np.uint32))
    chunks = _split_chunks(data, 4096)
    oneshot = compress(plan, data, chunk_bytes=4096)
    with CompressorSession(plan, n_workers=2) as sess:
        buf = io.BytesIO()
        sess.compress_chunks(iter(chunks), buf, n_chunks=len(chunks))
        assert buf.getvalue() == oneshot
        assert (
            sess.stats["prefetch_hits"] + sess.stats["prefetch_misses"]
            >= len(chunks) - 1
        )


def test_prefetch_source_error_propagates_promptly():
    """A lazy source that dies mid-stream fails the call with its own error
    as soon as the draw thread reports it — it must not hide behind a full
    window of in-flight encodes — and the session stays usable after."""
    import time

    plan = pipeline("delta", "range_pack")
    data = numeric(np.arange(120000, dtype=np.uint32))
    chunks = _split_chunks(data, 4096)

    class SourceDied(Exception):
        pass

    def source():
        for c in chunks[:3]:
            yield c
        raise SourceDied("lazy source died mid-stream")

    with CompressorSession(plan, chunk_bytes=4096, n_workers=2) as sess:
        buf = io.BytesIO()
        t0 = time.perf_counter()
        with pytest.raises(SourceDied, match="died mid-stream"):
            sess.compress_chunks(source(), buf, n_chunks=len(chunks))
        assert time.perf_counter() - t0 < 5.0  # surfaced, not deadlocked
        # the pool survives a poisoned source: the next request is clean
        assert sess.compress(data) == compress(plan, data, chunk_bytes=4096)
