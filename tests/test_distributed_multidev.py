"""Multi-(virtual-)device tests: sharded train step, compressed gradient
collectives, elastic mesh restore.  Each test runs in a subprocess because
XLA_FLAGS device-count must be set before jax initializes (the main test
process keeps 1 device, per the assignment's conftest rule)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(n_devices: int, body: str) -> str:
    script = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import jax as _jax
        if not hasattr(_jax, "shard_map"):
            # older jax: adapt the new jax.shard_map API to the experimental one
            from jax.experimental.shard_map import shard_map as _esm

            def _shard_map(f=None, *, mesh, in_specs, out_specs,
                           axis_names=None, check_vma=True, **_kw):
                auto = (
                    frozenset(getattr(mesh, "axis_names", ())) - set(axis_names)
                    if axis_names else frozenset()
                )
                def _wrap(fn):
                    return _esm(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=False, auto=auto)
                return _wrap(f) if f is not None else _wrap

            _jax.shard_map = _shard_map
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=480,
        env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_lm_train_step_matches_single_device():
    out = run_with_devices(
        8,
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.cells import build_cell
        from repro.launch.mesh import make_host_mesh

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cell = build_cell("llama3.2-1b", "train_4k", mesh=mesh, reduced=True)
        # NOTE: reduced cell built against a mesh gets real shardings
        args = cell.make_real_args(jax.random.PRNGKey(0))
        with mesh:
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
            )
            p1, o1, l1 = jitted(*args)
        # single-device reference
        cell1 = build_cell("llama3.2-1b", "train_4k", mesh=None, reduced=True)
        args1 = cell1.make_real_args(jax.random.PRNGKey(0))
        p1r, o1r, l1r = jax.jit(cell1.fn)(*args1)
        assert abs(float(l1) - float(l1r)) < 1e-4, (float(l1), float(l1r))
        print("LOSS_MATCH", float(l1))
        """,
    )
    assert "LOSS_MATCH" in out


def test_grad_compression_psum_accuracy_and_ef():
    out = run_with_devices(
        4,
        """
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed import grad_compress as gc

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g_local = jnp.asarray(rng.normal(size=(4, 1024)).astype(np.float32))
        exact = np.asarray(g_local).sum(0)

        @partial(jax.shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        def red_bf16(g):
            out, _ = gc.compressed_psum({"g": g[0]}, "pod", "bf16")
            return out["g"][None]

        got = np.asarray(red_bf16(g_local))[0]
        rel = np.abs(got - exact).max() / np.abs(exact).max()
        assert rel < 2e-2, rel
        print("BF16_REL", rel)

        # error-feedback residual is PER-DEVICE state: sharded on 'pod'
        ef0 = {"g": jnp.zeros((4, 1024), jnp.float32)}
        @partial(jax.shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")))
        def red_int8(g, ef):
            out, new_ef = gc.compressed_psum(
                {"g": g[0]}, "pod", "int8_ef", ef_state={"g": ef["g"][0]}
            )
            return out["g"][None], {"g": new_ef["g"][None]}

        got8, ef1 = red_int8(g_local, ef0)
        rel8 = np.abs(np.asarray(got8)[0] - exact).max() / np.abs(exact).max()
        assert rel8 < 5e-2, rel8
        # error feedback: residual captured, nonzero
        assert float(jnp.abs(ef1["g"]).max()) > 0
        print("INT8_REL", rel8)

        # EF unbiasedness over repeats: sum of (reduced_t) approaches sum of t*exact
        acc = np.zeros_like(exact); ef = ef0
        for t in range(20):
            r, ef = red_int8(g_local, ef)
            acc += np.asarray(r)[0]
        drift = np.abs(acc - 20 * exact).max() / np.abs(20 * exact).max()
        assert drift < 5e-3, drift
        print("EF_DRIFT", drift)
        """,
    )
    assert "EF_DRIFT" in out


def test_dryrun_entry_single_cell():
    """The dry-run module itself runs (512 virtual devices, one cheap cell)."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            "sasrec",
            "--shape",
            "serve_p99",
            "--force",
        ],
        capture_output=True,
        text=True,
        timeout=480,
        env=env,
        cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[ok]" in out.stdout


def test_elastic_checkpoint_across_meshes(tmp_path):
    out = run_with_devices(
        8,
        f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.checkpoint import save_checkpoint, restore_tree

        tree = {{"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}}
        # save from a (4,2) mesh layout
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sharded = jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))
        save_checkpoint("{tmp_path}", 1, {{"w": sharded}})
        # restore onto a DIFFERENT mesh shape (8,1) — elastic rescale
        mesh_b = jax.make_mesh((8, 1), ("data", "model"))
        sh_b = {{"w": NamedSharding(mesh_b, P("data", None))}}
        restored, _ = restore_tree("{tmp_path}", tree, 1, shardings=sh_b)
        assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
        print("ELASTIC_OK", restored["w"].sharding)
        """,
    )
    assert "ELASTIC_OK" in out
