"""Scalar reference implementations — the PRE-vectorization codec code.

Copied verbatim from the seed implementations of ``codecs/lz.py`` and
``codecs/entropy.py`` (commit 09cade9) with codec registration stripped.
The cross-check suite (``test_vectorized_equiv.py``) pins the vectorized
implementations against these: same inputs -> bit-identical output streams
and headers, which is the wire-compatibility guarantee for every frame any
older build ever produced.  Do not "fix" or modernize this module; it is the
specification.
"""
from __future__ import annotations


import zlib
from typing import List

import numpy as np

from repro.core.message import Stream, SType

from repro.codecs._util import HeaderReader, HeaderWriter, numeric_stream

MIN_MATCH = 4
MAX_MATCH = 1 << 16


def _prev_occurrence(data: np.ndarray) -> np.ndarray:
    """For each position i, the most recent j<i with the same 4-gram hash."""
    n = data.size
    if n < MIN_MATCH:
        return np.full(n, -1, dtype=np.int64)
    g = (
        data[:-3].astype(np.uint32)
        | (data[1:-2].astype(np.uint32) << 8)
        | (data[2:-1].astype(np.uint32) << 16)
        | (data[3:].astype(np.uint32) << 24)
    )
    h = (g * np.uint32(2654435761)) >> np.uint32(16)  # Knuth hash -> 16 bits
    order = np.argsort(h, kind="stable")
    prev = np.full(n, -1, dtype=np.int64)
    sh = h[order]
    same = np.zeros(order.size, dtype=bool)
    same[1:] = sh[1:] == sh[:-1]
    prev_sorted = np.where(same, np.concatenate([[0], order[:-1]]), -1)
    prev[order] = prev_sorted
    return prev


def _lz77_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("lz77: fixed-width streams only (string_split first)")
    data = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    n = data.size
    prev = _prev_occurrence(data)
    buf = data.tobytes()

    lit_runs: List[int] = []
    match_lens: List[int] = []
    offsets: List[int] = []
    literals = bytearray()
    i = 0
    lit_start = 0
    while i + MIN_MATCH <= n:
        j = prev[i]
        if j >= 0 and j < i and buf[j : j + MIN_MATCH] == buf[i : i + MIN_MATCH]:
            L = _extend(data, j, i, n)
            lit_runs.append(i - lit_start)
            literals += buf[lit_start:i]
            match_lens.append(L)
            offsets.append(i - j)
            i += L
            lit_start = i
        else:
            i += 1
    lit_runs.append(n - lit_start)
    literals += buf[lit_start:n]

    h = HeaderWriter().u8(int(s.stype)).varint(s.width).varint(n).done()
    return [
        Stream(np.frombuffer(bytes(literals), dtype=np.uint8), SType.SERIAL, 1),
        numeric_stream(np.asarray(lit_runs, dtype=np.uint32)),
        numeric_stream(np.asarray(match_lens, dtype=np.uint32)),
        numeric_stream(np.asarray(offsets, dtype=np.uint32)),
    ], h


def _extend(data: np.ndarray, j: int, i: int, n: int) -> int:
    """Longest common extension of data[i:] vs data[j:] (j < i).

    Overlapping matches (dist < L) are legal in LZ77: the copy source keeps
    reading bytes the copy itself just produced, which for the *extension
    check* is equivalent to comparing data[j+L] vs data[i+L] directly —
    data[] already holds the final bytes on the encode side.  So plain
    chunked comparison is correct regardless of overlap.
    """
    L = 0
    limit = min(n - i, MAX_MATCH)
    while L < limit:
        chunk = min(256, limit - L)
        a = data[j + L : j + L + chunk]
        b = data[i + L : i + L + chunk]
        neq = np.nonzero(a != b)[0]
        if neq.size:
            return L + int(neq[0])
        L += chunk
    return L


def _lz77_dec(outs, header):
    literals, lit_runs, match_lens, offsets = outs
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    n = r.varint()
    r.expect_end()
    out = np.empty(n, dtype=np.uint8)
    lit = literals.data
    runs = lit_runs.data.astype(np.int64)
    mls = match_lens.data.astype(np.int64)
    offs = offsets.data.astype(np.int64)
    pos = 0
    lpos = 0
    for k in range(runs.size):
        rl = int(runs[k])
        if rl:
            out[pos : pos + rl] = lit[lpos : lpos + rl]
            pos += rl
            lpos += rl
        if k < mls.size:
            L = int(mls[k])
            d = int(offs[k])
            src = pos - d
            if d >= L:
                out[pos : pos + L] = out[src : src + L]
            else:  # overlapping copy: replicate the period
                reps = -(-L // d)
                pattern = out[src:pos]
                out[pos : pos + L] = np.tile(pattern, reps)[:L]
            pos += L
    if pos != n:
        raise ValueError("lz77: corrupt token streams")
    from repro.core.message import from_wire

    return [from_wire(stype, width, out.tobytes(), None)]







import heapq
from typing import List, Tuple

import numpy as np

from repro.core.message import Stream, SType

from repro.codecs._util import HeaderReader, HeaderWriter, numeric_stream

BLOCK_LOG = 12  # 4096 symbols per lane-block
MAX_CODE_LEN = 15


def _as_u8(s: Stream, op: str) -> np.ndarray:
    if s.stype == SType.SERIAL or (s.stype == SType.NUMERIC and s.width == 1):
        return np.frombuffer(s.content_bytes(), dtype=np.uint8)
    if s.stype == SType.STRUCT and s.width == 1:
        return s.data
    raise ValueError(f"{op}: byte streams only (serial / numeric(1)); transpose first")


def _rebuild(stype_tag: int, result: np.ndarray) -> Stream:
    """Type-faithful reconstruction (codecs are bijections INCLUDING type)."""
    from repro.core.message import from_wire

    return from_wire(SType(stype_tag), 1, result.tobytes(), None)


# =====================================================================
# Canonical Huffman
# =====================================================================
def _huffman_code_lengths(counts: np.ndarray) -> np.ndarray:
    """Package-merge-free Huffman with length cap via count flattening."""
    sym = np.nonzero(counts)[0]
    if sym.size == 0:
        return np.zeros(256, dtype=np.uint8)
    if sym.size == 1:
        lens = np.zeros(256, dtype=np.uint8)
        lens[sym[0]] = 1
        return lens
    c = counts.astype(np.float64)
    for _ in range(16):  # flatten until the cap holds
        heap: List[Tuple[float, int]] = [(c[s], int(s)) for s in sym]
        heapq.heapify(heap)
        parent = {}
        next_id = 256
        while len(heap) > 1:
            a = heapq.heappop(heap)
            b = heapq.heappop(heap)
            parent[a[1]] = next_id
            parent[b[1]] = next_id
            heapq.heappush(heap, (a[0] + b[0], next_id))
            next_id += 1
        lens = np.zeros(256, dtype=np.uint8)
        for s in sym:
            d = 0
            node = int(s)
            while node in parent:
                node = parent[node]
                d += 1
            lens[s] = d
        if lens.max() <= MAX_CODE_LEN:
            return lens
        c = np.maximum(c, c[sym].sum() / (1 << MAX_CODE_LEN))  # flatten tail
    raise AssertionError("huffman length cap failed to converge")


def _canonical_codes(lens: np.ndarray) -> np.ndarray:
    """Assign canonical codes; returned bit-reversed for LSB-first packing."""
    codes = np.zeros(256, dtype=np.uint32)
    code = 0
    for length in range(1, MAX_CODE_LEN + 1):
        for s in range(256):
            if lens[s] == length:
                # bit-reverse `code` over `length` bits
                rev = int(f"{code:0{length}b}"[::-1], 2)
                codes[s] = rev
                code += 1
        code <<= 1
    return codes


def _write_bits_blocked(
    values: np.ndarray, nbits: np.ndarray, block: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack (value, nbits) pairs LSB-first; returns (bytes, per-symbol bit offs).

    Vectorized: global bit offsets by cumsum; each value ORs into <=3 bytes...
    values here are <= 2^15 wide so <= 3 byte-touches after alignment.
    """
    n = values.size
    offs = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nbits, out=offs[1:])
    total = int(offs[-1])
    out = np.zeros((total + 7) // 8 + 8, dtype=np.uint8)
    v = values.astype(np.uint64)
    start = offs[:-1]
    for b in range(4):
        byte_idx = (start >> 3) + b
        shift = (np.int64(b) << 3) - (start & 7)
        pos = shift >= 0
        contrib = np.where(
            pos,
            v >> np.where(pos, shift, 0).clip(max=63).astype(np.uint64),
            v << np.where(~pos, -shift, 0).astype(np.uint64),
        )
        contrib = np.where(shift >= 64, 0, contrib)
        np.bitwise_or.at(out, byte_idx, (contrib & 0xFF).astype(np.uint8))
    return out[: (total + 7) // 8], offs


def _huffman_enc(streams, params):
    x = _as_u8(streams[0], "huffman")
    n = x.size
    counts = np.bincount(x, minlength=256)
    lens = _huffman_code_lengths(counts)
    codes = _canonical_codes(lens)
    nbits = lens[x].astype(np.int64)
    packed, offs = _write_bits_blocked(codes[x], nbits, 1 << BLOCK_LOG)
    block = 1 << BLOCK_LOG
    block_offs = offs[:-1:block] if n else np.zeros(0, np.int64)
    h = HeaderWriter().varint(n).u8(BLOCK_LOG).u8(int(streams[0].stype))
    nib = (lens[0::2] | (lens[1::2] << 4)).astype(np.uint8)  # nibble-pack lengths
    h.bytes_(nib.tobytes())
    return [
        Stream(packed, SType.SERIAL, 1),
        numeric_stream(block_offs.astype(np.uint64)),
    ], h.done()


def _huffman_dec(outs, header):
    bitstream, block_offs_s = outs
    r = HeaderReader(header)
    n = r.varint()
    block_log = r.u8()
    stype_tag = r.u8()
    nib = np.frombuffer(r.bytes_(), dtype=np.uint8)
    r.expect_end()
    lens = np.zeros(256, dtype=np.uint8)
    lens[0::2] = nib & 0xF
    lens[1::2] = nib >> 4
    codes = _canonical_codes(lens)

    # build the 2^15 LSB-first decode LUT: lookup[low15] = (symbol, length)
    lut_sym = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
    lut_len = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
    for s in range(256):
        L = int(lens[s])
        if L == 0:
            continue
        base = int(codes[s])
        step = 1 << L
        idx = np.arange(base, 1 << MAX_CODE_LEN, step)
        lut_sym[idx] = s
        lut_len[idx] = L

    block = 1 << block_log
    n_blocks = (n + block - 1) // block
    buf = np.zeros(bitstream.data.size + 16, dtype=np.uint8)
    buf[: bitstream.data.size] = bitstream.data
    pos = block_offs_s.data.astype(np.int64).copy()
    if pos.size != n_blocks:
        raise ValueError("huffman: block offset count mismatch")
    out = np.zeros(n_blocks * block, dtype=np.uint8)
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)
    for i in range(block):
        active = rem > i
        if not active.any():
            break
        byte0 = pos >> 3
        window = np.zeros(n_blocks, dtype=np.uint64)
        for b in range(8):
            window |= buf[byte0 + b].astype(np.uint64) << np.uint64(8 * b)
        low = ((window >> (pos & 7).astype(np.uint64)) & np.uint64((1 << MAX_CODE_LEN) - 1)).astype(np.int64)
        sym = lut_sym[low]
        ln = lut_len[low].astype(np.int64)
        out[np.arange(n_blocks) * block + i] = np.where(active, sym, 0)
        pos += np.where(active, ln, 0)
    result = np.concatenate(
        [out[k * block : k * block + int(rem[k])] for k in range(n_blocks)]
    ) if n_blocks else np.zeros(0, np.uint8)
    return [_rebuild(stype_tag, result)]




# =====================================================================
# FSE / tANS
# =====================================================================
FSE_BLOCK_LOG = 10  # 1024 symbols/lane-block (encode loops positions, not lanes)


def _normalize_counts(counts: np.ndarray, table_log: int) -> np.ndarray:
    """Largest-remainder normalization of symbol counts to sum 2^table_log."""
    total = 1 << table_log
    n = counts.sum()
    if n == 0:
        raise ValueError("fse: empty input")
    scaled = counts.astype(np.float64) * total / n
    norm = np.floor(scaled).astype(np.int64)
    norm[(counts > 0) & (norm == 0)] = 1  # every present symbol needs a slot
    diff = total - norm.sum()
    if diff > 0:
        order = np.argsort(-(scaled - norm))
        for i in range(int(diff)):
            norm[order[i % order.size]] += 1
    elif diff < 0:
        # remove from the largest (keeping >=1 for present symbols)
        for _ in range(int(-diff)):
            cand = np.argmax(norm - (counts > 0))
            if norm[cand] <= 1:
                cand = int(np.argmax(norm))
            norm[cand] -= 1
    assert norm.sum() == total and (norm[counts > 0] >= 1).all()
    return norm


def _spread_symbols(norm: np.ndarray, table_log: int) -> np.ndarray:
    total = 1 << table_log
    step = (total >> 1) + (total >> 3) + 3
    spread = np.zeros(total, dtype=np.int64)
    position = 0
    for s in range(norm.size):
        for _ in range(int(norm[s])):
            spread[position] = s
            position = (position + step) & (total - 1)
    assert position == 0
    return spread


def _build_tables(norm: np.ndarray, table_log: int):
    """Build tANS encode/decode tables from normalized counts."""
    total = 1 << table_log
    spread = _spread_symbols(norm, table_log)
    # decode table: state j -> (symbol, nbits, new_state_base)
    occ = norm.copy()  # next x' per symbol starts at norm[s]
    dec_sym = spread.astype(np.uint8)
    dec_nb = np.zeros(total, dtype=np.int64)
    dec_base = np.zeros(total, dtype=np.int64)
    # encode: k-th (in slot order) occurrence of s maps x' = norm[s]+k -> slot
    enc_slot = {}
    counters = np.zeros(norm.size, dtype=np.int64)
    for j in range(total):
        s = spread[j]
        x = norm[s] + counters[s]
        counters[s] += 1
        nb = table_log - (int(x).bit_length() - 1)
        dec_nb[j] = nb
        dec_base[j] = (int(x) << nb) - total
        enc_slot[(int(s), int(x))] = j
    # per-symbol encode arrays: for x' in [norm[s], 2 norm[s]) -> slot id
    enc_table = np.zeros((norm.size, int(norm.max()) if norm.max() else 1), dtype=np.int64)
    for (s, x), j in enc_slot.items():
        enc_table[s, x - norm[s]] = j
    return dec_sym, dec_nb, dec_base, enc_table


def _fse_enc(streams, params):
    x = _as_u8(streams[0], "fse")
    n = x.size
    table_log = int(params.get("table_log", 11))
    stype_tag = int(streams[0].stype)
    if n == 0:
        h = (
            HeaderWriter().varint(0).u8(FSE_BLOCK_LOG).u8(table_log)
            .u8(stype_tag).bytes_(b"").done()
        )
        return [Stream(np.zeros(0, np.uint8), SType.SERIAL, 1), numeric_stream(np.zeros(0, np.uint32))], h
    counts = np.bincount(x, minlength=256)
    norm = _normalize_counts(counts, table_log)
    dec_sym, dec_nb, dec_base, enc_table = _build_tables(norm, table_log)
    total = 1 << table_log

    block = 1 << FSE_BLOCK_LOG
    n_blocks = (n + block - 1) // block
    padded = np.zeros(n_blocks * block, dtype=np.uint8)
    padded[:n] = x
    lanes = padded.reshape(n_blocks, block)
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)

    norm_l = norm.astype(np.int64)
    # vectorized across blocks; loop positions in reverse (tANS encodes backward)
    state = np.zeros(n_blocks, dtype=np.int64)  # slot ids in [0, total)
    first = True
    max_bits_per_sym = table_log + 1
    cap_bytes = (block * max_bits_per_sym + 7) // 8 + 8
    bitbuf = np.zeros((n_blocks, cap_bytes), dtype=np.uint8)
    bitpos = np.zeros(n_blocks, dtype=np.int64)
    lane_idx = np.arange(n_blocks)
    for i in range(block - 1, -1, -1):
        s = lanes[:, i].astype(np.int64)
        active = rem > i
        f = norm_l[s]
        if first:
            # initial state: x' = f + (something deterministic); use slot of x'=f
            st = enc_table[s, 0]
            state = np.where(active, st, state)
            started = active.copy()
            first = False
            continue
        X = state + total  # representative value in [total, 2*total)
        # nb such that (X >> nb) in [f, 2f): since bit_length(X) == tl+1 exactly,
        # nb0 = tl+1-bit_length(f) gives x0 with bit_length(f) bits; correct -1
        # when x0 < f (see tANS construction; property-tested in tests/).
        bl = np.zeros_like(f)
        ftmp = f.copy()
        while (ftmp > 0).any():
            bl += (ftmp > 0).astype(np.int64)
            ftmp >>= 1
        nb = (table_log + 1) - bl
        nb = np.where((X >> np.maximum(nb, 0)) < f, nb - 1, nb)
        nb = np.maximum(nb, 0)
        newly = active & ~started
        # lanes that start mid-stream (shorter tail lanes): initialize instead
        st_init = enc_table[s, 0]
        sub2 = X >> nb.astype(np.int64)
        emit_mask = active & started
        # emit nb low bits of X for emitting lanes
        val = (X & ((np.int64(1) << nb) - 1)).astype(np.uint64)
        nbe = np.where(emit_mask, nb, 0).astype(np.int64)
        _scatter_bits(bitbuf, bitpos, val, nbe, lane_idx)
        bitpos += nbe
        xprime = np.clip(sub2 - f, 0, enc_table.shape[1] - 1)
        new_state = enc_table[s, xprime]
        state = np.where(emit_mask, new_state, np.where(newly, st_init, state))
        started |= active

    # concatenate lane bitstreams
    nbytes = ((bitpos + 7) // 8).astype(np.int64)
    offsets = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(nbytes, out=offsets[1:])
    stream_out = np.zeros(int(offsets[-1]), dtype=np.uint8)
    for k in range(n_blocks):
        stream_out[offsets[k] : offsets[k + 1]] = bitbuf[k, : nbytes[k]]
    # block meta: (bit length, final state) as u32 pairs
    meta = np.empty(n_blocks * 2, dtype=np.uint32)
    meta[0::2] = bitpos.astype(np.uint32)
    meta[1::2] = state.astype(np.uint32)

    h = HeaderWriter().varint(n).u8(FSE_BLOCK_LOG).u8(table_log).u8(stype_tag)
    nz = np.nonzero(norm)[0]
    hw = HeaderWriter()
    hw.varint(nz.size)
    for s in nz:
        hw.varint(int(s))
        hw.varint(int(norm[s]))
    h.bytes_(hw.done())
    return [Stream(stream_out, SType.SERIAL, 1), numeric_stream(meta)], h.done()


def _scatter_bits(bitbuf, bitpos, val, nbits, lane_idx):
    """OR `val` (LSB-first, nbits wide) at per-lane bit cursor `bitpos`."""
    active = nbits > 0
    if not active.any():
        return
    for b in range(4):
        byte_idx = (bitpos >> 3) + b
        shift = (np.int64(b) << 3) - (bitpos & 7)
        pos = shift >= 0
        contrib = np.where(
            pos,
            val >> np.where(pos, shift, 0).clip(max=63).astype(np.uint64),
            val << np.where(~pos, -shift, 0).astype(np.uint64),
        )
        contrib = (contrib & 0xFF).astype(np.uint8)
        contrib = np.where(active & (shift < 64), contrib, 0)
        np.bitwise_or.at(bitbuf, (lane_idx, byte_idx), contrib)


def _fse_dec(outs, header):
    bitstream, meta_s = outs
    r = HeaderReader(header)
    n = r.varint()
    block_log = r.u8()
    table_log = r.u8()
    stype_tag = r.u8()
    tbl = HeaderReader(r.bytes_())
    r.expect_end()
    if n == 0:
        return [_rebuild(stype_tag, np.zeros(0, np.uint8))]
    norm = np.zeros(256, dtype=np.int64)
    for _ in range(tbl.varint()):
        s = tbl.varint()
        norm[s] = tbl.varint()
    dec_sym, dec_nb, dec_base, _enc = _build_tables(norm, table_log)

    block = 1 << block_log
    n_blocks = (n + block - 1) // block
    meta = meta_s.data.astype(np.int64)
    bitlen = meta[0::2]
    state = meta[1::2].copy()
    nbytes = (bitlen + 7) // 8
    offsets = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(nbytes, out=offsets[1:])
    # per-lane padded buffers for vectorized backward reads
    cap = int(nbytes.max()) + 16 if n_blocks else 16
    bitbuf = np.zeros((n_blocks, cap), dtype=np.uint8)
    for k in range(n_blocks):
        bitbuf[k, : nbytes[k]] = bitstream.data[offsets[k] : offsets[k + 1]]
    cursor = bitlen.copy()  # read backward from the end
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)
    out = np.zeros((n_blocks, block), dtype=np.uint8)
    lane = np.arange(n_blocks)
    for i in range(block):
        active = rem > i
        if not active.any():
            break
        sym = dec_sym[state]
        out[:, i] = np.where(active, sym, 0)
        nb = np.where(active, dec_nb[state], 0)
        base = dec_base[state]
        cursor2 = cursor - nb
        byte0 = (cursor2 >> 3).clip(min=0)
        window = np.zeros(n_blocks, dtype=np.uint64)
        for b in range(8):
            window |= bitbuf[lane, byte0 + b].astype(np.uint64) << np.uint64(8 * b)
        bits = (window >> (cursor2 & 7).astype(np.uint64)) & (
            (np.uint64(1) << nb.astype(np.uint64)) - np.uint64(1)
        )
        state = np.where(active, base + bits.astype(np.int64), state)
        cursor = np.where(active, cursor2, cursor)
    result = np.concatenate([out[k, : rem[k]] for k in range(n_blocks)])
    return [_rebuild(stype_tag, result)]


