"""Cross-checks for the cache-blocked lz77 match finder (codecs/lz.py).

The blocked chain build (``_PREV_BLOCK``-position stable-sort windows with a
last-occurrence stitch) and the windowed lockstep walk (``_WALK_WINDOW``)
must be *semantically invisible*: bit-identical token streams to the scalar
seed implementation (tests/_scalar_ref.py) and to the unblocked vectorized
path, for every input — in particular when matches straddle block
boundaries.  The property tests shrink the block constants so a few-KiB
hypothesis input straddles many windows; the deterministic cases straddle
the *real* 2^19-position boundary.
"""
import contextlib

import numpy as np
import pytest
from _hyp import given, settings, st

import _scalar_ref as sr
from repro.codecs import lz as vec_lz
from repro.core.message import serial

_BLOCK_ATTRS = ("_PREV_BLOCK", "_WALK_WINDOW", "_SEG")


@contextlib.contextmanager
def _block_sizes(prev_block, walk_window, seg=None):
    saved = {a: getattr(vec_lz, a) for a in _BLOCK_ATTRS}
    vec_lz._PREV_BLOCK = prev_block
    vec_lz._WALK_WINDOW = walk_window
    if seg is not None:
        vec_lz._SEG = seg
    try:
        yield
    finally:
        for a, v in saved.items():
            setattr(vec_lz, a, v)


def _assert_matches_scalar(data: bytes) -> None:
    s = serial(data)
    ref_outs, ref_h = sr._lz77_enc([s], {})
    new_outs, new_h = vec_lz._lz77_enc([s], {})
    assert ref_h == new_h
    assert len(ref_outs) == len(new_outs)
    for i, (a, b) in enumerate(zip(ref_outs, new_outs)):
        assert a.data.tobytes() == b.data.tobytes(), f"stream {i} diverged"
    assert vec_lz._lz77_dec(new_outs, new_h)[0].content_bytes() == data


@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=25, deadline=None)
def test_blocked_equiv_random(b):
    # 64-position chain blocks / 256-byte walk windows: a 2 KiB input spans
    # ~32 chain blocks, so cross-block candidates are the common case
    with _block_sizes(64, 256, seg=32):
        _assert_matches_scalar(b)


@given(st.binary(min_size=1, max_size=24), st.integers(2, 200))
@settings(max_examples=25, deadline=None)
def test_blocked_equiv_periodic_straddles(period, reps):
    # periodic data: every match source sits reps-of-period behind its
    # destination, hitting offsets that straddle block boundaries at
    # many alignments as reps grows
    with _block_sizes(64, 256, seg=32):
        _assert_matches_scalar(period * reps)


@given(st.binary(min_size=8, max_size=64), st.integers(0, 96))
@settings(max_examples=25, deadline=None)
def test_blocked_equiv_pair_straddles_boundary(phrase, gap):
    # a phrase placed so its repeat crosses the 64-position block boundary:
    # source in block 0, destination starting in block 0 or 1 and extending
    # across — the stitch must still find the cross-block predecessor
    rng = np.random.default_rng(len(phrase) * 131 + gap)
    junk = rng.integers(0, 256, gap, dtype=np.uint8).tobytes()
    with _block_sizes(64, 256, seg=32):
        _assert_matches_scalar(phrase + junk + phrase + phrase)


def test_two_block_straddle_real_boundary():
    """A match whose source lies before the real 2^19-position chain-block
    boundary and whose destination crosses it: blocked output must equal
    the unblocked (single global sort) output bit-for-bit."""
    rng = np.random.default_rng(42)
    B = vec_lz._PREV_BLOCK
    phrase = bytes(rng.integers(0, 256, 300, dtype=np.uint8))
    # phrase at B - 150: starts in block 0, extends 150 bytes into block 1;
    # its source copy sits mid-block-0; filler is incompressible noise
    data = bytearray(rng.integers(0, 256, B + (1 << 16), dtype=np.uint8))
    data[B // 2 : B // 2 + 300] = phrase
    data[B - 150 : B - 150 + 300] = phrase
    data[B + 500 : B + 500 + 300] = phrase  # block-1 dest, block-0/1 source
    data = bytes(data)

    s = serial(data)
    blocked_outs, blocked_h = vec_lz._lz77_enc([s], {})
    with _block_sizes(1 << 30, 1 << 30):
        unblocked_outs, unblocked_h = vec_lz._lz77_enc([s], {})
    assert blocked_h == unblocked_h
    for i, (a, b) in enumerate(zip(blocked_outs, unblocked_outs)):
        assert a.data.tobytes() == b.data.tobytes(), f"stream {i} diverged"
    assert vec_lz._lz77_dec(blocked_outs, blocked_h)[0].content_bytes() == data

    # sanity: the straddling repeats were actually found as matches
    lens = blocked_outs[2].data.astype(np.int64)
    assert lens.size >= 2 and int(lens.max()) >= 290


def test_walk_window_straddle_real_boundary():
    """Matches spanning the real _WALK_WINDOW byte boundary: window splicing
    must reproduce the unblocked walk exactly."""
    rng = np.random.default_rng(43)
    W = vec_lz._WALK_WINDOW
    phrase = bytes(rng.integers(0, 256, 4096, dtype=np.uint8))
    data = bytearray(rng.integers(0, 256, W + (1 << 16), dtype=np.uint8))
    data[1000 : 1000 + 4096] = phrase
    data[W - 2048 : W - 2048 + 4096] = phrase  # straddles the window edge
    data = bytes(data)

    s = serial(data)
    blocked_outs, blocked_h = vec_lz._lz77_enc([s], {})
    with _block_sizes(1 << 30, 1 << 30):
        unblocked_outs, unblocked_h = vec_lz._lz77_enc([s], {})
    assert blocked_h == unblocked_h
    for i, (a, b) in enumerate(zip(blocked_outs, unblocked_outs)):
        assert a.data.tobytes() == b.data.tobytes(), f"stream {i} diverged"
    assert vec_lz._lz77_dec(blocked_outs, blocked_h)[0].content_bytes() == data
