"""The deterministic fault-injection plane (repro.reliability.faults).

Standing policy under test: disarmed plans cost nothing and change nothing;
armed plans are seed-deterministic (same seed => same fault sequence over a
deterministic workload); injected failures are indistinguishable from the
real thing at every instrumented seam (file I/O, protocol framing, device
kernels) — and the backend-failover path they exercise stays bit-identical
on the wire.
"""
import io

import numpy as np
import pytest

from repro.codecs.profiles import resolve_profile_spec
from repro.core import CompressorSession, compress, numeric, pipeline, stream_io
from repro.reliability import (
    BackendHealth,
    FaultPlan,
    InjectedFault,
    Quarantine,
    current_plan,
    fault_point,
    wrap_io,
)


# ------------------------------------------------------------------ disarmed
def test_disarmed_is_pass_through():
    assert current_plan() is None
    f = io.BytesIO()
    assert wrap_io(f, "io.x") is f  # the original object, not a proxy
    fault_point("any.name")  # no-op, no state


# ----------------------------------------------------------------- schedules
def test_explicit_rule_fires_on_exact_occurrence():
    plan = FaultPlan().at("p.x", nth=3)
    with plan.arm():
        fault_point("p.x")
        fault_point("p.x")
        with pytest.raises(InjectedFault):
            fault_point("p.x")
        fault_point("p.x")  # times=1: only the 3rd fires
        fault_point("p.other")  # different point, own counter
    assert plan.fired == [("p.x", 3, "raise")]


def test_occurrences_count_per_point_name():
    plan = FaultPlan().at("a.*", nth=2)
    with plan.arm():
        fault_point("a.one")
        fault_point("a.two")  # each name is on its 1st occurrence
        with pytest.raises(InjectedFault):
            fault_point("a.one")
        with pytest.raises(InjectedFault):
            fault_point("a.two")


def test_seeded_random_schedule_is_deterministic():
    def run(seed):
        plan = FaultPlan(seed=seed).every("w.*", 0.3)
        fired = []
        with plan.arm():
            for i in range(200):
                try:
                    fault_point(f"w.{i % 5}")
                except InjectedFault:
                    fired.append(i)
        return fired

    a, b = run(7), run(7)
    assert a == b and a  # same seed => same sequence, and it does fire
    assert run(8) != a  # different seed => different sequence


def test_global_arming_is_exclusive():
    p1, p2 = FaultPlan(), FaultPlan()
    with p1.arm(all_threads=True):
        with pytest.raises(RuntimeError):
            with p2.arm(all_threads=True):
                pass
    with p2.arm(all_threads=True):  # slot released on exit
        pass
    assert current_plan() is None


def test_plan_json_roundtrip_for_subprocess_victims():
    plan = FaultPlan().at("a.x", nth=2, action="drop")
    clone = FaultPlan.from_json(plan.to_json())
    with clone.arm():
        fault_point("a.x")
        with pytest.raises(ConnectionResetError):
            fault_point("a.x")


# ----------------------------------------------------------------- I/O seams
def test_short_write_leaves_a_partial_prefix():
    buf = io.BytesIO()
    plan = FaultPlan().at("io.t.write", action="short")
    with plan.arm():
        f = wrap_io(buf, "io.t")
        with pytest.raises(InjectedFault):
            f.write(b"0123456789")
    assert 0 < len(buf.getvalue()) < 10  # torn, not absent and not complete


def test_compress_file_sink_fault_never_leaves_partial_output(tmp_path):
    src = tmp_path / "src.bin"
    src.write_bytes(b"log line payload\n" * 4000)
    dst = tmp_path / "out.ozl"
    plan_c = resolve_profile_spec("generic")
    with FaultPlan().at("io.sink.write", nth=3).arm(all_threads=True):
        with pytest.raises(InjectedFault):
            stream_io.compress_file(src, dst, plan_c, chunk_bytes=4096)
    assert not dst.exists()  # atomic sink: the final path never appeared
    assert not list(tmp_path.glob("*.tmp"))  # staging cleaned up on the error
    stream_io.compress_file(src, dst, plan_c, chunk_bytes=4096)  # disarmed: fine
    assert dst.exists()


def test_decompress_source_read_fault_propagates(tmp_path):
    src = tmp_path / "src.bin"
    src.write_bytes(b"abcdefgh" * 2000)
    dst = tmp_path / "out.ozl"
    back = tmp_path / "back.bin"
    stream_io.compress_file(src, dst, resolve_profile_spec("generic"))
    with FaultPlan().at("io.src.read").arm(all_threads=True):
        with pytest.raises(InjectedFault):
            stream_io.decompress_file(dst, back)
    assert not back.exists()


# ------------------------------------------------------------- protocol seam
def test_protocol_send_and_recv_drops():
    from repro.service import protocol as P

    buf = io.BytesIO()
    with FaultPlan().at("proto.send", action="drop").arm():
        with pytest.raises(ConnectionResetError):
            P.write_request(buf, P.VERB_PING, {})

    buf = io.BytesIO()
    P.write_response(buf, P.STATUS_OK, {"ok": True})
    buf.seek(0)
    with FaultPlan().at("proto.recv", action="drop").arm():
        with pytest.raises(ConnectionResetError):
            P.read_response(buf)


# ---------------------------------------------------- device faults, failover
DEV_PLAN = pipeline("delta", "bitpack")


def _payload():
    return numeric(np.arange(4096, dtype=np.uint32))


def test_device_fault_is_fatal_without_failover():
    with CompressorSession(DEV_PLAN, backend="device") as sess:
        with FaultPlan().at("device.encode.device.*", times=10**6).arm(
            all_threads=True
        ):
            with pytest.raises(InjectedFault):
                sess.compress(_payload())


def test_device_failover_serves_bit_identical_host_frames():
    ref = compress(DEV_PLAN, _payload())  # host path
    fo = BackendHealth(threshold=2, cooldown_s=1000.0)
    with CompressorSession(DEV_PLAN, backend="device", failover=fo) as sess:
        with FaultPlan().at("device.encode.device.*", times=10**6).arm(
            all_threads=True
        ):
            f1 = sess.compress(_payload())  # failover, failure 1 recorded
            f2 = sess.compress(_payload())  # failure 2 -> quarantined
        f3 = sess.compress(_payload())  # disarmed but benched: host directly
    assert f1 == ref and f2 == ref and f3 == ref
    st = fo.stats()["device"]
    assert st["quarantined"] and st["failovers"] >= 2


def test_device_failover_recovers_after_cooldown_probe():
    t = [0.0]
    fo = BackendHealth(threshold=1, cooldown_s=10.0, clock=lambda: t[0])
    with CompressorSession(DEV_PLAN, backend="device", failover=fo) as sess:
        with FaultPlan().at("device.encode.device.*").arm(all_threads=True):
            sess.compress(_payload())  # one failure -> quarantined
        assert fo.stats()["device"]["quarantined"]
        t[0] = 11.0  # cooldown expired: the next chunk is the probe
        # a healthy probe runs the genuine device path again (which may fuse
        # nodes — a different but equally valid frame from the host's)
        ref_dev = compress(DEV_PLAN, _payload(), backend="device")
        assert sess.compress(_payload()) == ref_dev
    assert not fo.stats()["device"]["quarantined"]  # probe succeeded


# --------------------------------------------------------- health unit tests
def test_backend_health_probe_protocol():
    t = [0.0]
    h = BackendHealth(threshold=1, cooldown_s=10.0, clock=lambda: t[0])
    assert not h.quarantined("dev")
    h.record_failure("dev")
    assert h.quarantined("dev")
    t[0] = 11.0
    assert not h.quarantined("dev")  # the single probe slot
    assert h.quarantined("dev")  # everyone else still benched
    h.record_failure("dev")  # probe failed: re-quarantined from now
    assert h.quarantined("dev")
    t[0] = 22.0
    assert not h.quarantined("dev")
    h.record_success("dev")  # probe succeeded: cleared
    assert not h.quarantined("dev")


def test_quarantine_breaker_protocol():
    t = [0.0]
    q = Quarantine(threshold=3, cooldown_s=5.0, clock=lambda: t[0])
    q.record_failure("d")
    q.record_failure("d")
    assert q.blocked("d") is None  # below threshold
    q.record_failure("d")
    remaining = q.blocked("d")
    assert remaining is not None and 0 < remaining <= 5.0
    t[0] = 6.0
    assert q.blocked("d") is None  # expiry admits a probe
    q.record_failure("d")  # probe failure re-trips immediately
    assert q.blocked("d") is not None
    t[0] = 12.0
    assert q.blocked("d") is None
    q.record_success("d")  # probe success clears the count entirely
    q.record_failure("d")
    q.record_failure("d")
    assert q.blocked("d") is None
