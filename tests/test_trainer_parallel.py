"""Parallel trainer: determinism, losslessness of every emitted tradeoff
point, NSGA-II edge cases, frontend auto-detection, and the `repro train`
CLI end to end (paper §VI-C)."""
import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import Compressor, decompress, numeric, serial
from repro.core.message import SType
from repro.core.serialize import serialize_plan
from repro.training import (
    CsvFrontend,
    Frontend,
    GraphFrontend,
    NumericFrontend,
    StructFrontend,
    TrainerService,
    crowding_distance,
    detect_frontend,
    nondominated_sort,
    rng_stream,
    train,
)

REPO_ROOT = Path(__file__).resolve().parents[1]


def _struct_blob(n: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(0, 1 << 20, n)).astype(np.uint32)
    b = rng.integers(0, 7, n).astype(np.uint32)
    rec = np.empty((n, 8), np.uint8)
    rec[:, :4] = a.view(np.uint8).reshape(n, 4)
    rec[:, 4:] = b.view(np.uint8).reshape(n, 4)
    return rec.reshape(-1).tobytes()


def _train_result(workers: int, seed: int = 7):
    tc = train(
        [[serial(_struct_blob(1200, s))] for s in (0, 1)],
        StructFrontend(widths=(4, 4)),
        pop_size=8,
        generations=2,
        seed=seed,
        workers=workers,
    )
    blobs = tuple(serialize_plan(p) for p, _, _ in tc.pareto_plans())
    objs = tuple((p.est_size, p.est_time) for p in tc.points)
    return tc, blobs, objs


# ------------------------------------------------------------- determinism
def test_same_seed_identical_across_worker_counts():
    """workers=1 vs workers=4: byte-identical Pareto set and plans."""
    _, blobs1, objs1 = _train_result(workers=1)
    _, blobs4, objs4 = _train_result(workers=4)
    assert objs1 == objs4
    assert blobs1 == blobs4, "serialized plans must not depend on worker count"


def test_different_seed_changes_search():
    # sanity check that the seed actually drives the search (otherwise the
    # determinism test above proves nothing)
    _, _, objs_a = _train_result(workers=1, seed=7)
    _, _, objs_b = _train_result(workers=1, seed=8)
    # identical Pareto *objectives* for different seeds are possible but the
    # RNG streams must differ
    assert rng_stream(7, "child", 0, 0).random() != rng_stream(8, "child", 0, 0).random()
    assert objs_a  # trained something
    assert objs_b


def test_rng_stream_is_stable_and_keyed():
    assert rng_stream(3, "a", 1).randrange(1 << 30) == rng_stream(3, "a", 1).randrange(1 << 30)
    assert rng_stream(3, "a", 1).random() != rng_stream(3, "a", 2).random()
    assert rng_stream(3, "a").random() != rng_stream(4, "a").random()


def test_every_tradeoff_point_roundtrips_on_held_out_data():
    tc, _, _ = _train_result(workers=2)
    held_out = _struct_blob(3000, seed=99)
    for plan, _sz, _tm in tc.pareto_plans():
        blob = Compressor(plan).serialize()
        clone = Compressor.deserialize(blob)
        assert clone.roundtrip_check(held_out), "tradeoff point not lossless"


def test_pareto_points_are_size_sorted_and_objective_unique():
    tc, _, objs = _train_result(workers=2)
    sizes = [p.est_size for p in tc.points]
    assert sizes == sorted(sizes)
    assert len(set(objs)) == len(objs), "duplicate-objective points not pruned"


def test_trainer_service_is_reusable_and_counts():
    with TrainerService(workers=2) as svc:
        sample = [[serial(_struct_blob(600))]]
        tc1 = train(sample, StructFrontend(widths=(4, 4)), pop_size=4,
                    generations=1, seed=0, service=svc)
        evals_after_first = svc.stats["evaluations"]
        tc2 = train(sample, StructFrontend(widths=(4, 4)), pop_size=4,
                    generations=1, seed=0, service=svc)
    assert evals_after_first > 0
    assert svc.stats["evaluations"] > evals_after_first
    assert svc.stats["session_hits"] > 0, "per-genome sessions never reused"
    # same seed, same service => same result (service state must not leak
    # into objectives)
    assert [(p.est_size, p.est_time) for p in tc1.points] == [
        (p.est_size, p.est_time) for p in tc2.points
    ]


# --------------------------------------------------------- NSGA-II edge cases
def test_nondominated_sort_duplicate_objectives_share_front():
    objs = [(1.0, 1.0), (1.0, 1.0), (2.0, 2.0), (1.0, 1.0)]
    fronts = nondominated_sort(objs)
    assert fronts[0] == [0, 1, 3]  # duplicates never dominate each other
    assert fronts[1] == [2]


def test_nondominated_sort_single_point():
    assert nondominated_sort([(5.0, 5.0)]) == [[0]]


def test_nondominated_sort_chain():
    objs = [(3.0, 3.0), (2.0, 2.0), (1.0, 1.0)]
    assert nondominated_sort(objs) == [[2], [1], [0]]


def test_crowding_distance_small_fronts_are_infinite():
    objs = [(1.0, 2.0), (2.0, 1.0)]
    dist = crowding_distance(objs, [0, 1])
    assert dist[0] == math.inf and dist[1] == math.inf
    assert crowding_distance([(1.0, 1.0)], [0]) == {0: math.inf}


def test_crowding_distance_duplicate_objective_column():
    # all values equal on one objective: hi == lo must not divide by zero
    objs = [(1.0, 5.0), (2.0, 5.0), (3.0, 5.0), (4.0, 5.0)]
    dist = crowding_distance(objs, [0, 1, 2, 3])
    assert dist[0] == math.inf and dist[3] == math.inf
    assert 0.0 <= dist[1] < math.inf and 0.0 <= dist[2] < math.inf


def test_crowding_distance_all_identical():
    objs = [(2.0, 2.0)] * 5
    dist = crowding_distance(objs, list(range(5)))
    assert all(v == math.inf or v == 0.0 for v in dist.values())


# ------------------------------------------------------- frontend detection
def test_detect_frontend_families():
    rng = np.random.default_rng(5)
    rows = [b"%d,%d" % (i, i * 2) for i in range(300)]
    assert isinstance(detect_frontend(b"\n".join(rows) + b"\n"), CsvFrontend)
    sorted_u32 = np.sort(rng.integers(0, 1 << 30, 4000)).astype(np.uint32)
    fe = detect_frontend(sorted_u32.tobytes())
    assert isinstance(fe, NumericFrontend) and fe.width == 4
    n = 2001
    rec = np.empty((n, 5), np.uint8)
    rec[:, :4] = rng.integers(0, 1000, n).astype(np.uint32).view(np.uint8).reshape(n, 4)
    rec[:, 4] = rng.integers(0, 3, n)
    fe = detect_frontend(rec.tobytes())
    assert isinstance(fe, StructFrontend) and sum(fe.widths) == 5
    raw = detect_frontend(rng.integers(0, 256, 7919).astype(np.uint8).tobytes())
    assert type(raw) is Frontend  # opaque bytes stay raw


def test_detect_frontend_graph_families():
    rng = np.random.default_rng(17)
    # SNAP-style text edge list: tab separated, # comments
    lines = [b"# Nodes: 200", b"# FromNodeId\tToNodeId"]
    for u in range(200):
        for v in np.unique(rng.integers(0, 200, 5)):
            lines.append(b"%d\t%d" % (u, v))
    fe = detect_frontend(b"\n".join(lines) + b"\n")
    assert isinstance(fe, GraphFrontend) and fe.sep == "\t" and not fe.binary_width
    # a *comma* two-integer-column file still sniffs as CSV (subsumes it)
    rows = [b"%d,%d" % (i, i * 2) for i in range(300)]
    assert isinstance(detect_frontend(b"\n".join(rows) + b"\n"), CsvFrontend)
    # binary interleaved (src, dst) pairs, source-sorted with sorted runs
    src = np.repeat(np.arange(150, dtype=np.uint32), 5)
    dst = np.concatenate(
        [np.sort(rng.choice(5000, 5, replace=False)) for _ in range(150)]
    ).astype(np.uint32)
    fe = detect_frontend(np.stack([src, dst], axis=1).tobytes())
    assert isinstance(fe, GraphFrontend) and fe.binary_width == 4
    # a plain sorted u32 array must stay numeric, not graph
    flat = np.sort(rng.integers(0, 1 << 30, 4000)).astype(np.uint32)
    assert isinstance(detect_frontend(flat.tobytes()), NumericFrontend)


def test_graph_frontend_trains_end_to_end():
    rng = np.random.default_rng(23)
    lines = [b"# graph"]
    for u in range(250):
        for v in np.unique(rng.integers(0, 250, 6)):
            lines.append(b"%d\t%d" % (u, v))
    data = b"\n".join(lines) + b"\n"
    fe = detect_frontend(data)
    assert isinstance(fe, GraphFrontend)
    tc = train([[serial(data)]], fe, pop_size=6, generations=1, seed=0, workers=2)
    comp = Compressor(tc.best_ratio_plan())
    assert comp.roundtrip_check(data)
    assert len(comp.compress(data)) < len(data)


def test_detected_frontend_trains_end_to_end():
    rng = np.random.default_rng(11)
    data = np.sort(rng.integers(0, 1 << 24, 3000)).astype(np.uint32).tobytes()
    fe = detect_frontend(data)
    tc = train([[serial(data)]], fe, pop_size=6, generations=1, seed=0, workers=2)
    comp = Compressor(tc.best_ratio_plan())
    assert comp.roundtrip_check(data)
    assert len(comp.compress(data)) < len(data)


# ------------------------------------------------------------------ CLI e2e
def test_cli_train_end_to_end(tmp_path):
    """`repro train` -> .ozp -> compress --plan -> decompress -> cmp, and
    `repro inspect` renders the trained graph."""
    rng = np.random.default_rng(3)
    animals = [b"cat", b"dog", b"emu"]
    rows = [
        b"%d,%s,%d" % (i * 5, animals[int(rng.integers(3))], int(rng.integers(50)))
        for i in range(2000)
    ]
    corpus = tmp_path / "tiny.csv"
    corpus.write_bytes(b"\n".join(rows) + b"\n")
    plan_path = tmp_path / "plan.ozp"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )

    def cli(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro", *args],
            check=True, env=env, cwd=REPO_ROOT, capture_output=True, text=True,
        ).stdout

    out = cli(
        "train", str(corpus), "--out", str(plan_path),
        "--pop", "6", "--gens", "1", "--workers", "2", "--seed", "0",
    )
    assert "frontend: csv (3 cols" in out
    assert "verified lossless" in out
    assert plan_path.exists() and plan_path.stat().st_size > 0

    frame_path = tmp_path / "tiny.ozl"
    out = cli("compress", str(corpus), "-o", str(frame_path),
              "--plan", str(plan_path))
    assert "plan=trained_csv" in out
    assert frame_path.stat().st_size < corpus.stat().st_size

    out = cli("inspect", str(frame_path))
    assert "csv_split" in out  # the trained graph renders

    rt_path = tmp_path / "tiny.rt"
    cli("decompress", str(frame_path), "-o", str(rt_path))
    assert rt_path.read_bytes() == corpus.read_bytes()


def test_cli_train_deterministic_across_workers(tmp_path):
    rng = np.random.default_rng(4)
    corpus = tmp_path / "vals.bin"
    corpus.write_bytes(
        np.sort(rng.integers(0, 1 << 24, 4000)).astype(np.uint32).tobytes()
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    blobs = {}
    for workers in (1, 4):
        plan_path = tmp_path / f"plan_w{workers}.ozp"
        subprocess.run(
            [
                sys.executable, "-m", "repro", "train", str(corpus),
                "--out", str(plan_path), "--pop", "6", "--gens", "1",
                "--seed", "5", "--workers", str(workers), "--all-points",
            ],
            check=True, env=env, cwd=REPO_ROOT, capture_output=True,
        )
        points = sorted(tmp_path.glob(f"plan_w{workers}*.ozp"))
        blobs[workers] = [p.read_bytes() for p in points]
    assert blobs[1] == blobs[4], "CLI plans differ across --workers"
