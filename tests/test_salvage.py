"""Salvage decoding: best-effort recovery of damaged containers.

The contract: the default decode path stays **fail-closed** (any corruption
raises), while the explicit salvage path recovers every intact chunk
byte-exactly and reports the lost chunk indices — never silently wrong
data, never a guess presented as a clean decode.
"""
import io

import numpy as np
import pytest

from repro.cli import main as cli_main
from repro.codecs.profiles import resolve_profile_spec
from repro.core import compress, decompress
from repro.core.engine import DecompressorSession
from repro.core.message import serial
from repro.core.wire import (
    FrameError,
    read_varint,
    salvage_container,
    verify_container,
)

CHUNK = 2048
N_CHUNKS = 64


def _payload() -> bytes:
    rng = np.random.default_rng(42)
    # compressible but chunk-distinct content
    base = rng.integers(0, 8, size=N_CHUNKS * CHUNK, dtype=np.uint8)
    return (base + np.arange(N_CHUNKS * CHUNK, dtype=np.uint64) // CHUNK % 8).astype(
        np.uint8
    ).tobytes()


def _container(payload: bytes) -> bytes:
    return compress(resolve_profile_spec("generic"), serial(payload), chunk_bytes=CHUNK)


def _chunk_spans(blob: bytes):
    """[(frame_start, frame_end)] for each chunk, plus each length-varint pos."""
    n, pos = read_varint(blob, 5)
    spans, lens = [], []
    for _ in range(n):
        lens.append(pos)
        ln, pos = read_varint(blob, pos)
        spans.append((pos, pos + ln))
        pos += ln
    return spans, lens


@pytest.fixture(scope="module")
def intact():
    payload = _payload()
    blob = _container(payload)
    return payload, blob


# ------------------------------------------------------------------ the demo
def test_salvage_recovers_61_of_64_chunks_byte_exact(intact):
    payload, blob = intact
    spans, _ = _chunk_spans(blob)
    assert len(spans) == N_CHUNKS
    bad = bytearray(blob)
    for i in (7, 8, 40):  # corrupt three chunk payloads (structure intact)
        lo, hi = spans[i]
        bad[(lo + hi) // 2] ^= 0xFF
    bad = bytes(bad)

    # default path: fail closed
    with pytest.raises((FrameError, ValueError)):
        decompress(bad)

    with DecompressorSession() as sess:
        streams, report = sess.decompress_salvage(bad)
    assert report.n_chunks == N_CHUNKS
    assert len(streams) == len(report.recovered) == N_CHUNKS - 3
    assert report.recovered_unplaced == 0
    assert report.damaged == [(7, 8), (40, 40)]
    assert not report.trailer_ok and not report.intact
    for s, idx in zip(streams, report.recovered):
        assert s.content_bytes() == payload[idx * CHUNK : (idx + 1) * CHUNK]


def test_destroyed_length_varint_resyncs_all_chunks(intact):
    payload, blob = intact
    _, lens = _chunk_spans(blob)
    bad = bytearray(blob)
    bad[lens[20]] ^= 0x80  # chunk 20's length varint: structure destroyed
    with pytest.raises((FrameError, ValueError)):
        decompress(bytes(bad))
    with DecompressorSession() as sess:
        streams, report = sess.decompress_salvage(bytes(bad))
    # resync on the next frame magic + per-frame CRC recovers everything:
    # chunk 20's frame itself is undamaged, only the container framing was
    assert len(streams) == N_CHUNKS and report.recovered == list(range(N_CHUNKS))
    for i, s in enumerate(streams):
        assert s.content_bytes() == payload[i * CHUNK : (i + 1) * CHUNK]


def test_truncated_tail_recovers_prefix(intact):
    payload, blob = intact
    spans, _ = _chunk_spans(blob)
    cut = (spans[-1][0] + spans[-1][1]) // 2  # mid-way through the last frame
    with DecompressorSession() as sess:
        streams, report = sess.decompress_salvage(blob[:cut])
    assert report.recovered == list(range(N_CHUNKS - 1))
    assert any(lo == N_CHUNKS - 1 for lo, _hi in report.damaged)
    for i, s in enumerate(streams):
        assert s.content_bytes() == payload[i * CHUNK : (i + 1) * CHUNK]


def test_intact_container_salvages_clean(intact):
    payload, blob = intact
    with DecompressorSession() as sess:
        streams, report = sess.decompress_salvage(blob)
    assert report.intact and report.trailer_ok
    assert b"".join(s.content_bytes() for s in streams) == payload


def test_salvage_bare_frame_paths():
    frame = compress(resolve_profile_spec("generic"), serial(b"hello " * 400))
    with DecompressorSession() as sess:
        streams, report = sess.decompress_salvage(frame)
        assert report.intact and len(streams) == 1
        bad = bytearray(frame)
        bad[len(bad) // 2] ^= 0xFF
        streams, report = sess.decompress_salvage(bytes(bad))
    # a bare frame has no chunk redundancy: nothing recoverable, says so
    assert streams == [] and report.damaged == [(0, 0)] and not report.intact


def test_verify_container_reports_damage_without_decoding(intact):
    _payload_, blob = intact
    assert verify_container(io.BytesIO(blob)).intact
    spans, _ = _chunk_spans(blob)
    bad = bytearray(blob)
    lo, hi = spans[3]
    bad[(lo + hi) // 2] ^= 0x01
    report = verify_container(io.BytesIO(bytes(bad)))
    assert not report.intact
    assert (3, 3) in report.damaged
    assert report.trailer_ok is False


def test_salvage_container_matches_session_report(intact):
    payload, blob = intact
    spans, _ = _chunk_spans(blob)
    bad = bytearray(blob)
    bad[sum(spans[11]) // 2] ^= 0x10
    frames, report = salvage_container(bytes(bad))
    assert report.damaged == [(11, 11)]
    assert len(frames) == N_CHUNKS - 1


# ------------------------------------------------------------------ CLI e2e
def test_cli_salvage_and_verify(tmp_path, intact, capsys):
    payload, blob = intact
    spans, _ = _chunk_spans(blob)
    bad = bytearray(blob)
    for i in (7, 8, 40):
        lo, hi = spans[i]
        bad[(lo + hi) // 2] ^= 0xFF
    good_f = tmp_path / "good.ozl"
    bad_f = tmp_path / "bad.ozl"
    good_f.write_bytes(blob)
    bad_f.write_bytes(bytes(bad))

    # inspect --verify: exit 0 on pristine, nonzero + damage report on corrupt
    assert cli_main(["inspect", str(good_f), "--verify"]) == 0
    assert cli_main(["inspect", str(bad_f), "--verify"]) == 1
    out = capsys.readouterr().out
    assert "61/64 recovered" in out and "7..8, 40" in out

    # default decompress: fail closed (CLI error exit), no output file
    dst = tmp_path / "out.bin"
    assert cli_main(["decompress", str(bad_f), "-o", str(dst)]) == 2
    assert not dst.exists()

    # salvage decompress: exit 1 (recovered with losses), intact chunks only
    assert cli_main(["decompress", str(bad_f), "-o", str(dst), "--salvage"]) == 1
    want = b"".join(
        payload[i * CHUNK : (i + 1) * CHUNK]
        for i in range(N_CHUNKS)
        if i not in (7, 8, 40)
    )
    assert dst.read_bytes() == want

    # salvage of an intact container: clean exit, full roundtrip
    dst2 = tmp_path / "out2.bin"
    assert cli_main(["decompress", str(good_f), "-o", str(dst2), "--salvage"]) == 0
    assert dst2.read_bytes() == payload
