"""Calibration tests documenting the dry-run measurement semantics that the
roofline analysis relies on (EXPERIMENTS.md §2):

  1. cost_analysis()['flops'] of an SPMD executable is PER-DEVICE,
  2. memory_analysis() argument sizes are PER-DEVICE (shards + replicas),
  3. post-SPMD HLO collectives carry per-device transfer shapes,
  4. while-loop (scan) bodies are counted ONCE by cost analysis — the
     documented undercount the roofline corrects by xN_layers.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run4(body: str) -> str:
    script = (
        "import os\n"
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"\n'
        # newer jax returns cost_analysis() as a dict, older as a 1-list of dicts
        "def _cost(compiled):\n"
        "    ca = compiled.cost_analysis()\n"
        "    return ca[0] if isinstance(ca, (list, tuple)) else ca\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=300, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_cost_and_memory_are_per_device():
    out = run4(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4,), ("data",))
        M = N = K = 1024
        sh_a = NamedSharding(mesh, P("data", None))
        sh_b = NamedSharding(mesh, P(None, None))
        with mesh:
            compiled = jax.jit(lambda a, b: a @ b, in_shardings=(sh_a, sh_b)).lower(
                jax.ShapeDtypeStruct((M, K), jnp.float32),
                jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
        flops = _cost(compiled)["flops"]
        # global 2*M*N*K = 2.147e9; per-device = /4
        assert abs(flops - 2 * M * N * K / 4) < 1e6, flops
        m = compiled.memory_analysis()
        # per-device args: a shard (1MB) + b replicated (4MB)
        assert abs(m.argument_size_in_bytes - (M * K + K * N + 0) * 4 // 4 - 3 * K * N) < (1 << 20)
        print("PER_DEVICE_OK", flops, m.argument_size_in_bytes)
        """
    )
    assert "PER_DEVICE_OK" in out


def test_scan_bodies_counted_once():
    """The undercount the roofline's xn_layers correction exists for."""
    out = run4(
        """
        import jax, jax.numpy as jnp
        N_STEPS = 8
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=N_STEPS)
            return out
        def f_unrolled(x, w):
            for _ in range(N_STEPS):
                x = jnp.tanh(x @ w)
            return x
        sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        fl_loop = _cost(jax.jit(f).lower(sds, sds).compile())["flops"]
        fl_unrl = _cost(jax.jit(f_unrolled).lower(sds, sds).compile())["flops"]
        ratio = fl_unrl / fl_loop
        assert 4 <= ratio <= N_STEPS * 1.5, (fl_loop, fl_unrl)
        print("SCAN_UNDERCOUNT_OK", ratio)
        """
    )
    assert "SCAN_UNDERCOUNT_OK" in out


def test_collective_parse_and_cross_pod_split():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = f32[4,128]{1,0} all-gather(%x), replica_groups=[1,4]<=[4], dimensions={0}
  %ar-start = bf16[256]{0} all-reduce-start(%y), replica_groups={{0,2},{1,3}}
  %ar-done = bf16[256]{0} all-reduce-done(%ar-start)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 4 * 128 * 4
    assert out["all-reduce"] == 256 * 2  # -start counted, -done skipped
    assert out["total"] == out["all-gather"] + out["all-reduce"]

    # cross-pod split: explicit groups {0,2},{1,3} cross the half=2 boundary
    out2 = collective_bytes(hlo.replace("[1,4]<=[4]", "[2,2]<=[4]"), n_devices=512)
    assert "cross_pod" in out2


def test_model_flops_sanity():
    """6*N*D for the dense LMs is within 2x of a hand count."""
    from benchmarks.roofline import model_flops

    # yi-9b train_4k: ~8.8e9 params x 1.05e6 tokens x 6 ~ 5.5e16 + attention
    mf = model_flops("yi-9b", "train_4k")
    assert 4e16 < mf < 1.2e17, mf
    # decode is ~seq_len smaller than prefill per token budget
    assert model_flops("yi-9b", "decode_32k") < mf / 1000
    # SWA long-context decode stays bounded by the window
    danube_long = model_flops("h2o-danube-3-4b", "long_500k")
    danube_32k = model_flops("h2o-danube-3-4b", "decode_32k")
    assert danube_long < danube_32k  # batch 1 vs 128, window-capped attention
