"""Adversarial service-protocol tests, in the spirit of test_wire_fuzz.py.

Invariant: no byte sequence a client can send — truncated, oversized,
garbage, or cut off mid-body — may wedge a server worker, leak a checked-out
session, or crash the daemon.  Every scenario ends the same way: the server
answers with an error response and/or drops the connection, and a subsequent
well-formed request on a fresh connection succeeds with the session pool
fully returned (``in_use == 0``).
"""
import io
import socket
import threading

import pytest

from repro.codecs import profiles as P
from repro.core import compress, serial
from repro.service import CompressionServer, PlanRegistry, ServiceClient
from repro.service import protocol as SP

DATA = b"fuzz corpus: level=INFO svc=auth handled\n" * 200


@pytest.fixture()
def server(tmp_path):
    registry = PlanRegistry()
    registry.register_profile("generic")
    srv = CompressionServer(
        registry,
        socket_path=str(tmp_path / "fuzz.sock"),
        max_clients=8,
        sessions_per_plan=2,
        request_timeout=5.0,
    )
    with srv:
        yield srv


def _connect(server) -> socket.socket:
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.settimeout(10.0)
    s.connect(server.socket_path)
    return s


def _send_then_close(server, blob: bytes) -> bytes:
    """Write raw bytes, half-close, read whatever the server answers.

    A reset/broken pipe mid-exchange *is* a valid server reaction to hostile
    bytes (it dropped us before we finished) — that reads as "no response".
    """
    s = _connect(server)
    out = bytearray()
    try:
        if blob:
            s.sendall(blob)
        s.shutdown(socket.SHUT_WR)
        while True:
            piece = s.recv(65536)
            if not piece:
                return bytes(out)
            out += piece
    except (ConnectionResetError, BrokenPipeError):
        return bytes(out)
    finally:
        s.close()


def _valid_request_bytes(chunk_bytes: int = 4096) -> bytes:
    buf = io.BytesIO()
    SP.write_request(
        buf,
        SP.VERB_COMPRESS,
        {"plan": "generic", "size": len(DATA), "chunk_bytes": chunk_bytes},
        SP.iter_body_blocks(DATA, 1024),
    )
    return buf.getvalue()


def _assert_healthy(server):
    """The one postcondition every scenario must leave behind."""
    with ServiceClient(server.address, timeout=10.0) as c:
        frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
        assert frame == compress(P.generic_profile(), serial(DATA), chunk_bytes=4096)
        st = c.stats()
    for key_stats in st["sessions"].values():
        assert key_stats["in_use"] == 0, "leaked checked-out session"


def _response_status(blob: bytes):
    """None when the server just closed; else the response status code."""
    if not blob:
        return None
    status, header, body = SP.read_response(io.BytesIO(blob))
    body.drain()
    return status, header


# ------------------------------------------------------------------ scenarios
def test_every_prefix_truncation(server):
    """EOF at any point of a request: the worker frees, the daemon survives."""
    req = _valid_request_bytes()
    for cut in range(0, len(req), max(len(req) // 59, 1)):
        out = _send_then_close(server, req[:cut])
        if out:  # if the server answered at all, it answered an error frame
            status, header = _response_status(out)
            assert status == SP.STATUS_ERROR
            assert header.get("error")
    _assert_healthy(server)


def test_random_bytes_fail_closed(server):
    import numpy as np

    rng = np.random.default_rng(7)
    for n in (1, 4, 16, 200, 4096):
        out = _send_then_close(server, rng.bytes(n))
        if out:
            status, _ = _response_status(out)
            assert status == SP.STATUS_ERROR
    _assert_healthy(server)


def test_garbage_verb_rejected(server):
    buf = io.BytesIO()
    SP.write_message(buf, SP.REQUEST_MAGIC, 99, {"plan": "generic"}, [b"x"])
    status, header = _response_status(_send_then_close(server, buf.getvalue()))
    assert status == SP.STATUS_ERROR
    _assert_healthy(server)


def test_bad_magic_rejected(server):
    req = _valid_request_bytes()
    status_out = _response_status(_send_then_close(server, b"EVIL" + req[4:]))
    if status_out is not None:
        assert status_out[0] == SP.STATUS_ERROR
    _assert_healthy(server)


def test_oversized_length_varints_rejected(server):
    # header length varint overflowing 64 bits
    blob = SP.REQUEST_MAGIC + bytes([SP.VERB_PING]) + b"\xff" * 10
    status_out = _response_status(_send_then_close(server, blob))
    if status_out is not None:
        assert status_out[0] == SP.STATUS_ERROR
    # header length over the 1 MiB cap (but a valid varint)
    head = bytearray(SP.REQUEST_MAGIC + bytes([SP.VERB_PING]))
    from repro.core.wire import write_varint

    write_varint(head, SP.MAX_HEADER_BYTES + 1)
    status_out = _response_status(_send_then_close(server, bytes(head)))
    if status_out is not None:
        assert status_out[0] == SP.STATUS_ERROR
    # body block over the 64 MiB cap
    buf = io.BytesIO()
    SP.write_message(
        buf, SP.REQUEST_MAGIC, SP.VERB_COMPRESS, {"plan": "generic"}
    )
    blob = bytearray(buf.getvalue()[:-1])  # drop the terminator
    write_varint(blob, SP.MAX_BLOCK_BYTES + 1)
    status_out = _response_status(_send_then_close(server, bytes(blob)))
    if status_out is not None:
        assert status_out[0] == SP.STATUS_ERROR
    _assert_healthy(server)


def test_undecodable_header_rejected(server):
    blob = bytearray(SP.REQUEST_MAGIC + bytes([SP.VERB_COMPRESS]))
    from repro.core.wire import write_varint

    junk = b"\xc1\xc1\xc1\xc1"  # 0xc1 is an invalid msgpack type byte
    write_varint(blob, len(junk))
    blob += junk
    status_out = _response_status(_send_then_close(server, bytes(blob)))
    if status_out is not None:
        assert status_out[0] == SP.STATUS_ERROR
    _assert_healthy(server)


def test_mid_body_disconnect(server):
    """Header promises a body; the client vanishes mid-block."""
    req = _valid_request_bytes()
    # find a cut point inside the body (past magic+verb+header)
    buf = io.BytesIO()
    SP.write_message(
        buf, SP.REQUEST_MAGIC, SP.VERB_COMPRESS,
        {"plan": "generic", "size": len(DATA), "chunk_bytes": 4096},
    )
    header_len = len(buf.getvalue()) - 1  # minus the empty-body terminator
    cut = header_len + (len(req) - header_len) // 2
    out = _send_then_close(server, req[:cut])
    if out:
        status, _ = _response_status(out)
        assert status == SP.STATUS_ERROR
    _assert_healthy(server)


def test_stacked_requests_then_garbage(server):
    """Several valid requests pipelined on one connection, then garbage: the
    valid ones are all answered before the connection drops."""
    req = _valid_request_bytes()
    blob = req * 3 + b"\x00garbage-that-is-not-a-request"
    out = _send_then_close(server, blob)
    r = io.BytesIO(out)
    statuses = []
    for _ in range(3):
        status, _h, body = SP.read_response(r)
        body.drain()
        statuses.append(status)
    assert statuses == [SP.STATUS_OK] * 3
    # whatever follows (error response and/or close) is not a fourth OK
    rest = r.read()
    if rest:
        status, _h, body = SP.read_response(io.BytesIO(rest))
        body.drain()
        assert status == SP.STATUS_ERROR
    _assert_healthy(server)


def test_concurrent_clients_with_interleaved_garbage(server):
    """8 threads hammer the daemon with alternating valid and hostile
    traffic; every valid exchange must still come back correct."""
    want = compress(P.generic_profile(), serial(DATA), chunk_bytes=4096)
    req = _valid_request_bytes()
    errors = []

    def hostile(i):
        try:
            for cut in range(0, len(req), max(len(req) // 7, 1)):
                _send_then_close(server, req[: cut + i])
        except Exception as err:  # pragma: no cover
            errors.append(("hostile", i, err))

    def honest(i):
        try:
            with ServiceClient(server.address, timeout=15.0) as c:
                for _ in range(3):
                    frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
                    assert frame == want
        except Exception as err:  # pragma: no cover
            errors.append(("honest", i, err))

    threads = [
        threading.Thread(target=hostile if i % 2 else honest, args=(i,))
        for i in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors, errors
    _assert_healthy(server)


def test_worker_not_wedged_by_many_bad_connections(server):
    """More hostile connections than worker threads: each one must free its
    worker, or this loop (and the health check) would deadlock."""
    for i in range(3 * server.max_clients):
        _send_then_close(server, b"\xff" * (i % 7))
    _assert_healthy(server)


def test_body_limit_cuts_off_oversized_senders():
    """A reader with a limit set must reject the first over-budget block
    before buffering it — the server's guard against size-lying floods."""
    buf = io.BytesIO()
    SP.write_message(buf, SP.REQUEST_MAGIC, SP.VERB_COMPRESS,
                     {"plan": "generic", "size": 16}, [b"x" * 64])
    _verb, _header, body = SP.read_request(io.BytesIO(buf.getvalue()))
    body.limit = 16
    with pytest.raises(SP.ProtocolError, match="limit"):
        body.read()
    # within budget: same body with a matching limit reads fine
    _verb, _header, body = SP.read_request(io.BytesIO(buf.getvalue()))
    body.limit = 64
    assert body.read() == b"x" * 64


def test_compress_declared_size_caps_body(server):
    """End to end: a tiny declared size with a huge body is rejected without
    the server swallowing the flood (bare-frame path included)."""
    s = _connect(server)
    try:
        w = s.makefile("wb")
        SP.write_request(
            w, SP.VERB_COMPRESS,
            {"plan": "generic", "size": 16, "chunk_bytes": 0},
            SP.iter_body_blocks(DATA, 1024),
        )
    except (BrokenPipeError, ConnectionResetError):
        pass  # server cut us off mid-flood: exactly the point
    finally:
        s.close()
    _assert_healthy(server)


def _small_cap_server(tmp_path, cap: int = 64 << 10) -> CompressionServer:
    registry = PlanRegistry()
    registry.register_profile("generic")
    return CompressionServer(
        registry,
        socket_path=str(tmp_path / "cap.sock"),
        max_body_bytes=cap,
        request_timeout=5.0,
    )


def test_declared_size_cannot_widen_the_cap(tmp_path):
    """Regression (high severity): a declared ``size`` above max_body_bytes
    used to *replace* the cap, so ``size=2**60`` unbounded the read.  The
    declaration may only narrow the budget; over-declaring is rejected up
    front, on both verbs."""
    with _small_cap_server(tmp_path) as srv:
        for verb, header in (
            (SP.VERB_COMPRESS,
             {"plan": "generic", "size": 1 << 60, "chunk_bytes": 0}),
            (SP.VERB_DECOMPRESS, {"size": 1 << 60}),
        ):
            buf = io.BytesIO()
            SP.write_request(buf, verb, header, [b"tiny"])
            status, header = _response_status(
                _send_then_close(srv, buf.getvalue())
            )
            assert status == SP.STATUS_ERROR
            assert "limit" in header["error"]
        _assert_healthy(srv)


def test_oversized_declared_flood_is_cut_off(tmp_path):
    """A hostile client that over-declares *and* keeps streaming is cut off
    after at most max_body_bytes — the reject-path drain is capped too."""
    with _small_cap_server(tmp_path) as srv:
        flood = b"\xaa" * (4 * srv.max_body_bytes)
        buf = io.BytesIO()
        SP.write_request(
            buf, SP.VERB_COMPRESS,
            {"plan": "generic", "size": 1 << 60, "chunk_bytes": 0},
            SP.iter_body_blocks(flood, 8192),
        )
        out = _send_then_close(srv, buf.getvalue())
        if out:  # the server may also just drop us mid-flood
            status, _ = _response_status(out)
            assert status == SP.STATUS_ERROR
        _assert_healthy(srv)


def test_undeclared_size_still_capped(tmp_path):
    """Omitting the size header must not lift the cap either (the original
    guard only fired when the client *declared* a size)."""
    with _small_cap_server(tmp_path) as srv:
        flood = b"\xaa" * (4 * srv.max_body_bytes)
        buf = io.BytesIO()
        SP.write_request(
            buf, SP.VERB_COMPRESS,
            {"plan": "generic", "chunk_bytes": 0},
            SP.iter_body_blocks(flood, 8192),
        )
        out = _send_then_close(srv, buf.getvalue())
        if out:
            status, _ = _response_status(out)
            assert status == SP.STATUS_ERROR
        _assert_healthy(srv)


def test_reject_path_drain_is_bounded(tmp_path):
    """A request rejected *before* its declared size is even looked at
    (unknown plan here) must still drain under the hard cap: the over-cap
    flood drops the connection, so a pipelined follow-up is never served
    (an uncapped drain would swallow the flood and answer it)."""
    with _small_cap_server(tmp_path) as srv:
        flood = b"\xaa" * (4 * srv.max_body_bytes)
        buf = io.BytesIO()
        SP.write_request(
            buf, SP.VERB_COMPRESS,
            {"plan": "no-such-plan", "chunk_bytes": 0},
            SP.iter_body_blocks(flood, 8192),
        )
        SP.write_request(buf, SP.VERB_PING, {})
        out = _send_then_close(srv, buf.getvalue())
        r = io.BytesIO(out)
        if out:
            status, _h, body = SP.read_response(r)
            body.drain()
            assert status == SP.STATUS_ERROR
        assert not r.read(), "server drained an over-cap body and kept serving"
        _assert_healthy(srv)


def test_client_rejects_malformed_response():
    """The client side fails closed too: a fake server speaking garbage."""
    fake = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    fake.bind(("127.0.0.1", 0))
    fake.listen(1)
    port = fake.getsockname()[1]

    def fake_server():
        conn, _ = fake.accept()
        conn.recv(65536)
        conn.sendall(b"HTTP/1.1 200 OK\r\n\r\nnot the protocol")
        conn.close()

    t = threading.Thread(target=fake_server)
    t.start()
    try:
        c = ServiceClient(("127.0.0.1", port), timeout=5.0)
        with pytest.raises(SP.ProtocolError, match="bad magic"):
            c.ping()
        c.close()
    finally:
        t.join(10)
        fake.close()


def test_struct_unpack_responses_have_no_padding():
    """Protocol primitives reject a truncated varint and short reads."""
    with pytest.raises(SP.ProtocolError):
        SP.read_response(io.BytesIO(SP.RESPONSE_MAGIC))  # no status byte
    with pytest.raises(SP.ProtocolError):
        SP.read_response(io.BytesIO(SP.RESPONSE_MAGIC + b"\x00\xff"))
    buf = io.BytesIO()
    SP.write_response(buf, SP.STATUS_OK, {"x": 1}, [b"abc"])
    blob = buf.getvalue()
    for cut in range(len(blob)):  # every proper prefix must fail closed
        try:
            status, header, body = SP.read_response(io.BytesIO(blob[:cut]))
            body.read()
        except SP.ProtocolError:
            continue
        pytest.fail(f"prefix of {cut}/{len(blob)} bytes parsed cleanly")
    # sanity: the full message parses
    status, header, body = SP.read_response(io.BytesIO(blob))
    assert (status, header, body.read()) == (SP.STATUS_OK, {"x": 1}, b"abc")


# ----------------------------------------------------- async frontend, hostile
# The same no-wedge invariant, aimed at the selector event loop: one thread
# multiplexes every socket, so a single parked parser state machine (or a
# thousand) must never stall honest traffic, and every deadline must fire
# without a thread blocked per victim.
import contextlib
import time

from repro.service import RateLimiter, RequestCore, ServiceFrontend
from repro.service import ServiceUnavailable


class _Frontend:
    """Duck-types the CompressionServer surface the helpers above touch."""

    def __init__(self, tmp_path, *, rate_limit=None, rate_burst=None, **kw):
        registry = PlanRegistry()
        registry.register_profile("generic")
        self.socket_path = str(tmp_path / "front.sock")
        self.address = f"unix:{self.socket_path}"
        self.core = RequestCore(
            registry,
            sessions_per_plan=2,
            request_timeout=kw.get("request_timeout", 5.0),
        )
        lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        lst.bind(self.socket_path)
        lst.listen(128)
        limiter = RateLimiter(rate_limit, rate_burst) if rate_limit else None
        self.frontend = ServiceFrontend(
            self.core,
            lst,
            compute_threads=2,
            rate_limiter=limiter,
            owns_listener=True,
            **kw,
        )
        self._thread = threading.Thread(
            target=self.frontend.serve_forever, daemon=True
        )
        self._thread.start()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.frontend.stop()
        self._thread.join(10)
        assert not self._thread.is_alive(), "event loop failed to exit"
        self.core.close()


@contextlib.contextmanager
def _frontend(tmp_path, **kw):
    with _Frontend(tmp_path, **kw) as f:
        yield f


def test_frontend_survives_hostile_blobs(tmp_path):
    """The incremental parser fails closed on the classic hostile shapes."""
    hostile = [
        b"",
        b"NOPE" + b"\x00" * 16,                      # bad magic
        SP.REQUEST_MAGIC,                            # magic, then EOF
        SP.REQUEST_MAGIC + b"\x63",                  # unknown verb
        SP.REQUEST_MAGIC + b"\x00" + b"\xff" * 10,   # varint overflow
        SP.REQUEST_MAGIC + b"\x00\x05nope!",         # undecodable header
        _valid_request_bytes()[:40],                 # truncated mid-header
    ]
    with _frontend(tmp_path) as srv:
        for blob in hostile:
            out = _send_then_close(srv, blob)
            if out:
                status, header = _response_status(out)
                assert status == SP.STATUS_ERROR
                assert header.get("error")
        _assert_healthy(srv)


def test_frontend_slow_loris_partial_frames(tmp_path):
    """Dozens of sockets each park a byte or two of a request and go silent.
    The event loop must keep serving honest clients at full speed, then
    reap every loris at the request deadline — without a thread per victim."""
    req = _valid_request_bytes()
    with _frontend(tmp_path, request_timeout=1.0, max_conns=128) as srv:
        lorises = []
        for i in range(40):
            s = _connect(srv)
            s.sendall(req[: 1 + (i % 7)])  # mid-frame: deadline must arm
            lorises.append(s)
        try:
            # honest traffic threads through the parked crowd, promptly
            t0 = time.monotonic()
            _assert_healthy(srv)
            assert time.monotonic() - t0 < 5.0, "loris crowd stalled the loop"
            # every loris gets reaped at the deadline, not held forever
            deadline = time.monotonic() + 10.0
            for s in lorises:
                s.settimeout(max(0.1, deadline - time.monotonic()))
                while True:
                    try:
                        if not s.recv(65536):
                            break
                    except (ConnectionResetError, BrokenPipeError):
                        break
        finally:
            for s in lorises:
                s.close()
        _assert_healthy(srv)
        st = srv.frontend.transport_stats()
        assert st["active_connections"] <= 1  # at most the health-check conn


def test_frontend_mid_frame_disconnect_storm(tmp_path):
    """Connections that vanish mid-frame, back to back, must not accumulate
    state or wedge the loop."""
    import numpy as np

    rng = np.random.default_rng(23)
    req = _valid_request_bytes()
    with _frontend(tmp_path, request_timeout=2.0) as srv:
        for _ in range(60):
            cut = int(rng.integers(1, len(req)))
            s = _connect(srv)
            s.sendall(req[:cut])
            s.close()  # no shutdown, no read: just gone
        _assert_healthy(srv)


def test_frontend_rate_limit_rejects_and_recovers(tmp_path):
    with _frontend(tmp_path, rate_limit=1.0, rate_burst=2.0) as srv:
        with ServiceClient(srv.address, timeout=10.0) as c:
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            with pytest.raises(ServiceUnavailable) as exc:
                c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert exc.value.kind == "rate_limited"
            assert exc.value.retry_after and exc.value.retry_after > 0
            # the connection survives the rejection; control verbs stay free
            assert c.ping()["ok"]
            assert c.stats()["rate_limited"] >= 1
        # a fresh connection holds a fresh bucket (Unix peers are per-conn)
        _assert_healthy(srv)


def test_frontend_sheds_connections_over_capacity(tmp_path):
    """Accepts past max_conns get the prebuilt overloaded frame, instantly,
    while the seated connections keep working."""
    with _frontend(tmp_path, max_conns=2) as srv:
        seated = [_connect(srv) for _ in range(2)]
        try:
            out = _send_then_close(srv, b"")
            assert out, "over-capacity connect got no shed frame"
            status, header = _response_status(out)
            assert status == SP.STATUS_ERROR
            assert header.get("error_kind") == "overloaded"
            assert header.get("retry_after")
        finally:
            for s in seated:
                s.close()
        # wait for the loop to notice the hangups — a dial that races the
        # EOF processing is (correctly) shed, which is not what we're testing
        deadline = time.monotonic() + 5.0
        while (
            srv.frontend.transport_stats()["active_connections"] > 0
            and time.monotonic() < deadline
        ):
            time.sleep(0.02)
        # seats freed: honest traffic flows again
        _assert_healthy(srv)
        assert srv.frontend.transport_stats()["shed_connections"] >= 1


def test_frontend_pipelined_requests_one_connection(tmp_path):
    """Two complete requests written back to back on one socket get two
    complete, in-order responses (the parser re-feeds buffered bytes)."""
    req = _valid_request_bytes()
    with _frontend(tmp_path) as srv:
        blob = _send_then_close(srv, req + req)
        r = io.BytesIO(blob)
        for _ in range(2):
            status, header, body = SP.read_response(r)
            out = body.read()
            assert status == SP.STATUS_OK
            assert out == compress(
                P.generic_profile(), serial(DATA), chunk_bytes=4096
            )
        assert not r.read()
        _assert_healthy(srv)
