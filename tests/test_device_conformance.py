"""Device-backend wire conformance against the frozen golden corpus.

The device backend registers *encoder twins* (currently huffman and fse)
that must be bit-identical to the host encoders — same streams, same
header, same frame.  Two layers of proof:

  * every golden vector re-encoded with ``backend="device"`` reproduces the
    frozen frame byte-for-byte (vectors whose streams fall outside the
    device routability window fall back to host inside ``run_encode_via``,
    which must *also* reproduce the frame — either way the wire is pinned);
  * a direct non-vacuousness check per twin: on inputs inside the window
    the device ``applies`` gate is True and the twin's raw encoder output
    (streams + header) matches the host encoder exactly, so the corpus pass
    above cannot be green merely because every twin declined to run.
"""
import numpy as np
import pytest
from _golden import (
    GOLDEN_DIR,
    LEVEL,
    MANIFEST,
    load_manifest,
    stream_from_entry,
)

from repro.codecs._util import device_available
from repro.core import CompressionCtx, compress
from repro.core.codec import get_backend_codec, get_codec
from repro.core.message import serial
from repro.core.serialize import deserialize_plan

MANIFEST_ENTRIES = load_manifest() if MANIFEST.exists() else {}
NAMES = sorted(MANIFEST_ENTRIES)
DEVICE_TWINS = ("huffman", "fse")

pytestmark = [
    pytest.mark.skipif(
        not MANIFEST_ENTRIES, reason="golden corpus missing (tests/golden/)"
    ),
    pytest.mark.skipif(
        not device_available(), reason="jax device backend unavailable"
    ),
]


def _input_stream(name: str):
    payload = (GOLDEN_DIR / f"{name}.in").read_bytes()
    return stream_from_entry(MANIFEST_ENTRIES[name], payload)


@pytest.mark.parametrize("name", NAMES)
def test_device_backend_emits_frozen_frame(name):
    entry = MANIFEST_ENTRIES[name]
    plan, _meta = deserialize_plan((GOLDEN_DIR / f"{name}.ozp").read_bytes())
    frame = compress(
        plan,
        [_input_stream(name)],
        ctx=CompressionCtx(entry["format_version"], LEVEL),
        backend="device",
        chunk_bytes=entry["chunk_bytes"] or None,
        use_resolve_cache=False,
    )
    assert frame == (GOLDEN_DIR / f"{name}.ozl").read_bytes(), (
        f"{name}: device-backend frame drifted from the frozen frame —"
        f" backend twins must be bit-identical to the host encoders"
    )


@pytest.mark.parametrize("codec", DEVICE_TWINS)
def test_device_twin_applies_and_matches_host(codec):
    rng = np.random.default_rng(7)
    # skewed bytes, comfortably inside the device routability window
    x = rng.zipf(1.3, size=1 << 16).astype(np.uint64) % 251
    s = serial(x.astype(np.uint8).tobytes())
    impl = get_backend_codec("device", codec)
    assert impl is not None and impl.applies([s], {}), (
        f"device twin for {codec} must accept an in-window byte stream"
    )
    spec = get_codec(codec)
    houts, hheader = spec.encode([s], {})
    douts, dheader = impl.encode([s], {})
    assert dheader == hheader
    assert len(douts) == len(houts)
    for d, h in zip(douts, houts):
        assert d.stype == h.stype and d.width == h.width
        assert d.content_bytes() == h.content_bytes()


@pytest.mark.parametrize("codec", DEVICE_TWINS)
def test_device_twin_declines_out_of_window(codec):
    impl = get_backend_codec("device", codec)
    tiny = serial(b"x" * 64)  # below _DEV_MIN: host fallback territory
    assert impl is not None and not impl.applies([tiny], {})
