"""OpenZL-compressed checkpointing: roundtrip, atomicity, keep-K, resume,
elastic restore, corruption detection (paper §VIII checkpoint use case)."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import (
    CheckpointManager,
    compress_leaf,
    decompress_leaf,
    latest_step,
    restore_checkpoint,
    restore_tree,
    save_checkpoint,
)

rng = np.random.default_rng(0)


def tree_eq(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


@pytest.fixture
def tree():
    return {
        "params": {
            "w": rng.normal(size=(64, 32)).astype(np.float32),
            "emb": rng.normal(size=(100, 16)).astype(np.float32),
            "steps": np.arange(50, dtype=np.int32),
        },
        "opt": {"m": rng.normal(size=(64, 32)).astype(np.float32), "count": np.int32(7)},
    }


def test_leaf_roundtrip_dtypes():
    for arr in [
        rng.normal(size=1000).astype(np.float32),
        rng.normal(size=1000).astype(np.float64),
        rng.integers(0, 1 << 30, 1000).astype(np.int64),
        rng.integers(0, 255, 1000).astype(np.uint8),
        (rng.random(1000) > 0.5),
        jnp.asarray(rng.normal(size=512), jnp.bfloat16),
    ]:
        arr = np.asarray(arr)
        frame = compress_leaf(arr)
        back = decompress_leaf(frame, arr.shape, arr.dtype)
        assert back.dtype == arr.dtype
        assert np.array_equal(back, arr)


def test_save_restore_roundtrip(tmp_path, tree):
    m = save_checkpoint(tmp_path, 10, tree)
    assert m["ratio"] > 1.0  # float-split graphs beat raw floats
    restored, manifest = restore_tree(tmp_path, tree, 10)
    assert tree_eq(tree, restored)
    assert manifest["step"] == 10


def test_bf16_embedding_compression_beats_raw(tmp_path):
    """Paper §VIII: bf16 embeddings compress ~30%; random normals compress
    less but MUST still beat raw (exponent plane is low entropy)."""
    emb = jnp.asarray(rng.normal(size=(1 << 14,)).astype(np.float32), jnp.bfloat16)
    tree = {"emb": emb}
    m = save_checkpoint(tmp_path, 1, tree)
    assert m["compressed_bytes"] < m["raw_bytes"] * 0.95
    restored, _ = restore_tree(tmp_path, tree, 1)
    assert np.array_equal(np.asarray(restored["emb"]), np.asarray(emb))


def test_atomicity_no_tmp_visible(tmp_path, tree):
    save_checkpoint(tmp_path, 5, tree)
    assert not list(tmp_path.glob("*.tmp"))
    assert latest_step(tmp_path) == 5


def test_partial_checkpoint_ignored(tmp_path, tree):
    save_checkpoint(tmp_path, 5, tree)
    save_checkpoint(tmp_path, 10, tree)
    # corrupt step 10: delete a leaf file
    victim = next((tmp_path / "step_0000000010").glob("leaf_*.ozl"))
    victim.unlink()
    assert latest_step(tmp_path) == 5  # falls back to last valid


def test_crc_detects_bitrot(tmp_path, tree):
    save_checkpoint(tmp_path, 5, tree)
    victim = next((tmp_path / "step_0000000005").glob("leaf_*.ozl"))
    blob = bytearray(victim.read_bytes())
    blob[len(blob) // 2] ^= 0x01
    victim.write_bytes(bytes(blob))
    with pytest.raises((IOError, ValueError)):
        restore_checkpoint(tmp_path, 5)


def test_manager_keep_k_and_resume(tmp_path, tree):
    mgr = CheckpointManager(tmp_path, save_interval=10, keep=2)
    for step in (10, 20, 30):
        mgr.save(step, tree)
    mgr.wait()
    steps = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step_"))
    assert len(steps) == 2  # keep-K enforced
    out = mgr.restore_or_none(tree)
    assert out is not None and out[0] == 30


def test_async_save(tmp_path, tree):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(7, tree)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_restore_resharding(tmp_path, tree):
    """Leaves are stored unsharded: restore works onto any device layout."""
    save_checkpoint(tmp_path, 3, tree)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree
    )
    restored, _ = restore_tree(tmp_path, tree, 3, shardings=shardings)
    assert tree_eq(tree, restored)
    assert all(
        isinstance(x, jax.Array) for x in jax.tree.leaves(restored)
    )
