"""Cross-checks pinning the vectorized LZ/Huffman/FSE hot paths against the
pre-existing scalar behavior (tests/_scalar_ref.py, the seed implementations).

THE invariant of this PR: for every input, the vectorized encoders emit
bit-identical output streams AND headers — so every frame any older build
produced still decodes, and every new frame is byte-for-byte what the old
build would have written.  Property-tested over random, constant, periodic,
and already-compressed inputs (hypothesis, guarded via tests/_hyp.py), plus
deterministic adversarial cases.
"""
import zlib

import numpy as np
import pytest
from _hyp import given, settings, st

import _scalar_ref as sr
from repro.codecs import entropy as vec_entropy
from repro.codecs import lz as vec_lz
from repro.core.message import serial


def _assert_bitwise_equal(codec, data):
    pairs = {
        "lz77": (sr._lz77_enc, vec_lz._lz77_enc, sr._lz77_dec, vec_lz._lz77_dec),
        "huffman": (
            sr._huffman_enc,
            vec_entropy._huffman_enc,
            sr._huffman_dec,
            vec_entropy._huffman_dec,
        ),
        "fse": (sr._fse_enc, vec_entropy._fse_enc, sr._fse_dec, vec_entropy._fse_dec),
    }
    ref_enc, new_enc, ref_dec, new_dec = pairs[codec]
    s = serial(data)
    ref_outs, ref_h = ref_enc([s], {})
    new_outs, new_h = new_enc([s], {})
    assert ref_h == new_h, f"{codec}: header diverged on {len(data)}-byte input"
    assert len(ref_outs) == len(new_outs)
    for i, (a, b) in enumerate(zip(ref_outs, new_outs)):
        assert a.stype == b.stype and a.width == b.width
        assert a.data.tobytes() == b.data.tobytes(), f"{codec}: stream {i} diverged"
    # old decoder reads new frames; new decoder reads (identical) old frames
    assert ref_dec(new_outs, new_h)[0].content_bytes() == data
    assert new_dec(ref_outs, ref_h)[0].content_bytes() == data


CODECS = ["lz77", "huffman", "fse"]


def _check_all(data: bytes) -> None:
    for codec in CODECS:
        _assert_bitwise_equal(codec, data)


@given(st.binary(min_size=0, max_size=8192))
@settings(max_examples=25, deadline=None)
def test_equiv_random(b):
    _check_all(b)


@given(st.integers(0, 255), st.integers(0, 12000))
@settings(max_examples=15, deadline=None)
def test_equiv_constant(byte, n):
    _check_all(bytes([byte]) * n)


@given(st.binary(min_size=1, max_size=16), st.integers(1, 2000))
@settings(max_examples=20, deadline=None)
def test_equiv_periodic(period, reps):
    _check_all(period * reps)


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=15, deadline=None)
def test_equiv_already_compressed(b):
    _check_all(zlib.compress(b, 9))


@pytest.mark.parametrize("codec", CODECS)
def test_equiv_deterministic_corpus(codec):
    rng = np.random.default_rng(1234)
    cases = [
        b"",
        b"a",
        b"abc",
        b"abcd",
        b"abcdabcd",
        b"the quick brown fox jumps over the lazy dog " * 250,
        bytes(rng.integers(0, 256, 70000).astype(np.uint8)),
        bytes(rng.integers(0, 4, 70000).astype(np.uint8)),
        np.cumsum(rng.integers(0, 3, 50000)).astype(np.uint8).tobytes(),
        b"\x00" * 70000,  # match length beyond MAX_MATCH
        (b"xy" + bytes(rng.integers(0, 256, 30000).astype(np.uint8))) * 2,
    ]
    for data in cases:
        _assert_bitwise_equal(codec, data)


def test_equiv_lane_block_boundaries():
    """Sizes straddling the entropy lane-block and LZ segment boundaries."""
    rng = np.random.default_rng(5)
    for n in [1023, 1024, 1025, 4095, 4096, 4097, 8192, 12289, 65536 + 17]:
        data = bytes(rng.choice(16, n).astype(np.uint8) + 97)
        for codec in CODECS:
            _assert_bitwise_equal(codec, data)


def test_prev_occurrence_matches_scalar():
    """The threaded half-sort hash chain equals the seed's global argsort."""
    rng = np.random.default_rng(9)
    for n in [0, 1, 3, 4, 100, 5000, (1 << 18) + 7, (1 << 18) + 4096]:
        data = rng.integers(0, 8, n).astype(np.uint8)
        got = vec_lz._prev_occurrence(data)
        want = sr._prev_occurrence(data)
        assert np.array_equal(got.astype(np.int64), want.astype(np.int64)), n


def test_trained_plans_still_roundtrip():
    """Wire compatibility: every shipped trained plan still encodes/decodes
    (and its frames hit the rewritten lz/entropy leaves)."""
    import json
    from pathlib import Path

    from repro.core import Compressor
    from repro.core.serialize import deserialize_plan

    cache = Path(__file__).resolve().parents[1] / "results" / "trained"
    blobs = sorted(cache.glob("*.ozp"))
    assert blobs, "trained plan cache missing"
    rng = np.random.default_rng(3)
    payload = bytes(rng.choice(32, 20000).astype(np.uint8) + 48)
    checked = 0
    for blob in blobs[:12]:
        plan, _meta = deserialize_plan(blob.read_bytes())
        if plan.n_inputs != 1:
            continue
        try:
            ok = Compressor(plan).roundtrip_check(payload)
        except ValueError:
            continue  # plan requires a typed/structured input shape
        assert ok, blob.name
        checked += 1
    assert checked >= 1


def test_lz77_segment_overshoot_sizes():
    """Regression: lane start positions arange(S)*ceil(n/S) can exceed n for
    sizes where ceil overshoots (e.g. 1200*1024 + 1) — must clamp, not crash,
    and stay bit-identical to the scalar parse."""
    rng = np.random.default_rng(21)
    for n in [1200 * 1024 + 1, 1536 * 1024 + 7]:
        data = bytes(rng.choice(8, n).astype(np.uint8) + 97)
        _assert_bitwise_equal("lz77", data)


def test_fse_large_table_log_flush():
    """Regression: at table_log >= 17 a single step can flush 3 whole bytes;
    the accumulator writer must not drop the third (bit-identical to the
    scalar 4-byte OR-writer, and roundtrip-exact)."""
    from repro.core.message import serial as mk_serial

    data = b"a" * 200_000 + bytes(range(98, 130))
    for table_log in (16, 17, 18):
        s = mk_serial(data)
        ref_outs, ref_h = sr._fse_enc([s], {"table_log": table_log})
        new_outs, new_h = vec_entropy._fse_enc([s], {"table_log": table_log})
        assert ref_h == new_h
        for a, b in zip(ref_outs, new_outs):
            assert a.data.tobytes() == b.data.tobytes(), table_log
        back = vec_entropy._fse_dec(new_outs, new_h)[0].content_bytes()
        assert back == data, table_log
