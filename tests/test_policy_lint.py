"""Repo policy linter: the rules fire on seeded violations, the sanctioned
patterns pass, and — the CI gate — the shipped ``src/`` tree is clean."""
from pathlib import Path

from repro.analysis import lint_source, lint_tree

REPO = Path(__file__).resolve().parents[1]


def _rules(violations):
    return [v.rule for v in violations]


# ------------------------------------------------------------ rule firing
def test_cpu_count_flagged():
    src = "import os\nworkers = os.cpu_count()\n"
    assert _rules(lint_source(src)) == ["cpu-count"]


def test_sched_getaffinity_passes():
    src = "import os\nworkers = len(os.sched_getaffinity(0))\n"
    assert lint_source(src) == []


def test_fault_point_in_loop_flagged():
    src = (
        "def f(items):\n"
        "    for x in items:\n"
        "        fault_point('encode.step')\n"
    )
    assert _rules(lint_source(src)) == ["fault-point-in-loop"]


def test_fault_point_on_boundary_passes():
    src = (
        "def f(items):\n"
        "    fault_point('encode.start')\n"
        "    for x in items:\n"
        "        work(x)\n"
    )
    assert lint_source(src) == []


def test_crash_point_in_loop_exempt():
    # crash_point marks irreversible per-artifact I/O steps; exempt by design
    src = (
        "def publish(shards):\n"
        "    for s in shards:\n"
        "        crash_point('shard.replace.before')\n"
    )
    assert lint_source(src) == []


def test_loop_depth_resets_inside_nested_function():
    src = (
        "for x in range(3):\n"
        "    def cb():\n"
        "        fault_point('cb')\n"
    )
    assert lint_source(src) == []


def test_bare_open_write_flagged():
    src = "def save(p, b):\n    with open(p, 'wb') as f:\n        f.write(b)\n"
    assert _rules(lint_source(src)) == ["atomic-sink"]


def test_write_bytes_flagged():
    src = "def save(p, b):\n    p.write_bytes(b)\n"
    assert _rules(lint_source(src)) == ["atomic-sink"]


def test_open_read_passes():
    src = "def load(p):\n    return open(p, 'rb').read()\n"
    assert lint_source(src) == []


def test_stage_then_replace_sanctioned():
    src = (
        "import os\n"
        "def save(p, b):\n"
        "    with open(str(p) + '.tmp', 'wb') as f:\n"
        "        f.write(b)\n"
        "    os.replace(str(p) + '.tmp', p)\n"
    )
    assert lint_source(src) == []


def test_atomic_sink_module_sanctioned():
    src = (
        "def _atomic_sink(path):\n"
        "    f = open(str(path) + '.part', 'wb')\n"
        "    return f\n"
    )
    assert lint_source(src) == []


def test_syntax_error_reported_not_raised():
    vs = lint_source("def broken(:\n")
    assert _rules(vs) == ["syntax"]


# ---------------------------------------------------------------- CI gate
def test_src_tree_is_policy_clean():
    violations = lint_tree(REPO / "src")
    assert violations == [], "\n".join(str(v) for v in violations)


def test_policy_cli_entrypoint():
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.policy", str(REPO / "src")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 violation(s)" in proc.stdout
