"""Coder-table cache: counters, scoping, thread-safety, bit-identical frames.

Mirrors the engine's resolve-cache contract: ``coder_cache_info()`` exposes
hit/miss counters; an ``ExecScratch`` scopes one compression call's tables;
the ``chunk_bytes`` thread pool shares a single scratch; and — the hard
invariant — frames are byte-identical with caching on, off, or scoped.
"""
import threading

import numpy as np
import pytest

from repro.codecs.coder_cache import (
    CoderCache,
    active_cache,
    coder_cache_clear,
    coder_cache_disabled,
    coder_cache_info,
    scoped,
)
from repro.core import ExecScratch, Compressor, compress, decompress, pipeline, serial
from repro.core.codec import get_codec


def _payload(n=200_000, seed=0):
    rng = np.random.default_rng(seed)
    return bytes(rng.choice(24, n, p=np.full(24, 1 / 24)).astype(np.uint8) + 60)


def test_info_counts_hits_and_misses():
    coder_cache_clear()
    spec = get_codec("fse")
    data = serial(_payload(50_000))
    before = coder_cache_info()
    outs, h = spec.run_encode([data], {})
    mid = coder_cache_info()
    assert mid["misses"] == before["misses"] + 1  # table built once
    spec.run_decode(outs, h)  # same (norm, table_log) -> hit
    after = coder_cache_info()
    assert after["hits"] == mid["hits"] + 1
    assert after["misses"] == mid["misses"]


def test_huffman_decode_lut_cached():
    coder_cache_clear()
    spec = get_codec("huffman")
    outs, h = spec.run_encode([serial(_payload(30_000))], {})
    spec.run_decode(outs, h)
    first = coder_cache_info()
    spec.run_decode(outs, h)
    second = coder_cache_info()
    assert second["hits"] > first["hits"]
    assert second["misses"] == first["misses"]


def test_bit_identical_with_cache_on_off_and_scoped():
    data = _payload()
    for plan in (pipeline("huffman"), pipeline("fse")):
        coder_cache_clear()
        warm = compress(plan, data)
        cached = compress(plan, data)  # hits the table cache
        with coder_cache_disabled():
            uncached = compress(plan, data)
        with scoped(CoderCache()):
            scoped_frame = compress(plan, data)
        assert warm == cached == uncached == scoped_frame
        assert decompress(warm)[0].content_bytes() == data


def test_scoped_cache_isolates_counters():
    coder_cache_clear()
    mine = CoderCache()
    spec = get_codec("fse")
    data = serial(_payload(20_000, seed=3))
    with scoped(mine):
        assert active_cache() is mine
        spec.run_encode([data], {})
    assert active_cache() is not mine
    assert mine.info()["misses"] == 1
    assert coder_cache_info()["misses"] == 0  # global untouched


def test_exec_scratch_shares_tables_across_chunk_pool():
    """chunk_bytes workers share one ExecScratch: the table for a given
    (norm, table_log) is built far fewer times than there are chunks."""
    data = _payload(1 << 20, seed=7)  # uniform-ish: same norm per chunk
    plan = pipeline("fse")
    comp = Compressor(plan, chunk_bytes=64 << 10)
    coder_cache_clear()
    frame_chunked = comp.compress(data)
    frame_plain = comp.compress(data, chunk_bytes=0)
    assert decompress(frame_chunked)[0].content_bytes() == data
    assert decompress(frame_plain)[0].content_bytes() == data
    # sanity: chunking actually happened
    from repro.core import wire

    assert wire.is_container(frame_chunked)


def test_coder_cache_thread_safety_under_contention():
    cache = CoderCache(maxsize=8)
    built = []
    lock = threading.Lock()

    def builder(k):
        def _b():
            with lock:
                built.append(k)
            return np.full(4, k)

        return _b

    def worker(tid):
        for i in range(500):
            k = i % 16
            v = cache.get_or_build(("t", k), builder(k))
            assert int(v[0]) == k

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    info = cache.info()
    assert info["size"] <= 8
    assert info["hits"] + info["misses"] == 8 * 500


def test_lru_eviction_bounds_size():
    cache = CoderCache(maxsize=4)
    for i in range(32):
        cache.get_or_build(("k", i), lambda i=i: i)
    assert cache.info()["size"] == 4
    # most recent keys survive
    assert cache.get_or_build(("k", 31), lambda: "rebuilt") == 31


def test_chunked_parallel_decode_bit_exact_with_cache():
    data = _payload(1 << 20, seed=11)
    frame = compress(pipeline("huffman"), data, chunk_bytes=128 << 10)
    coder_cache_clear()
    out1 = decompress(frame)[0].content_bytes()
    out2 = decompress(frame, n_workers=4)[0].content_bytes()
    assert out1 == out2 == data


def test_exec_scratch_table_cache_info():
    scratch = ExecScratch()
    info = scratch.table_cache_info()
    assert info["misses"] == 0 and info["size"] == 0
    from repro.core import execute, resolve

    data = _payload(30_000, seed=2)
    resolved = resolve(pipeline("fse"), serial(data))
    frame_a = execute(resolved, serial(data), scratch=scratch)
    assert scratch.table_cache_info()["misses"] >= 1
    frame_b = execute(resolved, serial(data))  # global cache path
    assert frame_a == frame_b
