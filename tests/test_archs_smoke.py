"""Per-architecture smoke tests (assignment deliverable f): instantiate the
REDUCED config of each family and run one forward/train step on CPU,
asserting output shapes and no NaNs — every (arch × shape) cell."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs
from repro.launch.cells import build_cell

CELLS = [
    (arch_id, shape.name)
    for arch_id, spec in sorted(all_archs().items())
    for shape in spec.shapes
]


@pytest.mark.parametrize("arch_id,shape_name", CELLS)
def test_cell_smoke(arch_id, shape_name):
    cell = build_cell(arch_id, shape_name, mesh=None, reduced=True)
    args = cell.make_real_args(jax.random.PRNGKey(0))
    out = jax.jit(cell.fn)(*args)
    for leaf in jax.tree.leaves(out):
        assert leaf.shape is not None
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), f"{arch_id}×{shape_name} NaN/inf"


def test_exactly_forty_cells_and_four_skips():
    archs = all_archs()
    total = sum(len(s.shapes) for s in archs.values())
    skips = sum(1 for s in archs.values() for sh in s.shapes if sh.skip)
    assert total == 40
    assert skips == 4  # long_500k for the four pure-full-attention LMs
    assert len(archs) == 10


def test_train_cells_reduce_loss():
    """One gradient step lowers (or at least computes) the loss for every
    train-kind cell — catches silent optimizer wiring bugs."""
    for arch_id, shape_name in [
        ("llama3.2-1b", "train_4k"),
        ("olmoe-1b-7b", "train_4k"),
        ("graphcast", "full_graph_sm"),
        ("xdeepfm", "train_batch"),
        ("dcn-v2", "train_batch"),
        ("sasrec", "train_batch"),
        ("mind", "train_batch"),
    ]:
        cell = build_cell(arch_id, shape_name, reduced=True)
        params, opt_state, batch = cell.make_real_args(jax.random.PRNGKey(1))
        step = jax.jit(cell.fn)
        p1, o1, l1 = step(params, opt_state, batch)
        p2, o2, l2 = step(p1, o1, batch)
        p3, o3, l3 = step(p2, o2, batch)
        assert float(l3) < float(l1), f"{arch_id}: loss did not drop ({l1}->{l3})"


def test_swa_cache_is_window_sized():
    """h2o-danube long_500k: ring cache = window, NOT 524288 (sub-quadratic
    memory is the whole point of running this cell)."""
    from repro.configs import get_arch
    from repro.configs.lm_common import lm_input_specs

    spec = get_arch("h2o-danube-3-4b")
    cfg = spec.model_cfg
    specs = lm_input_specs(cfg, spec.shape("long_500k"))
    assert specs["cache"]["k"].shape[2] == cfg.sliding_window == 4096
    # and a full-attention arch would have kept the full length
    yi = get_arch("yi-9b")
    specs_yi = lm_input_specs(yi.model_cfg, yi.shape("decode_32k"))
    assert specs_yi["cache"]["k"].shape[2] == 32768
