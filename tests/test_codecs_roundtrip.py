"""Property-based roundtrip tests: decode(encode(x)) == x for every codec,
over adversarial shapes/dtypes/values (hypothesis).  This is THE invariant of
the graph model — codecs must be bijective on their domains (paper §III-B)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-at-call-time stubs

from repro.core import Compressor, GraphBuilder, numeric, pipeline, serial, strings
from repro.core.codec import all_codecs


def chk(plan, stream):
    assert Compressor(plan).roundtrip_check(stream)


bytes_st = st.binary(min_size=0, max_size=4096)
small_bytes_st = st.binary(min_size=0, max_size=512)

uint_dtypes = st.sampled_from([np.uint8, np.uint16, np.uint32, np.uint64])


@st.composite
def numeric_arrays(draw, max_len=2048):
    dt = draw(uint_dtypes)
    n = draw(st.integers(0, max_len))
    bits = 8 * np.dtype(dt).itemsize
    vals = draw(
        st.lists(st.integers(0, (1 << bits) - 1), min_size=n, max_size=n)
    )
    return np.asarray(vals, dtype=dt)


@given(bytes_st)
@settings(max_examples=50, deadline=None)
def test_store_roundtrip(b):
    chk(pipeline("store"), serial(b))


@given(numeric_arrays())
@settings(max_examples=50, deadline=None)
def test_delta_roundtrip(x):
    chk(pipeline("delta"), numeric(x))


@given(numeric_arrays())
@settings(max_examples=50, deadline=None)
def test_zigzag_roundtrip(x):
    chk(pipeline("zigzag"), numeric(x))


@given(numeric_arrays())
@settings(max_examples=50, deadline=None)
def test_delta_zigzag_chain(x):
    chk(pipeline("delta", "zigzag"), numeric(x))


@given(numeric_arrays())
@settings(max_examples=50, deadline=None)
def test_transpose_roundtrip(x):
    chk(pipeline("transpose"), numeric(x))


@given(numeric_arrays())
@settings(max_examples=50, deadline=None)
def test_transpose_split_roundtrip(x):
    w = x.dtype.itemsize
    g = GraphBuilder(1)
    g.add("transpose_split", g.input(0), n_out=w)
    chk(g.build(), numeric(x))


@given(numeric_arrays(max_len=512))
@settings(max_examples=50, deadline=None)
def test_range_pack_roundtrip(x):
    if x.size and int(x.max()) - int(x.min()) >= (1 << 57):
        return  # documented bitpack limit
    chk(pipeline("range_pack"), numeric(x))


@given(st.lists(st.integers(0, 255), min_size=0, max_size=2048))
@settings(max_examples=50, deadline=None)
def test_bitpack_roundtrip(vals):
    chk(pipeline("bitpack"), numeric(np.asarray(vals, dtype=np.uint8)))


@given(numeric_arrays(max_len=512))
@settings(max_examples=50, deadline=None)
def test_rle_roundtrip(x):
    g = GraphBuilder(1)
    g.add("rle", g.input(0))
    chk(g.build(), numeric(x))


@given(numeric_arrays(max_len=512))
@settings(max_examples=50, deadline=None)
def test_tokenize_roundtrip(x):
    g = GraphBuilder(1)
    g.add("tokenize", g.input(0))
    chk(g.build(), numeric(x))


@given(st.lists(small_bytes_st, min_size=0, max_size=128))
@settings(max_examples=50, deadline=None)
def test_tokenize_strings_roundtrip(items):
    g = GraphBuilder(1)
    g.add("tokenize", g.input(0))
    chk(g.build(), strings(items))


@given(st.lists(small_bytes_st, min_size=0, max_size=64))
@settings(max_examples=50, deadline=None)
def test_string_split_roundtrip(items):
    g = GraphBuilder(1)
    g.add("string_split", g.input(0))
    chk(g.build(), strings(items))


@given(bytes_st)
@settings(max_examples=60, deadline=None)
def test_huffman_roundtrip(b):
    g = GraphBuilder(1)
    g.add("huffman", g.input(0))
    chk(g.build(), serial(b))


@given(bytes_st)
@settings(max_examples=60, deadline=None)
def test_fse_roundtrip(b):
    g = GraphBuilder(1)
    g.add("fse", g.input(0))
    chk(g.build(), serial(b))


@given(st.binary(min_size=0, max_size=8192))
@settings(max_examples=40, deadline=None)
def test_lz77_roundtrip(b):
    g = GraphBuilder(1)
    g.add("lz77", g.input(0))
    chk(g.build(), serial(b))


@given(st.binary(min_size=0, max_size=8192))
@settings(max_examples=25, deadline=None)
def test_lz77_on_repetitive(b):
    data = b * 4 + b[::-1] * 2
    g = GraphBuilder(1)
    g.add("lz77", g.input(0))
    chk(g.build(), serial(data))


@given(bytes_st)
@settings(max_examples=30, deadline=None)
def test_zlib_backend_roundtrip(b):
    chk(pipeline("zlib_backend"), serial(b))


@given(st.lists(st.floats(allow_nan=False, width=32), min_size=0, max_size=1024))
@settings(max_examples=40, deadline=None)
def test_float_split_f32_roundtrip(vals):
    x = np.asarray(vals, dtype=np.float32)
    g = GraphBuilder(1)
    g.add("float_split", g.input(0), fmt=2)
    chk(g.build(), numeric(x))


@given(st.lists(st.integers(0, (1 << 16) - 1), min_size=0, max_size=1024))
@settings(max_examples=40, deadline=None)
def test_float_split_bf16_roundtrip(vals):
    x = np.asarray(vals, dtype=np.uint16)  # arbitrary bf16 bit patterns
    g = GraphBuilder(1)
    g.add("float_split", g.input(0), fmt=0)
    chk(g.build(), numeric(x))


@given(st.lists(st.integers(0, (1 << 64) - 1), min_size=0, max_size=256))
@settings(max_examples=30, deadline=None)
def test_float_split_f64_roundtrip(vals):
    x = np.asarray(vals, dtype=np.uint64)
    g = GraphBuilder(1)
    g.add("float_split", g.input(0), fmt=3)
    chk(g.build(), numeric(x))


@given(
    st.lists(
        st.text(
            alphabet=st.characters(codec="ascii", exclude_characters=",\n"),
            max_size=12,
        ),
        min_size=1,
        max_size=40,
    ),
    st.integers(1, 4),
)
@settings(max_examples=40, deadline=None)
def test_csv_profile_roundtrip(cells, n_cols):
    rows = [cells[i : i + n_cols] for i in range(0, len(cells) - n_cols + 1, n_cols)]
    if not rows:
        return
    data = ("\n".join(",".join(r) for r in rows) + "\n").encode()
    if data == b"\n":
        return  # empty body: csv_split rejects by design (trainer falls back)
    from repro.codecs import csv_profile

    chk(csv_profile(n_cols), serial(data))


@given(st.lists(small_bytes_st, min_size=0, max_size=64))
@settings(max_examples=40, deadline=None)
def test_parse_numeric_roundtrip(items):
    # mix in genuine numbers to hit both branches
    mixed = items + [b"123", b"-987654321", b"0", b"007", b"-0", b"99999999999999999999999"]
    g = GraphBuilder(1)
    g.add("parse_numeric", g.input(0))
    chk(g.build(), strings(mixed))


@given(numeric_arrays(max_len=256))
@settings(max_examples=30, deadline=None)
def test_generic_profile_numeric(x):
    from repro.codecs import generic_profile

    chk(generic_profile(), numeric(x))


@given(bytes_st)
@settings(max_examples=30, deadline=None)
def test_generic_profile_bytes(b):
    from repro.codecs import generic_profile

    chk(generic_profile(), serial(b))


def test_every_registered_codec_is_exercised_somewhere():
    """Meta-test: the registry matches the documented id map."""
    ids = {spec.codec_id for spec in all_codecs().values()}
    assert ids == set(range(1, 30)), sorted(ids)


# --------------------------------------------------- csv_split regressions
def test_csv_split_multibyte_separator_roundtrip():
    """Regression: the header stored only sep_b[0], so decode rejoined with
    one byte and multi-byte separators corrupted silently."""
    raw = b"a::b::c\n1::2::3\nx::y::z\n"
    g = GraphBuilder(1)
    g.add("csv_split", g.input(0), n_out=3, sep="::")
    chk(g.build(), serial(raw))


@given(
    st.text(
        alphabet=st.characters(codec="ascii", exclude_characters="\r\n"),
        min_size=1,
        max_size=4,
    ),
    st.lists(st.integers(0, 999), min_size=2, max_size=40),
)
@settings(max_examples=40, deadline=None)
def test_csv_split_any_separator_roundtrip(sep, vals):
    rows = [f"{v}{sep}{v * 7}".encode() for v in vals]
    n_cols = rows[0].count(sep.encode()) + 1
    if any(r.count(sep.encode()) + 1 != n_cols for r in rows):
        return  # digits colliding with the separator: not rectangular
    g = GraphBuilder(1)
    g.add("csv_split", g.input(0), n_out=n_cols, sep=sep)
    chk(g.build(), serial(b"\n".join(rows) + b"\n"))


def test_csv_split_crlf_roundtrip():
    """Regression twin of the sniff_csv CRLF bug: \\r\\n files must
    round-trip byte-exactly and must NOT leave \\r glued to the last
    column (the per-column streams are clean)."""
    from repro.core.codec import get_codec

    raw = b"a,b\r\n1,2\r\n33,44\r\n"
    outs, h = get_codec("csv_split").run_encode([serial(raw)], {"sep": ","})
    assert outs[1].to_strings() == [b"b", b"2", b"44"]  # no \r suffixes
    rec = get_codec("csv_split").run_decode(outs, h)[0]
    assert rec.data.tobytes() == raw


@pytest.mark.parametrize(
    "raw,n_cols",
    [
        (b"a,b\r\n1,2\r", 2),  # final line CR without LF
        (b"a,b\r\n1,2\n", 2),  # mixed endings
        (b"\r\n", 1),
    ],
)
def test_csv_split_cr_edge_cases_roundtrip(raw, n_cols):
    g = GraphBuilder(1)
    g.add("csv_split", g.input(0), n_out=n_cols, sep=",")
    chk(g.build(), serial(raw))


@pytest.mark.parametrize("sep", ["", "a\nb", "\r", "x\ry"])
def test_csv_split_rejects_bad_separators(sep):
    from repro.core.codec import get_codec

    with pytest.raises(ValueError):
        get_codec("csv_split").run_encode([serial(b"a,b\n")], {"sep": sep})


# ---------------------------------------------------- graph codec roundtrips
def _edge_text(pairs, sep=b"\t", junk=(), trailing=True):
    lines = list(junk) + [b"%d%s%d" % (u, sep, v) for u, v in pairs]
    return b"\n".join(lines) + (b"\n" if trailing else b"")


@given(
    st.lists(st.tuples(st.integers(0, 500), st.integers(0, 500)), max_size=200),
    st.lists(small_bytes_st.filter(lambda b: b"\n" not in b), max_size=5),
    st.booleans(),
)
@settings(max_examples=40, deadline=None)
def test_edge_list_roundtrip(pairs, junk, trailing):
    raw = _edge_text(sorted(pairs), junk=junk, trailing=trailing)
    g = GraphBuilder(1)
    g.add("edge_list", g.input(0), sep="\t")
    chk(g.build(), serial(raw))


@given(bytes_st)
@settings(max_examples=40, deadline=None)
def test_edge_list_lossless_on_arbitrary_bytes(b):
    """edge_list is total: any byte blob round-trips (unparsed lines are
    byte-exact exceptions), under explicit and auto separators."""
    g = GraphBuilder(1)
    g.add("edge_list", g.input(0), sep="auto")
    chk(g.build(), serial(b))


@given(
    st.lists(st.integers(0, 2**64 - 1), max_size=300),
    st.integers(0, 8),
)
@settings(max_examples=40, deadline=None)
def test_adj_gap_roundtrip_unsorted(flat, window):
    """adj_gap must be lossless on ANY (src, dst) columns — unsorted,
    duplicate, full-u64-range — not just sorted adjacency."""
    n = len(flat) // 2
    src = np.asarray(flat[:n], dtype=np.uint64)
    dst = np.asarray(flat[n : 2 * n], dtype=np.uint64)
    g = GraphBuilder(2)
    g.add("adj_gap", g.input(0), g.input(1), window=window)
    assert Compressor(g.build()).roundtrip_check([numeric(src), numeric(dst)])


@given(st.integers(1, 200), st.integers(1, 12), st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_adj_gap_roundtrip_sorted_adjacency(n_nodes, max_deg, window):
    """The reference/copy-list path: sorted adjacency with repeated
    neighborhoods (every run similar), all widths."""
    rng = np.random.default_rng(n_nodes * 13 + max_deg)
    src, dst = [], []
    for u in range(n_nodes):
        for v in np.unique(rng.integers(0, n_nodes, max_deg)):
            src.append(u)
            dst.append(int(v))
    for dt in (np.uint16, np.uint32, np.uint64):
        s = np.asarray(src, dtype=dt)
        d = np.asarray(dst, dtype=dt)
        g = GraphBuilder(2)
        g.add("adj_gap", g.input(0), g.input(1), window=window)
        assert Compressor(g.build()).roundtrip_check([numeric(s), numeric(d)])


@given(
    st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1)),
             max_size=200),
    st.sampled_from([2, 4, 8]),
)
@settings(max_examples=40, deadline=None)
def test_edge_list_bin_roundtrip(pairs, width):
    hi = (1 << (8 * width)) - 1
    arr = np.asarray(
        [(u & hi, v & hi) for u, v in pairs], dtype=np.uint64
    ).astype({2: np.uint16, 4: np.uint32, 8: np.uint64}[width])
    raw = arr.tobytes()
    g = GraphBuilder(1)
    g.add("edge_list_bin", g.input(0), width=width)
    chk(g.build(), serial(raw))


def test_edge_list_bin_rejects_misaligned():
    from repro.core.codec import get_codec

    with pytest.raises(ValueError):
        get_codec("edge_list_bin").run_encode([serial(b"\x00" * 7)], {"width": 4})
    with pytest.raises(ValueError):
        get_codec("edge_list_bin").run_encode([serial(b"\x00" * 8)], {"width": 3})


@given(
    st.lists(st.tuples(st.integers(0, 300), st.integers(0, 300)), max_size=300)
)
@settings(max_examples=30, deadline=None)
def test_graph_profile_roundtrip(pairs):
    from repro.codecs import graph_profile

    raw = _edge_text(sorted(set(pairs)), junk=[b"# hdr"])
    chk(graph_profile(), serial(raw))


@given(bytes_st)
@settings(max_examples=30, deadline=None)
def test_graph_profile_lossless_on_arbitrary_bytes(b):
    from repro.codecs import graph_profile

    chk(graph_profile(), serial(b))


def test_concat_mixed_signedness_is_bit_exact():
    """Regression: np.concatenate(int64, uint64) promotes to float64 and
    silently rounds large values — concat must use unsigned bit views."""
    from repro.core.codec import get_codec

    big = np.array([2**63 + 12345, 2**64 - 1], dtype=np.uint64)
    signed = np.array([-7, 2**62], dtype=np.int64)
    cat = get_codec("concat")
    outs, h = cat.run_encode([numeric(signed), numeric(big)], {})
    back = cat.run_decode(outs, h)
    assert back[0].content_bytes() == numeric(signed).content_bytes()
    assert back[1].content_bytes() == numeric(big).content_bytes()


@pytest.mark.parametrize(
    "codec,stype_width",
    [
        ("huffman", ("serial", 1)),
        ("huffman", ("numeric", 1)),
        ("huffman", ("struct", 1)),
        ("fse", ("serial", 1)),
        ("fse", ("numeric", 1)),
        ("lz77", ("numeric", 2)),
        ("rle", ("numeric", 4)),
        ("tokenize", ("struct", 3)),
        ("zlib_backend", ("numeric", 8)),
        ("lzma_backend", ("numeric", 4)),
        ("bz2_backend", ("numeric", 2)),
        ("transpose", ("numeric", 4)),
    ],
)
def test_codecs_are_type_faithful(codec, stype_width):
    """decode(encode(x)) must reproduce the TYPE, not just the bytes —
    regression for the huffman/fse SERIAL-flattening bug."""
    from repro.core import struct as mk_struct
    from repro.core.codec import get_codec

    kind, w = stype_width
    rng = np.random.default_rng(0)
    if kind == "serial":
        s = serial(rng.integers(0, 9, 500).astype(np.uint8).tobytes())
    elif kind == "numeric":
        s = numeric(rng.integers(0, 7, 300).astype(f"uint{8*w}"))
    else:
        s = mk_struct(rng.integers(0, 5, 300 * w).astype(np.uint8), w)
    spec = get_codec(codec)
    outs, header = spec.run_encode([s], {})
    (back,) = spec.run_decode(outs, header)
    assert back.stype == s.stype and back.width == s.width
    assert back.content_bytes() == s.content_bytes()
