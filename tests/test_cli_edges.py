"""Regression tests for the CLI/stream_io data-loss and edge-case bugs.

The worst of them: ``python -m repro compress F -o F`` opened the output
``w+b`` *before* the first read, truncating the source to zero bytes and then
"compressing" the empty file — silent, total data loss.  The fix routes every
path-destined write through a same-directory temp file with an atomic
``os.replace``, so in-place operation reads the intact source, and a crash
mid-write never leaves a partial output.
"""
import io
import struct
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro.cli import main
from repro.codecs import profiles as P
from repro.core import compress, serial, stream_io, wire

DATA = b"the quick brown fox jumps over the lazy dog\n" * 250  # 11,000 bytes


# ----------------------------------------------------------- in-place safety
def test_compress_file_in_place_roundtrips(tmp_path):
    f = tmp_path / "corpus.bin"
    f.write_bytes(DATA)
    stats = stream_io.compress_file(f, f, P.generic_profile(), chunk_bytes=4096)
    assert stats["bytes_in"] == len(DATA)  # read the real bytes, not 0
    frame = f.read_bytes()
    assert frame[:4] in (wire.MAGIC, wire.CONTAINER_MAGIC)
    out = tmp_path / "corpus.out"
    stream_io.decompress_file(f, out)
    assert out.read_bytes() == DATA


def test_decompress_file_in_place_roundtrips(tmp_path):
    f = tmp_path / "corpus.ozl"
    f.write_bytes(compress(P.generic_profile(), serial(DATA), chunk_bytes=2048))
    stats = stream_io.decompress_file(f, f)
    assert stats["bytes_out"] == len(DATA)
    assert f.read_bytes() == DATA


def test_cli_compress_in_place_roundtrips(tmp_path):
    f = tmp_path / "corpus.bin"
    f.write_bytes(DATA)
    assert main(["compress", str(f), "-o", str(f), "--profile", "generic"]) == 0
    assert f.stat().st_size > 0
    assert main(["decompress", str(f), "-o", str(f)]) == 0
    assert f.read_bytes() == DATA


def test_cli_default_output_paths_unharmed(tmp_path):
    """The no--o defaults (INPUT.ozl / strip-.ozl) must leave inputs intact."""
    f = tmp_path / "corpus.bin"
    f.write_bytes(DATA)
    assert main(["compress", str(f), "--profile", "generic"]) == 0
    assert f.read_bytes() == DATA  # source untouched
    ozl = tmp_path / "corpus.bin.ozl"
    assert ozl.exists()
    assert main(["decompress", str(ozl)]) == 0  # strips .ozl -> corpus.bin
    assert f.read_bytes() == DATA


def test_in_place_via_symlink_roundtrips(tmp_path):
    """samefile-style aliasing (symlink to the source) is still in-place."""
    real = tmp_path / "real.bin"
    real.write_bytes(DATA)
    link = tmp_path / "alias.bin"
    link.symlink_to(real)
    stream_io.compress_file(link, real, P.generic_profile(), chunk_bytes=0)
    out = tmp_path / "out.bin"
    stream_io.decompress_file(real, out)
    assert out.read_bytes() == DATA


def test_atomic_sink_writes_through_symlink_destination(tmp_path):
    """A symlink destination must behave like ``open(dst, "wb")`` did: the
    link's *target* gets the new content and the link survives (regression:
    the atomic rename replaced the symlink itself with a regular file)."""
    real = tmp_path / "real.ozl"
    real.write_bytes(b"old")
    link = tmp_path / "alias.ozl"
    link.symlink_to(real)
    src = tmp_path / "in.bin"
    src.write_bytes(DATA)
    stream_io.compress_file(src, link, P.generic_profile(), chunk_bytes=0)
    assert link.is_symlink()  # the link itself was not clobbered
    assert real.read_bytes() == link.read_bytes() != b"old"
    out = tmp_path / "out.bin"
    stream_io.decompress_file(real, out)
    assert out.read_bytes() == DATA


def test_failed_compress_leaves_no_partial_output(tmp_path):
    src = tmp_path / "corpus.bin"
    src.write_bytes(DATA)
    dst = tmp_path / "corpus.ozl"
    with pytest.raises(Exception):
        stream_io.compress_file(src, dst, P.generic_profile(), chunk_bytes=-5)
    assert not dst.exists()
    assert not list(tmp_path.glob("*.tmp"))


def test_same_path_detection(tmp_path):
    a = tmp_path / "a.bin"
    a.write_bytes(b"x")
    assert stream_io.same_path(a, a)
    assert stream_io.same_path(str(a), a)
    assert stream_io.same_path(a, tmp_path / ".." / tmp_path.name / "a.bin")
    assert not stream_io.same_path(a, tmp_path / "b.bin")
    assert not stream_io.same_path(io.BytesIO(), io.BytesIO())
    link = tmp_path / "ln.bin"
    link.symlink_to(a)
    assert stream_io.same_path(a, link)


def test_atomic_sink_passes_file_objects_through():
    buf = io.BytesIO()
    with stream_io._atomic_sink(buf) as f:
        assert f is buf


def test_atomic_sink_honors_umask_and_preserves_modes(tmp_path):
    """mkstemp's private 0600 must not leak to outputs: fresh files get the
    umask-honoring mode open() would have given, rewrites keep dst's mode."""
    import os

    src = tmp_path / "in.bin"
    src.write_bytes(DATA)
    fresh = tmp_path / "fresh.ozl"
    old_umask = os.umask(0o022)
    try:
        stream_io.compress_file(src, fresh, P.generic_profile(), chunk_bytes=0)
        assert (fresh.stat().st_mode & 0o777) == 0o644
        existing = tmp_path / "existing.ozl"
        existing.write_bytes(b"old")
        existing.chmod(0o604)
        stream_io.compress_file(src, existing, P.generic_profile(), chunk_bytes=0)
        assert (existing.stat().st_mode & 0o777) == 0o604
    finally:
        os.umask(old_umask)


# -------------------------------------------------------- inspect edge cases
def _empty_container() -> bytes:
    body = bytearray(b"OZLC\x04")
    wire.write_varint(body, 0)
    return bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)


def test_inspect_foreign_zero_chunk_container(tmp_path, capsys):
    """A structurally valid container we'd never write must still inspect
    cleanly (no ``min([]) `` ValueError, no traceback)."""
    f = tmp_path / "empty.ozlc"
    f.write_bytes(_empty_container())
    assert main(["inspect", str(f)]) == 0
    out = capsys.readouterr().out
    assert "0 chunk(s)" in out


def test_iter_container_frames_allow_empty():
    blob = _empty_container()
    assert list(wire.iter_container_frames(io.BytesIO(blob), allow_empty=True)) == []
    # decoding keeps rejecting: an empty container regenerates nothing
    with pytest.raises(wire.FrameError):
        list(wire.iter_container_frames(io.BytesIO(blob)))
    # allow_empty must not weaken any other check (trailing garbage here)
    with pytest.raises(wire.FrameError):
        list(wire.iter_container_frames(io.BytesIO(blob + b"x"), allow_empty=True))


def test_inspect_garbage_still_fails(tmp_path, capsys):
    f = tmp_path / "junk.bin"
    f.write_bytes(b"definitely not a frame")
    assert main(["inspect", str(f)]) == 2


def test_serve_without_address_is_usage_error():
    with pytest.raises(SystemExit) as exc:
        main(["serve", "--profile", "text"])
    assert "--socket" in str(exc.value)
    with pytest.raises(SystemExit):
        main(["serve", "--socket", "/tmp/x.sock", "--tcp", "127.0.0.1:1"])
    for bad_tcp in ("localhost", "host:abc"):  # malformed HOST:PORT forms
        with pytest.raises(SystemExit):
            main(["serve", "--tcp", bad_tcp])


def test_serve_registration_errors_are_clean(tmp_path):
    """Bad --profile/--register values must exit with a message, not a raw
    ValueError/FileNotFoundError traceback."""
    sock = str(tmp_path / "x.sock")
    with pytest.raises(SystemExit) as exc:
        main(["serve", "--socket", sock, "--profile", "bogus"])
    assert "unknown profile" in str(exc.value)
    with pytest.raises(SystemExit) as exc:
        main(["serve", "--socket", sock, "--register", str(tmp_path / "no.ozp")])
    assert "serve:" in str(exc.value)


def test_profile_spec_errors_are_clean():
    from repro.codecs.profiles import resolve_profile_spec

    for bad in ("bogus", "struct:", "struct:0", "struct:a", "csv:", "csv:x"):
        with pytest.raises(ValueError):
            resolve_profile_spec(bad)
    with pytest.raises(SystemExit):  # the CLI converts to a usage error
        main(["compress", "/nonexistent", "--profile", "bogus"])


def test_csv_empty_separator_spec_is_value_error():
    """Regression: 'csv:3:' (trailing colon -> empty separator) used to reach
    sep_b[0] and raise IndexError instead of the documented ValueError."""
    from repro.codecs.profiles import resolve_profile_spec

    for bad in ("csv:3:", "csv:0", "csv:2:\n", "csv:-1"):
        with pytest.raises(ValueError):
            resolve_profile_spec(bad)
    # separators containing ':' are expressible: 'csv:3::' means sep ':'
    resolve_profile_spec("csv:3::")
    resolve_profile_spec("csv:3:::")  # sep '::'


def test_graph_profile_specs(tmp_path):
    from repro.codecs.profiles import resolve_profile_spec

    for good in ("graph", "graph:\t", "graph: ", "graph:bin", "graph:bin:8"):
        resolve_profile_spec(good)
    for bad in ("graph:", "graph:bin:3", "graph:bin:x", "graph:bin:4:junk"):
        with pytest.raises(ValueError):
            resolve_profile_spec(bad)

    # CLI end to end: compress + universal decompress with the graph profile
    edges = tmp_path / "edges.txt"
    edges.write_bytes(
        b"# golden\n" + b"".join(b"%d\t%d\n" % (i // 3, i % 7) for i in range(60))
    )
    out = tmp_path / "edges.ozl"
    back = tmp_path / "edges.rt"
    assert main(["compress", str(edges), "--profile", "graph", "-o", str(out)]) == 0
    assert main(["decompress", str(out), "-o", str(back)]) == 0
    assert back.read_bytes() == edges.read_bytes()


# ---------------------------------------------------------- train edge cases
def test_train_no_pareto_point_is_clear_error(tmp_path, monkeypatch):
    """An empty training result must exit with a message, not IndexError."""

    class _EmptyResult:
        stats = {
            "train_seconds": 0.0,
            "evaluations": 0.0,
            "workers": 1.0,
            "eval_wall_seconds": 0.0,
            "n_streams": 0.0,
            "n_clusters": 0.0,
        }

        def pareto_plans(self):
            return []

    import repro.training

    monkeypatch.setattr(
        repro.training, "train", lambda *a, **k: _EmptyResult()
    )
    sample = tmp_path / "sample.bin"
    sample.write_bytes(b"abc" * 100)
    with pytest.raises(SystemExit) as exc:
        main(["train", str(sample), "--out", str(tmp_path / "p.ozp")])
    assert "no Pareto point" in str(exc.value)


def test_train_all_points_skipped_is_clear_error(tmp_path, monkeypatch):
    """Plans that exist but all get skipped must not hit emitted[0][1]."""
    from repro.cli import _cmd_train  # noqa: F401  (the guarded function)

    class _OnePlan:
        stats = {
            "train_seconds": 0.0,
            "evaluations": 1.0,
            "workers": 1.0,
            "eval_wall_seconds": 0.0,
            "n_streams": 1.0,
            "n_clusters": 1.0,
        }

        def pareto_plans(self):
            from repro.core import pipeline

            return [(pipeline("zlib_backend"), 10.0, 0.001)]

    import repro.cli
    import repro.training

    monkeypatch.setattr(repro.training, "train", lambda *a, **k: _OnePlan())
    # force the "skip every point" path by making the roundtrip check fail
    monkeypatch.setattr(
        repro.cli.Compressor, "roundtrip_check", lambda self, b: False
    )
    sample = tmp_path / "sample.bin"
    sample.write_bytes(b"abc" * 100)
    with pytest.raises(SystemExit) as exc:
        main(["train", str(sample), "--out", str(tmp_path / "p.ozp")])
    msg = str(exc.value)
    assert "IndexError" not in msg and ("lossless" in msg or "no plan" in msg)
