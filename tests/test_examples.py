"""The examples are part of the public API surface: the fast ones must run
to completion as subprocesses."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_example(name: str, timeout: int = 240) -> str:
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, str(REPO / "examples" / name)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    assert out.returncode == 0, f"{name} failed:\n{out.stderr[-2000:]}"
    return out.stdout


def test_quickstart():
    out = run_example("quickstart.py")
    assert "universal decoder: roundtrip OK" in out
    assert "serialized compressor" in out


def test_device_codec():
    out = run_example("device_codec.py")
    assert "bit-exact" in out
    assert "exponent entropy" in out


def test_stream_file(tmp_path):
    # small corpus via argv so the example stays fast under pytest
    src = tmp_path / "corpus.log"
    src.write_bytes(b"level=INFO svc=ingest msg=flushed in 42us\n" * 20000)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, str(REPO / "examples" / "stream_file.py"), str(src)],
        capture_output=True, text=True, timeout=240, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "bit-exact" in out.stdout


def test_serve_lm_smoke():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "llama3.2-1b", "--reduced",
            "--batch", "2", "--prompt-len", "8", "--gen", "8",
        ],
        capture_output=True, text=True, timeout=240, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "decode:" in out.stdout
