"""Sniffer fuzz: the format sniffers behind ``--frontend auto`` must (a)
never raise on arbitrary bytes and (b) *agree with the parser they route to*
— whatever a sniffer claims, the corresponding frontend codec must encode
that sample losslessly (after the trainer's own sample alignment).  A sniffer
that detects a format its codec then chokes on turns ``repro train`` into a
crash, so sniff→parse agreement is the real invariant, not detection rate.

Runs both as seeded deterministic fuzz (no dependencies) and as hypothesis
properties when hypothesis is installed (CI).
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-at-call-time stubs

from repro.codecs.parse import (
    sniff_csv,
    sniff_edge_list,
    sniff_edge_list_bin,
    sniff_numeric_width,
    sniff_struct_width,
)
from repro.core import Compressor, GraphBuilder, serial

SNIFFERS = [
    sniff_csv,
    sniff_edge_list,
    sniff_edge_list_bin,
    sniff_numeric_width,
    sniff_struct_width,
]


def _rt(plan, raw: bytes) -> None:
    assert Compressor(plan).roundtrip_check(serial(raw))


def assert_sniffs_agree_with_parsers(raw: bytes) -> None:
    """Every sniffer claim must be backed by a lossless parse of the sample
    the trainer would feed the codec (line-trimmed for text frontends; the
    fixed-width sniffers only claim aligned inputs in the first place)."""
    csv = sniff_csv(raw)
    if csv is not None:
        n_cols, sep = csv
        cut = raw.rfind(b"\n")
        trimmed = raw[: cut + 1] if cut >= 0 else raw
        g = GraphBuilder(1)
        g.add("csv_split", g.input(0), n_out=n_cols, sep=sep)
        _rt(g.build(), trimmed)

    sep = sniff_edge_list(raw)
    if sep is not None:
        g = GraphBuilder(1)
        g.add("edge_list", g.input(0), sep=sep)
        _rt(g.build(), raw)  # edge_list is total: no trimming required

    w = sniff_edge_list_bin(raw)
    if w is not None:
        g = GraphBuilder(1)
        g.add("edge_list_bin", g.input(0), width=w)
        _rt(g.build(), raw)  # the sniffer only claims 2w-aligned inputs

    w = sniff_numeric_width(raw)
    if w is not None:
        g = GraphBuilder(1)
        g.add("interpret_numeric", g.input(0), width=w)
        _rt(g.build(), raw)

    w = sniff_struct_width(raw)
    if w is not None:
        g = GraphBuilder(1)
        g.add("field_split", g.input(0), n_out=w, widths=[1] * w)
        _rt(g.build(), raw)


# ------------------------------------------------- deterministic seeded fuzz
def _structured_blobs(rng: np.random.Generator):
    """Blobs shaped to actually trip each sniffer (plus raw noise)."""
    n = int(rng.integers(0, 2048))
    kind = int(rng.integers(0, 7))
    if kind == 0:
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    if kind == 1:  # csv-ish, sometimes ragged, sometimes CRLF
        eol = b"\r\n" if rng.random() < 0.3 else b"\n"
        sep = [b",", b"\t", b";", b"|"][int(rng.integers(4))]
        rows = []
        for _ in range(int(rng.integers(0, 40))):
            width = int(rng.integers(1, 5)) + (rng.random() < 0.1)
            rows.append(sep.join(b"%d" % v for v in rng.integers(0, 500, width)))
        return eol.join(rows) + (eol if rng.random() < 0.8 else b"")
    if kind == 2:  # text edge list with comments / junk tail
        sep = b"\t" if rng.random() < 0.5 else b" "
        lines = [b"# fuzz graph"]
        for _ in range(int(rng.integers(0, 80))):
            lines.append(b"%d%s%d" % (rng.integers(0, 300), sep, rng.integers(0, 300)))
        if rng.random() < 0.2:
            lines.append(b"trailing junk")
        return b"\n".join(lines) + (b"\n" if rng.random() < 0.8 else b"")
    if kind == 3:  # binary (src, dst) pairs, sorted adjacency
        w = [2, 4, 8][int(rng.integers(3))]
        dt = {2: np.uint16, 4: np.uint32, 8: np.uint64}[w]
        src = np.repeat(
            np.arange(int(rng.integers(1, 80)), dtype=dt), int(rng.integers(1, 8))
        )
        dst = rng.integers(0, 1000, src.size).astype(dt)
        dst.sort()
        return np.stack([src, dst], axis=1).tobytes()
    if kind == 4:  # sorted numeric
        w = [2, 4, 8][int(rng.integers(3))]
        dt = {2: np.uint16, 4: np.uint32, 8: np.uint64}[w]
        return np.sort(rng.integers(0, 10000, int(rng.integers(0, 300))).astype(dt)).tobytes()
    if kind == 5:  # struct-ish records
        w = int(rng.integers(2, 12))
        rec = np.zeros((int(rng.integers(0, 64)), w), np.uint8)
        rec[:, : w // 2] = rng.integers(0, 4, rec[:, : w // 2].shape)
        rec[:, w // 2 :] = rng.integers(0, 256, rec[:, w // 2 :].shape)
        return rec.tobytes()
    return rng.integers(32, 127, n, dtype=np.uint8).tobytes()  # printable noise


def test_sniffers_never_raise_and_agree_seeded():
    rng = np.random.default_rng(0xC0DEC)
    for _ in range(300):
        raw = _structured_blobs(rng)
        for sniff in SNIFFERS:
            sniff(raw)  # never raises, whatever the bytes
        assert_sniffs_agree_with_parsers(raw)


def test_detect_frontend_never_raises_seeded():
    from repro.training import detect_frontend

    rng = np.random.default_rng(0xF20)
    for _ in range(150):
        raw = _structured_blobs(rng)
        detect_frontend(raw)


@pytest.mark.parametrize(
    "raw",
    [
        b"",
        b"\n",
        b"\r\n" * 40,
        b"\x00" * 1024,
        b"#" * 1024,
        b"1\t2\n" * 64,
        b"-0\t007\n" * 64,  # non-canonical ints: must stay exceptions
        bytes(range(256)) * 8,
    ],
)
def test_sniffer_edge_inputs(raw):
    for sniff in SNIFFERS:
        sniff(raw)
    assert_sniffs_agree_with_parsers(raw)


# ------------------------------------------------------ hypothesis properties
@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=150, deadline=None)
def test_sniffers_never_raise_hypothesis(raw):
    for sniff in SNIFFERS:
        sniff(raw)


@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=75, deadline=None)
def test_sniff_parse_agreement_hypothesis(raw):
    assert_sniffs_agree_with_parsers(raw)


@given(
    st.lists(
        st.tuples(st.integers(0, 99), st.integers(0, 99)),
        min_size=40,
        max_size=200,
    ),
    st.sampled_from([b"\t", b" "]),
)
@settings(max_examples=50, deadline=None)
def test_sniff_parse_agreement_on_edge_lists(pairs, sep):
    raw = b"\n".join(b"%d%s%d" % (u, sep, v) for u, v in sorted(pairs)) + b"\n"
    assert sniff_edge_list(raw) == sep.decode()
    assert_sniffs_agree_with_parsers(raw)


@given(
    st.lists(st.lists(st.integers(0, 999), min_size=3, max_size=3), min_size=2, max_size=60),
    st.sampled_from([b"\n", b"\r\n"]),
)
@settings(max_examples=50, deadline=None)
def test_sniff_parse_agreement_on_csv(rows, eol):
    raw = eol.join(b",".join(b"%d" % v for v in r) for r in rows) + eol
    got = sniff_csv(raw)
    assert got is not None and got[0] == 3
    assert_sniffs_agree_with_parsers(raw)
