"""Graph-model core: plan validation, wire format, universal decoder,
serialization, versioning."""
import numpy as np
import pytest

from repro.core import (
    CompressionCtx,
    Compressor,
    GraphBuilder,
    Plan,
    VersionError,
    compress,
    decompress,
    decompress_bytes,
    numeric,
    pipeline,
    serial,
    strings,
)
from repro.core.graph import KIND_CODEC, PlanNode
from repro.core.wire import FrameError, read_frame


def test_single_consumer_enforced():
    g = GraphBuilder(1)
    e = g.add("delta", g.input(0))
    g.add("range_pack", e)
    with pytest.raises(ValueError, match="consumed twice"):
        g.add("range_pack", e)
        g.build()


def test_undefined_edge_rejected():
    plan = Plan(1, (PlanNode(KIND_CODEC, "delta", (5,), 1),))
    with pytest.raises(ValueError, match="undefined"):
        plan.validate()


def test_dup_enables_fanout():
    g = GraphBuilder(1)
    a, b = g.add("dup", g.input(0))
    g.add("huffman", a)
    g.add("fse", b)
    c = Compressor(g.build())
    assert c.roundtrip_check(b"abcabcabc" * 100)


def test_empty_plan_stores_input():
    frame = compress(Plan(1, ()), b"raw passthrough")
    assert decompress_bytes(frame) == b"raw passthrough"


def test_multi_input_graph():
    g = GraphBuilder(2)
    merged = g.add("concat", g.input(0), g.input(1))
    g.add("huffman", merged)
    plan = g.build()
    a, b = serial(b"xxxxyyy" * 50), serial(b"zzzz" * 99)
    frame = compress(plan, [a, b])
    out = decompress(frame)
    assert out[0].content_bytes() == a.content_bytes()
    assert out[1].content_bytes() == b.content_bytes()


def test_universal_decoder_needs_no_plan():
    """Any frame decodes through the same decompress() — no plan argument."""
    plans = [
        pipeline("delta", "range_pack"),
        pipeline("transpose", "huffman"),
        pipeline("transpose", "fse"),
    ]
    x = numeric(np.arange(1000, dtype=np.uint32))
    for p in plans:
        frame = compress(p, [x])
        (out,) = decompress(frame)  # same universal entry point
        assert out.content_bytes() == x.content_bytes()


def test_frame_crc_detects_corruption():
    frame = bytearray(compress(pipeline("huffman"), b"hello entropy" * 64))
    frame[len(frame) // 2] ^= 0xFF
    with pytest.raises(FrameError, match="checksum"):
        read_frame(bytes(frame))


def test_frame_magic_rejected():
    with pytest.raises(FrameError, match="magic"):
        read_frame(b"NOPE" + b"\x00" * 32)


def test_version_gating_encode():
    with pytest.raises(ValueError, match="requires format version"):
        compress(
            pipeline("zlib_backend"), b"x" * 10, ctx=CompressionCtx(format_version=2)
        )


def test_version_out_of_range():
    with pytest.raises(VersionError):
        compress(pipeline("store"), b"x", ctx=CompressionCtx(format_version=99))


def test_frame_records_selected_version():
    frame = compress(pipeline("delta"), numeric(np.arange(10, dtype=np.uint8)),
                     ctx=CompressionCtx(format_version=1))
    version, *_ = read_frame(frame)
    assert version == 1


def test_serialized_compressor_roundtrip():
    from repro.codecs import sao_profile

    c = Compressor(sao_profile())
    blob = c.serialize()
    assert len(blob) < 2048, "paper §V-D: serialized compressors are <2KB"
    c2 = Compressor.deserialize(blob)
    assert c2.plan == c.plan


def test_selector_expansion_is_recorded_resolved():
    """Frames never contain selectors — only resolved codecs (paper §III-E)."""
    from repro.codecs import generic_profile
    from repro.core.codec import get_codec_by_id

    frame = compress(generic_profile(), numeric(np.arange(5000, dtype=np.uint32)))
    _, _, nodes, _ = read_frame(frame)
    for node in nodes:
        get_codec_by_id(node.codec_id)  # raises if not a registered codec


def test_string_streams_roundtrip_via_wire():
    s = strings([b"alpha", b"", b"gamma" * 10])
    frame = compress(Plan(1, ()), [s])
    (out,) = decompress(frame)
    assert out.to_strings() == s.to_strings()
