"""Trainer: NSGA-II invariants, GP genome validity, clustering behaviour,
end-to-end training (paper §VI-C)."""
import random

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-at-call-time stubs

from repro.core import Compressor, numeric, serial
from repro.core.message import SType, Stream
from repro.training import (
    CsvFrontend,
    NumericFrontend,
    StructFrontend,
    cluster_streams,
    compile_genome,
    crossover,
    mutate,
    nondominated_sort,
    pareto_prune,
    random_genome,
    train,
)

rng_np = np.random.default_rng(0)


# ----------------------------------------------------------------- NSGA-II
@given(
    st.lists(
        st.tuples(st.floats(0, 100), st.floats(0, 100)), min_size=1, max_size=40
    )
)
@settings(max_examples=30, deadline=None)
def test_nondominated_sort_front0_is_nondominated(objs):
    fronts = nondominated_sort(objs)
    f0 = fronts[0]
    for i in f0:
        for j in f0:
            if i != j:
                assert not (
                    objs[i][0] <= objs[j][0]
                    and objs[i][1] <= objs[j][1]
                    and (objs[i][0] < objs[j][0] or objs[i][1] < objs[j][1])
                )


@given(
    st.lists(st.tuples(st.floats(0, 100), st.floats(0, 100)), min_size=5, max_size=40),
    st.integers(1, 10),
)
@settings(max_examples=30, deadline=None)
def test_pareto_prune_keeps_k(objs, k):
    items = list(range(len(objs)))
    kept, kobjs = pareto_prune(items, objs, k)
    assert len(kept) == min(k, len(items))


# ------------------------------------------------------------------ GP ops
@pytest.mark.parametrize("sig", [(int(SType.NUMERIC), 4), (int(SType.SERIAL), 1),
                                 (int(SType.NUMERIC), 8), (int(SType.STRUCT), 3)])
def test_random_genomes_compile_and_roundtrip(sig):
    r = random.Random(7)
    stype, w = sig
    if stype == int(SType.NUMERIC):
        data = numeric(rng_np.integers(0, 1000, 500).astype(f"uint{8*w}"))
    elif stype == int(SType.STRUCT):
        from repro.core import struct as mk_struct

        data = mk_struct(rng_np.integers(0, 5, 300 * w).astype(np.uint8), w)
    else:
        data = serial(rng_np.integers(0, 30, 800).astype(np.uint8).tobytes())
    for _ in range(25):
        gno = random_genome(sig, r)
        plan = compile_genome(gno, sig)
        c = Compressor(plan)
        try:
            assert c.roundtrip_check(data), "silent corruption is never allowed"
        except ValueError:
            # data-dependent applicability (e.g. bitpack >57 bits) may REJECT
            # at encode time — a clean refusal, which the trainer discards
            pass


def test_mutate_and_crossover_stay_valid():
    sig = (int(SType.NUMERIC), 4)
    r = random.Random(3)
    data = numeric(np.cumsum(rng_np.integers(0, 9, 400)).astype(np.uint32))
    a = random_genome(sig, r)
    b = random_genome(sig, r)
    for _ in range(30):
        a = mutate(a, sig, r)
        child = crossover(a, b, sig, r)
        assert Compressor(compile_genome(child, sig)).roundtrip_check(data)


# -------------------------------------------------------------- clustering
def test_clustering_merges_identical_streams():
    # identical streams: zlib finds the cross-boundary match after concat,
    # so merged size < sum of individual sizes -> greedy merge fires
    base = rng_np.integers(0, 1 << 16, 4000).astype(np.uint32)
    streams = [numeric(base), numeric(base.copy()), numeric(rng_np.integers(0, 1 << 30, 4000).astype(np.uint32))]
    cl = cluster_streams(streams)
    asn = cl.assignment()
    assert asn[0] == asn[1], "identical streams should merge"
    assert asn[2] != asn[0], "uncorrelated stream should stay apart"


def test_clustering_respects_type_signatures():
    streams = [numeric(np.arange(100, dtype=np.uint32)), numeric(np.arange(100, dtype=np.uint16))]
    cl = cluster_streams(streams)
    assert len(cl.clusters) == 2  # different widths can never concat


# ------------------------------------------------------------- end-to-end
def test_train_struct_end_to_end():
    def sample(n):
        a = np.sort(rng_np.integers(0, 1 << 20, n)).astype(np.uint32)
        b = rng_np.integers(0, 7, n).astype(np.uint32)
        rec = np.empty((n, 8), np.uint8)
        rec[:, :4] = a.view(np.uint8).reshape(n, 4)
        rec[:, 4:] = b.view(np.uint8).reshape(n, 4)
        return rec.reshape(-1).tobytes()

    tc = train(
        [[serial(sample(1500))] for _ in range(2)],
        StructFrontend(widths=(4, 4)),
        pop_size=8,
        generations=2,
    )
    test_blob = sample(4000)
    plan = tc.best_ratio_plan()
    c = Compressor(plan)
    assert c.roundtrip_check(test_blob)
    assert len(c.compress(test_blob)) < len(test_blob) * 0.6
    # Pareto ordering: sizes ascending, times (roughly) descending
    sizes = [p.est_size for p in tc.points]
    assert sizes == sorted(sizes)
    # serialized deployment (paper §V-D)
    blob = Compressor(plan).serialize()
    c2 = Compressor.deserialize(blob)
    assert c2.roundtrip_check(test_blob)


def test_train_csv_end_to_end():
    rows = [b"%d,%s,%d" % (i, b"cat" if i % 3 else b"dog", (i * 7) % 50) for i in range(4000)]
    blob = b"\n".join(rows) + b"\n"
    tc = train(
        [[serial(blob)]],
        CsvFrontend(n_cols=3),
        pop_size=10,
        generations=4,
    )
    c = Compressor(tc.best_ratio_plan())
    assert c.roundtrip_check(blob)
    assert len(c.compress(blob)) < len(blob) * 0.5
