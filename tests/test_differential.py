"""Differential execution-path harness: one matrix, byte-identity everywhere.

The engine promises that *how* you drive it never changes the wire bytes:
one-shot ``compress()`` vs a reused ``CompressorSession`` vs the streaming
``stream_io`` file path vs the CLI subprocess, host vs device backend,
chunked vs unchunked, known vs unknown chunk count.  Before this harness
those promises were pinned by scattered per-PR checks; this module is the
single table that states them — extend ``CASES`` (or the path functions)
when a PR adds an execution path or corpus family.

Every case clears the resolve cache first: byte-identity must come from the
engine's contract, not from paths accidentally sharing a cached selector
choice (the CLI subprocess starts cold and would expose that).
"""
import io
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-at-call-time stubs

from repro.codecs import profiles as P
from repro.core import (
    CompressionCtx,
    CompressorSession,
    compress,
    decompress,
    numeric,
    resolve_cache_clear,
    serial,
    stream_io,
)
from repro.core.codec import available_backends
from repro.core.graph import pipeline
from repro.core.message import SType, Stream, strings, struct as mk_struct

REPO_ROOT = Path(__file__).resolve().parents[1]
CHUNK = 2048  # small enough that every corpus splits into several chunks


# ----------------------------------------------------------------- corpora
def corpus_text(seed: int = 0) -> Stream:
    rng = np.random.default_rng(seed)
    words = [b"request", b"handled", b"auth", b"cache", b"miss", b"hit", b"the"]
    parts = [words[int(i)] for i in rng.integers(0, len(words), 4000)]
    return serial(b" ".join(parts)[:16000])


def corpus_numeric(seed: int = 0) -> Stream:
    rng = np.random.default_rng(seed)
    return numeric(np.cumsum(rng.integers(0, 50, 5000)).astype(np.uint32))


def corpus_struct(seed: int = 0) -> Stream:
    rng = np.random.default_rng(seed)
    n = 2000
    rec = np.empty((n, 6), np.uint8)
    rec[:, :4] = rng.integers(0, 100000, n).astype(np.uint32).view(np.uint8).reshape(n, 4)
    rec[:, 4:] = rng.integers(0, 5, (n, 2))
    return mk_struct(rec.reshape(-1), 6)


def corpus_string(seed: int = 0) -> Stream:
    rng = np.random.default_rng(seed)
    words = [b"alpha", b"beta", b"gamma", b"", b"delta" * 10]
    return strings([words[int(i)] for i in rng.integers(0, len(words), 3000)])


CORPORA = {
    "text": corpus_text,
    "numeric": corpus_numeric,
    "struct": corpus_struct,
    "string": corpus_string,
}

PLANS = {
    "text": P.text_profile,
    "generic": P.generic_profile,
    "numeric": P.numeric_profile,
    "delta_chain": lambda: pipeline("delta", "transpose", ("zlib_backend", {"level": 1})),
}

# The matrix: (corpus, plan, chunk_bytes).  chunk_bytes=0 -> single frame.
CASES = [
    ("text", "text", 0),
    ("text", "text", CHUNK),
    ("text", "generic", 0),
    ("text", "generic", CHUNK),
    ("numeric", "numeric", 0),
    ("numeric", "numeric", CHUNK),
    ("numeric", "delta_chain", 0),
    ("numeric", "delta_chain", CHUNK),
    ("struct", "generic", 0),
    ("struct", "generic", CHUNK),
    ("string", "generic", 0),
    ("string", "generic", CHUNK),
]

IDS = [f"{c}-{p}-{'chunked' if k else 'single'}" for c, p, k in CASES]


# ------------------------------------------------------------------- paths
def path_oneshot(plan, stream, chunk, backend="host") -> bytes:
    return compress(plan, stream, chunk_bytes=chunk or None, backend=backend)


def path_session(plan, stream, chunk, backend="host") -> bytes:
    with CompressorSession(plan, chunk_bytes=chunk or None, backend=backend) as s:
        return s.compress(stream)


def path_session_to(plan, stream, chunk, backend="host") -> bytes:
    buf = io.BytesIO()
    with CompressorSession(plan, chunk_bytes=chunk or None, backend=backend) as s:
        s.compress_to(stream, buf)
    return buf.getvalue()


def path_unknown_count(plan, stream, chunk, backend="host") -> bytes:
    """Streaming writer with n_chunks=None (seekable backpatch mode)."""
    from repro.core.engine import _split_chunks

    buf = io.BytesIO()
    with CompressorSession(plan, backend=backend) as s:
        s.compress_chunks(iter(_split_chunks(stream, chunk)), buf, n_chunks=None)
    return buf.getvalue()


IN_MEMORY_PATHS = {
    "session": path_session,
    "session_to": path_session_to,
}


def _roundtrip_equal(stream: Stream, frame: bytes) -> None:
    (out,) = decompress(frame)
    assert out.content_bytes() == stream.content_bytes()
    assert out.stype == stream.stype and out.width == stream.width
    if stream.stype == SType.STRING:
        assert np.array_equal(out.lengths, stream.lengths)


# ------------------------------------------------------------------- matrix
@pytest.mark.parametrize("corpus,plan_name,chunk", CASES, ids=IDS)
def test_paths_byte_identical(corpus, plan_name, chunk):
    stream = CORPORA[corpus]()
    plan = PLANS[plan_name]()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    for name, path in IN_MEMORY_PATHS.items():
        resolve_cache_clear()
        assert path(plan, stream, chunk) == ref, f"{name} diverged from one-shot"
    if chunk and len(ref) > 4 and ref[:4] == b"OZLC":
        # unknown-count mode reserves a 5-byte padded count varint (wire.py):
        # bytes differ at exactly that field — and therefore at the trailing
        # CRC, which covers it — everything between must match and the frame
        # must decode identically
        resolve_cache_clear()
        unknown = path_unknown_count(plan, stream, chunk)
        pad = len(unknown) - len(ref)
        assert 0 <= pad <= 4, "unknown-count writer: unexpected layout change"
        assert unknown[5 + 5 : -4] == ref[5 + 5 - pad : -4], (
            "unknown-count container writer diverged beyond the count field"
        )
        _roundtrip_equal(stream, unknown)
    _roundtrip_equal(stream, ref)


@pytest.mark.parametrize(
    "chunk", [0, CHUNK], ids=["single", "chunked"]
)
def test_device_backend_byte_identical(chunk):
    if "device" not in available_backends():
        pytest.skip("no device backend registered")
    stream = corpus_numeric()
    plan = PLANS["delta_chain"]()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    resolve_cache_clear()
    dev = path_oneshot(plan, stream, chunk, backend="device")
    assert dev == ref, "device backend frames must be byte-identical to host"
    resolve_cache_clear()
    assert path_session(plan, stream, chunk, backend="device") == ref


@pytest.mark.parametrize("chunk", [0, CHUNK], ids=["single", "chunked"])
def test_stream_io_byte_identical(tmp_path, chunk):
    """File path == in-memory path, for serial corpora (files are bytes)."""
    stream = corpus_text()
    plan = P.text_profile()
    src = tmp_path / "corpus.bin"
    src.write_bytes(stream.content_bytes())
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    dst = tmp_path / "corpus.ozl"
    resolve_cache_clear()
    stream_io.compress_file(src, dst, plan, chunk_bytes=chunk or None)
    assert dst.read_bytes() == ref, "stream_io.compress_file diverged"
    out = tmp_path / "corpus.out"
    stream_io.decompress_file(dst, out)
    assert out.read_bytes() == stream.content_bytes()


@pytest.fixture(scope="module")
def service_server(tmp_path_factory):
    """One daemon for the whole module, with every serial-input plan
    registered — the service column of the matrix."""
    from repro.service import CompressionServer, PlanRegistry

    registry = PlanRegistry()
    registry.register_profile("text")
    registry.register_profile("generic")
    sock = tmp_path_factory.mktemp("svc") / "diff.sock"
    with CompressionServer(registry, socket_path=str(sock)) as srv:
        yield srv


@pytest.mark.parametrize(
    "profile,chunk",
    [("text", 0), ("text", CHUNK), ("generic", 0), ("generic", CHUNK)],
    ids=["text-single", "text-chunked", "generic-single", "generic-chunked"],
)
def test_service_byte_identical(service_server, profile, chunk):
    """The daemon's hot-session path emits the offline path's exact bytes."""
    from repro.service import ServiceClient

    stream = corpus_text()
    plan = PLANS[profile]()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    with ServiceClient(service_server.address) as client:
        frame, info = client.compress_bytes(
            stream.content_bytes(), profile, chunk_bytes=chunk
        )
        assert frame == ref, "service diverged from the offline path"
        assert info["bytes_out"] == len(ref)
        back, _ = client.decompress_bytes(frame)
        assert back == stream.content_bytes()


@pytest.mark.parametrize(
    "profile,chunk",
    [("text", CHUNK), ("generic", 0)],
    ids=["text-chunked", "generic-single"],
)
def test_cli_subprocess_byte_identical(tmp_path, profile, chunk):
    """A cold CLI process emits the same bytes as the warm in-memory path."""
    stream = corpus_text()
    plan = PLANS[profile]()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    src = tmp_path / "corpus.bin"
    src.write_bytes(stream.content_bytes())
    dst = tmp_path / "corpus.ozl"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    subprocess.run(
        [
            sys.executable, "-m", "repro", "compress", str(src), "-o", str(dst),
            "--profile", profile, "--chunk-bytes", str(chunk),
        ],
        check=True, env=env, cwd=REPO_ROOT, capture_output=True,
    )
    assert dst.read_bytes() == ref, "CLI subprocess diverged from in-memory path"


# ---------------------------------------------------------------- hypothesis
@given(
    data=st.binary(min_size=1, max_size=4096),
    chunk=st.sampled_from([0, 512]),
)
@settings(max_examples=25, deadline=None)
def test_fuzz_serial_paths_agree(data, chunk):
    stream = serial(data)
    plan = P.generic_profile()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    assert path_session(plan, stream, chunk) == ref
    assert path_session_to(plan, stream, chunk) == ref
    _roundtrip_equal(stream, ref)


@given(
    vals=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=2000),
    chunk=st.sampled_from([0, 512]),
)
@settings(max_examples=25, deadline=None)
def test_fuzz_numeric_paths_agree(vals, chunk):
    stream = numeric(np.asarray(vals, dtype=np.uint32))
    plan = P.numeric_profile()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    assert path_session(plan, stream, chunk) == ref
    assert path_session_to(plan, stream, chunk) == ref
    _roundtrip_equal(stream, ref)


@given(
    items=st.lists(st.binary(min_size=0, max_size=40), min_size=1, max_size=300),
    chunk=st.sampled_from([0, 256]),
)
@settings(max_examples=15, deadline=None)
def test_fuzz_string_paths_agree(items, chunk):
    stream = strings(items)
    plan = P.generic_profile()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    assert path_session(plan, stream, chunk) == ref
    assert path_session_to(plan, stream, chunk) == ref
    _roundtrip_equal(stream, ref)


@given(
    data=st.binary(min_size=6, max_size=3000),
    chunk=st.sampled_from([0, 512]),
)
@settings(max_examples=15, deadline=None)
def test_fuzz_struct_paths_agree(data, chunk):
    width = 6
    data = data[: len(data) - len(data) % width] or b"\0" * width
    stream = mk_struct(data, width)
    plan = P.generic_profile()
    resolve_cache_clear()
    ref = path_oneshot(plan, stream, chunk)
    assert path_session(plan, stream, chunk) == ref
    assert path_session_to(plan, stream, chunk) == ref
    _roundtrip_equal(stream, ref)
