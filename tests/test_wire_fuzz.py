"""Adversarial wire-format tests (paper §I claim v: centralizing compression
shrinks the security surface — so the universal decoder must fail CLOSED).

Invariants:
  * decompress() of arbitrary/corrupted bytes raises a CONTROLLED error
    (FrameError/ValueError/KeyError/IndexError) — never hangs, never
    segfaults, never returns wrong data silently (CRC catches bit-rot).
  * truncation at every prefix length is rejected.
  * header/graph-section mutations that survive the CRC are still rejected
    by structural validation.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-at-call-time stubs

from repro.core import compress, decompress, numeric, pipeline
from repro.core.wire import FrameError

CONTROLLED = (FrameError, ValueError, KeyError, IndexError, OverflowError)


def _a_frame() -> bytes:
    return compress(
        pipeline("delta", "range_pack"), numeric(np.arange(500, dtype=np.uint32))
    )


@given(st.binary(min_size=0, max_size=512))
@settings(max_examples=200, deadline=None)
def test_random_bytes_fail_closed(blob):
    with pytest.raises(CONTROLLED):
        decompress(blob)


@given(st.data())
@settings(max_examples=120, deadline=None)
def test_single_byte_corruption_fails_closed(data):
    frame = bytearray(_a_frame())
    pos = data.draw(st.integers(0, len(frame) - 1))
    bit = data.draw(st.integers(0, 7))
    frame[pos] ^= 1 << bit
    try:
        out = decompress(bytes(frame))
    except CONTROLLED:
        return  # fail-closed: good
    # the only acceptance: the flip landed somewhere semantically inert AND
    # the data still roundtrips bit-exactly
    (s,) = out
    assert s.content_bytes() == np.arange(500, dtype=np.uint32).tobytes()


def test_truncation_every_prefix_rejected():
    frame = _a_frame()
    for cut in range(0, len(frame) - 1, max(len(frame) // 97, 1)):
        with pytest.raises(CONTROLLED):
            decompress(frame[:cut])


def test_crc_is_last_line_of_defense():
    """Flipping a payload byte AND fixing the CRC must still fail (structural
    checks) or roundtrip correctly — silent corruption is never accepted."""
    import struct
    import zlib

    frame = bytearray(_a_frame())
    # corrupt one payload byte near the end (stored stream data)
    frame[-20] ^= 0xFF
    body = bytes(frame[:-4])
    frame[-4:] = struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)
    try:
        (s,) = decompress(bytes(frame))
    except CONTROLLED:
        return
    # decoded without error: output must DIFFER from the original (the codec
    # chain propagated the corruption — acceptable; silence about it is not)
    assert s.content_bytes() != np.arange(500, dtype=np.uint32).tobytes()


def test_unknown_codec_id_rejected():
    from repro.core.engine import ResolvedNode
    from repro.core import wire

    frame = wire.write_frame(3, 1, [ResolvedNode(200, (0,), 1, b"")], [])
    with pytest.raises(CONTROLLED):
        decompress(frame)
    # fail-closed means a *diagnosable* FrameError naming the offending id —
    # a bare KeyError out of the registry is a decoder bug
    with pytest.raises(FrameError, match="unknown codec id 200"):
        decompress(frame)


def test_future_codec_in_old_frame_min_version_gated():
    """A registered codec referenced below its min_version is a FrameError,
    not a silent decode — same gate as the unknown-id path."""
    from repro.core.engine import ResolvedNode
    from repro.core import wire

    # codec id 26 = fused_delta_bitpack, min_version 4, inside a v3 frame
    frame = wire.write_frame(3, 1, [ResolvedNode(26, (0,), 1, b"")], [])
    with pytest.raises(FrameError, match="min_version"):
        decompress(frame)


@given(st.integers(0, 1 << 16))
@settings(max_examples=80, deadline=None)
def test_arbitrary_codec_ids_fail_closed(codec_id):
    from repro.core.codec import _BY_ID, _ensure_standard_library
    from repro.core.engine import ResolvedNode
    from repro.core import wire

    _ensure_standard_library()
    frame = wire.write_frame(3, 1, [ResolvedNode(codec_id, (0,), 1, b"")], [])
    try:
        decompress(frame)
    except FrameError as err:
        if codec_id not in _BY_ID:
            assert str(codec_id) in str(err)
    except CONTROLLED:
        pass


def test_absurd_counts_rejected_fast():
    """Node/stream counts near 2^60 must be rejected without allocation."""
    import struct
    import zlib

    body = bytearray(b"OZLJ\x03\x01")
    body += b"\xff\xff\xff\xff\xff\xff\xff\xff\x7f"  # varint n_nodes ~ 2^62
    blob = bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(CONTROLLED):
        decompress(blob)


# ----------------------------------------------------------- container frames
def _a_container() -> bytes:
    return compress(
        pipeline("delta", "range_pack"),
        numeric(np.arange(5000, dtype=np.uint32)),
        chunk_bytes=4096,
    )


def test_container_single_byte_corruption_fails_closed():
    base = _a_container()
    for pos in range(0, len(base), max(len(base) // 63, 1)):
        frame = bytearray(base)
        frame[pos] ^= 0xFF
        try:
            (s,) = decompress(bytes(frame))
        except CONTROLLED:
            continue
        assert s.content_bytes() == np.arange(5000, dtype=np.uint32).tobytes()


def test_container_absurd_chunk_count_rejected_fast():
    import struct
    import zlib

    body = bytearray(b"OZLC\x04")
    body += b"\xff\xff\xff\xff\xff\xff\xff\xff\x7f"  # varint n_chunks ~ 2^62
    blob = bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(CONTROLLED):
        decompress(blob)


def test_nested_container_rejected():
    import struct
    import zlib
    from repro.core.wire import read_varint, write_varint

    inner = _a_container()
    body = bytearray(b"OZLC\x04")
    write_varint(body, 1)
    write_varint(body, len(inner))
    body += inner
    blob = bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(CONTROLLED):
        decompress(blob)


# ------------------------------------------------- streaming container input
def _drain(blob: bytes):
    """Fully consume the streaming iterator over an in-memory container."""
    import io
    from repro.core.wire import iter_container_frames

    return list(iter_container_frames(io.BytesIO(blob)))


def test_stream_iter_matches_read_container():
    from repro.core import wire

    blob = _a_container()
    _version, frames = wire.read_container(blob)
    assert _drain(blob) == frames


def test_stream_truncation_every_prefix_rejected():
    """EOF at any point — header, count varint, length varint, mid-chunk,
    trailer — must raise FrameError, never hang or return cleanly."""
    blob = _a_container()
    for cut in range(len(blob)):  # every proper prefix, incl. len-1
        with pytest.raises(CONTROLLED):
            _drain(blob[:cut])


def test_stream_bad_chunk_length_varint():
    import struct
    import zlib
    from repro.core.wire import write_varint

    # container advertising 1 chunk whose length varint overflows 64 bits
    body = bytearray(b"OZLC\x04")
    write_varint(body, 1)
    body += b"\xff" * 10  # varint with shift > 63
    blob = bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(CONTROLLED):
        _drain(blob)

    # ... and one whose length claims more bytes than exist (mid-chunk EOF)
    body = bytearray(b"OZLC\x04")
    write_varint(body, 1)
    write_varint(body, 1 << 30)
    body += b"OZLJ\x04 some bytes that end early"
    blob = bytes(body) + struct.pack("<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(CONTROLLED):
        _drain(blob)


def test_stream_crc_mismatch_raises_after_chunks():
    """Flipping a bit in the trailer (or body) must surface as FrameError by
    the time the iterator is drained — a corrupt container never completes
    silently."""
    blob = bytearray(_a_container())
    blob[-1] ^= 0x01  # trailer CRC byte
    with pytest.raises(CONTROLLED):
        _drain(bytes(blob))


def test_stream_trailing_garbage_rejected():
    with pytest.raises(CONTROLLED):
        _drain(_a_container() + b"x")


@given(st.binary(min_size=0, max_size=256))
@settings(max_examples=150, deadline=None)
def test_stream_random_bytes_fail_closed(blob):
    with pytest.raises(CONTROLLED):
        _drain(blob)


def test_stream_random_mutations_fail_closed_or_roundtrip():
    """Single-byte corruption anywhere in the container: the streaming
    iterator + universal decoder either raise a controlled error or the data
    roundtrips bit-exactly (the flip was semantically inert)."""
    from repro.core.engine import DecompressorSession

    base = _a_container()
    want = np.arange(5000, dtype=np.uint32).tobytes()
    with DecompressorSession() as sess:
        for pos in range(0, len(base), max(len(base) // 63, 1)):
            import io

            blob = bytearray(base)
            blob[pos] ^= 0xFF
            try:
                parts = list(sess.iter_frames(io.BytesIO(bytes(blob))))
                got = b"".join(p.content_bytes() for p in parts)
            except CONTROLLED:
                continue
            assert got == want


# ------------------------------------------------------ bit-flip region sweep
def test_bit_flips_in_every_region_fail_closed_or_roundtrip():
    """Exhaustive single-BIT flips over every structural byte of a
    multi-chunk container (container magic, version, chunk-count varint,
    per-chunk length varints, frame magics, frame CRCs, trailer CRC) plus a
    stride of payload bytes.  Two invariants per flip:

      * the default decoder fails closed or the data roundtrips bit-exactly;
      * salvage never lies — every stream it returns is the byte-exact
        content of a real chunk, and every *placed* stream is the chunk it
        claims to be.
    """
    import io
    from repro.core.engine import DecompressorSession
    from repro.core.wire import read_varint

    base = _a_container()
    data = np.arange(5000, dtype=np.uint32).tobytes()
    chunk_slices = [data[i : i + 4096] for i in range(0, len(data), 4096)]
    true_chunks = set(chunk_slices)

    # map the container's byte regions by walking the framing
    n, pos = read_varint(base, 5)
    structural = set(range(0, pos))  # magic + version + count varint
    payload_positions = []
    for _ in range(n):
        lpos = pos
        ln, pos = read_varint(base, pos)
        structural.update(range(lpos, pos))  # chunk length varint
        structural.update(range(pos, pos + 5))  # frame magic + version
        structural.update(range(pos + ln - 4, pos + ln))  # frame CRC
        payload_positions.extend(range(pos + 5, pos + ln - 4))
        pos += ln
    structural.update(range(len(base) - 4, len(base)))  # trailer CRC
    assert pos + 4 == len(base)
    sampled = sorted(structural) + payload_positions[:: max(len(payload_positions) // 40, 1)]

    with DecompressorSession() as sess:
        for bpos in sampled:
            for bit in range(8):
                blob = bytearray(base)
                blob[bpos] ^= 1 << bit
                blob = bytes(blob)
                try:
                    parts = decompress(blob)
                    got = b"".join(p.content_bytes() for p in parts)
                except CONTROLLED:
                    pass
                else:
                    assert got == data, f"silent corruption at byte {bpos} bit {bit}"
                streams, report = sess.decompress_salvage(blob)
                assert len(streams) == len(report.recovered) + report.recovered_unplaced
                for s, idx in zip(streams, report.recovered):
                    assert s.content_bytes() == chunk_slices[idx], (
                        f"salvage misplaced chunk {idx} (byte {bpos} bit {bit})"
                    )
                for s in streams[len(report.recovered) :]:
                    assert s.content_bytes() in true_chunks, (
                        f"salvage invented content (byte {bpos} bit {bit})"
                    )


def test_container_writer_count_mismatch_rejected():
    import io
    from repro.core import wire

    blob = _a_container()
    _v, frames = wire.read_container(blob)
    w = wire.ContainerWriter(io.BytesIO(), 4, n_chunks=len(frames) + 1)
    for f in frames:
        w.write_chunk(f)
    with pytest.raises(CONTROLLED):
        w.close()
    w2 = wire.ContainerWriter(io.BytesIO(), 4, n_chunks=1)
    w2.write_chunk(frames[0])
    with pytest.raises(CONTROLLED):
        w2.write_chunk(frames[1])
