"""Seeded ill-typed plan corpus: plans the static analyzer must reject.

Each ``.ozp`` here deserializes fine (structurally valid) but carries a
definite type error — the analyzer catalogue's E_* codes — and must be
rejected fail-closed at every entry point: ``PlanRegistry.register_*``,
``repro lint``, and the trainer's static pruning.  Regenerate with:

    PYTHONPATH=src python tests/illtyped/_make_corpus.py

``manifest.json`` maps each file to the diagnostic code it must trigger.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

from repro.core.graph import GraphBuilder  # noqa: E402
from repro.core.serialize import serialize_plan  # noqa: E402

ILLTYPED_DIR = Path(__file__).resolve().parent


def bad_type_entropy_delta():
    """huffman's serial output fed to delta (numeric-only) -> E_TYPE."""
    g = GraphBuilder(1)
    lit, lens = g.add("huffman", g.input(0), n_out=2)
    g.add("delta", lit)
    g.add("store", lens)
    return g.build("bad_type_entropy_delta"), "E_TYPE", None


def bad_type_string_zlib():
    """parse_numeric's STRING residue fed to zlib_backend -> E_TYPE."""
    g = GraphBuilder(1)
    mask, nums, residue = g.add("parse_numeric", g.input(0), n_out=3)
    g.add("zlib_backend", residue, level=6)
    g.add("store", mask)
    g.add("store", nums)
    return g.build("bad_type_string_zlib"), "E_TYPE", None


def bad_width_huffman():
    """width-4 numerics into huffman (byte alphabet only) -> E_WIDTH."""
    g = GraphBuilder(1)
    n4 = g.add("interpret_numeric", g.input(0), width=4)
    g.add("huffman", n4, n_out=2)
    return g.build("bad_width_huffman"), "E_WIDTH", None


def bad_params_float_split():
    """float_split(fmt=float64) on a pinned width-4 stream -> E_PARAMS."""
    g = GraphBuilder(1)
    n4 = g.add("interpret_numeric", g.input(0), width=4)
    g.add("float_split", n4, n_out=3, fmt=3)
    return g.build("bad_params_float_split"), "E_PARAMS", None


def bad_version_fused():
    """fused_delta_bitpack (min_version 4) in a v2 plan -> E_VERSION."""
    g = GraphBuilder(1)
    n4 = g.add("interpret_numeric", g.input(0), width=4)
    g.add("fused_delta_bitpack", n4)
    return g.build("bad_version_fused"), "E_VERSION", 2


def main() -> None:
    manifest = {}
    for fn in (
        bad_type_entropy_delta,
        bad_type_string_zlib,
        bad_width_huffman,
        bad_params_float_split,
        bad_version_fused,
    ):
        plan, code, fv = fn()
        blob = serialize_plan(plan, plan.name, format_version=fv)
        (ILLTYPED_DIR / f"{plan.name}.ozp").write_bytes(blob)
        manifest[f"{plan.name}.ozp"] = {"expect": code}
        print(f"{plan.name}.ozp: {len(blob)}B expect {code}")
    (ILLTYPED_DIR / "manifest.json").write_text(
        json.dumps(manifest, indent=1, sort_keys=True) + "\n"
    )


if __name__ == "__main__":
    main()
