"""The multi-core service plane: pre-forked workers, one shared listener.

Availability claims come with their failure modes injected, per the standing
reliability policy: worker death is proven by SIGKILLing *real* forked
processes — both directly by pid and through enumerated ``svc.request.*``
crash sites armed inside the workers — and every scenario must end with the
client's retried request served and no session leaked anywhere in the plane.
"""
import io
import os
import signal
import socket
import time

import pytest

from repro.codecs import profiles as PR
from repro.core import compress, serial
from repro.reliability.faults import FaultPlan
from repro.service import (
    PlanRegistry,
    ServiceClient,
    ServicePlane,
    ServiceUnavailable,
)
from repro.service import protocol as SP

DATA = b"plane corpus: ts=171 dev=3 level=INFO handled\n" * 400


def _registry() -> PlanRegistry:
    registry = PlanRegistry()
    registry.register_profile("generic")
    return registry


def _plane(tmp_path, **kw) -> ServicePlane:
    kw.setdefault("workers", 2)
    kw.setdefault("request_timeout", 10.0)
    return ServicePlane(
        _registry(), socket_path=str(tmp_path / "plane.sock"), **kw
    )


def _client(plane, **kw) -> ServiceClient:
    kw.setdefault("timeout", 15.0)
    return ServiceClient(plane.address, **kw)


def _aggregate_in_use(stats: dict) -> int:
    return sum(s.get("in_use", 0) for s in (stats.get("sessions") or {}).values())


def _wait_for(predicate, timeout=10.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


# ------------------------------------------------------------------ basics
def test_plane_roundtrip_byte_identical(tmp_path):
    """Frames through the plane match the in-process engine byte for byte."""
    want = compress(PR.generic_profile(), serial(DATA), chunk_bytes=4096)
    with _plane(tmp_path) as plane, _client(plane) as c:
        frame, stats = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
        assert frame == want
        back, _ = c.decompress_bytes(frame)
        assert back == DATA
        assert stats["digest"]


def test_plane_spreads_connections_across_processes(tmp_path):
    """Distinct worker processes actually serve: with enough fresh
    connections, at least two different pids answer ping."""
    with _plane(tmp_path, workers=2) as plane:
        pids = set()
        for _ in range(20):
            with _client(plane) as c:
                pids.add(c.ping()["pid"])
            if len(pids) >= 2:
                break
        assert pids <= set(plane.worker_pids())
        assert len(pids) >= 2, f"all connections served by one worker: {pids}"


def test_plane_aggregated_stats_and_metrics(tmp_path):
    with _plane(tmp_path) as plane, _client(plane) as c:
        for _ in range(3):
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
        # aggregation needs every worker's snapshot: the serving worker's
        # travels with the query, the idle sibling's arrives by heartbeat
        assert _wait_for(lambda: len(c.stats().get("per_worker", {})) >= 2)
        st = c.stats()
        assert st["workers"] == 2
        assert st["workers_alive"] == 2
        assert st["requests"]["compress"] >= 3
        assert _aggregate_in_use(st) == 0
        text = c.metrics().decode()
        assert "ozl_workers 2" in text
        assert 'ozl_requests_total{verb="compress"}' in text
        assert "ozl_worker_sessions_in_use" in text


def test_plane_stats_dict_shape_matches_threaded_server(tmp_path):
    """The aggregate keeps the single-process stats surface (plus plane
    keys), so dashboards and clients need no per-flavor switches."""
    with _plane(tmp_path) as plane, _client(plane) as c:
        c.compress_bytes(DATA, "generic", chunk_bytes=4096)
        st = c.stats()
        for key in (
            "ok", "protocol_version", "plans", "uptime_s", "address",
            "requests", "errors", "shed", "bytes_in", "bytes_out",
            "sessions", "latency", "resolve_cache", "coder_cache",
            "backend_health", "quarantine", "registry",
        ):
            assert key in st, f"aggregate missing {key!r}"


# ------------------------------------------------------------- worker death
def test_sigkill_serving_worker_mid_session_absorbed(tmp_path):
    """SIGKILL the worker a client is pinned to; the retried request must be
    served by a sibling (the shared listener never refuses) and the plane
    must end with zero checked-out sessions and a respawned worker."""
    with _plane(tmp_path) as plane:
        with _client(plane, retries=5, backoff_base=0.1) as c:
            want, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            victim = c.ping()["pid"]
            assert victim in plane.worker_pids()
            os.kill(victim, signal.SIGKILL)
            frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert frame == want, "retried request produced different bytes"
            assert _wait_for(lambda: victim not in plane.worker_pids())
            assert _wait_for(lambda: len(plane.worker_pids()) == 2)
            assert plane.worker_restarts >= 1
            st = c.stats()
            assert _aggregate_in_use(st) == 0, "leaked session after kill"


def test_crash_sites_enumerable_and_kill_mid_compress_absorbed(tmp_path):
    """Per the standing policy, the kill sites are enumerated from a record
    run, then a real worker is SIGKILLed at one of them mid-request."""
    # 1. enumerate: a record-mode plan sees the request-path crash sites
    recorder = FaultPlan(record=True)
    from repro.service.server import RequestCore

    core = RequestCore(_registry())
    try:
        with recorder.arm(all_threads=True):
            buf = io.BytesIO()
            SP.write_request(
                buf, SP.VERB_COMPRESS,
                {"plan": "generic", "size": len(DATA), "chunk_bytes": 4096},
                SP.iter_body_blocks(DATA, 4096),
            )
            _verb, header, body = SP.read_request(io.BytesIO(buf.getvalue()))
            resp, out = core.handle(SP.VERB_COMPRESS, header, body)
            out.close()
    finally:
        core.close()
    sites = {name for name, _n in recorder.sites}
    assert "svc.request.compress.begin" in sites
    assert "svc.request.compress.mid" in sites

    # 2. kill a real worker at the mid-compress site (after the session is
    # checked out, before the response) — the client's retry must succeed
    plan = FaultPlan().at("svc.request.compress.mid", nth=1, action="kill")
    with _plane(tmp_path, worker_fault_json=plan.to_json()) as plane:
        before = set(plane.worker_pids())
        with _client(plane, retries=6, backoff_base=0.1) as c:
            want = compress(PR.generic_profile(), serial(DATA), chunk_bytes=4096)
            frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert frame == want
            # at least one worker died at the crash site and was replaced
            assert _wait_for(lambda: plane.worker_restarts >= 1)
            assert _wait_for(lambda: len(plane.worker_pids()) == 2)
            assert before - set(plane.worker_pids()), "no worker was killed"
            # respawned workers come up clean (fault_respawns=False):
            # a fresh request must succeed without burning retries
            with _client(plane) as c2:
                frame2, _ = c2.compress_bytes(DATA, "generic", chunk_bytes=4096)
                assert frame2 == want
            st = c.stats()
            assert _aggregate_in_use(st) == 0


def test_restart_budget_bounds_respawns(tmp_path):
    """A kill rule re-armed on every respawn cannot crash-loop the plane
    past its restart budget."""
    plan = FaultPlan().at("svc.request.compress.begin", nth=1, action="kill")
    with _plane(
        tmp_path,
        workers=1,
        worker_fault_json=plan.to_json(),
        fault_respawns=True,
        max_restarts=2,
    ) as plane:
        # short timeout: once the budget is spent there is no worker left to
        # accept, and the attempt must end at the deadline, not hang
        with _client(plane, retries=8, backoff_base=0.1, timeout=3.0) as c:
            # each attempt kills the (sole, re-faulted) worker until the
            # restart budget is spent; the plane must shrink, not crash-loop
            with pytest.raises(Exception):
                c.compress_bytes(DATA, "generic", chunk_bytes=4096)
        assert plane.worker_restarts <= 2


# ------------------------------------------------------------ rate limiting
def test_plane_rate_limit_rejects_with_retry_after(tmp_path):
    with _plane(tmp_path, workers=1, rate_limit=1.0, rate_burst=2.0) as plane:
        with _client(plane) as c:
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            with pytest.raises(ServiceUnavailable) as exc:
                c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert exc.value.kind == "rate_limited"
            assert exc.value.retry_after and exc.value.retry_after > 0
            # pings are free: control verbs are never rate limited
            assert c.ping()["ok"]
            st = c.stats()
            assert st["rate_limited"] >= 1
            assert _aggregate_in_use(st) == 0


def test_rate_limited_client_recovers_after_backoff(tmp_path):
    with _plane(tmp_path, workers=1, rate_limit=20.0, rate_burst=1.0) as plane:
        # retries honor the server's retry_after, so a client with budget
        # rides straight through the rejection window
        with _client(plane, retries=4, backoff_base=0.05) as c:
            for _ in range(3):
                frame, _ = c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert frame


def test_threaded_server_rate_limit(tmp_path):
    """The per-connection limiter also guards the classic threaded server."""
    from repro.service import CompressionServer

    with CompressionServer(
        _registry(),
        socket_path=str(tmp_path / "thr.sock"),
        rate_limit=1.0,
        rate_burst=2.0,
        request_timeout=5.0,
    ) as srv:
        with ServiceClient(srv.address, timeout=10.0) as c:
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            with pytest.raises(ServiceUnavailable) as exc:
                c.compress_bytes(DATA, "generic", chunk_bytes=4096)
            assert exc.value.kind == "rate_limited"
        st = srv.stats()
        assert st["rate_limited"] >= 1
        assert st["rate_limiter"]["rejected"] >= 1


# --------------------------------------------------------------- client side
def test_client_retries_connection_refused():
    """ECONNREFUSED during a restart window is retried under the jittered
    backoff budget, succeeding once the plane's listener is back.  TCP keeps
    the refused window deterministic: a closed port refuses instantly, and
    rebinding the same port (REUSEADDR) has no missing-path moment."""
    import threading

    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    lst.bind(("127.0.0.1", 0))
    port = lst.getsockname()[1]
    lst.listen(1)
    # the client connects eagerly in __init__, so dial the throwaway
    # listener first, then tear it down to open the refused window
    c = ServiceClient(("127.0.0.1", port), timeout=10.0, retries=6,
                      backoff_base=0.15, backoff_max=0.5)
    lst.close()
    c.close()  # drop the dead connection; the next call redials

    started = []

    def bring_up():
        time.sleep(0.4)
        plane = ServicePlane(_registry(), host="127.0.0.1", port=port, workers=1)
        plane.start()
        started.append(plane)

    t = threading.Thread(target=bring_up)
    t.start()
    try:
        assert c.ping()["ok"]  # retried through the refused window
    finally:
        t.join(10)
        c.close()
        for plane in started:
            plane.shutdown()


def test_connection_lost_is_hard_error_without_budget(tmp_path):
    """A server that dies before responding surfaces as ConnectionLost, and
    retries=0 keeps it a hard error (fail closed, never silently resend
    forever)."""
    from repro.service import ConnectionLost

    sock_path = str(tmp_path / "mute.sock")
    lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    lst.bind(sock_path)
    lst.listen(4)

    import threading

    def mute_server():
        # accept and slam the door without ever answering — the shape of a
        # worker crashing between request and response.  Exactly two accepts:
        # the client's eager connect and its one transparent redial (a third
        # would block in accept() forever; close() does not wake it)
        for _ in range(2):
            try:
                conn, _addr = lst.accept()
            except OSError:
                return
            conn.close()

    t = threading.Thread(target=mute_server)
    t.start()
    try:
        c = ServiceClient(f"unix:{sock_path}", timeout=5.0, retries=0)
        with pytest.raises(ConnectionLost):
            c.ping()
        c.close()
    finally:
        lst.close()
        t.join(10)
