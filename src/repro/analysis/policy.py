"""AST-based repo policy linter: ROADMAP standing policies as checked rules.

Rules
-----
``cpu-count``
    ``os.cpu_count()`` is banned: it reports the machine, not the cgroup /
    affinity mask this process may actually use, so containerized CI
    oversubscribes.  Use ``len(os.sched_getaffinity(0))``.

``fault-point-in-loop``
    ``fault_point()`` must not be called inside a ``for``/``while`` body.
    Fault points belong on operation boundaries; a per-element call burns a
    contextvar read per element on the data plane's hottest paths.  The
    ``crash_point`` alias is exempt *by definition*: it marks irreversible
    I/O steps (rename/replace/write boundaries), and a loop iteration that
    performs real file I/O dwarfs the hook.

``atomic-sink``
    Path-destined writes (``open(p, "w"/"wb"/...)``, ``Path.write_bytes``,
    ``Path.write_text``) must go through ``_atomic_sink`` so a crash never
    leaves a torn file at the final path.  Two shapes are sanctioned:
    the module that *defines* ``_atomic_sink`` (it has to open files), and
    functions that stage into a temp location and publish with
    ``os.replace`` (the shard store / checkpoint writer pattern) — the
    linter checks the enclosing function for an ``os.replace`` call.

Run over the tree (CI does this)::

    python -m repro.analysis.policy src
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence

__all__ = ["PolicyViolation", "lint_file", "lint_source", "lint_tree"]

_WRITE_MODES = frozenset("wax")
_WRITE_METHODS = frozenset({"write_bytes", "write_text"})


@dataclass(frozen=True)
class PolicyViolation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _call_name(node: ast.Call) -> Optional[str]:
    """Trailing name of the called thing: ``os.cpu_count`` -> ``cpu_count``."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _is_os_replace(node: ast.Call) -> bool:
    fn = node.func
    return (
        isinstance(fn, ast.Attribute)
        and fn.attr == "replace"
        and isinstance(fn.value, ast.Name)
        and fn.value.id == "os"
    )


def _open_write_mode(node: ast.Call) -> Optional[str]:
    """The mode string when this is ``open(..., "w*")``-like, else None."""
    if _call_name(node) not in ("open", "fdopen"):
        return None
    mode_arg = None
    if len(node.args) >= 2:
        mode_arg = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode_arg = kw.value
    if isinstance(mode_arg, ast.Constant) and isinstance(mode_arg.value, str):
        if set(mode_arg.value) & _WRITE_MODES:
            return mode_arg.value
    return None


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.violations: List[PolicyViolation] = []
        self._loop_depth = 0
        self._fn_stack: List[ast.AST] = []
        # module-level exemption: the file that implements _atomic_sink
        self._defines_atomic_sink = "_atomic_sink" in source and any(
            line.lstrip().startswith(("def _atomic_sink", "async def _atomic_sink"))
            for line in source.splitlines()
        )

    # ----------------------------------------------------------- structure
    def _visit_loop(self, node) -> None:
        self._loop_depth += 1
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self._loop_depth -= 1

    visit_For = visit_AsyncFor = visit_While = _visit_loop

    def _visit_fn(self, node) -> None:
        self._fn_stack.append(node)
        outer_depth, self._loop_depth = self._loop_depth, 0
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self._loop_depth = outer_depth
        self._fn_stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_fn

    def _enclosing_fn_replaces(self) -> bool:
        for fn in reversed(self._fn_stack):
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) and _is_os_replace(sub):
                    return True
        return False

    # --------------------------------------------------------------- rules
    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node)

        if name == "cpu_count":
            self.violations.append(PolicyViolation(
                "cpu-count", self.path, node.lineno,
                "os.cpu_count() ignores the affinity mask/cgroup —"
                " use len(os.sched_getaffinity(0))",
            ))

        if name == "fault_point" and self._loop_depth > 0:
            self.violations.append(PolicyViolation(
                "fault-point-in-loop", self.path, node.lineno,
                "fault_point() inside a loop body: hooks belong on operation"
                " boundaries, not per-element paths (crash_point marks"
                " sanctioned per-artifact I/O steps)",
            ))

        mode = _open_write_mode(node)
        is_write_method = (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _WRITE_METHODS
        )
        if (mode is not None or is_write_method) and not (
            self._defines_atomic_sink or self._enclosing_fn_replaces()
        ):
            what = (
                f"open(..., {mode!r})" if mode is not None
                else f".{node.func.attr}(...)"
            )
            self.violations.append(PolicyViolation(
                "atomic-sink", self.path, node.lineno,
                f"path-destined write {what} outside _atomic_sink: a crash"
                " here tears the final file — write through"
                " repro.core.stream_io._atomic_sink or stage + os.replace",
            ))

        self.generic_visit(node)


def lint_source(source: str, path: str = "<string>") -> List[PolicyViolation]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        return [PolicyViolation("syntax", path, err.lineno or 0, str(err))]
    checker = _Checker(path, source)
    checker.visit(tree)
    return checker.violations


def lint_file(path) -> List[PolicyViolation]:
    p = Path(path)
    return lint_source(p.read_text(encoding="utf-8"), str(p))


def lint_tree(root) -> List[PolicyViolation]:
    """Lint every ``*.py`` under ``root`` (deterministic order)."""
    out: List[PolicyViolation] = []
    for p in sorted(Path(root).rglob("*.py")):
        out.extend(lint_file(p))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.analysis.policy DIR [DIR...]", file=sys.stderr)
        return 2
    violations: List[PolicyViolation] = []
    for root in argv:
        violations.extend(
            lint_file(root) if Path(root).is_file() else lint_tree(root)
        )
    for v in violations:
        print(v)
    print(f"policy: {len(violations)} violation(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
