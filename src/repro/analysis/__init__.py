"""Static plan analysis (paper §III-C: edges of the graph are *typed*).

``typecheck`` abstractly interprets a :class:`~repro.core.graph.Plan` over the
stream-type lattice using the signatures every codec/selector declares
(:class:`~repro.core.codec.CodecSig`) and emits structured diagnostics —
before a single byte is compressed.  ``policy`` is the AST-based repo policy
linter that turns the ROADMAP's standing policies into checked invariants.

Fail-closed integration points:

* ``PlanRegistry.register_*`` rejects ill-typed plans (``PlanTypeError``).
* ``TrainerService`` prunes statically-rejected genomes before trial
  compression (``pruned_static`` counter).
* ``repro lint PLAN.ozp`` prints diagnostics, exit 1 on error.
* ``engine.resolve`` gains an opt-in debug assert (``REPRO_RESOLVE_CHECK=1``).
"""
from .typecheck import (  # noqa: F401
    Diagnostic,
    PlanCheckReport,
    PlanTypeError,
    annotate_resolved_nodes,
    atoms_for_streams,
    check_plan,
    fmt_atoms,
)
from .policy import PolicyViolation, lint_file, lint_source, lint_tree  # noqa: F401

__all__ = [
    "Diagnostic",
    "PlanCheckReport",
    "PlanTypeError",
    "annotate_resolved_nodes",
    "atoms_for_streams",
    "check_plan",
    "fmt_atoms",
    "PolicyViolation",
    "lint_file",
    "lint_source",
    "lint_tree",
]
