"""Abstract interpretation of plans over the stream-type lattice.

An *atom* is one lattice point ``(stype, width)`` — ``stype`` is an
``int(SType)`` or ``None`` (unknown), ``width`` an ``int`` or ``None``
(unknown).  An edge's abstract value is a finite set of atoms: every concrete
stream type the edge could carry.  The checker walks a plan's nodes in their
(already topological) order, filters each input edge through the consuming
codec's declared :class:`~repro.core.codec.InPort`, and pushes the declared
transfer function over the cartesian product of feasible input atoms.

Diagnostics are *definite*: an error means no concrete input typing can make
the plan execute (the trainer relies on this — statically pruned genomes must
be exactly genomes that would have scored INVALID at runtime).  Anything
merely suspicious (a selector off its declared types, recompressing
entropy-packed bytes, an identity ``store`` feeding the wire) is a warning.

Diagnostic catalogue
--------------------
==========  ========  =====================================================
code        severity  meaning
==========  ========  =====================================================
E_STRUCT    error     structural validation failed (arity/edges/consumption)
E_UNKNOWN   error     unknown codec/selector name or wire codec id
E_TYPE      error     ill-typed edge: no accepted stype reaches the input
E_WIDTH     error     stypes fit but no accepted width reaches the input
E_PARAMS    error     params/cross-input conflict: transfer rejects every
                      feasible input combination
E_VERSION   error     codec ``min_version`` exceeds the plan format version
W_SELECTOR  warning   selector wired off its declared input types
                      (trial menu will degrade to ``store``)
W_PACKED    warning   selector-after-terminal: consumer re-codes the packed
                      output of an entropy/bitpacking stage
W_DEAD      warning   dead node: identity ``store`` feeding the wire
I_EXPAND    info      worst-case expansion bound for a terminal edge
==========  ========  =====================================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.codec import CodecSig, InPort, get_codec, get_codec_by_id
from repro.core.graph import KIND_CODEC, KIND_SELECTOR, Plan
from repro.core.message import SType
from repro.core.selector import get_selector

__all__ = [
    "Diagnostic",
    "PlanCheckReport",
    "PlanTypeError",
    "annotate_resolved_nodes",
    "atoms_for_streams",
    "check_plan",
    "fmt_atoms",
]

Atom = Tuple[Optional[int], Optional[int]]

_SERIAL = int(SType.SERIAL)
_STRUCT = int(SType.STRUCT)
_NUMERIC = int(SType.NUMERIC)
_STRING = int(SType.STRING)

#: Every concrete atom shape: the lattice top after normalization.
TOP_ATOMS = frozenset(
    [(_SERIAL, 1), (_STRING, 1), (_STRUCT, None)]
    + [(_NUMERIC, w) for w in (1, 2, 4, 8)]
)

_MAX_EDGE_ATOMS = 16  # collapse wider sets to TOP (keeps products bounded)
_MAX_PRODUCT = 4096  # cap on transfer enumeration; beyond -> sound TOP


def _normalize(atoms) -> frozenset:
    """Expand unknowns into the concrete shapes they may stand for."""
    out = set()
    for st, w in atoms:
        if st is None:
            out.update(TOP_ATOMS)
        elif st == _NUMERIC:
            if w is None:
                out.update((_NUMERIC, x) for x in (1, 2, 4, 8))
            else:
                out.add((_NUMERIC, w))
        elif st == _STRUCT:
            out.add((_STRUCT, w))
        else:  # SERIAL / STRING are always width 1
            out.add((st, 1))
    if len(out) > _MAX_EDGE_ATOMS:
        return TOP_ATOMS
    return frozenset(out)


def _fmt_atom(atom: Atom) -> str:
    st, w = atom
    if st is None:
        return "any"
    name = SType(st).name.lower()
    if st in (_SERIAL, _STRING):
        return name
    return f"{name}({'*' if w is None else w})"


def fmt_atoms(atoms) -> str:
    """Human form of an abstract edge value, e.g. ``numeric(4)`` or ``any``."""
    atoms = frozenset(atoms)
    if atoms >= TOP_ATOMS:
        return "any"
    if not atoms:
        return "none"
    # fold full numeric width fans back into numeric(*)
    widths = {w for st, w in atoms if st == _NUMERIC}
    parts = []
    if widths == {1, 2, 4, 8}:
        parts.append("numeric(*)")
        atoms = {a for a in atoms if a[0] != _NUMERIC}
    return "|".join(sorted(parts + [_fmt_atom(a) for a in atoms]))


def atoms_for_streams(streams) -> List[Atom]:
    """Concrete atoms of real input streams (resolve-time debug checks)."""
    return [(int(s.stype), int(s.width)) for s in streams]


@dataclass(frozen=True)
class Diagnostic:
    code: str
    severity: str  # "error" | "warning" | "info"
    message: str
    node: Optional[int] = None
    edge: Optional[int] = None

    def to_dict(self) -> dict:
        d = {"code": self.code, "severity": self.severity, "message": self.message}
        if self.node is not None:
            d["node"] = self.node
        if self.edge is not None:
            d["edge"] = self.edge
        return d

    def __str__(self) -> str:
        where = []
        if self.node is not None:
            where.append(f"node {self.node}")
        if self.edge is not None:
            where.append(f"edge {self.edge}")
        loc = f" {' '.join(where)}:" if where else ""
        return f"{self.severity}[{self.code}]{loc} {self.message}"


class PlanCheckReport:
    """Structured outcome of one plan check."""

    def __init__(self, diagnostics: List[Diagnostic], edge_types: Dict[int, frozenset]):
        self.diagnostics = list(diagnostics)
        self.edge_types = dict(edge_types)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def infos(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "info"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def render(self) -> str:
        if not self.diagnostics:
            return "clean: no diagnostics"
        return "\n".join(str(d) for d in self.diagnostics)


class PlanTypeError(ValueError):
    """Fail-closed rejection of an ill-typed plan.

    ``extra`` matches the service error-header convention (additive keys,
    no protocol magic bump): ``error_kind="ill_typed_plan"`` plus the
    structured ``diagnostics`` list.
    """

    def __init__(self, message: str, diagnostics: Sequence[Diagnostic] = ()):
        super().__init__(message)
        self.diagnostics = list(diagnostics)
        self.extra = {
            "error_kind": "ill_typed_plan",
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }


# ------------------------------------------------------------------- walker
class _Node:
    """One walkable node: a plan node or a wire-resolved node."""

    __slots__ = ("kind", "name", "inputs", "n_out", "params", "spec", "sig",
                 "min_version")

    def __init__(self, kind, name, inputs, n_out, params, spec, sig, min_version):
        self.kind = kind
        self.name = name
        self.inputs = tuple(inputs)
        self.n_out = int(n_out)
        self.params = dict(params)
        self.spec = spec
        self.sig = sig
        self.min_version = min_version


def _port_for(sig, j: int) -> Optional[InPort]:
    if sig is None or not sig.inputs:
        return None
    return sig.inputs[j] if j < len(sig.inputs) else sig.inputs[0]


def _filter_port(atoms: frozenset, port: Optional[InPort]):
    """Split an edge's atoms into (accepted, stype_ok) for one port."""
    if port is None:
        return atoms, True
    accepted = frozenset(a for a in atoms if port.accepts(a))
    stype_ok = any(a[0] is None or a[0] in port.stypes for a in atoms)
    return accepted, stype_ok


def _walk(
    n_inputs: int,
    nodes: List[_Node],
    *,
    format_version: Optional[int],
    input_atoms: Optional[Sequence[Atom]],
) -> Tuple[List[Diagnostic], Dict[int, frozenset], List[Tuple[str, str]]]:
    diags: List[Diagnostic] = []
    edge_types: Dict[int, frozenset] = {}
    node_types: List[Tuple[str, str]] = []  # (in, out) rendered per node

    if input_atoms is not None:
        for e, atom in enumerate(input_atoms[:n_inputs]):
            edge_types[e] = _normalize([atom])
    for e in range(n_inputs):
        edge_types.setdefault(e, TOP_ATOMS)

    expansion: Dict[int, float] = {e: 1.0 for e in range(n_inputs)}
    packed_edges = set()
    consumed = set()
    store_out_edge: Dict[int, int] = {}  # node index -> its store output edge

    eid = n_inputs
    for i, node in enumerate(nodes):
        out_ids = list(range(eid, eid + node.n_out))
        eid += node.n_out
        consumed.update(node.inputs)

        if node.spec is None and node.sig is None and node.name is not None:
            # unknown codec/selector: poison nothing, outputs unknown
            diags.append(Diagnostic(
                "E_UNKNOWN", "error",
                f"unknown {node.kind} {node.name!r}", node=i,
            ))

        if (
            format_version is not None
            and node.min_version is not None
            and node.min_version > format_version
        ):
            diags.append(Diagnostic(
                "E_VERSION", "error",
                f"codec {node.name!r} requires format version"
                f" >= {node.min_version}, plan declares {format_version}",
                node=i,
            ))

        sig = node.sig
        in_sets: List[frozenset] = []
        definite_reject = False
        for j, e in enumerate(node.inputs):
            atoms = edge_types.get(e, TOP_ATOMS)
            port = _port_for(sig, j)
            accepted, stype_ok = _filter_port(atoms, port)
            if not accepted:
                want = fmt_atoms(
                    _normalize((st, None) for st in port.stypes)
                    if port.widths is None
                    else [(st, w) for st in port.stypes for w in port.widths]
                )
                if not stype_ok:
                    diags.append(Diagnostic(
                        "E_TYPE", "error",
                        f"{node.kind} {node.name!r} input {j} expects {want},"
                        f" edge carries {fmt_atoms(atoms)}",
                        node=i, edge=e,
                    ))
                else:
                    diags.append(Diagnostic(
                        "E_WIDTH", "error",
                        f"{node.kind} {node.name!r} input {j} expects {want},"
                        f" edge carries incompatible width"
                        f" ({fmt_atoms(atoms)})",
                        node=i, edge=e,
                    ))
                if node.kind == KIND_SELECTOR:
                    # selectors degrade to store at runtime: downgrade
                    diags[-1] = Diagnostic(
                        "W_SELECTOR", "warning",
                        diags[-1].message + " — trial menu degrades to store",
                        node=i, edge=e,
                    )
                else:
                    definite_reject = True
                accepted = atoms  # keep walking with the unfiltered set
            in_sets.append(accepted)
            if e in packed_edges and (
                node.kind == KIND_SELECTOR
                or getattr(sig, "packed_outputs", ())
            ):
                diags.append(Diagnostic(
                    "W_PACKED", "warning",
                    f"{node.kind} {node.name!r} re-codes entropy-packed bytes"
                    f" from edge {e} (selector-after-terminal: wasted work)",
                    node=i, edge=e,
                ))

        # transfer over the product of feasible input atoms
        out_sets: List[set] = [set() for _ in out_ids]
        if node.kind == KIND_SELECTOR or sig is None or definite_reject:
            for s in out_sets:
                s.update(TOP_ATOMS)
        else:
            combos = 1
            for s in in_sets:
                combos *= max(len(s), 1)
            if combos > _MAX_PRODUCT or not node.inputs:
                feasible = True
                for s in out_sets:
                    s.update(TOP_ATOMS)
                if not node.inputs:
                    try:
                        outs = sig.transfer((), node.params, node.n_out)
                    except Exception:
                        outs = None
                    if outs is not None and len(outs) == node.n_out:
                        out_sets = [set(_normalize([a])) for a in outs]
            else:
                feasible = False
                import itertools

                for combo in itertools.product(*in_sets):
                    try:
                        outs = sig.transfer(tuple(combo), node.params, node.n_out)
                    except Exception:
                        feasible = True
                        for s in out_sets:
                            s.update(TOP_ATOMS)
                        continue
                    if outs is None:
                        continue
                    if len(outs) != node.n_out:
                        continue  # this combination cannot produce the wiring
                    feasible = True
                    for s, a in zip(out_sets, outs):
                        s.update(_normalize([a]))
                if not feasible:
                    diags.append(Diagnostic(
                        "E_PARAMS", "error",
                        f"codec {node.name!r}: no feasible typing —"
                        f" params {node.params or '{}'} / input combination"
                        f" rejected for inputs"
                        f" [{', '.join(fmt_atoms(s) for s in in_sets)}]"
                        f" with {node.n_out} outputs",
                        node=i,
                    ))
                    for s in out_sets:
                        s.update(TOP_ATOMS)

        in_bound = max((expansion.get(e, 1.0) for e in node.inputs), default=1.0)
        out_bound = in_bound * getattr(sig, "expansion", 1.0)
        for k, e in enumerate(out_ids):
            edge_types[e] = frozenset(out_sets[k]) or TOP_ATOMS
            expansion[e] = out_bound
            if k in getattr(sig, "packed_outputs", ()):
                packed_edges.add(e)

        if node.kind == KIND_CODEC and node.name == "store" and out_ids:
            store_out_edge[i] = out_ids[0]

        node_types.append((
            ", ".join(fmt_atoms(edge_types.get(e, TOP_ATOMS)) for e in node.inputs),
            ", ".join(fmt_atoms(edge_types[e]) for e in out_ids),
        ))

    for i, e in store_out_edge.items():
        if e not in consumed:
            diags.append(Diagnostic(
                "W_DEAD", "warning",
                "dead node: identity 'store' feeding the wire — storing its"
                " input directly is strictly smaller",
                node=i, edge=e,
            ))

    for e in range(eid):
        if e not in consumed:
            bound = expansion.get(e, 1.0)
            diags.append(Diagnostic(
                "I_EXPAND", "info",
                f"terminal edge {e} ({fmt_atoms(edge_types.get(e, TOP_ATOMS))}):"
                f" worst-case expansion <= {bound:.2f}x of graph input",
                edge=e,
            ))

    return diags, edge_types, node_types


def _plan_nodes(plan: Plan) -> List[_Node]:
    nodes = []
    for n in plan.nodes:
        spec = sig = None
        min_version = None
        try:
            if n.kind == KIND_CODEC:
                spec = get_codec(n.name)
                sig = spec.sig
                min_version = spec.min_version
            else:
                spec = get_selector(n.name)
                sig = spec.sig
        except KeyError:
            pass
        nodes.append(_Node(
            n.kind, n.name, n.inputs, n.n_out, n.param_dict(), spec, sig,
            min_version,
        ))
    return nodes


def check_plan(
    plan: Plan,
    *,
    format_version: Optional[int] = None,
    input_atoms: Optional[Sequence[Atom]] = None,
) -> PlanCheckReport:
    """Type-check a plan; never raises.

    ``format_version`` (when known, e.g. from a deserialized ``.ozp``) enables
    the ``min_version`` conflict check.  ``input_atoms`` pins the graph input
    types (one atom per input) — omitted inputs start at lattice top.
    """
    try:
        plan.validate()
    except KeyError as err:
        # validate() resolves codec names; an unknown one surfaces here
        return PlanCheckReport(
            [Diagnostic("E_UNKNOWN", "error", str(err.args[0] if err.args else err))], {}
        )
    except ValueError as err:
        return PlanCheckReport(
            [Diagnostic("E_STRUCT", "error", str(err))], {}
        )
    diags, edge_types, _ = _walk(
        plan.n_inputs, _plan_nodes(plan),
        format_version=format_version, input_atoms=input_atoms,
    )
    return PlanCheckReport(diags, edge_types)


def annotate_resolved_nodes(
    n_inputs: int, resolved_nodes, *, format_version: Optional[int] = None
) -> Tuple[List[Tuple[str, str]], PlanCheckReport]:
    """Infer per-node input/output stream types for wire-resolved nodes.

    ``resolved_nodes`` carry only ``codec_id``/``inputs``/``n_out`` (params
    live in opaque headers), so inference starts every graph input at lattice
    top and propagates what the signatures pin down.  Returns one rendered
    ``(input types, output types)`` pair per node plus the full report.
    """
    nodes = []
    for rn in resolved_nodes:
        spec = sig = None
        name = f"#{rn.codec_id}"
        min_version = None
        try:
            spec = get_codec_by_id(rn.codec_id)
            name = spec.name
            sig = spec.sig
            min_version = spec.min_version
        except KeyError:
            pass
        nodes.append(_Node(
            KIND_CODEC, name, rn.inputs, rn.n_out, {}, spec, sig, min_version,
        ))
    diags, edge_types, node_types = _walk(
        n_inputs, nodes, format_version=format_version, input_atoms=None
    )
    return node_types, PlanCheckReport(diags, edge_types)
