"""Synthetic dataset generators for the example drivers and benchmarks.

LM corpora are Zipf-distributed token streams with Markov bigram structure
(so entropy coding AND the LM both have signal); recsys batches follow
power-law item popularity; graphs are preferential-attachment-ish.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np


def zipf_tokens(n: int, vocab: int, seed: int = 0, alpha: float = 1.2) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks**-alpha
    probs /= probs.sum()
    # light bigram structure: each token biases the next toward a shifted rank
    base = rng.choice(vocab, size=n, p=probs).astype(np.int32)
    shift = rng.integers(0, 7, size=n).astype(np.int32)
    out = (base + np.roll(base, 1) % 7 + shift) % vocab
    return out.astype(np.int32)


def lm_batches(
    tokens: np.ndarray, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = tokens.shape[0] - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        idx = starts[:, None] + np.arange(seq)[None, :]
        yield {"tokens": tokens[idx], "labels": tokens[idx + 1]}


def recsys_ctr_batches(
    batch: int, n_sparse: int, vocab: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        ids = (rng.pareto(1.2, size=(batch, n_sparse)) * vocab * 0.01).astype(np.int64)
        ids = np.clip(ids, 0, vocab - 1).astype(np.int32)
        w = rng.normal(size=n_sparse)
        logit = (ids * w[None, :]).sum(1) / vocab * 20 - 1.0
        labels = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        yield {"sparse_ids": ids, "labels": labels}


def random_graph(
    n_nodes: int, n_edges: int, d_feat: int, d_out: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    # power-law-ish degree: preferential dst choice
    dst = (rng.pareto(1.0, n_edges) * n_nodes * 0.05).astype(np.int64) % n_nodes
    src = rng.integers(0, n_nodes, n_edges)
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    nodes = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    w = rng.normal(size=(d_feat, d_out)).astype(np.float32) / np.sqrt(d_feat)
    targets = nodes @ w
    return {
        "nodes": nodes,
        "edges": edges,
        "edge_feats": rng.normal(size=(n_edges, 4)).astype(np.float32),
        "targets": targets,
    }
