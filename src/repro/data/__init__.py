"""Data pipeline: synthetic generators, OpenZL-compressed shard store,
straggler-tolerant prefetcher, GNN neighbour sampler."""
from .pipeline import Prefetcher, Straggler  # noqa: F401
from .sampler import CSRGraph, sample_subgraph  # noqa: F401
from .shard_store import CompressedShardStore  # noqa: F401
