"""OpenZL-compressed training-data shards (paper §VIII "Feature storage",
"Training data" integrations).

Shards are dicts of arrays; every array is compressed with the same profiles
the checkpoint path uses.  The store measures ratio (the paper's 10-30%
wins) and feeds the straggler-tolerant Prefetcher.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.distributed.checkpoint import compress_leaf, decompress_leaf


class CompressedShardStore:
    def __init__(self, directory):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def write_shard(self, idx: int, arrays: Dict[str, np.ndarray]) -> dict:
        tmp = self.directory / f"shard_{idx:06d}.tmp"
        final = self.directory / f"shard_{idx:06d}"
        tmp.mkdir(parents=True, exist_ok=True)
        entries = []
        raw = comp = 0
        for name, arr in arrays.items():
            frame = compress_leaf(np.asarray(arr))
            (tmp / f"{name}.ozl").write_bytes(frame)
            raw += arr.nbytes
            comp += len(frame)
            entries.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "raw_bytes": int(arr.nbytes),
                    "compressed_bytes": len(frame),
                    "crc32": zlib.crc32(frame) & 0xFFFFFFFF,
                }
            )
        meta = {"idx": idx, "entries": entries, "raw_bytes": raw, "compressed_bytes": comp}
        (tmp / "meta.json").write_text(json.dumps(meta))
        import os

        os.replace(tmp, final)
        return meta

    def read_shard(self, idx: int) -> Dict[str, np.ndarray]:
        d = self.directory / f"shard_{idx:06d}"
        meta = json.loads((d / "meta.json").read_text())
        out = {}
        for e in meta["entries"]:
            frame = (d / f"{e['name']}.ozl").read_bytes()
            if (zlib.crc32(frame) & 0xFFFFFFFF) != e["crc32"]:
                raise IOError(f"shard {idx} entry {e['name']} corrupt")
            out[e["name"]] = decompress_leaf(frame, tuple(e["shape"]), e["dtype"])
        return out

    def shard_ids(self) -> List[int]:
        return sorted(
            int(d.name[6:])
            for d in self.directory.iterdir()
            if d.name.startswith("shard_") and not d.name.endswith(".tmp")
        )

    def stats(self) -> dict:
        raw = comp = 0
        for i in self.shard_ids():
            meta = json.loads((self.directory / f"shard_{i:06d}" / "meta.json").read_text())
            raw += meta["raw_bytes"]
            comp += meta["compressed_bytes"]
        return {"raw_bytes": raw, "compressed_bytes": comp, "ratio": raw / max(comp, 1)}
