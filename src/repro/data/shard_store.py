"""OpenZL-compressed training-data shards (paper §VIII "Feature storage",
"Training data" integrations).

Shards are dicts of arrays; every array is compressed with the same profiles
the checkpoint path uses.  The store measures ratio (the paper's 10-30%
wins) and feeds the straggler-tolerant Prefetcher.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.distributed.checkpoint import compress_leaf, decompress_leaf
from repro.reliability.faults import crash_point


class CompressedShardStore:
    # a tmp dir untouched for this long is a crashed writer's leftover; a
    # *live* concurrent writer's staging dir is always younger (it is being
    # written right now), so the sweep never deletes in-flight work
    STALE_TMP_SECONDS = 15 * 60

    def __init__(self, directory):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def _stale_tmps(self, idx: int) -> List[Path]:
        import time

        cutoff = time.time() - self.STALE_TMP_SECONDS
        final_exists = (self.directory / f"shard_{idx:06d}").exists()
        candidates = [
            d for d in self.directory.glob(f"shard_{idx:06d}.*.tmp") if d.is_dir()
        ]
        legacy = self.directory / f"shard_{idx:06d}.tmp"
        if legacy.is_dir():  # pre-atomic-rewrite fixed tmp name (old crashes)
            candidates.append(legacy)
        out = []
        for d in candidates:
            if ".old." in d.name and not final_exists:
                continue  # the aside may be the only surviving copy: keep it
            try:
                if d.stat().st_mtime <= cutoff:
                    out.append(d)
            except OSError:
                pass  # vanished under us: someone else cleaned it
        return out

    def _recover_aside(self, idx: int) -> None:
        """Self-heal after a crash between rewrite's two ``os.replace`` calls:
        if the shard dir is missing but a renamed-aside copy exists, promote
        the newest aside back to the canonical path."""
        final = self.directory / f"shard_{idx:06d}"
        if final.exists():
            return
        stamped = []
        for d in self.directory.glob(f"shard_{idx:06d}.old.*.tmp"):
            try:
                if d.is_dir():
                    stamped.append((d.stat().st_mtime, d))
            except OSError:
                pass  # vanished between glob and stat: concurrent cleanup
        if not stamped:
            return
        newest = max(stamped, key=lambda t: t[0])[1]
        try:
            os.replace(newest, final)
        except OSError:
            pass  # another process recovered first

    def write_shard(self, idx: int, arrays: Dict[str, np.ndarray]) -> dict:
        """Write (or atomically rewrite) one shard directory.

        Every call stages into a *fresh* unique tmp dir — reusing a stale
        ``.tmp`` left by a crashed writer would leak its orphan ``.ozl``
        entries into the new shard (present on disk, absent from
        ``meta.json``).  Rewriting an existing shard renames it aside first
        (``os.replace`` cannot replace a non-empty directory), swaps the new
        dir in, then deletes the old one; a concurrent reader may observe the
        brief gap between the two renames as a missing dir (one writer per
        shard is the contract — readers retry or tolerate), and a reader
        whose ``_recover_aside`` promotes the aside back *into* that gap is
        handled by re-renaming it aside and retrying the swap (the writer's
        new data always wins); a *crash* in that gap is recovered: the aside copy is never swept while the
        canonical dir is missing, and the next write or read promotes it
        back.  Stale tmps from crashed writers (age-gated, so a live
        concurrent writer's staging is untouched) are swept on the way out.
        """
        self._recover_aside(idx)
        final = self.directory / f"shard_{idx:06d}"
        tmp = Path(
            tempfile.mkdtemp(
                dir=self.directory, prefix=f"shard_{idx:06d}.", suffix=".tmp"
            )
        )
        crash_point("shard.staged")
        try:
            entries = []
            raw = comp = 0
            for name, arr in arrays.items():
                arr = np.asarray(arr)
                frame = compress_leaf(arr)
                (tmp / f"{name}.ozl").write_bytes(frame)
                crash_point("shard.entry")
                raw += arr.nbytes
                comp += len(frame)
                entries.append(
                    {
                        "name": name,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "raw_bytes": int(arr.nbytes),
                        "compressed_bytes": len(frame),
                        "crc32": zlib.crc32(frame) & 0xFFFFFFFF,
                    }
                )
            meta = {
                "idx": idx,
                "entries": entries,
                "raw_bytes": raw,
                "compressed_bytes": comp,
            }
            (tmp / "meta.json").write_text(json.dumps(meta))
            crash_point("shard.meta")
            if final.exists():
                # rename-aside-then-replace: readers only ever see a complete
                # shard dir (old or new), never a partially deleted one
                aside = Path(
                    tempfile.mkdtemp(
                        dir=self.directory,
                        prefix=f"shard_{idx:06d}.old.",
                        suffix=".tmp",
                    )
                )
                os.rmdir(aside)
                crash_point("shard.aside.before")
                os.replace(final, aside)
                crash_point("shard.aside.after")
                for _ in range(16):
                    try:
                        os.replace(tmp, final)
                        break
                    except OSError:
                        # a concurrent reader's _recover_aside can promote
                        # the aside back into the rename gap, refilling
                        # final: move it aside again and retry — the
                        # writer's new data must win
                        try:
                            os.replace(final, aside)
                        except OSError:
                            pass
                else:
                    raise OSError(
                        f"shard {idx}: canonical dir kept reappearing while"
                        " swapping in the rewrite"
                    )
                crash_point("shard.swap.after")
                shutil.rmtree(aside, ignore_errors=True)
                crash_point("shard.cleanup")
            else:
                crash_point("shard.publish.before")
                os.replace(tmp, final)
                crash_point("shard.publish.after")
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        for stale in self._stale_tmps(idx):
            shutil.rmtree(stale, ignore_errors=True)
        crash_point("shard.done")
        return meta

    def read_shard(self, idx: int) -> Dict[str, np.ndarray]:
        d = self.directory / f"shard_{idx:06d}"
        if not d.exists():
            self._recover_aside(idx)
        meta = json.loads((d / "meta.json").read_text())
        out = {}
        for e in meta["entries"]:
            frame = (d / f"{e['name']}.ozl").read_bytes()
            if (zlib.crc32(frame) & 0xFFFFFFFF) != e["crc32"]:
                raise IOError(f"shard {idx} entry {e['name']} corrupt")
            out[e["name"]] = decompress_leaf(frame, tuple(e["shape"]), e["dtype"])
        return out

    def shard_ids(self) -> List[int]:
        return sorted(
            int(d.name[6:])
            for d in self.directory.iterdir()
            if d.name.startswith("shard_") and not d.name.endswith(".tmp")
        )

    def stats(self) -> dict:
        raw = comp = 0
        for i in self.shard_ids():
            meta = json.loads((self.directory / f"shard_{i:06d}" / "meta.json").read_text())
            raw += meta["raw_bytes"]
            comp += meta["compressed_bytes"]
        return {"raw_bytes": raw, "compressed_bytes": comp, "ratio": raw / max(comp, 1)}
