"""Real neighbour sampler for the GNN ``minibatch_lg`` shape (fanout 15, 10).

CSR over the full edge list; per batch: uniform fanout sampling per hop,
padded to static shapes (XLA), with edge/node masks.  GraphSAGE-style.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,) neighbour ids
    n_nodes: int

    @staticmethod
    def from_edges(edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edges[:, 0], edges[:, 1]
        order = np.argsort(src, kind="stable")
        sorted_src = src[order]
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        counts = np.bincount(sorted_src, minlength=n_nodes)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr, dst[order].astype(np.int32), n_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int, rng) -> Tuple[np.ndarray, np.ndarray]:
        """Uniform fanout sample -> (edges (len(nodes)*fanout, 2), mask)."""
        deg = self.indptr[nodes + 1] - self.indptr[nodes]
        # random offsets within each node's neighbour list
        offs = (rng.random((nodes.shape[0], fanout)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
        idx = self.indptr[nodes][:, None] + offs
        nbrs = self.indices[np.minimum(idx, len(self.indices) - 1)]
        valid = (deg[:, None] > 0)
        src = nbrs.reshape(-1)
        dst = np.repeat(nodes, fanout)
        mask = np.broadcast_to(valid, (nodes.shape[0], fanout)).reshape(-1)
        edges = np.stack([src, dst], axis=1).astype(np.int32)
        return edges, mask.astype(np.float32)


def sample_subgraph(
    graph: CSRGraph,
    node_feats: np.ndarray,
    targets: np.ndarray,
    seeds: np.ndarray,
    fanouts: List[int],
    *,
    pad_nodes: int,
    pad_edges: int,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Multi-hop sampled subgraph with LOCAL node ids, padded to static
    shapes.  Seeds occupy local ids [0, len(seeds)); node_mask marks them
    (the loss is computed on seeds only)."""
    rng = np.random.default_rng(seed)
    frontier = seeds.astype(np.int64)
    all_edges = []
    all_masks = []
    layer_nodes = [seeds.astype(np.int64)]
    for f in fanouts:
        edges, mask = graph.sample_neighbors(frontier, f, rng)
        all_edges.append(edges)
        all_masks.append(mask)
        frontier = np.unique(edges[mask > 0, 0])
        layer_nodes.append(frontier)
    # global -> local remap (seeds first), fully vectorized
    global_ids = np.unique(np.concatenate(layer_nodes))
    rest = np.setdiff1d(global_ids, seeds, assume_unique=False)
    ordered = np.concatenate([seeds, rest])
    n_real = len(ordered)
    sort_idx = np.argsort(ordered, kind="stable")
    sorted_vals = ordered[sort_idx]
    edges_g = np.concatenate(all_edges) if all_edges else np.zeros((0, 2), np.int64)
    emask = np.concatenate(all_masks) if all_masks else np.zeros(0, np.float32)
    # masked (invalid) edges may reference unsampled nodes: zero them first
    edges_g = np.where(emask[:, None] > 0, edges_g, ordered[0] if n_real else 0)

    def to_local(g):
        pos = np.searchsorted(sorted_vals, g)
        return sort_idx[np.minimum(pos, n_real - 1)]

    edges_l = np.stack([to_local(edges_g[:, 0]), to_local(edges_g[:, 1])], axis=1)
    # pad to static shapes
    nodes_out = np.zeros((pad_nodes, node_feats.shape[1]), np.float32)
    nodes_out[:n_real] = node_feats[ordered]
    tgt_out = np.zeros((pad_nodes, targets.shape[1]), np.float32)
    tgt_out[:n_real] = targets[ordered]
    nmask = np.zeros(pad_nodes, np.float32)
    nmask[: len(seeds)] = 1.0  # loss on seeds
    e_out = np.zeros((pad_edges, 2), np.int32)
    m_out = np.zeros(pad_edges, np.float32)
    ne = min(edges_l.shape[0], pad_edges)
    e_out[:ne] = edges_l[:ne]
    m_out[:ne] = emask[:ne]
    return {
        "nodes": nodes_out,
        "edges": e_out,
        "edge_feats": np.zeros((pad_edges, 4), np.float32),
        "edge_mask": m_out,
        "node_mask": nmask,
        "targets": tgt_out,
        "n_real_nodes": n_real,
    }
