"""Straggler-tolerant, resumable input pipeline.

At 1000+ nodes the input tail matters: a slow/hung storage read must not
stall the step loop.  The Prefetcher keeps a bounded queue filled by a
background thread; ``next(timeout)`` falls back to SKIPPING the straggler
shard (it is re-queued at the end) after the deadline — the paper's Scribe
integration notes the same drop-under-pressure philosophy for log traffic.

Resumability: the cursor (next shard index, epoch) is part of the state dict
checkpointed with the model, so restarts are deterministic.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np


class Straggler(Exception):
    pass


class Prefetcher:
    def __init__(
        self,
        load_fn: Callable[[int], Any],
        shard_ids: List[int],
        *,
        depth: int = 2,
        start_cursor: int = 0,
        epoch: int = 0,
        inject_delay: Optional[Callable[[int], float]] = None,  # test hook
    ):
        self.load_fn = load_fn
        self.shard_ids = list(shard_ids)
        self.depth = depth
        self.cursor = start_cursor
        self.epoch = epoch
        self.skipped: List[int] = []
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._inject_delay = inject_delay
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- worker
    def _worker(self) -> None:
        while not self._stop.is_set():
            idx = self.cursor % len(self.shard_ids)
            shard = self.shard_ids[idx]
            try:
                if self._inject_delay is not None:
                    time.sleep(self._inject_delay(shard))
                data = self.load_fn(shard)
            except Exception as e:  # damaged shard: skip it permanently
                self.skipped.append(shard)
                self.cursor += 1
                continue
            item = {"shard": shard, "cursor": self.cursor, "data": data}
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            self.cursor += 1
            if self.cursor % len(self.shard_ids) == 0:
                self.epoch += 1

    # -------------------------------------------------------------- public
    def next(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        """Blocking get; on timeout raises Straggler (caller may retry with a
        longer deadline or synthesize/skip a batch)."""
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise Straggler(f"input pipeline stalled >{timeout}s") from None

    def state(self) -> dict:
        return {"cursor": self.cursor, "epoch": self.epoch, "skipped": list(self.skipped)}

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
