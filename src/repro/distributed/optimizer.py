"""Optimizers from scratch (no optax in this container): AdamW and Adafactor.

Optimizer state mirrors the parameter tree, so it inherits the parameter
sharding (FSDP'd params => fully sharded optimizer state, ZeRO-style).
Adafactor's factored second moment (row/col statistics) is what makes the
1T-param kimi config trainable at 512 chips (DESIGN.md §4): m in bf16,
v factored — ~2.25 bytes/param of optimizer state instead of 8.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]  # params -> state
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params) -> (params, state)


# ------------------------------------------------------------------- AdamW
def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1**c
        bc2 = 1.0 - b2**c

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            newp = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), m.astype(p.dtype if p.dtype == jnp.bfloat16 else jnp.float32), v.astype(jnp.float32)

        flat_p, tree = jax.tree.flatten(params)
        flat_g = tree.flatten_up_to(grads)
        flat_m = tree.flatten_up_to(state["m"])
        flat_v = tree.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tree.unflatten([o[0] for o in out])
        new_m = tree.unflatten([o[1] for o in out])
        new_v = tree.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer("adamw", init, update)


# --------------------------------------------------------------- Adafactor
def adafactor(
    lr: float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    momentum_dtype=jnp.bfloat16,
) -> Optimizer:
    """Shazeer & Stern (2018): factored second moments for >=2-D params."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf_state(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    "m": jnp.zeros(p.shape, momentum_dtype),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32), "m": jnp.zeros(p.shape, momentum_dtype)}

        return {
            "per_param": jax.tree.map(leaf_state, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c**-decay  # increasing decay schedule

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps
                    )
                )
                step = g32 / jnp.maximum(denom, eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                step = g32 / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            m = 0.9 * s["m"].astype(jnp.float32) + 0.1 * step
            new_s["m"] = m.astype(momentum_dtype)
            newp = (p.astype(jnp.float32) - lr * m).astype(p.dtype)
            return newp, new_s

        flat_p, tree = jax.tree.flatten(params)
        flat_g = tree.flatten_up_to(grads)
        flat_s = tree.flatten_up_to(state["per_param"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tree.unflatten([o[0] for o in out])
        new_s = tree.unflatten([o[1] for o in out])
        return new_p, {"per_param": new_s, "count": count}

    return Optimizer("adafactor", init, update)


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new_p = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)), params, grads)
        return new_p, {"count": state["count"] + 1}

    return Optimizer("sgd", init, update)


def for_arch(family: str, arch_id: str) -> Optimizer:
    """Default optimizer per arch: Adafactor for the 1T MoE, AdamW otherwise."""
    if arch_id.startswith("kimi"):
        return adafactor()
    return adamw()
