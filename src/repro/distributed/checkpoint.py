"""Distributed checkpointing with OpenZL compression (paper §VIII "PyTorch
model checkpoints" / "Embedding storage").

Every pytree leaf is compressed with the float-split graphs (f32/bf16/f64) or
the numeric auto-profile — the exact technique the paper deploys at Meta
(~17% on fp32 checkpoints, ~30% on bf16 embeddings).  Frames are
self-describing, so restore needs no compressor config (universal decoder).

Fault-tolerance contract:
  * atomic: write to step_<n>.tmp, fsync, rename — a crash never leaves a
    half checkpoint visible;
  * restartable: CheckpointManager.restore_latest() picks the newest valid
    manifest (corrupt/partial steps are skipped with a warning);
  * elastic: leaves are stored as FULL (unsharded) arrays + the manifest
    records shapes/dtypes, so restore can re-shard onto ANY mesh
    (restore_for_shardings);
  * async: save() can overlap the next train step (background thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.codecs import (
    bfloat16_profile,
    float32_profile,
    float64_profile,
    numeric_profile,
)
from repro.core import CompressorSession, DecompressorSession, numeric
from repro.core.graph import Plan, pipeline as plan_pipeline
from repro.reliability.faults import crash_point

MANIFEST = "manifest.json"

# ------------------------------------------------- long-lived codec sessions
# One CompressorSession per distinct leaf plan and one shared
# DecompressorSession per worker process: thousands of checkpoint leaves reuse
# the same resolve cache, coder-table scratch, and thread pool instead of
# paying session construction per leaf.  Sessions are thread-safe, so the
# async-save background thread shares them with the restore path.
_SESSION_LOCK = threading.Lock()
_ENC_SESSIONS: Dict[Plan, CompressorSession] = {}
_DEC_SESSION: list = []  # 0 or 1 DecompressorSession


def _enc_session(plan: Plan) -> CompressorSession:
    with _SESSION_LOCK:
        sess = _ENC_SESSIONS.get(plan)
        if sess is None:
            sess = _ENC_SESSIONS[plan] = CompressorSession(plan)
        return sess


def _dec_session() -> DecompressorSession:
    with _SESSION_LOCK:
        if not _DEC_SESSION:
            _DEC_SESSION.append(DecompressorSession())
        return _DEC_SESSION[0]


def codec_session_stats() -> dict:
    """Aggregate encode/decode session counters (for serving diagnostics)."""
    with _SESSION_LOCK:
        enc = [s.stats for s in _ENC_SESSIONS.values()]
        dec = _DEC_SESSION[0].stats if _DEC_SESSION else {}
    agg = {"enc_plans": len(enc)}
    for k in ("calls", "bytes_in", "bytes_out"):
        agg[f"enc_{k}"] = sum(s[k] for s in enc)
        agg[f"dec_{k}"] = int(dec.get(k, 0))
    return agg


def close_codec_sessions() -> None:
    """Release session thread pools (tests / worker shutdown)."""
    with _SESSION_LOCK:
        sessions = list(_ENC_SESSIONS.values()) + list(_DEC_SESSION)
        _ENC_SESSIONS.clear()
        _DEC_SESSION.clear()
    for s in sessions:
        s.close()


def _leaf_key(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


# Trained-plan overrides (the `repro train` -> deploy loop, paper §VI-C):
# a plan registered for a dtype name ("float32", ...) — or "*" for all
# dtypes — replaces the shipped profile for checkpoint leaves.  Restore is
# unaffected: frames are self-describing, the universal decoder reads both.
_PLAN_OVERRIDES: Dict[str, Plan] = {}


def set_checkpoint_plan(dtype_name: str, plan: Optional[Plan]) -> None:
    """Route checkpoint leaves of ``dtype_name`` (or ``"*"``) through
    ``plan`` — typically a deserialized trained ``.ozp``.  ``None`` clears
    the override."""
    with _SESSION_LOCK:
        if plan is None:
            _PLAN_OVERRIDES.pop(dtype_name, None)
        else:
            _PLAN_OVERRIDES[dtype_name] = plan.validate()


def _plan_for_dtype(dtype) -> Tuple[Plan, bool]:
    """-> (plan, is_trained_override)."""
    name = str(dtype)
    with _SESSION_LOCK:
        override = _PLAN_OVERRIDES.get(name) or _PLAN_OVERRIDES.get("*")
    if override is not None:
        return override, True
    if name == "float32":
        return float32_profile(), False
    if name == "bfloat16":
        return bfloat16_profile(), False
    if name == "float64":
        return float64_profile(), False
    if name in ("int8", "uint8", "bool"):
        return plan_pipeline("zlib_backend"), False
    return numeric_profile(), False


def _to_numeric_stream(arr: np.ndarray):
    flat = np.ascontiguousarray(arr).reshape(-1)
    if flat.dtype == np.bool_:
        flat = flat.view(np.uint8)
    if str(flat.dtype) == "bfloat16":
        flat = flat.view(np.uint16)
    if flat.dtype.kind == "f":
        flat = flat.view({2: np.uint16, 4: np.uint32, 8: np.uint64}[flat.dtype.itemsize])
    if flat.dtype.kind in "iu":
        width = flat.dtype.itemsize
        return numeric(flat.view({1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[width]))
    raise TypeError(f"unsupported checkpoint dtype {arr.dtype}")


def compress_leaf(arr: np.ndarray) -> bytes:
    plan, trained = _plan_for_dtype(arr.dtype)
    stream = _to_numeric_stream(arr)
    if not trained:
        return _enc_session(plan).compress(stream)
    try:
        return _enc_session(plan).compress(stream)
    except Exception:
        # plans trained by `repro train` on raw sample files start from a
        # SERIAL input (their frontend re-types the bytes); numeric leaves
        # feed them as raw bytes instead — the frame stays self-describing
        # either way, so restore is unchanged
        return _enc_session(plan).compress(stream.as_serial())


def decompress_leaf(frame: bytes, shape, dtype) -> np.ndarray:
    (stream,) = _dec_session().decompress(frame)
    raw = stream.content_bytes()
    if str(dtype) == "bfloat16":
        import ml_dtypes

        return np.frombuffer(raw, dtype=ml_dtypes.bfloat16).reshape(shape).copy()
    out = np.frombuffer(raw, dtype=np.dtype(dtype) if str(dtype) != "bool" else np.uint8)
    if str(dtype) == "bool":
        out = out.astype(np.bool_)
    return out.reshape(shape).copy()


# ---------------------------------------------------------------- save/load
def save_checkpoint(
    directory: Path, step: int, tree: Any, metadata: Optional[dict] = None
) -> dict:
    directory = Path(directory)
    tmp = directory / f"step_{step:010d}.tmp"
    final = directory / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = []
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    t0 = time.time()
    raw_total = comp_total = 0
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        frame = compress_leaf(arr)
        fname = f"leaf_{i:05d}.ozl"
        (tmp / fname).write_bytes(frame)
        crash_point("ckpt.leaf")
        raw_total += arr.nbytes
        comp_total += len(frame)
        leaves.append(
            {
                "key": _leaf_key(path),
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "raw_bytes": int(arr.nbytes),
                "compressed_bytes": len(frame),
                "crc32": zlib.crc32(frame) & 0xFFFFFFFF,
            }
        )
    manifest = {
        "step": step,
        "created": time.time(),
        "save_seconds": round(time.time() - t0, 3),
        "raw_bytes": raw_total,
        "compressed_bytes": comp_total,
        "ratio": round(raw_total / max(comp_total, 1), 4),
        "metadata": metadata or {},
        "leaves": leaves,
    }
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    crash_point("ckpt.manifest")
    os.replace(tmp, final)  # atomic publish
    crash_point("ckpt.publish.after")
    return manifest


def _valid_manifest(step_dir: Path) -> Optional[dict]:
    mpath = step_dir / MANIFEST
    if not mpath.exists():
        return None
    try:
        manifest = json.loads(mpath.read_text())
        for leaf in manifest["leaves"]:
            f = step_dir / leaf["file"]
            if not f.exists():
                return None
        return manifest
    except Exception:
        return None


def restore_checkpoint(
    directory: Path, step: Optional[int] = None, *, verify_crc: bool = True
) -> Tuple[Dict[str, np.ndarray], dict]:
    """Returns ({leaf_key: array}, manifest).  Use restore_tree to rebuild
    a concrete pytree structure."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    step_dir = directory / f"step_{step:010d}"
    manifest = _valid_manifest(step_dir)
    if manifest is None:
        raise FileNotFoundError(f"checkpoint step {step} invalid/missing")
    out: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        frame = (step_dir / leaf["file"]).read_bytes()
        if verify_crc and (zlib.crc32(frame) & 0xFFFFFFFF) != leaf["crc32"]:
            raise IOError(f"checkpoint leaf {leaf['key']} corrupt (crc mismatch)")
        out[leaf["key"]] = decompress_leaf(frame, tuple(leaf["shape"]), leaf["dtype"])
    return out, manifest


def restore_tree(directory: Path, like: Any, step: Optional[int] = None, *, shardings=None):
    """Rebuild a pytree shaped `like` (tree of arrays or SDS), optionally
    device_put with per-leaf shardings (elastic restore onto any mesh)."""
    leaves_by_key, manifest = restore_checkpoint(directory, step)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (path, leaf) in enumerate(flat):
        key = _leaf_key(path)
        if key not in leaves_by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = leaves_by_key[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if str(arr.dtype) != str(want_dtype):
            arr = arr.astype(want_dtype)
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [x for x in out]), manifest


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and not d.name.endswith(".tmp"):
            try:
                s = int(d.name[5:])
            except ValueError:
                continue
            if _valid_manifest(d):
                steps.append(s)
    return max(steps) if steps else None


class CheckpointManager:
    """keep-K, interval-based, optionally async checkpointing with resume."""

    def __init__(
        self,
        directory,
        *,
        save_interval: int = 100,
        keep: int = 3,
        async_save: bool = False,
    ):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.save_interval = save_interval
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.history: list = []

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            m = save_checkpoint(self.directory, step, host_tree, metadata)
            self.history.append(m)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self) -> None:
        steps = sorted(
            int(d.name[5:])
            for d in self.directory.iterdir()
            if d.name.startswith("step_") and not d.name.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:010d}", ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_or_none(self, like: Any, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, manifest = restore_tree(self.directory, like, step, shardings=shardings)
        return step, tree, manifest
