"""Compressed gradient collectives (beyond-paper distributed optimization).

The cross-pod data-parallel all-reduce is the collective-bound term of
multi-pod training (DCN links are ~10x slower than ICI).  Three policies:

  none    — fp32 psum (baseline)
  bf16    — cast to bf16 before the pod psum: wire bytes ÷2, error ~1e-3 rel
  int8_ef — per-block (256) absmax int8 quantization with ERROR FEEDBACK:
            wire bytes ÷4 (+1/64 for scales); the quantization residual is
            carried to the next step, so the *accumulated* update is unbiased
            (1-bit Adam / EF-SGD lineage).

These run inside shard_map over the 'pod' axis; within a pod the usual
XLA-SPMD sharding applies untouched.  EXPERIMENTS.md §Perf measures the
collective-byte reduction on the lowered HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8_blockwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 of same size padded to BLOCK, f32 scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)]) if pad else flat
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(
        jnp.int8
    )
    return q, scale[:, 0]


def dequantize_int8_blockwise(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _all_gather_sum(g: jax.Array, axis_name: str, wire_dtype=None) -> jax.Array:
    """psum expressed as all_gather + local sum.  Semantically identical;
    chosen so all three policies differ ONLY in the wire payload dtype
    (also dodges an XLA crash for psum under partial-manual shard_map)."""
    if wire_dtype is None:
        gathered = jax.lax.all_gather(g, axis_name)
        return gathered.astype(jnp.float32).sum(axis=0).astype(g.dtype)
    # route the narrow payload through an INTEGER bitcast: XLA's simplifier
    # folds bf16->f32 convert pairs (re-widening the wire), but never folds
    # through integer bitcasts
    payload = jax.lax.bitcast_convert_type(g.astype(wire_dtype), jnp.int16)
    gathered = jax.lax.all_gather(payload, axis_name)
    back = jax.lax.bitcast_convert_type(gathered, wire_dtype)
    return back.astype(jnp.float32).sum(axis=0).astype(g.dtype)


def psum_none(tree: Any, axis_name: str) -> Any:
    return jax.tree.map(lambda g: _all_gather_sum(g, axis_name), tree)


def psum_bf16(tree: Any, axis_name: str) -> Any:
    return jax.tree.map(
        lambda g: _all_gather_sum(g, axis_name, jnp.bfloat16), tree
    )


def psum_int8_ef(tree: Any, ef_state: Any, axis_name: str) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce.  Returns (reduced_tree, new_ef_state).

    Each device quantizes (grad + residual); the int8 payload crosses the
    wire (psum over the pod axis accumulates int32-safe by upcasting AFTER
    the all-gather of int8 shards); the residual stays local.
    """

    def red(g, ef):
        g32 = g.astype(jnp.float32) + ef
        q, scale = quantize_int8_blockwise(g32)
        local_dq = dequantize_int8_blockwise(q, scale, g32.shape)
        residual = g32 - local_dq  # error feedback
        # wire: int8 payload + f32/BLOCK scales, gathered across pods
        q_all = jax.lax.all_gather(q, axis_name)  # (P, nblk, BLOCK) int8
        s_all = jax.lax.all_gather(scale, axis_name)  # (P, nblk) f32
        summed = jnp.einsum(
            "pbk,pb->bk", q_all.astype(jnp.float32), s_all
        ).reshape(-1)
        n = 1
        for d in g32.shape:
            n *= d
        return summed[:n].reshape(g32.shape).astype(g.dtype), residual

    flat_g, tree_def = jax.tree.flatten(tree)
    flat_e = tree_def.flatten_up_to(ef_state)
    out = [red(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tree_def.unflatten([o[0] for o in out]),
        tree_def.unflatten([o[1] for o in out]),
    )


def init_ef_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(tree, axis_name: str, method: str, ef_state=None):
    if method == "none":
        return psum_none(tree, axis_name), ef_state
    if method == "bf16":
        return psum_bf16(tree, axis_name), ef_state
    if method == "int8_ef":
        if ef_state is None:
            raise ValueError("int8_ef needs error-feedback state")
        return psum_int8_ef(tree, ef_state, axis_name)
    raise ValueError(method)
