"""Pod-level data parallelism with compressed gradient collectives (§Perf/H3).

Wraps an LM loss in a PARTIAL-MANUAL shard_map: the 'pod' axis is manual
(so we control the cross-pod gradient reduction and can compress its
payload), while 'data'/'model' stay automatic (XLA SPMD shards the per-pod
computation exactly as in the baseline step).

Cross-pod wire bytes per step:
    none    : fp32 psum            -> 4 B/param   (baseline)
    bf16    : bf16 psum            -> 2 B/param
    int8_ef : int8 all-gather + f32/256 scales -> ~1.016 B/param,
              error feedback keeps the accumulated update unbiased.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import transformer

from . import grad_compress as gc
from .optimizer import Optimizer


def make_pod_dp_train_step(cfg, optimizer: Optimizer, mesh: Mesh, method: str):
    """Returns (step_fn, in_specs, out_specs) for jit under `mesh`.

    step_fn(params, opt_state, ef_state, batch) -> (params, opt_state,
    ef_state, loss).  params/opt replicated over 'pod' (their intra-pod
    data/model sharding is untouched: those axes are auto).  batch sharded
    over 'pod' on dim 0; ef_state sharded over 'pod' (per-pod residual).
    """

    def body(params, opt_state, ef_state, batch):
        # per-pod loss on this pod's batch shard (data/model axes stay auto)
        loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
        ef_local = jax.tree.map(lambda e: e[0], ef_state)  # drop pod axis
        grads, ef_local = gc.compressed_psum(grads, "pod", method, ef_local)
        npods = jax.lax.psum(1, "pod")
        grads = jax.tree.map(lambda g: g / npods, grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        loss = jax.lax.pmean(loss, "pod")
        ef_state = jax.tree.map(lambda e: e[None], ef_local)
        return params, opt_state, ef_state, loss

    rep = P()  # replicated over pod; data/model placement handled by auto
    batch_spec = {"tokens": P("pod"), "labels": P("pod")}
    in_specs = (rep, rep, P("pod"), batch_spec)
    out_specs = (rep, rep, P("pod"), rep)
    if hasattr(jax, "shard_map"):
        step = partial(
            jax.shard_map,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names={"pod"},
            check_vma=False,
        )(body)
    else:  # older jax: same partial-manual mapping via the experimental API
        from jax.experimental.shard_map import shard_map

        step = shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
            auto=frozenset(mesh.axis_names) - {"pod"},
        )
    return step


def make_ef_state_specs(params_sds, n_pods: int):
    """EF residual mirrors params with a leading (n_pods,) axis; shard_map's
    P('pod') in_spec gives each pod its own residual slice."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_pods,) + tuple(s.shape), jnp.float32),
        params_sds,
    )
