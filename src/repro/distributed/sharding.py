"""Sharding rules: DP (pod × data), FSDP (data), TP/EP (model).

Mesh axes: ('data','model') single-pod, ('pod','data','model') multi-pod.
  * batch dims shard over all DP axes ('pod','data'),
  * parameters FSDP-shard a large dim over 'data' and TP/EP-shard heads /
    d_ff / experts / vocab over 'model' (pod axis: pure replication => the
    gradient all-reduce crosses pods once per step),
  * optimizer state mirrors the parameter sharding (ZeRO).

Rules are name-based over the parameter tree paths and check divisibility —
a dim that doesn't divide its mesh axis falls back to replication (recorded;
e.g. danube's d_head=120 on a 16-way model axis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Return axes if dim divides the axes' total size, else None."""
    return axes if axes and dim % axis_size(mesh, axes) == 0 else None


def _spec(mesh: Mesh, shape, *axes_per_dim) -> NamedSharding:
    entries = [
        _fit(mesh, d, a) for d, a in zip(shape, axes_per_dim)
    ]
    return NamedSharding(mesh, P(*entries))


# ----------------------------------------------------------------- LM rules
def lm_param_shardings(params_sds, mesh: Mesh):
    """Path-pattern rules for transformer params (stacked layer leaves have a
    leading L axis)."""

    def rule(path, sds):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        shape = sds.shape
        if name == "embed":
            return _spec(mesh, shape, "model", "data")
        if name == "lm_head":
            return _spec(mesh, shape, "data", "model")
        if name in ("final_norm", "attn_norm", "mlp_norm"):
            return NamedSharding(mesh, P(*([None] * len(shape))))
        if name in ("wq", "wk", "wv"):
            return _spec(mesh, shape, None, "data", "model")
        if name == "wo":
            return _spec(mesh, shape, None, "model", "data")
        if name == "router":
            return _spec(mesh, shape, None, "data", None)
        if name in ("w_gate", "w_up"):
            if len(shape) == 4:  # MoE (L, E, D, F)
                return _spec(mesh, shape, None, "model", "data", None)
            return _spec(mesh, shape, None, "data", "model")
        if name == "w_down":
            if len(shape) == 4:  # MoE (L, E, F, D)
                return _spec(mesh, shape, None, "model", None, "data")
            return _spec(mesh, shape, None, "model", "data")
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(rule, params_sds)


def lm_cache_shardings(cache_sds, mesh: Mesh):
    """KV cache (L, B, T, KV, dh): shard B on DP; shard KV or dh on model."""
    dp = dp_axes(mesh)

    def rule(path, sds):
        L, B, T, KV, dh = sds.shape
        b_ax = dp if B % axis_size(mesh, dp) == 0 else None
        if KV % axis_size(mesh, "model") == 0:
            return NamedSharding(mesh, P(None, b_ax, None, "model", None))
        if dh % axis_size(mesh, "model") == 0:
            return NamedSharding(mesh, P(None, b_ax, None, None, "model"))
        return NamedSharding(mesh, P(None, b_ax, None, None, None))

    return jax.tree_util.tree_map_with_path(rule, cache_sds)


# ---------------------------------------------------------------- GNN rules
def gnn_param_shardings(params_sds, mesh: Mesh):
    """Processor MLPs are small (~10M params): replicate; FSDP the encoder
    when the input dim divides (it rarely matters)."""

    def rule(path, sds):
        return NamedSharding(mesh, P(*([None] * len(sds.shape))))

    return jax.tree_util.tree_map_with_path(rule, params_sds)


# ------------------------------------------------------------- recsys rules
def recsys_param_shardings(params_sds, mesh: Mesh):
    """Embedding tables row-shard on 'model' (they are the memory); everything
    else replicates (MLPs are ~10M params)."""

    def rule(path, sds):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        shape = sds.shape
        if name in ("embed", "linear", "item_embed"):
            return _spec(mesh, shape, "model", None)
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(rule, params_sds)


def param_shardings(family: str, params_sds, mesh: Mesh):
    if family == "lm":
        return lm_param_shardings(params_sds, mesh)
    if family == "gnn":
        return gnn_param_shardings(params_sds, mesh)
    if family == "recsys":
        return recsys_param_shardings(params_sds, mesh)
    raise ValueError(family)


# --------------------------------------------------------------- activations
def batch_shardings(specs: Dict[str, Any], mesh: Mesh, family: str):
    """First-dim DP sharding for every input (scalars replicated)."""
    dp = dp_axes(mesh)

    def rule(sds):
        if not hasattr(sds, "shape") or len(sds.shape) == 0:
            return NamedSharding(mesh, P())
        b = sds.shape[0]
        first = dp if b % axis_size(mesh, dp) == 0 else None
        return NamedSharding(mesh, P(first, *([None] * (len(sds.shape) - 1))))

    return jax.tree.map(rule, specs)


def opt_state_shardings(opt_state_sds, params_shardings, mesh: Mesh):
    """Optimizer leaves mirror the param sharding; factored Adafactor stats
    drop the reduced dim's spec entry; scalars replicate."""
    flat_params = {
        tuple(getattr(k, "key", str(k)) for k in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(params_shardings)[0]
    }

    def rule(path, sds):
        keys = tuple(getattr(k, "key", str(k)) for k in path)
        if len(sds.shape) == 0:
            return NamedSharding(mesh, P())
        # match the param this state leaf mirrors: strip optimizer wrappers
        stripped = tuple(k for k in keys if k not in ("m", "v", "vr", "vc", "per_param"))
        leaf_kind = keys[-1]
        pspec = None
        for cand, sh in flat_params.items():
            if cand == stripped:
                pspec = sh.spec
                break
        if pspec is None:
            return NamedSharding(mesh, P(*([None] * len(sds.shape))))
        # normalize spec to the PARAM's ndim (P() pads implicitly with None)
        param_ndim = len(sds.shape) + (1 if leaf_kind in ("vr", "vc") else 0)
        full = tuple(pspec) + (None,) * (param_ndim - len(tuple(pspec)))
        if leaf_kind == "vr":  # reduced over last dim
            return NamedSharding(mesh, P(*full[:-1]))
        if leaf_kind == "vc":  # reduced over second-to-last dim
            return NamedSharding(mesh, P(*full[:-2], full[-1]))
        return NamedSharding(mesh, P(*full))

    return jax.tree_util.tree_map_with_path(rule, opt_state_sds)
