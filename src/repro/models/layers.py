"""Shared pure-JAX layers: initializers, RMSNorm, RoPE, MLPs.

No flax/optax in this container — parameters are plain pytrees (nested dicts
of jnp arrays), models are (init, apply) function pairs.  Logical sharding
axes are attached later by repro.distributed.sharding via path-pattern rules.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        f"w{i}": dense_init(keys[i], sizes[i], sizes[i + 1], dtype)
        for i in range(len(sizes) - 1)
    } | {
        f"b{i}": jnp.zeros((sizes[i + 1],), dtype) for i in range(len(sizes) - 1)
    }


def mlp_apply(p: Params, x: jax.Array, act=jax.nn.relu, final_act=None) -> jax.Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, d_head: int, theta: float = 10000.0):
    """positions: (...,) int -> cos/sin of shape (..., d_head//2)."""
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., d_head); cos/sin broadcastable to (..., d_head//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------- utilities
def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask=None):
    """logits (..., V), labels (...) int -> mean NLL (fp32)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))
