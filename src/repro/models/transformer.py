"""LM-family transformer: dense + MoE, GQA, optional sliding-window attention,
RoPE, scan-over-layers (stacked params keep HLO size O(1) in depth), KV-cache
decode step.  Covers olmoe-1b-7b, kimi-k2-1t-a32b, yi-9b, h2o-danube-3-4b,
llama3.2-1b from the assignment.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (
    Params,
    apply_rope,
    cross_entropy_loss,
    dense_init,
    embed_init,
    rms_norm,
    rope_angles,
)


@dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    # MoE (n_experts == 0 => dense)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_groups: int = 1  # dispatch groups == data-parallel shards at scale
    # attention
    sliding_window: Optional[int] = None  # h2o-danube SWA
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: Any = jnp.float32
    remat: bool = True
    # §Perf/H1: constrain logits to (batch_axes, None, vocab_axis) so the
    # (tokens, vocab) activation is vocab-sharded instead of all-gathered.
    logits_pspec: Optional[tuple] = None
    # §Perf/H1-iter2: activation sharding constraints.  act_dp = mesh axes for
    # the batch dim of every activation; act_tp = mesh axis for heads/ffn.
    # Without these, XLA propagates the FSDP weight shardings onto the
    # residual stream (batch becomes REPLICATED) — see EXPERIMENTS.md §Perf.
    act_dp: Optional[tuple] = None
    act_tp: Optional[str] = None
    # unroll the layer scan (dry-run flop accounting: XLA cost_analysis
    # counts while-loop bodies ONCE, so loops undercount flops by ~n_layers)
    scan_unroll: bool = False

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# ------------------------------------------------------------------- init
def init_params(key: jax.Array, cfg: TransformerConfig) -> Params:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    D, H, KV, dh, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff

    def layer_params(k) -> Params:
        ks = jax.random.split(k, 10)
        p: Params = {
            "attn_norm": jnp.ones((D,), cfg.dtype),
            "mlp_norm": jnp.ones((D,), cfg.dtype),
            "wq": dense_init(ks[0], D, H * dh, cfg.dtype),
            "wk": dense_init(ks[1], D, KV * dh, cfg.dtype),
            "wv": dense_init(ks[2], D, KV * dh, cfg.dtype),
            "wo": dense_init(ks[3], H * dh, D, cfg.dtype),
        }
        if cfg.is_moe:
            E = cfg.n_experts
            p["router"] = dense_init(ks[4], D, E, cfg.dtype)
            p["w_gate"] = (
                jax.random.normal(ks[5], (E, D, F)) / np.sqrt(D)
            ).astype(cfg.dtype)
            p["w_up"] = (jax.random.normal(ks[6], (E, D, F)) / np.sqrt(D)).astype(cfg.dtype)
            p["w_down"] = (jax.random.normal(ks[7], (E, F, D)) / np.sqrt(F)).astype(cfg.dtype)
        else:
            p["w_gate"] = dense_init(ks[5], D, F, cfg.dtype)
            p["w_up"] = dense_init(ks[6], D, F, cfg.dtype)
            p["w_down"] = dense_init(ks[7], F, D, cfg.dtype)
        return p

    # stacked layer params: every leaf gets a leading (n_layers,) axis
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(layer_params)(layer_keys)

    params: Params = {
        "embed": embed_init(k_embed, cfg.vocab, D, cfg.dtype),
        "final_norm": jnp.ones((D,), cfg.dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, D, cfg.vocab, cfg.dtype)
    return params


# -------------------------------------------------------------- attention
def _shard(x, *spec):
    """with_sharding_constraint helper; None spec entries pass through."""
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))


def _gqa_attention(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,  # (B, T, KV, dh)
    v: jax.Array,  # (B, T, KV, dh)
    *,
    cfg: "TransformerConfig",
    sliding_window: Optional[int],
    q_positions: jax.Array,  # (S,) absolute positions of queries
    kv_positions: jax.Array,  # (T,)
) -> jax.Array:
    B, S, H, dh = q.shape
    KV = k.shape[2]
    # flatten GQA groups to a single H dim (repeat_kv): heads then shard
    # H-way on the TP axis — (KV, group) split dims cap tiling at KV-way
    k = jnp.repeat(k, H // KV, axis=2)  # (B, T, H, dh)
    v = jnp.repeat(v, H // KV, axis=2)
    if cfg.act_dp is not None:
        q = _shard(q, cfg.act_dp, None, cfg.act_tp, None)
        k = _shard(k, cfg.act_dp, None, cfg.act_tp, None)
        v = _shard(v, cfg.act_dp, None, cfg.act_tp, None)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(dh)
    if cfg.act_dp is not None:
        scores = _shard(scores, cfg.act_dp, cfg.act_tp, None, None)
    # mask: causal + optional sliding window on absolute positions
    rel = q_positions[:, None] - kv_positions[None, :]  # (S, T)
    mask = rel >= 0
    if sliding_window is not None:
        mask &= rel < sliding_window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, S, H * dh)


# ------------------------------------------------------------------- MoE
def _moe_ffn(p: Params, x: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """Top-k routed experts, grouped scatter dispatch (GShard capacity model).

    Tokens are split into `n_groups` dispatch groups (sharded on the data
    mesh axes); each group has local expert capacity C.  Dispatch/combine are
    scatter-add / gather — O(N·D) memory, never materializing the one-hot
    (N,K,E,C) tensor.  With experts sharded on 'model', the grouped einsum
    reshard lowers to the MoE all-to-all.
    """
    B, S, D = x.shape
    E, K, G = cfg.n_experts, cfg.top_k, cfg.moe_groups
    N = B * S
    assert N % G == 0, f"tokens {N} not divisible by moe_groups {G}"
    Ng = N // G
    C = max(int(cfg.capacity_factor * Ng * K / E), 1)
    xt = x.reshape(G, Ng, D)
    if cfg.act_dp is not None:
        xt = _shard(xt, cfg.act_dp, None, None)
    logits = jnp.einsum("gnd,de->gne", xt, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (G, Ng, K)
    gate_vals = (
        gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    ).astype(x.dtype)
    # position of each (token, k) pick within its expert's queue (per group)
    onehot = jax.nn.one_hot(idx.reshape(G, Ng * K), E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot  # (G, Ng*K, E)
    pos = jnp.take_along_axis(
        pos, idx.reshape(G, Ng * K)[..., None], axis=-1
    )[..., 0].reshape(G, Ng, K)
    keep = pos < C
    slot = jnp.where(keep, idx * C + pos, E * C)  # overflow slot E*C
    # dispatch: scatter tokens into (G, E*C+1, D) expert buffers
    g_idx = jnp.arange(G)[:, None, None]
    buf = jnp.zeros((G, E * C + 1, D), x.dtype)
    buf = buf.at[g_idx, slot].add(xt[:, :, None, :] * keep[..., None].astype(x.dtype))
    expert_in = buf[:, : E * C].reshape(G, E, C, D)
    # NOTE (§Perf/H1-iter4, refuted hypothesis): constraining expert_in to
    # P(dp, tp, None, None) here FORCED a reshard of the (G,E,C,D) buffer and
    # DOUBLED MoE collective bytes (olmoe train 70->146 GiB).  The grouped
    # einsum against E-sharded weights already lowers to the right all-to-all;
    # leave the dispatch buffers unconstrained.
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    ) * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G, E, C, D)
    # combine: gather each pick's expert output, weight by gate
    flat_out = jnp.concatenate(
        [expert_out.reshape(G, E * C, D), jnp.zeros((G, 1, D), x.dtype)], axis=1
    )
    picked = flat_out[g_idx, slot]  # (G, Ng, K, D)
    out = jnp.sum(picked * gate_vals[..., None], axis=2)
    return out.reshape(B, S, D)


def _dense_ffn(p: Params, x: jax.Array, cfg: "TransformerConfig") -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    if cfg.act_dp is not None:
        h = _shard(h, cfg.act_dp, None, cfg.act_tp)
    return h @ p["w_down"]


# ------------------------------------------------------------------ layers
def _layer_fwd(
    p: Params,
    x: jax.Array,
    cfg: TransformerConfig,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
):
    """One transformer block.  Returns (x, new_kv) where new_kv is the
    (k, v) to store when running with a cache."""
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if cfg.act_dp is not None:
        x = _shard(x, cfg.act_dp, None, None)
    h = rms_norm(x, p["attn_norm"])
    q = (h @ p["wq"]).reshape(B, S, H, dh)
    k = (h @ p["wk"]).reshape(B, S, KV, dh)
    v = (h @ p["wv"]).reshape(B, S, KV, dh)
    cos_q, sin_q = rope_angles(q_positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos_q[None, :, None, :], sin_q[None, :, None, :])
    k_rot = apply_rope(k, cos_q[None, :, None, :], sin_q[None, :, None, :])

    if kv_cache is not None:
        ck, cv = kv_cache  # (B, T, KV, dh) ring or linear cache
        ck = jax.lax.dynamic_update_slice(ck, k_rot, (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, cache_index, 0, 0))
        attn = _gqa_attention(
            q,
            ck,
            cv,
            cfg=cfg,
            sliding_window=cfg.sliding_window,
            q_positions=q_positions,
            kv_positions=kv_positions,
        )
        new_kv = (ck, cv)
    else:
        attn = _gqa_attention(
            q,
            k_rot,
            v,
            cfg=cfg,
            sliding_window=cfg.sliding_window,
            q_positions=q_positions,
            kv_positions=kv_positions,
        )
        new_kv = (k_rot, v)
    x = x + attn @ p["wo"]
    if cfg.act_dp is not None:
        x = _shard(x, cfg.act_dp, None, None)
    h2 = rms_norm(x, p["mlp_norm"])
    ffn = _moe_ffn(p, h2, cfg) if cfg.is_moe else _dense_ffn(p, h2, cfg)
    out = x + ffn
    if cfg.act_dp is not None:
        out = _shard(out, cfg.act_dp, None, None)
    return out, new_kv


# ------------------------------------------------------------------ forward
def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V).  lax.scan over stacked layers."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(S)

    def body(x, layer_p):
        def one_layer(p, h):
            return _layer_fwd(
                p, h, cfg=cfg, q_positions=positions, kv_positions=positions
            )[0]

        if cfg.remat:
            one_layer = jax.checkpoint(one_layer)
        return one_layer(layer_p, x), None

    x, _ = jax.lax.scan(
        body, x, params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.logits_pspec is not None:
        from jax.sharding import PartitionSpec as P

        logits = jax.lax.with_sharding_constraint(logits, P(*cfg.logits_pspec))
    return logits


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: TransformerConfig):
    logits = forward(params, batch["tokens"], cfg)
    return cross_entropy_loss(logits, batch["labels"])


# ---------------------------------------------------------------- KV cache
def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int) -> Params:
    """Cache length: sliding-window archs only keep `window` entries — that is
    what makes h2o-danube's long_500k decode sub-quadratic AND sub-linear in
    memory."""
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def decode_step(
    params: Params,
    cache: Params,
    tokens: jax.Array,  # (B, 1) the new token
    position: jax.Array,  # scalar: absolute position of the new token
    cfg: TransformerConfig,
):
    """One incremental decode step -> (logits (B, V), updated cache)."""
    B = tokens.shape[0]
    T = cache["k"].shape[2]
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, D)
    q_pos = position[None]  # (1,)
    slot = position % T  # ring-buffer slot for SWA; linear when T >= max_len
    # absolute positions held in each cache slot after this write
    slots = jnp.arange(T)
    written = jnp.where(
        position >= T,
        position - ((slot - slots) % T),
        slots,
    )
    valid = written <= position
    # invalid (unwritten) slots get a FUTURE position so the causal mask
    # (rel >= 0) rejects them for full-attention archs too
    kv_positions = jnp.where(valid, written, position + 1_000_000_000)

    def body(x, layer):
        layer_p, ck, cv = layer
        out, (nk, nv) = _layer_fwd(
            layer_p,
            x,
            cfg,
            q_positions=q_pos,
            kv_positions=kv_positions,
            kv_cache=(ck, cv),
            cache_index=slot,
        )
        return out, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return logits, {"k": nk, "v": nv}
