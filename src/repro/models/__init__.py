"""Pure-JAX model zoo for the 10 assigned architectures: LM transformers
(dense + MoE + GQA + SWA), GraphCast-style message-passing GNN, and four
recsys models (xDeepFM, DCN-v2, SASRec, MIND)."""
from . import gnn, layers, recsys, transformer  # noqa: F401
