"""RecSys architectures: xDeepFM (CIN), DCN-v2 (cross network), SASRec
(sequential self-attention), MIND (multi-interest capsule routing).

JAX has no nn.EmbeddingBag — per the assignment we build it:
``embedding_bag`` = jnp.take + jax.ops.segment_sum over a ragged bag layout.
CTR models use one-id-per-field lookups (a special case); the bag op is
exercised by multi-hot fields and tested against a numpy oracle.

Tables are sharded row-wise on the 'model' mesh axis at scale
(repro.distributed.sharding); ``retrieval_cand`` scores 1M candidates as one
batched matmul, never a loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init, embed_init, mlp_apply, mlp_init, rms_norm


# ------------------------------------------------------------ embedding ops
def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """(V, D) table, (...,) int ids -> (..., D)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,  # (n_total,) flat multi-hot ids
    segments: jax.Array,  # (n_total,) bag id per entry
    n_bags: int,
    mode: str = "sum",
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce."""
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(vecs, segments, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(vecs, segments, num_segments=n_bags)
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segments, vecs.dtype), segments, num_segments=n_bags
        )
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(vecs, segments, num_segments=n_bags)
    raise ValueError(mode)


# =====================================================================
# xDeepFM (arXiv:1803.05170): linear + CIN + DNN
# =====================================================================
@dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp_sizes: Tuple[int, ...] = (400, 400)
    dtype: Any = jnp.float32


def xdeepfm_init(key, cfg: XDeepFMConfig) -> Params:
    ks = jax.random.split(key, 6 + len(cfg.cin_layers))
    m, d = cfg.n_sparse, cfg.embed_dim
    p: Params = {
        "embed": embed_init(ks[0], cfg.n_sparse * cfg.vocab_per_field, d, cfg.dtype),
        "linear": embed_init(ks[1], cfg.n_sparse * cfg.vocab_per_field, 1, cfg.dtype),
        "mlp": mlp_init(ks[2], [m * d, *cfg.mlp_sizes, 1], cfg.dtype),
    }
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        p[f"cin_w{i}"] = (
            jax.random.normal(ks[3 + i], (h_prev * m, h)) * 0.1
        ).astype(cfg.dtype)
        h_prev = h
    p["cin_out"] = dense_init(ks[-1], sum(cfg.cin_layers), 1, cfg.dtype)
    return p


def _field_offsets(ids: jax.Array, vocab: int) -> jax.Array:
    """Per-field id spaces share one big table: offset field f by f*vocab."""
    m = ids.shape[-1]
    return ids + (jnp.arange(m, dtype=ids.dtype) * vocab)[None, :]


def xdeepfm_forward(p: Params, sparse_ids: jax.Array, cfg: XDeepFMConfig):
    """sparse_ids (B, n_sparse) -> logits (B,)."""
    ids = _field_offsets(sparse_ids, cfg.vocab_per_field)
    x0 = embedding_lookup(p["embed"], ids)  # (B, m, d)
    lin = embedding_lookup(p["linear"], ids).sum(axis=(1, 2))  # (B,)
    # CIN: x^{k+1}_h = sum_{i,j} W^k_{h,ij} (x^k_i * x^0_j)
    xk = x0
    cin_outs: List[jax.Array] = []
    for i in range(len(cfg.cin_layers)):
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # (B, Hk, m, d)
        B, Hk, m, d = z.shape
        xk = jnp.einsum(
            "bqd,qh->bhd", z.reshape(B, Hk * m, d), p[f"cin_w{i}"]
        )  # (B, Hk+1, d)
        cin_outs.append(xk.sum(-1))  # sum-pool over d
    cin_logit = (jnp.concatenate(cin_outs, -1) @ p["cin_out"])[:, 0]
    dnn_logit = mlp_apply(p["mlp"], x0.reshape(x0.shape[0], -1))[:, 0]
    return lin + cin_logit + dnn_logit


def xdeepfm_loss(p, batch, cfg):
    logits = xdeepfm_forward(p, batch["sparse_ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# =====================================================================
# DCN-v2 (arXiv:2008.13535): cross network v2 + deep tower
# =====================================================================
@dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    vocab_per_field: int = 1_000_000
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp_sizes: Tuple[int, ...] = (1024, 1024, 512)
    dtype: Any = jnp.float32

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def dcnv2_init(key, cfg: DCNv2Config) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_cross_layers)
    D = cfg.d_input
    p: Params = {
        "embed": embed_init(ks[0], cfg.n_sparse * cfg.vocab_per_field, cfg.embed_dim, cfg.dtype),
        "mlp": mlp_init(ks[1], [D, *cfg.mlp_sizes], cfg.dtype),
        "head": dense_init(ks[2], D + cfg.mlp_sizes[-1], 1, cfg.dtype),
    }
    for i in range(cfg.n_cross_layers):
        p[f"cross_w{i}"] = dense_init(ks[3 + i], D, D, cfg.dtype, scale=0.5)
        p[f"cross_b{i}"] = jnp.zeros((D,), cfg.dtype)
    return p


def dcnv2_forward(p, dense_feats: jax.Array, sparse_ids: jax.Array, cfg: DCNv2Config):
    ids = _field_offsets(sparse_ids, cfg.vocab_per_field)
    emb = embedding_lookup(p["embed"], ids)  # (B, m, d)
    x0 = jnp.concatenate(
        [dense_feats.astype(cfg.dtype), emb.reshape(emb.shape[0], -1)], axis=-1
    )
    x = x0
    for i in range(cfg.n_cross_layers):
        x = x0 * (x @ p[f"cross_w{i}"] + p[f"cross_b{i}"]) + x  # DCN-v2 cross
    deep = mlp_apply(p["mlp"], x0, act=jax.nn.relu)
    return (jnp.concatenate([x, deep], -1) @ p["head"])[:, 0]


def dcnv2_loss(p, batch, cfg):
    logits = dcnv2_forward(p, batch["dense"], batch["sparse_ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# =====================================================================
# SASRec (arXiv:1808.09781): causal self-attention over item history
# =====================================================================
@dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dtype: Any = jnp.float32


def sasrec_init(key, cfg: SASRecConfig) -> Params:
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    p: Params = {
        "item_embed": embed_init(ks[0], cfg.n_items, d, cfg.dtype),
        "pos_embed": embed_init(ks[1], cfg.seq_len, d, cfg.dtype),
    }
    for b in range(cfg.n_blocks):
        o = 2 + 6 * b
        p[f"b{b}"] = {
            "norm1": jnp.ones((d,), cfg.dtype),
            "norm2": jnp.ones((d,), cfg.dtype),
            "wq": dense_init(ks[o], d, d, cfg.dtype),
            "wk": dense_init(ks[o + 1], d, d, cfg.dtype),
            "wv": dense_init(ks[o + 2], d, d, cfg.dtype),
            "wo": dense_init(ks[o + 3], d, d, cfg.dtype),
            "ff1": dense_init(ks[o + 4], d, 4 * d, cfg.dtype),
            "ff2": dense_init(ks[o + 5], 4 * d, d, cfg.dtype),
        }
    return p


def sasrec_encode(p, item_ids: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """item_ids (B, S) -> user state (B, d) (last position representation)."""
    B, S = item_ids.shape
    h = embedding_lookup(p["item_embed"], item_ids) + p["pos_embed"][None, :S]
    H, d = cfg.n_heads, cfg.embed_dim
    dh = d // H
    causal = jnp.tril(jnp.ones((S, S), bool))
    for b in range(cfg.n_blocks):
        bp = p[f"b{b}"]
        x = rms_norm(h, bp["norm1"])
        q = (x @ bp["wq"]).reshape(B, S, H, dh)
        k = (x @ bp["wk"]).reshape(B, S, H, dh)
        v = (x @ bp["wv"]).reshape(B, S, H, dh)
        scores = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(dh)
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(h.dtype)
        attn = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, d)
        h = h + attn @ bp["wo"]
        x = rms_norm(h, bp["norm2"])
        h = h + jax.nn.relu(x @ bp["ff1"]) @ bp["ff2"]
    return h[:, -1]


def sasrec_loss(p, batch, cfg: SASRecConfig):
    """In-batch sampled softmax over next-item targets."""
    state = sasrec_encode(p, batch["history"], cfg)  # (B, d)
    targets = embedding_lookup(p["item_embed"], batch["target"])  # (B, d)
    logits = state @ targets.T  # in-batch negatives
    labels = jnp.arange(state.shape[0])
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
    gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def sasrec_score_candidates(p, history: jax.Array, candidates: jax.Array, cfg):
    """retrieval_cand shape: (B, S) history x (N_c,) candidates -> (B, N_c)."""
    state = sasrec_encode(p, history, cfg)
    cand = embedding_lookup(p["item_embed"], candidates)
    return state @ cand.T  # one matmul, not a loop


# =====================================================================
# MIND (arXiv:1904.08030): multi-interest capsule routing
# =====================================================================
@dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    dtype: Any = jnp.float32


def mind_init(key, cfg: MINDConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_embed": embed_init(k1, cfg.n_items, d, cfg.dtype),
        "bilinear": dense_init(k2, d, d, cfg.dtype),  # shared routing transform
        "label_attn_pow": jnp.ones((), cfg.dtype),
    }


def _squash(v: jax.Array) -> jax.Array:
    n2 = jnp.sum(jnp.square(v), -1, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + 1e-9)


def mind_interests(p, history: jax.Array, cfg: MINDConfig) -> jax.Array:
    """history (B, S) -> K interest capsules (B, K, d) via dynamic routing."""
    B, S = history.shape
    h = embedding_lookup(p["item_embed"], history) @ p["bilinear"]  # (B, S, d)
    K = cfg.n_interests
    b_logits = jnp.zeros((B, S, K), jnp.float32)
    caps = jnp.zeros((B, K, cfg.embed_dim), h.dtype)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_logits, axis=-1).astype(h.dtype)  # (B, S, K)
        caps = _squash(jnp.einsum("bsk,bsd->bkd", w, h))
        b_logits = b_logits + jnp.einsum("bsd,bkd->bsk", h, caps).astype(jnp.float32)
    return caps


def mind_loss(p, batch, cfg: MINDConfig):
    """Label-aware attention: train against the best-matching interest."""
    caps = mind_interests(p, batch["history"], cfg)  # (B, K, d)
    tgt = embedding_lookup(p["item_embed"], batch["target"])  # (B, d)
    # label-aware attention selects the interest (paper: softmax^pow -> max)
    sim = jnp.einsum("bkd,bd->bk", caps, tgt)
    user = jnp.einsum(
        "bk,bkd->bd", jax.nn.softmax(sim * 4.0, -1).astype(caps.dtype), caps
    )
    logits = user @ embedding_lookup(p["item_embed"], batch["target"]).T
    labels = jnp.arange(user.shape[0])
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
    gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


def mind_score_candidates(p, history, candidates, cfg):
    """Serving: max over interests (paper's retrieval rule) — one matmul."""
    caps = mind_interests(p, history, cfg)  # (B, K, d)
    cand = embedding_lookup(p["item_embed"], candidates)  # (N, d)
    scores = jnp.einsum("bkd,nd->bkn", caps, cand)
    return scores.max(axis=1)  # (B, N)
