"""GraphCast-style encode-process-decode message-passing GNN (arXiv:2212.12794).

JAX has no sparse message-passing primitive — per the assignment, the
edge-index -> ``jax.ops.segment_sum`` scatter IS part of the system:

    msg_e   = MLP([h_src(e), h_dst(e), e_feat(e)])
    agg_v   = segment_sum(msg, dst, N)
    h_v    += MLP([h_v, agg_v])          (residual, as in GraphCast)
    e_feat += msg                         (edge residual update)

Supports full-batch graphs (cora/ogbn-products shapes), sampled minibatches
(padded subgraphs from the neighbour sampler in repro.data.sampler), and
batched small molecule graphs (leading batch dim via vmap).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .layers import Params, mlp_apply, mlp_init


@dataclass(frozen=True)
class GNNConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    d_in: int = 227  # n_vars for graphcast; d_feat for benchmark graphs
    d_out: int = 227
    d_edge_in: int = 4  # raw edge features (e.g. displacement vectors)
    aggregator: str = "sum"
    mesh_refinement: int = 6  # graphcast icosahedral refinement (metadata)
    dtype: Any = jnp.float32
    remat: bool = True
    # §Perf/H2: row-shard node/edge activations over these mesh axes so the
    # per-layer (N,H)/(E,H) tensors never replicate.
    act_axes: Optional[tuple] = None
    scan_unroll: bool = False  # dry-run flop accounting (see transformer.py)


def init_params(key: jax.Array, cfg: GNNConfig) -> Params:
    H = cfg.d_hidden
    k_enc_n, k_enc_e, k_proc, k_dec = jax.random.split(key, 4)

    def proc_layer(k) -> Params:
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "msg": mlp_init(k1, [3 * H, H, H], cfg.dtype),
            "upd": mlp_init(k2, [2 * H, H, H], cfg.dtype),
        }

    layer_keys = jax.random.split(k_proc, cfg.n_layers)
    return {
        "enc_node": mlp_init(k_enc_n, [cfg.d_in, H, H], cfg.dtype),
        "enc_edge": mlp_init(k_enc_e, [cfg.d_edge_in, H, H], cfg.dtype),
        "layers": jax.vmap(proc_layer)(layer_keys),
        "dec_node": mlp_init(k_dec, [H, H, cfg.d_out], cfg.dtype),
    }


def _aggregate(msgs: jax.Array, dst: jax.Array, n_nodes: int, how: str) -> jax.Array:
    if how == "sum":
        return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if how == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        cnt = jax.ops.segment_sum(jnp.ones_like(dst, msgs.dtype), dst, num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if how == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
    raise ValueError(how)


def forward(
    params: Params,
    nodes: jax.Array,  # (N, d_in)
    edges: jax.Array,  # (E, 2) int32 [src, dst]
    edge_feats: Optional[jax.Array],  # (E, d_edge_in) or None
    cfg: GNNConfig,
    edge_mask: Optional[jax.Array] = None,  # (E,) 1.0 valid / 0.0 padding
) -> jax.Array:
    N = nodes.shape[0]
    src, dst = edges[:, 0], edges[:, 1]

    def _constrain(x):
        if cfg.act_axes is None:
            return x
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(cfg.act_axes, None))

    h = _constrain(mlp_apply(params["enc_node"], nodes.astype(cfg.dtype), act=jax.nn.silu))
    if edge_feats is None:
        edge_feats = jnp.zeros((edges.shape[0], cfg.d_edge_in), cfg.dtype)
    e = _constrain(mlp_apply(params["enc_edge"], edge_feats.astype(cfg.dtype), act=jax.nn.silu))

    def layer(carry, lp):
        h, e = carry

        def inner(h, e, lp):
            m_in = jnp.concatenate([h[src], h[dst], e], axis=-1)
            msg = _constrain(mlp_apply(lp["msg"], m_in, act=jax.nn.silu))
            if edge_mask is not None:
                msg = msg * edge_mask[:, None].astype(msg.dtype)
            agg = _constrain(_aggregate(msg, dst, N, cfg.aggregator))
            upd = mlp_apply(lp["upd"], jnp.concatenate([h, agg], -1), act=jax.nn.silu)
            return _constrain(h + upd), _constrain(e + msg)

        if cfg.remat:
            h, e = jax.checkpoint(inner)(h, e, lp)
        else:
            h, e = inner(h, e, lp)
        return (h, e), None

    (h, _e), _ = jax.lax.scan(
        layer, (h, e), params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1
    )
    return mlp_apply(params["dec_node"], h, act=jax.nn.silu)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: GNNConfig):
    """Regression MSE on node targets (GraphCast trains on weather residuals);
    node_mask selects training nodes (e.g. sampled seed nodes)."""
    out = forward(
        params,
        batch["nodes"],
        batch["edges"],
        batch.get("edge_feats"),
        cfg,
        edge_mask=batch.get("edge_mask"),
    )
    err = jnp.square(out - batch["targets"].astype(out.dtype))
    mask = batch.get("node_mask")
    if mask is not None:
        return jnp.sum(err * mask[:, None]) / jnp.maximum(
            jnp.sum(mask) * err.shape[-1], 1.0
        )
    return jnp.mean(err)


def forward_batched(params, nodes, edges, edge_feats, cfg, edge_mask=None):
    """Batched small graphs (molecule shape): vmap over the leading axis."""
    fn = partial(forward, cfg=cfg)
    return jax.vmap(lambda n, ed, ef, m: fn(params, n, ed, ef, edge_mask=m))(
        nodes, edges, edge_feats, edge_mask
    )


def loss_fn_batched(params, batch, cfg):
    out = forward_batched(
        params,
        batch["nodes"],
        batch["edges"],
        batch.get("edge_feats"),
        cfg,
        batch.get("edge_mask"),
    )
    return jnp.mean(jnp.square(out - batch["targets"].astype(out.dtype)))
