"""Crash-kill fuzzing: SIGKILL real subprocesses at every crash point.

The durability seams (shard rewrites, checkpoint publishes, atomic sinks)
promise specific invariants across a crash at *any* instant — the aside copy
is never swept while the canonical dir is missing, a half-written checkpoint
step is never visible, the final output path never holds partial bytes.
Monkeypatched exceptions cannot honestly test those promises: a Python
exception unwinds ``finally`` blocks and context managers that a real crash
does not.  This harness forks a genuine victim process per kill site and
``SIGKILL``s it mid-operation:

1. a *record* run (``FaultPlan(record=True)``) executes the scenario once,
   cleanly, enumerating every ``(crash point, occurrence)`` it passes;
2. one victim subprocess per site re-runs the scenario with a ``kill`` rule
   armed at exactly that occurrence — the process dies with ``-SIGKILL``,
   no cleanup code of any kind runs;
3. the parent asserts the scenario's recovery invariants over the remains.

Scenario state is content-addressed by version number (:func:`shard_arrays`
etc. are pure functions of an integer), so the parent can check that what
survived is byte-exactly *some consistent version* — old or new, never a
blend, never a torn file.

The victim entry point is ``python -m repro.reliability._victim``; the fault
plan travels in the ``REPRO_FAULT_PLAN`` environment variable as JSON.
"""
from __future__ import annotations

import io
import json
import os
import signal
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .faults import FaultPlan

__all__ = [
    "SCENARIOS",
    "ENV_PLAN",
    "shard_arrays",
    "ckpt_tree",
    "sink_payload",
    "run_victim",
    "enumerate_sites",
    "run_kill",
    "check_invariants",
    "kill_sweep",
]

ENV_PLAN = "REPRO_FAULT_PLAN"
SITES_FILE = "sites.json"
SCENARIOS = ("shard_rewrite", "checkpoint", "atomic_sink")
SINK_CHUNK_BYTES = 1 << 12
VICTIM_TIMEOUT = 300.0


# ----------------------------------------------------------- scenario content
# Pure functions of a version number: the victim writes version 1 over a
# version-0 baseline, and the parent regenerates both to decide which one
# (exactly) survived the kill.
def shard_arrays(version: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(1000 + version)
    return {
        f"col{i:02d}": rng.integers(0, 1 << 16, size=192 + 8 * i, dtype=np.uint32)
        for i in range(12)
    }


def ckpt_tree(version: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(2000 + version)
    return {
        f"layer{i:02d}": rng.standard_normal(48 + 4 * i).astype(np.float32)
        for i in range(16)
    }


def sink_payload(version: int) -> bytes:
    rng = np.random.default_rng(3000 + version)
    return rng.integers(0, 256, size=10 * SINK_CHUNK_BYTES, dtype=np.uint8).tobytes()


def _sink_plan():
    from repro.codecs.profiles import resolve_profile_spec

    return resolve_profile_spec("generic")


# ------------------------------------------------------------------- victim
def _armed(plan: Optional[FaultPlan], fn) -> None:
    if plan is None:
        fn()
    else:
        with plan.arm(all_threads=True):
            fn()


def run_victim(scenario: str, workdir) -> None:
    """Scenario body executed *inside the victim process*.

    Establishes the version-0 baseline unfaulted (once per workdir), then
    performs the version-1 operation with the environment's fault plan armed
    — the kill lands somewhere inside that operation.
    """
    workdir = Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    blob = os.environ.get(ENV_PLAN)
    plan = FaultPlan.from_json(blob) if blob else None
    setup_done = workdir / "setup.done"

    if scenario == "shard_rewrite":
        from repro.data.shard_store import CompressedShardStore

        store = CompressedShardStore(workdir / "store")
        if not setup_done.exists():
            store.write_shard(0, shard_arrays(0))
            setup_done.touch()
        _armed(plan, lambda: store.write_shard(0, shard_arrays(1)))
    elif scenario == "checkpoint":
        from repro.distributed import checkpoint as ck

        ckdir = workdir / "ckpt"
        if not setup_done.exists():
            ck.save_checkpoint(ckdir, 1, ckpt_tree(0))
            setup_done.touch()
        _armed(plan, lambda: ck.save_checkpoint(ckdir, 2, ckpt_tree(1)))
    elif scenario == "atomic_sink":
        from repro.core import stream_io

        src = workdir / "src.bin"
        old = workdir / "old_src.bin"
        dst = workdir / "out.ozl"
        sink_plan = _sink_plan()
        if not setup_done.exists():
            with stream_io._atomic_sink(src) as f:
                f.write(sink_payload(1))
            with stream_io._atomic_sink(old) as f:
                f.write(sink_payload(0))
            stream_io.compress_file(old, dst, sink_plan, chunk_bytes=SINK_CHUNK_BYTES)
            setup_done.touch()
        _armed(
            plan,
            lambda: stream_io.compress_file(
                src, dst, sink_plan, chunk_bytes=SINK_CHUNK_BYTES
            ),
        )
    else:
        raise SystemExit(f"unknown crash-kill scenario {scenario!r}")

    if plan is not None and plan.record:
        from repro.core.stream_io import _atomic_sink

        with _atomic_sink(workdir / SITES_FILE) as f:
            f.write(json.dumps([[name, occ] for name, occ in plan.sites]).encode())


# ------------------------------------------------------------------ harness
def _spawn(scenario: str, workdir: Path, plan: Optional[FaultPlan]):
    env = dict(os.environ)
    if plan is not None:
        env[ENV_PLAN] = plan.to_json()
    else:
        env.pop(ENV_PLAN, None)
    return subprocess.run(
        [sys.executable, "-m", "repro.reliability._victim", scenario, str(workdir)],
        env=env,
        capture_output=True,
        timeout=VICTIM_TIMEOUT,
    )


def enumerate_sites(scenario: str, workdir) -> List[Tuple[str, int]]:
    """Record run: execute the scenario cleanly, return every kill site."""
    workdir = Path(workdir)
    proc = _spawn(scenario, workdir, FaultPlan(record=True))
    if proc.returncode != 0:
        raise RuntimeError(
            f"record run for {scenario!r} failed rc={proc.returncode}:\n"
            f"{proc.stderr.decode(errors='replace')}"
        )
    sites = json.loads((workdir / SITES_FILE).read_text())
    return [(name, int(occ)) for name, occ in sites]


def run_kill(scenario: str, workdir, point: str, occurrence: int) -> int:
    """One kill run: victim must die with SIGKILL at (point, occurrence)."""
    plan = FaultPlan().at(point, nth=occurrence, action="kill")
    proc = _spawn(scenario, Path(workdir), plan)
    return proc.returncode


# --------------------------------------------------------------- invariants
def _assert_arrays_match_version(
    got: Dict[str, np.ndarray], make, label: str
) -> int:
    for version in (0, 1):
        want = make(version)
        if set(got) == set(want) and all(
            np.array_equal(got[k], want[k]) for k in want
        ):
            return version
    raise AssertionError(f"{label}: survivor matches neither version 0 nor 1")


def check_invariants(scenario: str, workdir) -> dict:
    """Assert the scenario's recovery contract over a (possibly killed)
    workdir; returns which content version survived."""
    workdir = Path(workdir)
    if scenario == "shard_rewrite":
        from repro.data.shard_store import CompressedShardStore

        store = CompressedShardStore(workdir / "store")
        got = store.read_shard(0)  # promotes the aside if the kill left one
        version = _assert_arrays_match_version(got, shard_arrays, "shard 0")
        final = store.directory / "shard_000000"
        if not final.exists():
            raise AssertionError("canonical shard dir missing after recovery")
        names = {p.name for p in final.iterdir()}
        meta = json.loads((final / "meta.json").read_text())
        want_names = {f"{e['name']}.ozl" for e in meta["entries"]} | {"meta.json"}
        if names != want_names:
            raise AssertionError(
                f"orphan entries in shard dir: {sorted(names ^ want_names)}"
            )
        return {"scenario": scenario, "version": version}
    if scenario == "checkpoint":
        from repro.distributed import checkpoint as ck

        ckdir = workdir / "ckpt"
        step = ck.latest_step(ckdir)
        if step is None:
            raise AssertionError("no valid checkpoint survived the kill")
        leaves, _manifest = ck.restore_checkpoint(ckdir, step)  # CRC-verified
        version = 0 if step == 1 else 1
        want = ckpt_tree(version)
        if set(leaves) != set(want) or not all(
            np.array_equal(leaves[k], want[k]) for k in want
        ):
            raise AssertionError(f"restored step {step} is not version {version}")
        for d in ckdir.iterdir():
            # anything published (no .tmp suffix) must be a complete step
            if d.name.startswith("step_") and not d.name.endswith(".tmp"):
                if ck._valid_manifest(d) is None:
                    raise AssertionError(f"half-published checkpoint dir {d.name}")
        return {"scenario": scenario, "version": version, "step": step}
    if scenario == "atomic_sink":
        from repro.core import stream_io

        dst = workdir / "out.ozl"
        if not dst.exists():
            raise AssertionError("final output path vanished")
        out = io.BytesIO()
        stream_io.decompress_file(dst, out)  # fail-closed: any tear raises
        got = out.getvalue()
        for version in (0, 1):
            if got == sink_payload(version):
                return {"scenario": scenario, "version": version}
        raise AssertionError("final output is neither the old nor new payload")
    raise ValueError(f"unknown crash-kill scenario {scenario!r}")


# -------------------------------------------------------------------- sweep
def kill_sweep(
    base_dir,
    scenarios: Sequence[str] = SCENARIOS,
    *,
    max_workers: int = 8,
) -> dict:
    """Full sweep: enumerate every kill site per scenario, SIGKILL a fresh
    victim at each, assert recovery invariants every time.  Returns a summary
    (site counts, survivor-version histogram) for reporting."""
    base_dir = Path(base_dir)
    summary: dict = {"scenarios": {}, "total_sites": 0}
    for scenario in scenarios:
        sites = enumerate_sites(scenario, base_dir / scenario / "record")
        if not sites:
            raise AssertionError(f"{scenario}: record run saw no crash points")
        check_invariants(scenario, base_dir / scenario / "record")

        def one(i_site):
            i, (point, occ) = i_site
            workdir = base_dir / scenario / f"site_{i:03d}"
            rc = run_kill(scenario, workdir, point, occ)
            if rc != -signal.SIGKILL:
                raise AssertionError(
                    f"{scenario} site {point}#{occ}: victim exited rc={rc},"
                    f" expected SIGKILL — the kill rule never fired"
                )
            verdict = check_invariants(scenario, workdir)
            return point, occ, verdict

        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(one, enumerate(sites)))
        versions: Dict[int, int] = {}
        for _point, _occ, verdict in results:
            versions[verdict["version"]] = versions.get(verdict["version"], 0) + 1
        summary["scenarios"][scenario] = {
            "sites": len(sites),
            "survivor_versions": versions,
        }
        summary["total_sites"] += len(sites)
    return summary
