"""Crash-kill victim entry point (subprocess target — see ``crashkill.py``).

Runs one scenario in *this* process with the fault plan from the
``REPRO_FAULT_PLAN`` environment variable armed.  A ``kill`` rule terminates
the process with a real ``SIGKILL`` mid-operation; a ``record`` plan instead
completes cleanly and writes the enumerated kill sites for the harness.
"""
from __future__ import annotations

import sys


def main(argv) -> int:
    if len(argv) != 2:
        print(
            "usage: python -m repro.reliability._victim SCENARIO WORKDIR",
            file=sys.stderr,
        )
        return 2
    from .crashkill import run_victim

    run_victim(argv[0], argv[1])
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
