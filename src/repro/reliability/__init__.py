"""repro.reliability — deterministic fault injection and the resilience it
proves.

    Fault plane ......... repro.reliability.faults    (FaultPlan, fault_point)
    Degradation ......... repro.reliability.failover  (BackendHealth, Quarantine)
    Crash-kill sweeps ... repro.reliability.crashkill (subprocess SIGKILL harness)

Everything here is disarmed by default: with no :class:`FaultPlan` armed the
hooks cost one contextvar read and production behavior is untouched.
"""
from .faults import (  # noqa: F401
    FaultPlan,
    FaultRule,
    FaultyIO,
    InjectedFault,
    crash_point,
    current_plan,
    fault_point,
    wrap_io,
)
from .failover import BackendHealth, Quarantine  # noqa: F401
