"""Deterministic fault injection: seeded schedules of provoked failures.

The repo's crash-recovery machinery (rename-aside shard rewrites, atomic
sinks, fail-closed wire parsing, poisoned-session drops) was previously only
exercised by hand-written monkeypatches.  This module gives every one of
those seams a *named fault point* and a way to arm a reproducible schedule
of failures against them:

    plan = FaultPlan().at("io.sink.write", nth=3)        # 3rd sink write fails
    with plan.arm(all_threads=True):
        compress_file(src, dst, plan_)                   # raises InjectedFault

Principles (standing policy, see ROADMAP):

* **Disarmed by default, zero overhead.**  ``fault_point(name)`` is a single
  contextvar read (plus one module-global read) when no plan is armed; the
  file proxies in :func:`wrap_io` return the original object untouched.
  Production code paths never pay for the instrumentation.
* **Deterministic.**  Explicit rules fire on the *nth occurrence* of a named
  point (per-point counters), and seeded random rules draw from one
  ``random.Random(seed)`` in hit order — for a deterministic workload the
  same seed yields the same fault sequence.  (Points hit concurrently from
  worker threads are counted under a lock; their relative order is the
  workload's own scheduling.)
* **Faults look real.**  Injected errors are :class:`InjectedFault`
  (an ``IOError``) for I/O points, ``ConnectionResetError`` for ``drop``
  rules at protocol points, and a genuine ``SIGKILL`` for crash points —
  recovery code cannot tell them from the failures they model.

Actions
-------
``raise``  raise :class:`InjectedFault` (or the rule's ``exc`` factory)
``drop``   raise ``ConnectionResetError`` — a torn connection
``short``  at a :func:`wrap_io` write: write a partial prefix, then raise
           (a torn write); at a bare fault point, same as ``raise``
``kill``   ``SIGKILL`` the current process — for crash-recovery sweeps

Crash points are ordinary fault points hit at the named irreversible steps
(``shard.*``, ``ckpt.*``, ``sink.*``); :func:`crash_point` is an alias kept
for greppability.  A plan built with ``record=True`` fires nothing and
instead records every ``(point, occurrence)`` it sees — the crash-kill
harness (:mod:`repro.reliability.crashkill`) uses one recording run to
enumerate the kill sites it then SIGKILLs a victim subprocess at, one by one.
"""
from __future__ import annotations

import fnmatch
import json
import os
import signal
import threading
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from random import Random
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "InjectedFault",
    "FaultRule",
    "FaultPlan",
    "fault_point",
    "crash_point",
    "current_plan",
    "wrap_io",
    "FaultyIO",
]


class InjectedFault(IOError):
    """An error injected by an armed :class:`FaultPlan` (an I/O error to
    callers — recovery paths must treat it exactly like the real thing)."""


ACTIONS = ("raise", "drop", "short", "kill")


@dataclass
class FaultRule:
    """Fire ``action`` on the ``nth .. nth+times-1``-th occurrence of every
    point matching ``pattern`` (fnmatch; occurrences count per point name)."""

    pattern: str
    action: str = "raise"
    nth: int = 1
    times: int = 1
    exc: Optional[Callable[[str], BaseException]] = None

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.nth < 1 or self.times < 1:
            raise ValueError("nth and times are 1-based and positive")


class FaultPlan:
    """A seeded, deterministic schedule of injectable faults.

    Explicit rules (:meth:`at`) target exact occurrences; :meth:`every` adds
    a seeded random rule firing each matching hit with probability ``rate``.
    Arm with :meth:`arm` (a context manager); ``all_threads=True`` makes the
    plan visible to the engine's worker/draw threads (contextvars do not
    propagate into already-running pool threads).
    """

    def __init__(self, *, seed: Optional[int] = None, record: bool = False):
        self._rules: List[FaultRule] = []
        self._random_rules: List[Tuple[str, float, str]] = []
        self._rng = Random(seed)
        self.record = record
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self.fired: List[Tuple[str, int, str]] = []
        self.sites: List[Tuple[str, int]] = []

    # ------------------------------------------------------------- authoring
    def at(
        self,
        pattern: str,
        *,
        nth: int = 1,
        times: int = 1,
        action: str = "raise",
        exc: Optional[Callable[[str], BaseException]] = None,
    ) -> "FaultPlan":
        self._rules.append(FaultRule(pattern, action, nth, times, exc))
        return self

    def every(self, pattern: str, rate: float, *, action: str = "raise") -> "FaultPlan":
        """Seeded random rule: each matching hit fires with probability
        ``rate`` (drawn from this plan's RNG in hit order)."""
        if action not in ACTIONS:
            raise ValueError(f"unknown fault action {action!r}")
        if not (0.0 <= rate <= 1.0):
            raise ValueError("rate must be in [0, 1]")
        self._random_rules.append((pattern, rate, action))
        return self

    # -------------------------------------------------------------- arming
    @contextmanager
    def arm(self, *, all_threads: bool = False):
        """Arm this plan for the duration of the ``with`` block.

        Default visibility is the current context (contextvar); pass
        ``all_threads=True`` when the workload spans the engine's thread
        pools or any code path outside the arming context.
        """
        global _GLOBAL
        token = None
        if all_threads:
            with _GLOBAL_LOCK:
                if _GLOBAL is not None:
                    raise RuntimeError("another FaultPlan is already armed globally")
                _GLOBAL = self
        else:
            token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            if all_threads:
                with _GLOBAL_LOCK:
                    _GLOBAL = None
            else:
                _ACTIVE.reset(token)

    # ------------------------------------------------------------- matching
    def _hit(self, name: str) -> Optional[FaultRule]:
        with self._lock:
            k = self._counts.get(name, 0) + 1
            self._counts[name] = k
            if self.record:
                self.sites.append((name, k))
                return None
            for rule in self._rules:
                if (
                    rule.nth <= k < rule.nth + rule.times
                    and fnmatch.fnmatchcase(name, rule.pattern)
                ):
                    self.fired.append((name, k, rule.action))
                    return rule
            for pattern, rate, action in self._random_rules:
                if fnmatch.fnmatchcase(name, pattern):
                    if self._rng.random() < rate:
                        self.fired.append((name, k, action))
                        return FaultRule(pattern, action, k)
            return None

    # -------------------------------------------- subprocess victim support
    def to_json(self) -> str:
        """Serialize explicit rules (for arming a victim subprocess).  Random
        rules and custom ``exc`` factories are process-local and not carried."""
        return json.dumps(
            {
                "record": self.record,
                "rules": [
                    {
                        "pattern": r.pattern,
                        "action": r.action,
                        "nth": r.nth,
                        "times": r.times,
                    }
                    for r in self._rules
                ],
            }
        )

    @classmethod
    def from_json(cls, blob: str) -> "FaultPlan":
        spec = json.loads(blob)
        plan = cls(record=bool(spec.get("record", False)))
        for r in spec.get("rules", []):
            plan.at(
                r["pattern"],
                nth=int(r.get("nth", 1)),
                times=int(r.get("times", 1)),
                action=r.get("action", "raise"),
            )
        return plan


_ACTIVE: ContextVar[Optional[FaultPlan]] = ContextVar("repro_fault_plan", default=None)
_GLOBAL: Optional[FaultPlan] = None
_GLOBAL_LOCK = threading.Lock()


def _faults_after_fork() -> None:
    """Disarm any inherited global plan in a forked child.

    The service plane forks session workers; a plan armed in the parent must
    not silently fire inside them (their occurrence counters would diverge
    from the parent's, breaking determinism).  Workers that *should* fault
    arm an explicit plan of their own (``ServicePlane(worker_fault_json=)`` /
    the ``REPRO_FAULT_PLAN`` env var) after the fork.  Crash-kill victims are
    unaffected — they are spawned via exec, not fork.
    """
    global _GLOBAL, _GLOBAL_LOCK
    _GLOBAL = None
    _GLOBAL_LOCK = threading.Lock()


if hasattr(os, "register_at_fork"):  # pragma: no branch - POSIX in CI
    os.register_at_fork(after_in_child=_faults_after_fork)


def current_plan() -> Optional[FaultPlan]:
    plan = _ACTIVE.get()
    if plan is not None:
        return plan
    return _GLOBAL  # unlocked read: arming is rare, None is the fast path


def _perform(rule: FaultRule, name: str) -> None:
    if rule.action == "kill":
        os.kill(os.getpid(), signal.SIGKILL)
    if rule.exc is not None:
        raise rule.exc(name)
    if rule.action == "drop":
        raise ConnectionResetError(f"injected connection drop at {name!r}")
    raise InjectedFault(f"injected fault at {name!r}")


def fault_point(name: str) -> None:
    """Hook: a named place where an armed plan may inject a failure.

    No-op (one contextvar read) when nothing is armed.
    """
    plan = current_plan()
    if plan is None:
        return
    rule = plan._hit(name)
    if rule is not None:
        _perform(rule, name)


#: Crash points are fault points at irreversible steps (rename/replace/write
#: boundaries); the alias marks them for the crash-kill harness.
crash_point = fault_point


class FaultyIO:
    """A thin file proxy whose ``read``/``write`` hit ``<prefix>.read`` /
    ``<prefix>.write`` fault points.  A ``short`` rule on a write lands a
    partial prefix before raising — a torn write, as a crash or full disk
    would leave it."""

    def __init__(self, f, prefix: str):
        self._f = f
        self._prefix = prefix

    def write(self, data):
        plan = current_plan()
        if plan is not None:
            rule = plan._hit(self._prefix + ".write")
            if rule is not None:
                if rule.action == "short" and len(data) > 1:
                    self._f.write(data[: max(1, len(data) // 2)])
                    raise InjectedFault(
                        f"injected short write at {self._prefix + '.write'!r}"
                    )
                _perform(rule, self._prefix + ".write")
        return self._f.write(data)

    def read(self, n: int = -1):
        plan = current_plan()
        if plan is not None:
            rule = plan._hit(self._prefix + ".read")
            if rule is not None:
                _perform(rule, self._prefix + ".read")
        return self._f.read(n)

    def __getattr__(self, attr):
        return getattr(self._f, attr)


def wrap_io(f, prefix: str):
    """Wrap ``f`` in a :class:`FaultyIO` only while a plan is armed; the
    original object passes through untouched otherwise (zero overhead)."""
    if current_plan() is None:
        return f
    return FaultyIO(f, prefix)
