"""Backend quarantine and plan-digest quarantine for graceful degradation.

:class:`BackendHealth` is the duck-typed health object a
:class:`~repro.core.engine.CompressorSession` consults when an execution
backend fails mid-chunk: the failing chunk is transparently re-executed on
``host`` (bit-identical frames — the PR 6 conformance guarantee makes the
failover invisible on the wire), the failure is recorded here, and once the
failure count reaches ``threshold`` the backend is quarantined so later
chunks skip it without paying the failure.  After ``cooldown_s`` one probe
request is let through (half-open); a success re-opens the backend, another
failure re-quarantines it.

:class:`Quarantine` is the serving layer's per-key circuit breaker: a plan
digest whose sessions keep getting poisoned (``consecutive failures >=
threshold``) is quarantined for ``cooldown_s`` and requests for it get a
structured error instead of feeding a crash loop.  Any success resets the
count.

Both classes are self-contained (stdlib only) so the engine can accept them
without importing the service layer, and both take an injectable ``clock``
for deterministic tests.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

__all__ = ["BackendHealth", "Quarantine"]


class BackendHealth:
    """Failure accounting + quarantine per execution backend."""

    def __init__(
        self,
        *,
        threshold: int = 1,
        cooldown_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._failures: Dict[str, int] = {}
        self._successes: Dict[str, int] = {}
        self._failovers: Dict[str, int] = {}
        self._quarantined_at: Dict[str, float] = {}
        self._probing: Dict[str, bool] = {}

    def quarantined(self, backend: str) -> bool:
        """True when chunks should skip ``backend`` and go straight to host.

        After ``cooldown_s`` the first caller gets one probe (returns False
        once); the probe's outcome decides whether the quarantine lifts.
        """
        with self._lock:
            since = self._quarantined_at.get(backend)
            if since is None:
                return False
            if self._clock() - since < self.cooldown_s:
                return True
            if self._probing.get(backend):
                return True  # someone else holds the probe slot
            self._probing[backend] = True
            return False

    def record_failure(self, backend: str, exc: Optional[BaseException] = None) -> None:
        with self._lock:
            self._failures[backend] = self._failures.get(backend, 0) + 1
            self._failovers[backend] = self._failovers.get(backend, 0) + 1
            if self._probing.pop(backend, None):
                self._quarantined_at[backend] = self._clock()  # failed probe
            elif self._failures[backend] >= self.threshold:
                self._quarantined_at[backend] = self._clock()

    def record_success(self, backend: str) -> None:
        with self._lock:
            self._successes[backend] = self._successes.get(backend, 0) + 1
            if self._probing.pop(backend, None):
                self._quarantined_at.pop(backend, None)  # probe succeeded
                self._failures[backend] = 0

    def stats(self) -> Dict[str, dict]:
        with self._lock:
            backends = set(self._failures) | set(self._successes)
            return {
                b: {
                    "failures": self._failures.get(b, 0),
                    "successes": self._successes.get(b, 0),
                    "failovers": self._failovers.get(b, 0),
                    "quarantined": b in self._quarantined_at,
                }
                for b in sorted(backends)
            }


class Quarantine:
    """Circuit breaker keyed by an arbitrary string (the plan digest)."""

    def __init__(
        self,
        *,
        threshold: int = 3,
        cooldown_s: float = 10.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._consecutive: Dict[str, int] = {}
        self._opened_at: Dict[str, float] = {}
        self._trips: Dict[str, int] = {}

    def blocked(self, key: str) -> Optional[float]:
        """Seconds until the quarantine on ``key`` lifts, or None when open.

        Expiry admits the next request as a probe: its outcome (via
        :meth:`record_failure` / :meth:`record_success`) re-trips or clears.
        """
        with self._lock:
            opened = self._opened_at.get(key)
            if opened is None:
                return None
            remaining = self.cooldown_s - (self._clock() - opened)
            if remaining <= 0:
                del self._opened_at[key]
                # leave the consecutive count at threshold-1: one more
                # failure re-trips immediately, one success clears
                self._consecutive[key] = self.threshold - 1
                return None
            return remaining

    def record_failure(self, key: str) -> None:
        with self._lock:
            n = self._consecutive.get(key, 0) + 1
            self._consecutive[key] = n
            if n >= self.threshold and key not in self._opened_at:
                self._opened_at[key] = self._clock()
                self._trips[key] = self._trips.get(key, 0) + 1

    def record_success(self, key: str) -> None:
        with self._lock:
            self._consecutive.pop(key, None)
            self._opened_at.pop(key, None)

    def stats(self) -> Dict[str, dict]:
        with self._lock:
            keys = set(self._consecutive) | set(self._trips)
            return {
                k: {
                    "consecutive_failures": self._consecutive.get(k, 0),
                    "quarantined": k in self._opened_at,
                    "trips": self._trips.get(k, 0),
                }
                for k in sorted(keys)
            }
