"""MIND [arXiv:1904.08030; unverified]: embed 64, 4 interest capsules,
3 routing iterations, multi-interest retrieval; 1M-item catalogue."""
import dataclasses

from repro.models.recsys import MINDConfig

from .base import ArchSpec, register_arch
from .recsys_common import RECSYS_SHAPES

CFG = MINDConfig(
    name="mind",
    n_items=1_000_000,
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    seq_len=50,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="mind",
        family="recsys",
        source="arXiv:1904.08030; unverified",
        model_cfg=CFG,
        shapes=RECSYS_SHAPES,
        reduced_cfg=dataclasses.replace(
            CFG, n_items=500, embed_dim=16, seq_len=10,
        ),
    )
)
