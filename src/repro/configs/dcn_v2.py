"""DCN-v2 [arXiv:2008.13535; paper]: 13 dense + 26 sparse (embed 16),
3 cross layers, MLP 1024-1024-512."""
import dataclasses

from repro.models.recsys import DCNv2Config

from .base import ArchSpec, register_arch
from .recsys_common import RECSYS_SHAPES

CFG = DCNv2Config(
    name="dcn-v2",
    n_dense=13,
    n_sparse=26,
    vocab_per_field=1_000_000,
    embed_dim=16,
    n_cross_layers=3,
    mlp_sizes=(1024, 1024, 512),
)

SPEC = register_arch(
    ArchSpec(
        arch_id="dcn-v2",
        family="recsys",
        source="arXiv:2008.13535; paper",
        model_cfg=CFG,
        shapes=RECSYS_SHAPES,
        reduced_cfg=dataclasses.replace(
            CFG, n_dense=3, n_sparse=4, vocab_per_field=100, embed_dim=4,
            n_cross_layers=2, mlp_sizes=(16, 8),
        ),
    )
)
