"""xDeepFM [arXiv:1803.05170; paper]: 39 sparse fields, embed 10, CIN
200-200-200, DNN 400-400.  Criteo-scale hashed vocab 1e6/field: the 390M-row
shared embedding table is the hot path (model-axis row sharding)."""
import dataclasses

from repro.models.recsys import XDeepFMConfig

from .base import ArchSpec, register_arch
from .recsys_common import RECSYS_SHAPES

CFG = XDeepFMConfig(
    name="xdeepfm",
    n_sparse=39,
    vocab_per_field=1_000_000,
    embed_dim=10,
    cin_layers=(200, 200, 200),
    mlp_sizes=(400, 400),
)

SPEC = register_arch(
    ArchSpec(
        arch_id="xdeepfm",
        family="recsys",
        source="arXiv:1803.05170; paper",
        model_cfg=CFG,
        shapes=RECSYS_SHAPES,
        reduced_cfg=dataclasses.replace(
            CFG, n_sparse=5, vocab_per_field=100, embed_dim=4,
            cin_layers=(8, 8), mlp_sizes=(16,),
        ),
    )
)
