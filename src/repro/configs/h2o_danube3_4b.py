"""H2O-Danube-3-4B [arXiv:2401.16818; unverified]: llama+mistral mix with
sliding-window attention — 24L d3840 32H (kv=8) d_ff=10240 vocab 32000.
SWA (window 4096) makes decode sub-quadratic: long_500k RUNS for this arch
(ring-buffer KV cache of window size, not seq_len)."""
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, register_arch
from .lm_common import lm_shapes, reduced_lm

CFG = TransformerConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    sliding_window=4096,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="h2o-danube-3-4b",
        family="lm",
        source="arXiv:2401.16818; unverified",
        model_cfg=CFG,
        shapes=lm_shapes(sub_quadratic=True),
        reduced_cfg=reduced_lm(CFG),
        notes="SWA window 4096; long_500k decode cache is 4096 slots (ring)",
    )
)
