"""GraphCast [arXiv:2212.12794; unverified]: encoder-processor-decoder mesh
GNN — 16 processor layers, d_hidden 512, mesh refinement 6, sum aggregator,
227 variables.

The four GNN shapes exercise the same message-passing core on standard
benchmark graph regimes; per-shape feature/output dims follow the public
datasets the shapes are drawn from (cora / reddit / ogbn-products /
molecules).  ``minibatch_lg`` consumes padded subgraphs from the real
neighbour sampler in repro.data.sampler (fanout 15, 10).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp

from repro.models.gnn import GNNConfig

from .base import SDS, ArchSpec, ShapeSpec, register_arch

CFG = GNNConfig(
    name="graphcast",
    n_layers=16,
    d_hidden=512,
    d_in=227,
    d_out=227,
    d_edge_in=4,
    aggregator="sum",
    mesh_refinement=6,
)

# fanout 15-10 sampled-subgraph budget (padded static shapes)
_SEEDS = 1024
_HOP1 = _SEEDS * 15
_HOP2 = _HOP1 * 10
_MB_NODES = _SEEDS + _HOP1 + _HOP2  # 169,984
_MB_EDGES = _HOP1 + _HOP2  # 168,960

SHAPES = (
    ShapeSpec(
        "full_graph_sm",
        "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "d_out": 7},
    ),
    ShapeSpec(
        "minibatch_lg",
        "train",
        {
            "n_nodes": 232_965,
            "n_edges": 114_615_892,
            "batch_nodes": _SEEDS,
            "pad_nodes": _MB_NODES,
            "pad_edges": _MB_EDGES,
            "d_feat": 602,
            "d_out": 41,
        },
    ),
    ShapeSpec(
        "ogb_products",
        "train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "d_out": 47},
    ),
    ShapeSpec(
        "molecule",
        "train",
        {"batch": 128, "n_nodes": 30, "n_edges": 64, "d_feat": 32, "d_out": 1},
    ),
)


def gnn_cfg_for_shape(cfg: GNNConfig, shape: ShapeSpec) -> GNNConfig:
    """The shape's dataset fixes encoder/decoder dims."""
    return dataclasses.replace(
        cfg, d_in=shape.dims["d_feat"], d_out=shape.dims["d_out"]
    )


def gnn_input_specs(shape: ShapeSpec, *, reduced: bool = False) -> Dict[str, object]:
    d_feat, d_out = shape.dims["d_feat"], shape.dims["d_out"]
    if shape.name == "molecule":
        B = 4 if reduced else shape.dims["batch"]
        N = shape.dims["n_nodes"]
        E = shape.dims["n_edges"]
        return {
            "nodes": SDS((B, N, d_feat), jnp.float32),
            "edges": SDS((B, E, 2), jnp.int32),
            "edge_feats": SDS((B, E, 4), jnp.float32),
            "edge_mask": SDS((B, E), jnp.float32),
            "targets": SDS((B, N, d_out), jnp.float32),
        }
    if shape.name == "minibatch_lg":
        N = 2048 if reduced else shape.dims["pad_nodes"]
        E = 2048 if reduced else shape.dims["pad_edges"]
    else:
        N = min(shape.dims["n_nodes"], 256) if reduced else shape.dims["n_nodes"]
        E = min(shape.dims["n_edges"], 1024) if reduced else shape.dims["n_edges"]
    specs = {
        "nodes": SDS((N, d_feat), jnp.float32),
        "edges": SDS((E, 2), jnp.int32),
        "edge_feats": SDS((E, 4), jnp.float32),
        "targets": SDS((N, d_out), jnp.float32),
    }
    if shape.name == "minibatch_lg":
        specs["edge_mask"] = SDS((E,), jnp.float32)
        specs["node_mask"] = SDS((N,), jnp.float32)
    return specs


SPEC = register_arch(
    ArchSpec(
        arch_id="graphcast",
        family="gnn",
        source="arXiv:2212.12794; unverified",
        model_cfg=CFG,
        shapes=SHAPES,
        reduced_cfg=dataclasses.replace(
            CFG, n_layers=2, d_hidden=32, d_in=16, d_out=4, remat=False
        ),
        notes="message passing via segment_sum over edge index (DESIGN.md §3)",
    )
)
