"""Yi-9B [arXiv:2403.04652; hf]: llama-arch dense GQA — 48L d4096 32H
(kv=4) d_ff=11008 vocab 64000.  Full attention -> long_500k skipped."""
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, register_arch
from .lm_common import lm_shapes, reduced_lm

CFG = TransformerConfig(
    name="yi-9b",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="yi-9b",
        family="lm",
        source="arXiv:2403.04652; hf",
        model_cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        reduced_cfg=reduced_lm(CFG),
    )
)
