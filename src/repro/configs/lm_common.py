"""Shared shape set + input_specs for the LM-family transformers.

Shapes (assignment): train_4k (train), prefill_32k (inference-prefill),
decode_32k (one-token step with 32k KV cache), long_500k (524288-token
decode — sub-quadratic attention only; full-attention archs carry an
explicit skip reason, see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig, init_kv_cache

from .base import SDS, ArchSpec, ShapeSpec

FULL_ATTN_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is pure full "
    "attention (a 512k-KV full-attention decode is quadratic-cost) — skipped "
    "per assignment, see DESIGN.md §5"
)


def lm_shapes(sub_quadratic: bool) -> tuple:
    return (
        ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
        ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
        ShapeSpec(
            "long_500k",
            "decode",
            {"seq_len": 524288, "global_batch": 1},
            skip=None if sub_quadratic else FULL_ATTN_SKIP,
        ),
    )


def lm_input_specs(
    cfg: TransformerConfig, shape: ShapeSpec, *, reduced: bool = False
) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    S = shape.dims["seq_len"] if not reduced else min(shape.dims["seq_len"], 64)
    B = shape.dims["global_batch"] if not reduced else min(shape.dims["global_batch"], 2)
    if shape.kind == "train":
        return {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": SDS((B, S), jnp.int32)}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_kv_cache(cfg, B, S))
        return {
            "cache": cache,
            "tokens": SDS((B, 1), jnp.int32),
            "position": SDS((), jnp.int32),
        }
    raise ValueError(shape.kind)


def reduced_lm(cfg: TransformerConfig) -> TransformerConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.n_experts else cfg.top_k,
        sliding_window=16 if cfg.sliding_window else None,
        remat=False,
    )
