"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B; unverified]: 16L d2048 32H
(kv=8) d_ff=8192 vocab 128256, tied embeddings, rope theta 500k.
Full attention -> long_500k skipped."""
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, register_arch
from .lm_common import lm_shapes, reduced_lm

CFG = TransformerConfig(
    name="llama3.2-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="llama3.2-1b",
        family="lm",
        source="hf:meta-llama/Llama-3.2-1B; unverified",
        model_cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        reduced_cfg=reduced_lm(CFG),
    )
)
