"""Architecture configs: one module per assigned arch (``--arch <id>``).

  LM:     olmoe-1b-7b  kimi-k2-1t-a32b  yi-9b  h2o-danube-3-4b  llama3.2-1b
  GNN:    graphcast
  RecSys: xdeepfm  mind  sasrec  dcn-v2
"""
from .base import ArchSpec, ShapeSpec, all_archs, get_arch, register_arch  # noqa: F401
