"""Shared shape set + input_specs for the recsys family.

Shapes (assignment): train_batch (B=65,536 training), serve_p99 (B=512
online), serve_bulk (B=262,144 offline scoring), retrieval_cand (one query
against 1,000,000 candidates — a single batched matmul / bulk forward, never
a loop)."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .base import SDS, ShapeSpec

N_CANDIDATES = 1_000_000

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65_536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": N_CANDIDATES}),
)


def _b(shape: ShapeSpec, reduced: bool) -> int:
    return min(shape.dims.get("batch", 1), 8) if reduced else shape.dims.get("batch", 1)


def _nc(shape: ShapeSpec, reduced: bool) -> int:
    n = shape.dims.get("n_candidates", N_CANDIDATES)
    return min(n, 64) if reduced else n


def ctr_input_specs(
    shape: ShapeSpec, n_sparse: int, n_dense: int = 0, *, reduced: bool = False
) -> Dict[str, object]:
    """xDeepFM / DCN-v2 style (sparse-field CTR models)."""
    B = _b(shape, reduced)
    if shape.kind == "retrieval":
        # candidate scoring: item field varied across 1M candidates
        specs = {
            "base_ids": SDS((1, n_sparse), jnp.int32),
            "candidates": SDS((_nc(shape, reduced),), jnp.int32),
        }
        if n_dense:
            specs["dense"] = SDS((1, n_dense), jnp.float32)
        return specs
    specs = {"sparse_ids": SDS((B, n_sparse), jnp.int32)}
    if n_dense:
        specs["dense"] = SDS((B, n_dense), jnp.float32)
    if shape.kind == "train":
        specs["labels"] = SDS((B,), jnp.float32)
    return specs


def seq_input_specs(
    shape: ShapeSpec, seq_len: int, *, reduced: bool = False
) -> Dict[str, object]:
    """SASRec / MIND style (sequential models)."""
    B = _b(shape, reduced)
    S = min(seq_len, 10) if reduced else seq_len
    if shape.kind == "retrieval":
        return {
            "history": SDS((1, S), jnp.int32),
            "candidates": SDS((_nc(shape, reduced),), jnp.int32),
        }
    specs = {"history": SDS((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["target"] = SDS((B,), jnp.int32)
    return specs
