"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d2048 16H (GQA kv=16) d_ff=1024,
MoE 64 experts top-8, vocab 50304.  Full attention -> long_500k skipped."""
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, register_arch
from .lm_common import lm_shapes, reduced_lm

CFG = TransformerConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        source="arXiv:2409.02060; hf",
        model_cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        reduced_cfg=reduced_lm(CFG),
        notes="64-expert top-8 MoE; 1B active / 7B total params",
    )
)
