"""SASRec [arXiv:1808.09781; paper]: embed 50, 2 blocks, 1 head, seq 50,
self-attention over item history; 1M-item catalogue."""
import dataclasses

from repro.models.recsys import SASRecConfig

from .base import ArchSpec, register_arch
from .recsys_common import RECSYS_SHAPES

CFG = SASRecConfig(
    name="sasrec",
    n_items=1_000_000,
    embed_dim=50,
    n_blocks=2,
    n_heads=1,
    seq_len=50,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="sasrec",
        family="recsys",
        source="arXiv:1808.09781; paper",
        model_cfg=CFG,
        shapes=RECSYS_SHAPES,
        reduced_cfg=dataclasses.replace(
            CFG, n_items=500, embed_dim=16, seq_len=10,
        ),
    )
)
