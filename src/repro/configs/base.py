"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``) with its own input-shape set, a reduced smoke variant, and
``input_specs()`` returning ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the multi-pod dry-run."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode" | "serve" | "retrieval"
    dims: Dict[str, int]
    skip: Optional[str] = None  # reason string when the cell is inapplicable


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "gnn" | "recsys"
    source: str  # citation tag from the assignment
    model_cfg: Any
    shapes: Tuple[ShapeSpec, ...]
    reduced_cfg: Any  # smoke-test configuration
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}")

    def runnable_shapes(self) -> List[ShapeSpec]:
        return [s for s in self.shapes if s.skip is None]


_REGISTRY: Dict[str, ArchSpec] = {}


def register_arch(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _load_all()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}"
        ) from None


def all_archs() -> Dict[str, ArchSpec]:
    _load_all()
    return dict(_REGISTRY)


_loaded = False


def _load_all() -> None:
    global _loaded
    if not _loaded:
        _loaded = True
        from repro.configs import (  # noqa: F401
            dcn_v2,
            graphcast,
            h2o_danube3_4b,
            kimi_k2_1t_a32b,
            llama3_2_1b,
            mind,
            olmoe_1b_7b,
            sasrec,
            xdeepfm,
            yi_9b,
        )
