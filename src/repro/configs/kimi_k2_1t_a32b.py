"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table]: 61L d7168
64H (GQA kv=8) d_ff=2048/expert, MoE 384 experts top-8, vocab 163840.
Trillion-param MoE: 32B active.  Full attention -> long_500k skipped."""
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, register_arch
from .lm_common import lm_shapes, reduced_lm

CFG = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
)

SPEC = register_arch(
    ArchSpec(
        arch_id="kimi-k2-1t-a32b",
        family="lm",
        source="arXiv:2501.kimi2; unverified",
        model_cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        reduced_cfg=reduced_lm(CFG),
        notes="~1T total params; Adafactor + bf16 recommended (see DESIGN.md)",
    )
)
