"""The universal command-line interface: ``python -m repro``.

The shell-facing twin of OpenZL's ``zli`` tool: any named profile or
serialized trained plan compresses any file into the self-describing wire
format, and *every* frame — whoever produced it, whatever graph it embeds —
decompresses and inspects with the same two subcommands, no out-of-band
configuration.

    python -m repro compress  corpus.bin -o corpus.ozl --profile text
    python -m repro inspect   corpus.ozl
    python -m repro decompress corpus.ozl -o corpus.out
    python -m repro profiles
    python -m repro train     samples/*.bin --out plan.ozp

``train`` is the ``zli-train`` analogue (paper §VI-C): it sniffs the sample
format (``--frontend auto``: csv / struct / numeric / raw), runs the
parallel NSGA-II trainer over a persistent session-backed worker pool, and
writes deployable ``.ozp`` plans that ``compress --plan`` consumes directly.
Training is deterministic: the same ``--seed`` yields byte-identical plans
for any ``--workers`` value.

Compression streams through a :class:`~repro.core.engine.CompressorSession`
(bounded in-flight window; the file is never fully loaded), so arbitrarily
large inputs run in ~``window × chunk_bytes`` memory.  ``inspect`` parses the
embedded graph and stored streams structurally without decoding any payload.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

from repro import codecs as _codecs  # noqa: F401  (registers the codec suite)
from repro.core import Compressor, CompressionCtx, stream_io, wire
from repro.core.codec import get_codec_by_id
from repro.core.graph import Plan
from repro.core.message import SType
from repro.core.versioning import CURRENT_FORMAT_VERSION

__all__ = ["main", "named_profiles"]


# ------------------------------------------------------------------ profiles
def named_profiles() -> Dict[str, Tuple[Callable[[], Plan], str]]:
    """Parameterless named profiles: name -> (factory, one-line description)."""
    from repro.codecs.profiles import named_profiles as _named

    return _named()


def _profile_plan(spec: str) -> Plan:
    """Resolve ``--profile``: a named profile, ``struct:W1,W2,..`` or ``csv:N``."""
    from repro.codecs.profiles import resolve_profile_spec

    try:
        return resolve_profile_spec(spec)
    except ValueError as err:
        raise SystemExit(str(err)) from None


def _parse_size(text: str) -> int:
    t = text.strip()
    mult = 1
    for suffix, m in (
        ("KIB", 1 << 10), ("MIB", 1 << 20), ("GIB", 1 << 30),
        ("KB", 10 ** 3), ("MB", 10 ** 6), ("GB", 10 ** 9),
        ("K", 1 << 10), ("M", 1 << 20), ("G", 1 << 30),
    ):
        if t.upper().endswith(suffix):
            mult = m
            t = t[: -len(suffix)]
            break
    try:
        return int(float(t) * mult)
    except ValueError:
        raise SystemExit(f"bad size {text!r} (try 1048576, 4MiB, 64K, ...)") from None


def _load_compressor(args) -> Compressor:
    if args.plan:
        blob = Path(args.plan).read_bytes()
        comp = Compressor.deserialize(blob)
    else:
        comp = Compressor(_profile_plan(args.profile))
    if args.level is not None:
        comp.level = args.level
    if args.format_version is not None:
        comp.format_version = args.format_version
    return comp


# --------------------------------------------------------------- subcommands
def _cmd_compress(args) -> int:
    src = Path(args.input)
    dst = Path(args.output) if args.output else src.with_name(src.name + ".ozl")
    comp = _load_compressor(args)
    ctx = CompressionCtx(comp.format_version, comp.level)
    stats = stream_io.compress_file(
        src,
        dst,
        comp.plan,
        ctx=ctx,
        backend=args.backend,
        chunk_bytes=_parse_size(args.chunk_bytes),
        n_workers=args.workers,
        window=args.window,
    )
    ratio = stats["bytes_in"] / max(stats["bytes_out"], 1)
    kind = "container" if stats["container"] else "frame"
    print(
        f"{src} -> {dst}: {stats['bytes_in']} -> {stats['bytes_out']} bytes"
        f" (x{ratio:.2f}), {stats['chunks']} chunk(s), {kind},"
        f" plan={comp.name or comp.plan.name or 'anonymous'}"
    )
    return 0


def _cmd_decompress(args) -> int:
    src = Path(args.input)
    if args.output:
        dst = Path(args.output)
    elif src.suffix == ".ozl":
        dst = src.with_suffix("")
    else:
        dst = src.with_name(src.name + ".out")
    stats = stream_io.decompress_file(
        src, dst, n_workers=args.workers, window=args.window, salvage=args.salvage
    )
    print(
        f"{src} -> {dst}: {stats['bytes_in']} -> {stats['bytes_out']} bytes,"
        f" {stats['chunks']} chunk(s)"
    )
    rep = stats.get("salvage")
    if rep is not None:
        report = wire.SalvageReport(
            n_chunks=rep["n_chunks"],
            recovered=list(rep["recovered"]),
            recovered_unplaced=rep["recovered_unplaced"],
            damaged=[tuple(r) for r in rep["damaged"]],
            trailer_ok=rep["trailer_ok"],
            notes=list(rep["notes"]),
        )
        print(f"salvage: {report.summary()}")
        if not rep["intact"]:
            # recovered-with-losses is distinguishable from a clean decode
            print("salvage: output is PARTIAL (see damaged ranges)", file=sys.stderr)
            return 1
    return 0


_STYPE_NAMES = {t: t.name for t in SType}


def _codec_name(codec_id: int) -> str:
    try:
        return get_codec_by_id(codec_id).name
    except KeyError:
        return f"codec#{codec_id}"


def _print_frame(frame: bytes, indent: str = "") -> None:
    """Pretty-print one frame's embedded graph — payloads are never decoded.

    Each node is annotated with its *inferred* input/output stream types
    (``repro.analysis`` abstract interpretation over the codec signatures),
    still without touching any payload bytes.
    """
    from repro.analysis import annotate_resolved_nodes

    version, n_inputs, nodes, stored = wire.read_frame(frame)
    print(
        f"{indent}frame v{version}: {len(frame)} bytes, {n_inputs} input(s),"
        f" {len(nodes)} codec node(s), {len(stored)} stored stream(s)"
    )
    node_types, _report = annotate_resolved_nodes(
        n_inputs, nodes, format_version=version
    )
    for i, node in enumerate(nodes):
        ins = ",".join(map(str, node.inputs))
        in_t, out_t = node_types[i]
        print(
            f"{indent}  node {i:3d}  {_codec_name(node.codec_id):<20}"
            f" in=[{ins}] out={node.n_out} header={len(node.header)}B"
            f"  :: {in_t or '-'} -> {out_t or '-'}"
        )
    payload_total = 0
    for eid in sorted(stored):
        s = stored[eid]
        payload = s.data.nbytes
        payload_total += payload
        extra = f" strings={s.n_elts}" if s.stype == SType.STRING else ""
        print(
            f"{indent}  edge {eid:4d}  {_STYPE_NAMES[s.stype]:<8} w={s.width}"
            f" n={s.n_elts} payload={payload}B{extra}"
        )
    print(f"{indent}  stored payload total: {payload_total}B")


def _cmd_inspect(args) -> int:
    path = Path(args.input)
    if args.verify:
        # per-chunk CRC walk without materializing any payload: damage is
        # reported chunk-exact and the exit code is the verdict
        with open(path, "rb") as f:
            report = wire.verify_container(f)
        print(f"{path}: {report.summary()}")
        return 0 if report.intact else 1
    with open(path, "rb") as f:
        magic = f.read(4)
        f.seek(0)
        if magic == wire.CONTAINER_MAGIC:
            sizes = []
            shown = 0
            # allow_empty: inspect is structural — it must tolerate a foreign
            # zero-chunk container even though our writers refuse to emit one
            for i, chunk in enumerate(
                wire.iter_container_frames(f, allow_empty=True)
            ):
                sizes.append(len(chunk))
                if shown < args.chunks:
                    print(f"chunk {i}:")
                    _print_frame(chunk, indent="  ")
                    shown += 1
            total = path.stat().st_size
            if not sizes:
                print(
                    f"container: 0 chunk(s), {total} bytes total"
                    " (empty container: no data, nothing to decode)"
                )
                return 0
            print(
                f"container: {len(sizes)} chunk(s), {total} bytes total,"
                f" chunk frames min/median/max ="
                f" {min(sizes)}/{sorted(sizes)[len(sizes)//2]}/{max(sizes)}B"
            )
            if shown < len(sizes):
                print(f"(graphs shown for first {shown}; --chunks N for more)")
        elif magic == wire.MAGIC:
            _print_frame(f.read())
        else:
            print(f"{path}: not an OZLJ frame or OZLC container", file=sys.stderr)
            return 2
    return 0


# ------------------------------------------------------------------ training
def _parse_frontend(spec: str, first_sample: bytes):
    """Resolve ``--frontend``: auto-sniffing or an explicit frontend form."""
    from repro.codecs.parse import sniff_csv
    from repro.training import (
        CsvFrontend,
        Frontend,
        GraphFrontend,
        NumericFrontend,
        StructFrontend,
        detect_frontend,
    )

    if spec == "auto":
        return detect_frontend(first_sample)
    if spec == "raw":
        return Frontend()
    if spec == "csv" or spec.startswith("csv:"):
        parts = spec.split(":")
        sep = parts[2] if len(parts) > 2 else ","
        if len(parts) > 1 and parts[1]:
            return CsvFrontend(n_cols=int(parts[1]), sep=sep)
        sniffed = sniff_csv(first_sample, seps=(sep.encode(),))
        if sniffed is None:
            raise SystemExit(
                f"--frontend csv: samples are not rectangular {sep!r}-separated"
                f" CSV; pass csv:N to force a column count"
            )
        return CsvFrontend(n_cols=sniffed[0], sep=sniffed[1])
    if spec.startswith("struct:"):
        widths = tuple(int(w) for w in spec[len("struct:") :].split(",") if w)
        if not widths or any(w < 1 for w in widths):
            raise SystemExit(f"--frontend {spec!r}: field widths must be >= 1")
        return StructFrontend(widths=widths)
    if spec == "numeric" or spec.startswith("numeric:"):
        width = int(spec.split(":")[1]) if ":" in spec else 4
        if width not in (1, 2, 4, 8):
            raise SystemExit(f"--frontend {spec!r}: width must be 1/2/4/8")
        return NumericFrontend(width=width)
    if spec == "graph" or spec.startswith("graph:"):
        parts = spec.split(":")
        if len(parts) > 1 and parts[1] == "bin":
            try:
                width = int(parts[2]) if len(parts) > 2 and parts[2] else 4
            except ValueError:
                raise SystemExit(f"--frontend {spec!r}: bad pair width") from None
            if width not in (2, 4, 8) or len(parts) > 3:
                raise SystemExit(
                    f"--frontend {spec!r}: expected graph:bin:W with W in 2/4/8"
                )
            return GraphFrontend(binary_width=width)
        sep = ":".join(parts[1:]) if len(parts) > 1 else "auto"
        if not sep or "\n" in sep or "\r" in sep:
            raise SystemExit(
                f"--frontend {spec!r}: separator must be non-empty, newline-free"
            )
        return GraphFrontend(sep=sep)
    raise SystemExit(
        f"unknown frontend {spec!r}; known: auto, raw, csv[:N[:sep]],"
        f" struct:W1,W2,.., numeric[:W], graph[:sep], graph:bin[:W]"
    )


def _trim_sample(frontend, blob: bytes) -> bytes:
    """Cut a sample so the frontend parses it whole (line/record aligned)."""
    name = getattr(frontend, "name", "raw")
    if name == "csv":
        cut = blob.rfind(b"\n")
        return blob[: cut + 1] if cut >= 0 else blob
    if name == "numeric":
        return blob[: len(blob) - len(blob) % frontend.width]
    if name == "struct":
        rec = sum(frontend.widths) or 1
        return blob[: len(blob) - len(blob) % rec]
    if name == "graph":
        if frontend.binary_width:
            pair = 2 * frontend.binary_width
            return blob[: len(blob) - len(blob) % pair]
        cut = blob.rfind(b"\n")
        return blob[: cut + 1] if cut >= 0 else blob
    return blob


def _frontend_desc(frontend) -> str:
    name = getattr(frontend, "name", "raw")
    if name == "csv":
        return f"csv ({frontend.n_cols} cols, sep {frontend.sep!r})"
    if name == "numeric":
        return f"numeric (width {frontend.width})"
    if name == "struct":
        return f"struct (record {sum(frontend.widths)}B, {len(frontend.widths)} fields)"
    if name == "graph":
        if frontend.binary_width:
            return f"graph (binary pairs, width {frontend.binary_width})"
        return f"graph (edge list, sep {frontend.sep!r})"
    return name


def _cmd_train(args) -> int:
    from repro.core.message import serial
    from repro.training import train

    paths = [Path(p) for p in args.samples]
    limit = _parse_size(args.sample_bytes)
    blobs = [p.read_bytes()[:limit] for p in paths]
    if not blobs or not any(blobs):
        raise SystemExit("train: no sample bytes")
    frontend = _parse_frontend(args.frontend, blobs[0])
    blobs = [_trim_sample(frontend, b) for b in blobs]
    blobs = [b for b in blobs if b]
    if not blobs:
        raise SystemExit(
            "train: no usable sample bytes after frontend alignment"
            f" ({_frontend_desc(frontend)})"
        )
    total = sum(len(b) for b in blobs)
    print(
        f"training on {len(blobs)} sample(s), {total} bytes,"
        f" frontend: {_frontend_desc(frontend)}"
    )
    tc = train(
        [[serial(b)] for b in blobs],
        frontend,
        pop_size=args.pop,
        generations=args.gens,
        n_points=args.points,
        seed=args.seed,
        workers=args.workers,
        verbose=args.verbose,
    )
    st = tc.stats
    print(
        f"trained in {st['train_seconds']:.1f}s: {st['evaluations']:.0f} candidate"
        f" evaluations on {st['workers']:.0f} worker(s)"
        f" ({st['eval_wall_seconds']:.1f}s candidate encode time),"
        f" {st['n_streams']:.0f} stream(s) -> {st['n_clusters']:.0f} cluster(s)"
    )
    plans = tc.pareto_plans()  # size-ascending (best ratio first)
    if not plans:
        raise SystemExit(
            "train: no Pareto point survived training — nothing to emit"
            " (try more samples, a higher --pop, or more --gens)"
        )
    print("pareto tradeoff points (training-sample size vs encode-cost estimate):")
    for i, (plan, sz, tm) in enumerate(plans):
        print(f"  [{i}] {sz:>10.0f} B  {tm * 1e3:>8.2f} ms  {len(plan.nodes)} codec node(s)")

    out = Path(args.out) if args.out else paths[0].with_suffix(".ozp")
    emitted = []
    for i, (plan, _sz, _tm) in enumerate(plans):
        if i == 0:
            path = out
        elif args.all_points:
            path = out.with_name(f"{out.stem}.p{i}{out.suffix or '.ozp'}")
        else:
            continue
        comp = Compressor(plan, level=args.level if args.level is not None else 5)
        if not all(comp.roundtrip_check(b) for b in blobs):
            raise SystemExit(f"train: point {i} failed the losslessness check")
        with stream_io._atomic_sink(path) as f:
            f.write(comp.serialize())
        emitted.append((i, path))
    if not emitted:
        raise SystemExit(
            "train: no plan emitted (every tradeoff point was skipped)"
        )
    for i, path in emitted:
        tag = "best-ratio point" if i == 0 else f"tradeoff point {i}"
        print(f"wrote {path} ({path.stat().st_size} bytes, {tag}; verified lossless)")
    print(f"deploy with: python -m repro compress FILE --plan {emitted[0][1]}")
    return 0


def _cmd_lint(args) -> int:
    """Static plan analysis: type-check ``.ozp`` plans / profile specs.

    Exit 0 when every target is error-free (warnings and infos don't fail
    the lint), 1 when any target has a type error, 2 on unreadable targets.
    """
    import json as _json

    from repro.analysis import check_plan
    from repro.codecs.profiles import resolve_profile_spec
    from repro.core.serialize import deserialize_plan

    results = []
    broken = False
    for target in args.targets:
        path = Path(target)
        try:
            if path.exists():
                plan, meta = deserialize_plan(path.read_bytes())
                fv = meta.get("format_version")
            else:  # not a file: treat as a profile spec (`generic`, `csv:3`)
                plan, fv = resolve_profile_spec(target), None
        except (ValueError, KeyError, OSError) as err:
            broken = True
            results.append({"target": str(target), "ok": False,
                            "load_error": str(err), "diagnostics": []})
            continue
        report = check_plan(plan, format_version=fv)
        results.append({"target": str(target), **report.to_dict()})

    n_err = sum(
        1 for r in results
        for d in r["diagnostics"] if d["severity"] == "error"
    )
    if args.json:
        print(_json.dumps({"targets": results, "errors": n_err}, indent=1))
    else:
        for r in results:
            verdict = "clean" if r["ok"] else "FAILED"
            print(f"{r['target']}: {verdict}")
            if r.get("load_error"):
                print(f"  unreadable: {r['load_error']}")
            for d in r["diagnostics"]:
                loc = "".join(
                    f" {k} {d[k]}" for k in ("node", "edge") if k in d
                )
                print(f"  {d['severity']}[{d['code']}]{loc}: {d['message']}")
    if broken:
        return 2
    return 1 if n_err else 0


def _cmd_profiles(_args) -> int:
    for name, (_fn, doc) in sorted(named_profiles().items()):
        print(f"{name:<12} {doc}")
    print("struct:W1,..  Generic record format: field_split + per-field auto backend.")
    print("csv:N[:sep]   CSV frontend + per-column parse_numeric + auto backends.")
    print("graph:bin:W   Binary edge-list frontend: interleaved width-W (u, v) pairs.")
    return 0


# ------------------------------------------------------------------- service
def _service_address(args) -> str:
    if args.socket and args.tcp:
        raise SystemExit("pass --socket or --tcp, not both")
    if args.socket:
        return f"unix:{args.socket}"
    if args.tcp:
        return args.tcp
    raise SystemExit("pass --socket PATH or --tcp HOST:PORT")


def _cmd_serve(args) -> int:
    import signal

    from repro.service import CompressionServer, PlanRegistry

    import socket as _socket

    from repro.service.protocol import parse_address

    spec = _service_address(args)  # exactly one of --socket / --tcp
    try:
        family, target = parse_address(spec)
    except ValueError as err:
        raise SystemExit(str(err)) from None
    registry = PlanRegistry()
    try:
        for spec in args.profile or []:
            entry = registry.register_profile(spec)
            print(f"registered profile {entry.plan_id} (digest {entry.digest[:12]})")
        for path in args.register or []:
            entry = registry.register_file(path)
            print(
                f"registered plan {entry.plan_id} from {path}"
                f" (digest {entry.digest[:12]})"
            )
    except (ValueError, OSError) as err:
        raise SystemExit(f"serve: {err}") from None
    if not len(registry):
        print("warning: no plans registered; only decompress/stats will work")

    kw = dict(
        max_clients=args.max_clients,
        sessions_per_plan=args.sessions_per_plan,
        n_workers=args.session_threads,
        window=args.window,
        request_timeout=args.timeout,
        idle_timeout=args.idle_timeout,
        admission_timeout=args.admission_timeout,
        backend=args.backend,
        rate_limit=args.rate_limit,
        rate_burst=args.rate_burst,
    )
    if family == _socket.AF_UNIX:
        addr_kw = dict(socket_path=target)
    else:
        host, port = target
        addr_kw = dict(host=host, port=port)

    if args.workers and args.workers > 0:
        # multi-core plane: pre-forked session workers on a shared listener
        import os as _os
        import threading as _threading

        from repro.service import ServicePlane

        plane = ServicePlane(
            registry,
            workers=args.workers,
            # chaos harnesses arm worker fault plans through the standard env
            worker_fault_json=_os.environ.get("REPRO_FAULT_PLAN"),
            **addr_kw,
            **kw,
        )
        stop = _threading.Event()

        def _stop(_sig, _frm):
            stop.set()

        signal.signal(signal.SIGINT, _stop)
        signal.signal(signal.SIGTERM, _stop)
        plane.start()
        print(
            f"serving on {plane.address} ({len(registry)} plan(s),"
            f" {args.workers} worker process(es); ^C to stop)"
        )
        sys.stdout.flush()
        try:
            stop.wait()
        finally:
            plane.shutdown()
            print("server stopped")
        return 0

    server = CompressionServer(registry, **addr_kw, **kw)

    def _stop(_sig, _frm):
        server.request_stop()

    signal.signal(signal.SIGINT, _stop)
    signal.signal(signal.SIGTERM, _stop)
    print(f"serving on {server.address} ({len(registry)} plan(s); ^C to stop)")
    sys.stdout.flush()
    try:
        server.serve_forever()
    finally:
        server.shutdown()
        print("server stopped")
    return 0


def _cmd_client(args) -> int:
    from repro.service import ServiceClient

    address = _service_address(args)
    with ServiceClient(address, timeout=args.timeout, retries=args.retries) as client:
        if args.action == "stats":
            import json as _json

            print(_json.dumps(client.stats(), indent=2, sort_keys=True))
            return 0
        if args.action == "metrics":
            sys.stdout.write(client.metrics().decode())
            return 0
        if args.action == "ping":
            info = client.ping()
            print(
                f"{address}: ok, protocol v{info['protocol_version']},"
                f" {info['plans']} plan(s), up {info['uptime_s']}s"
            )
            return 0
        if not args.input:
            raise SystemExit(f"client {args.action} needs an input file")
        src = Path(args.input)
        if args.action == "compress":
            if not args.plan_id:
                raise SystemExit("client compress needs --plan-id")
            dst = Path(args.output) if args.output else src.with_name(src.name + ".ozl")
            stats = client.compress_file(
                src, dst, args.plan_id, chunk_bytes=_parse_size(args.chunk_bytes)
            )
            ratio = stats["bytes_in"] / max(stats["bytes_out"], 1)
            kind = "container" if stats["container"] else "frame"
            print(
                f"{src} -> {dst}: {stats['bytes_in']} -> {stats['bytes_out']}"
                f" bytes (x{ratio:.2f}), {stats['chunks']} chunk(s), {kind},"
                f" plan={stats['plan_id']} digest={stats['digest'][:12]}"
            )
        else:  # decompress
            if args.output:
                dst = Path(args.output)
            elif src.suffix == ".ozl":
                dst = src.with_suffix("")
            else:
                dst = src.with_name(src.name + ".out")
            stats = client.decompress_file(src, dst)
            print(
                f"{src} -> {dst}: {stats['bytes_in']} -> {stats['bytes_out']}"
                f" bytes, {stats['chunks']} chunk(s)"
            )
    return 0


# -------------------------------------------------------------------- parser
def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="OpenZL-style graph compression: universal compress /"
        " decompress / inspect over the self-describing wire format.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("compress", help="compress a file with a profile or plan")
    c.add_argument("input")
    c.add_argument("-o", "--output", default=None, help="default: INPUT.ozl")
    g = c.add_mutually_exclusive_group()
    g.add_argument("--profile", default="generic", help="named profile (see"
                   " `profiles`), struct:W1,W2,.., csv:N[:sep] or graph[:bin:W]")
    g.add_argument("--plan", default=None, help="serialized trained plan (.ozp)")
    c.add_argument("--chunk-bytes", default="4MiB", help="chunk size for the"
                   " streaming container; 0 = single frame (default 4MiB)")
    c.add_argument("--backend", default="host", help="execution backend"
                   " (host/device)")
    c.add_argument("--level", type=int, default=None, help="effort 1-9")
    c.add_argument("--format-version", type=int, default=None,
                   help=f"wire format version (default {CURRENT_FORMAT_VERSION})")
    c.add_argument("--workers", type=int, default=None, help="encode threads")
    c.add_argument("--window", type=int, default=None,
                   help="max in-flight chunks (bounds peak memory)")
    c.set_defaults(fn=_cmd_compress)

    d = sub.add_parser("decompress", help="universal decode of any frame")
    d.add_argument("input")
    d.add_argument("-o", "--output", default=None,
                   help="default: strip .ozl, else INPUT.out")
    d.add_argument("--workers", type=int, default=None, help="decode threads")
    d.add_argument("--window", type=int, default=None,
                   help="max in-flight chunks (bounds peak memory)")
    d.add_argument("--salvage", action="store_true",
                   help="best-effort recovery of a damaged container: write"
                   " every intact chunk, report lost ranges, exit 1 on losses"
                   " (default: fail closed on any corruption)")
    d.set_defaults(fn=_cmd_decompress)

    i = sub.add_parser(
        "inspect", help="print a frame's embedded graph without decompressing"
    )
    i.add_argument("input")
    i.add_argument("--chunks", type=int, default=1,
                   help="container chunks to print graphs for (default 1)")
    i.add_argument("--verify", action="store_true",
                   help="walk every chunk's CRC (no payload decode); nonzero"
                   " exit + damage report when anything fails")
    i.set_defaults(fn=_cmd_inspect)

    t = sub.add_parser(
        "train", help="train a compressor from sample files (paper §VI-C)"
    )
    t.add_argument("samples", nargs="+", help="sample files (one input each)")
    t.add_argument("--out", default=None,
                   help="output plan path (default: FIRST_SAMPLE.ozp)")
    t.add_argument("--frontend", default="auto",
                   help="auto (sniff graph/csv/struct/numeric/raw), raw,"
                   " csv[:N[:sep]], struct:W1,W2,.., numeric[:W],"
                   " graph[:sep], graph:bin[:W]")
    t.add_argument("--pop", type=int, default=16, help="NSGA-II population")
    t.add_argument("--gens", type=int, default=6, help="NSGA-II generations")
    t.add_argument("--points", type=int, default=8,
                   help="max Pareto tradeoff points kept")
    t.add_argument("--seed", type=int, default=0,
                   help="training seed (same seed => byte-identical plans)")
    t.add_argument("--workers", type=int, default=None,
                   help="evaluation threads (default: all CPUs)")
    t.add_argument("--level", type=int, default=None,
                   help="effort 1-9 recorded in the emitted plan")
    t.add_argument("--sample-bytes", default="4MiB",
                   help="per-file training sample cap (default 4MiB)")
    t.add_argument("--all-points", action="store_true",
                   help="also write every tradeoff point as OUT.pN.ozp")
    t.add_argument("-v", "--verbose", action="store_true")
    t.set_defaults(fn=_cmd_train)

    p = sub.add_parser("profiles", help="list named profiles")
    p.set_defaults(fn=_cmd_profiles)

    ln = sub.add_parser(
        "lint", help="static type-check of .ozp plans / profile specs"
    )
    ln.add_argument("targets", nargs="+", metavar="PLAN.ozp|PROFILE",
                    help="serialized plan files or profile specs to check")
    ln.add_argument("--json", action="store_true",
                    help="machine-readable diagnostics")
    ln.set_defaults(fn=_cmd_lint)

    s = sub.add_parser(
        "serve", help="run the compression daemon (paper §VIII services)"
    )
    s.add_argument("--socket", default=None, help="Unix socket path to bind")
    s.add_argument("--tcp", default=None, help="HOST:PORT to bind (TCP)")
    s.add_argument("--register", action="append", metavar="PLAN.ozp",
                   help="serialized trained plan to register (repeatable;"
                   " id = file stem)")
    s.add_argument("--profile", action="append", metavar="NAME",
                   help="named profile to register (repeatable; id = name)")
    s.add_argument("--max-clients", type=int, default=8,
                   help="concurrent connections served (default 8; per worker"
                        " process with --workers)")
    s.add_argument("--sessions-per-plan", type=int, default=2,
                   help="compressor sessions pooled per plan (default 2)")
    s.add_argument("--workers", type=int, default=0,
                   help="session-worker processes sharing the listener"
                        " (default 0: single-process threaded server); each"
                        " owns its own session pool and caches")
    s.add_argument("--session-threads", type=int, default=None,
                   help="encode/decode threads per compression session")
    s.add_argument("--rate-limit", type=float, default=None,
                   help="per-client token-bucket rate (requests/second) for"
                        " compress/decompress; rejected requests carry"
                        " error_kind=rate_limited + retry_after")
    s.add_argument("--rate-burst", type=float, default=None,
                   help="token-bucket burst capacity (default 2x rate)")
    s.add_argument("--window", type=int, default=None,
                   help="max in-flight chunks per request (bounds memory)")
    s.add_argument("--timeout", type=float, default=60.0,
                   help="per-request socket timeout seconds (default 60)")
    s.add_argument("--idle-timeout", type=float, default=300.0,
                   help="seconds a persistent connection may sit idle between"
                        " requests before the server drops it (default 300)")
    s.add_argument("--admission-timeout", type=float, default=None,
                   help="shed compress requests that cannot get a pooled"
                        " session within this many seconds (error_kind="
                        "overloaded + retry_after); default: block instead")
    s.add_argument("--backend", default=None,
                   help="execution backend for every pooled session (host/"
                        "device); faulting device backends fail over to host")
    s.set_defaults(fn=_cmd_serve)

    cl = sub.add_parser("client", help="talk to a running compression daemon")
    cl.add_argument(
        "action",
        choices=["compress", "decompress", "stats", "ping", "metrics"],
    )
    cl.add_argument("input", nargs="?", default=None)
    cl.add_argument("-o", "--output", default=None, help="default: INPUT.ozl /"
                    " strip .ozl")
    cl.add_argument("--socket", default=None, help="daemon Unix socket path")
    cl.add_argument("--tcp", default=None, help="daemon HOST:PORT")
    cl.add_argument("--plan-id", default=None,
                    help="registered plan id or content digest (compress)")
    cl.add_argument("--chunk-bytes", default="4MiB",
                    help="chunk size for the container (default 4MiB, as the"
                    " offline CLI)")
    cl.add_argument("--timeout", type=float, default=60.0,
                    help="client socket timeout seconds (default 60)")
    cl.add_argument("--retries", type=int, default=0,
                    help="bounded retries (backoff + jitter, honoring the"
                         " server's retry_after) when the daemon sheds load")
    cl.set_defaults(fn=_cmd_client)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except SystemExit:
        raise
    except Exception as err:  # fail with a message, not a traceback
        kind = type(err).__name__ if not isinstance(err, wire.FrameError) else "frame"
        print(f"error ({kind}): {err}", file=sys.stderr)
        return 2


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
