"""The service wire protocol: length-prefixed frames over a byte stream.

Layout (varints are LEB128, exactly like ``repro.core.wire``):

    request   magic  b"OZS1"          (magic + protocol version, one token)
              u8     verb             (PING / COMPRESS / DECOMPRESS / STATS)
              varint header_len, header bytes   (msgpack dict, <= 1 MiB)
              body blocks:  (varint block_len in [1, 64 MiB], block bytes)*
              varint 0                (body terminator)
    response  magic  b"OZR1"
              u8     status           (0 = ok, 1 = error)
              varint header_len, header bytes   (msgpack dict)
              body blocks + 0 terminator, as above

Both sides stream bodies as bounded blocks, so neither ever needs the whole
payload in memory to frame it, and a reader always knows how many bytes to
expect next — truncation at *any* point is a hard :class:`ProtocolError`
(a ``repro.core.wire.FrameError`` subclass: the service fails closed exactly
like the container format).  Oversized length varints are rejected before any
allocation.  Connections are persistent: a client sends any number of
requests back to back; responses come in order.
"""
from __future__ import annotations

import socket
from typing import BinaryIO, Dict, Iterable, Iterator, Optional, Tuple, Union

import msgpack

from repro.core.wire import FrameError, write_varint
from repro.reliability.faults import fault_point, wrap_io

PROTOCOL_VERSION = 1
REQUEST_MAGIC = b"OZS1"
RESPONSE_MAGIC = b"OZR1"

VERB_PING = 0
VERB_COMPRESS = 1
VERB_DECOMPRESS = 2
VERB_STATS = 3
VERBS = {VERB_PING: "ping", VERB_COMPRESS: "compress",
         VERB_DECOMPRESS: "decompress", VERB_STATS: "stats"}

STATUS_OK = 0
STATUS_ERROR = 1

MAX_HEADER_BYTES = 1 << 20
MAX_BLOCK_BYTES = 64 << 20
DEFAULT_BLOCK_BYTES = 256 << 10

__all__ = [
    "PROTOCOL_VERSION",
    "REQUEST_MAGIC",
    "RESPONSE_MAGIC",
    "VERB_PING",
    "VERB_COMPRESS",
    "VERB_DECOMPRESS",
    "VERB_STATS",
    "VERBS",
    "STATUS_OK",
    "STATUS_ERROR",
    "MAX_HEADER_BYTES",
    "MAX_BLOCK_BYTES",
    "DEFAULT_BLOCK_BYTES",
    "ProtocolError",
    "BlockReader",
    "read_message",
    "write_message",
    "read_request",
    "read_request_or_eof",
    "read_request_rest",
    "write_request",
    "read_response",
    "read_response_or_eof",
    "write_response",
    "iter_body_blocks",
    "parse_address",
]


class ProtocolError(FrameError):
    """Malformed, truncated, or oversized service traffic (fail closed)."""


# ------------------------------------------------------------------ primitives
def _read_exact(r: BinaryIO, n: int) -> bytes:
    """Read exactly n bytes or raise (EOF mid-message is never silent)."""
    out = bytearray()
    while len(out) < n:
        piece = r.read(n - len(out))
        if not piece:
            raise ProtocolError(
                f"connection closed mid-message ({len(out)}/{n} bytes)"
            )
        out += piece
    return bytes(out)


def _read_varint(r: BinaryIO) -> int:
    result = 0
    shift = 0
    while True:
        b = r.read(1)
        if not b:
            raise ProtocolError("truncated varint")
        result |= (b[0] & 0x7F) << shift
        if not (b[0] & 0x80):
            return result
        shift += 7
        if shift > 63:
            raise ProtocolError("varint overflow")


def _pack_header(header: dict) -> bytes:
    blob = msgpack.packb(header or {}, use_bin_type=True)
    if len(blob) > MAX_HEADER_BYTES:
        raise ProtocolError(f"header too large ({len(blob)} bytes)")
    return blob


def _unpack_header(blob: bytes) -> dict:
    try:
        header = msgpack.unpackb(blob, raw=False)
    except Exception as err:
        raise ProtocolError(f"undecodable message header: {err}") from None
    if not isinstance(header, dict):
        raise ProtocolError("message header must be a map")
    return header


# ----------------------------------------------------------------- body stream
class BlockReader:
    """File-like view over a 0-terminated block stream (bounded memory).

    ``read(n)`` hands out bytes one block at a time, so peak memory is one
    block regardless of body size.  ``size_hint`` (from the request header,
    when the sender knows its payload length) is what lets the server's
    ``stream_io.compress_file`` take the known-chunk-count container path —
    the one whose bytes match the offline CLI exactly.  After the terminator
    the reader reports EOF; :meth:`drain` skips any unread remainder so the
    connection can be reused for the next request.

    ``limit`` (settable by the consumer) is a hard ceiling on total body
    bytes, enforced *before* each block is buffered — a sender that declared
    ``size=16`` and then streams gigabytes is cut off at the first
    over-budget block, not after the body has been swallowed into memory.
    """

    def __init__(self, r: BinaryIO, size_hint: Optional[int] = None):
        self._r = r
        self._buf = b""
        self._done = False
        self.bytes_read = 0
        self.size_hint = size_hint
        self.limit: Optional[int] = None

    def _next_block(self) -> bool:
        if self._done:
            return False
        n = _read_varint(self._r)
        if n == 0:
            self._done = True
            return False
        if n > MAX_BLOCK_BYTES:
            raise ProtocolError(f"body block too large ({n} bytes)")
        if self.limit is not None and self.bytes_read + n > self.limit:
            raise ProtocolError(
                f"body exceeds its limit of {self.limit} bytes"
                f" ({self.bytes_read + n}+ sent)"
            )
        self._buf = _read_exact(self._r, n)
        self.bytes_read += n
        return True

    def read(self, n: int = -1) -> bytes:
        if n is None or n < 0:
            parts = [self._buf]
            self._buf = b""
            while self._next_block():
                parts.append(self._buf)
                self._buf = b""
            return b"".join(parts)
        out = bytearray()
        while len(out) < n:
            if not self._buf and not self._next_block():
                break
            take = min(n - len(out), len(self._buf))
            out += self._buf[:take]
            self._buf = self._buf[take:]
        return bytes(out)

    def drain(self) -> int:
        """Consume through the terminator -> bytes skipped (resync point)."""
        skipped = len(self._buf)
        self._buf = b""
        while self._next_block():
            skipped += len(self._buf)
            self._buf = b""
        return skipped


def iter_body_blocks(
    src: Union[bytes, bytearray, memoryview, BinaryIO],
    block_bytes: int = DEFAULT_BLOCK_BYTES,
) -> Iterator[bytes]:
    """Cut a bytes-like or binary file into body blocks of ``block_bytes``."""
    if isinstance(src, (bytes, bytearray, memoryview)):
        view = memoryview(src)
        if view.itemsize != 1 or view.ndim != 1:
            # slice in *bytes*, not elements (e.g. an int64 array view)
            try:
                view = view.cast("B")
            except TypeError:  # non-contiguous: fall back to one copy
                view = memoryview(view.tobytes())
        for i in range(0, len(view), block_bytes):
            yield bytes(view[i : i + block_bytes])
        return
    while True:
        piece = src.read(block_bytes)
        if not piece:
            return
        yield piece


def _write_body(w: BinaryIO, body: Optional[Iterable[bytes]]) -> int:
    total = 0
    for block in body or ():
        if not block:
            continue
        if len(block) > MAX_BLOCK_BYTES:
            raise ProtocolError(f"body block too large ({len(block)} bytes)")
        prefix = bytearray()
        write_varint(prefix, len(block))
        w.write(bytes(prefix))
        w.write(block)
        total += len(block)
    w.write(b"\x00")
    return total


# ------------------------------------------------------------------- messages
def write_message(
    w: BinaryIO,
    magic: bytes,
    tag: int,
    header: dict,
    body: Optional[Iterable[bytes]] = None,
) -> int:
    """Emit one framed message -> body bytes written (flushes the sink)."""
    fault_point("proto.send")  # injectable connection drop / torn frame
    w = wrap_io(w, "proto.io")
    blob = _pack_header(header)
    head = bytearray()
    head += magic
    head.append(tag & 0xFF)
    write_varint(head, len(blob))
    head += blob
    w.write(bytes(head))
    total = _write_body(w, body)
    w.flush()
    return total


def _check_magic(got: bytes, magic: bytes) -> None:
    if got != magic:
        raise ProtocolError(
            f"bad magic {got!r} (expected {magic!r}; wrong endpoint or a"
            f" protocol-version mismatch)"
        )


def _read_tail(r: BinaryIO) -> Tuple[int, dict, BlockReader]:
    fault_point("proto.recv")  # injectable mid-message connection loss
    tag = _read_exact(r, 1)[0]
    hlen = _read_varint(r)
    if hlen > MAX_HEADER_BYTES:
        raise ProtocolError(f"header too large ({hlen} bytes)")
    header = _unpack_header(_read_exact(r, hlen))
    return tag, header, BlockReader(r, header.get("size"))


def read_message(r: BinaryIO, magic: bytes) -> Tuple[int, dict, BlockReader]:
    """Parse one message -> (tag, header, body reader).

    The caller must fully consume (or :meth:`BlockReader.drain`) the body
    before reading the next message off the same stream.
    """
    _check_magic(_read_exact(r, len(magic)), magic)
    return _read_tail(r)


def write_request(
    w: BinaryIO, verb: int, header: dict, body: Optional[Iterable[bytes]] = None
) -> int:
    return write_message(w, REQUEST_MAGIC, verb, header, body)


def read_request(r: BinaryIO) -> Tuple[int, dict, BlockReader]:
    verb, header, body = read_message(r, REQUEST_MAGIC)
    if verb not in VERBS:
        raise ProtocolError(f"unknown verb {verb}")
    return verb, header, body


def read_request_rest(r: BinaryIO, first: bytes) -> Tuple[int, dict, BlockReader]:
    """Parse a request whose first byte was already consumed by the caller
    (servers read it separately to tell an idle hangup/timeout from a
    mid-request one)."""
    _check_magic(first + _read_exact(r, len(REQUEST_MAGIC) - 1), REQUEST_MAGIC)
    verb, header, body = _read_tail(r)
    if verb not in VERBS:
        raise ProtocolError(f"unknown verb {verb}")
    return verb, header, body


def read_request_or_eof(r: BinaryIO) -> Optional[Tuple[int, dict, BlockReader]]:
    """Like :func:`read_request`, but a clean EOF *between* requests (the
    client hung up after completing its last exchange) returns None instead of
    raising — that's the one place on a persistent connection where closing is
    not an error."""
    first = r.read(1)
    if not first:
        return None
    return read_request_rest(r, first)


def write_response(
    w: BinaryIO, status: int, header: dict, body: Optional[Iterable[bytes]] = None
) -> int:
    return write_message(w, RESPONSE_MAGIC, status, header, body)


def read_response(r: BinaryIO) -> Tuple[int, dict, BlockReader]:
    status, header, body = read_message(r, RESPONSE_MAGIC)
    if status not in (STATUS_OK, STATUS_ERROR):
        raise ProtocolError(f"unknown response status {status}")
    return status, header, body


def read_response_or_eof(r: BinaryIO) -> Optional[Tuple[int, dict, BlockReader]]:
    """Like :func:`read_response`, but a clean EOF *before any response byte*
    returns None instead of raising — the signature of a server that closed a
    persistent connection (idle timeout, restart) between exchanges.  A
    truncation after the first byte is still a hard :class:`ProtocolError`."""
    first = r.read(1)
    if not first:
        return None
    _check_magic(first + _read_exact(r, len(RESPONSE_MAGIC) - 1), RESPONSE_MAGIC)
    status, header, body = _read_tail(r)
    if status not in (STATUS_OK, STATUS_ERROR):
        raise ProtocolError(f"unknown response status {status}")
    return status, header, body


# ------------------------------------------------------------------ addresses
def parse_address(spec: Union[str, Tuple[str, int]]) -> Tuple[int, object]:
    """Resolve an address spec -> (socket family, connect/bind argument).

    Accepted forms: ``unix:/path``, any string containing ``/`` (a Unix
    socket path), ``host:port``, ``:port`` (localhost), or an explicit
    ``(host, port)`` tuple.
    """
    if isinstance(spec, tuple):
        host, port = spec
        return socket.AF_INET, (host or "127.0.0.1", int(port))
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"bad service address {spec!r}")
    if spec.startswith("unix:"):
        return socket.AF_UNIX, spec[len("unix:") :]
    if "/" in spec:
        return socket.AF_UNIX, spec
    host, sep, port = spec.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(
            f"bad service address {spec!r} (want unix:/path, /path, host:port)"
        )
    return socket.AF_INET, (host or "127.0.0.1", int(port))
