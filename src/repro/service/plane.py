"""Multi-core service plane: pre-forked session workers, one shared listener.

Python's GIL caps the threaded :class:`~repro.service.server.CompressionServer`
at roughly one core of entropy coding no matter how many clients connect.
:class:`ServicePlane` escapes it with processes:

* The supervisor binds the listener once, then **forks** ``workers`` session
  workers that all inherit the fd and accept from it directly — the kernel
  load-balances connections, no fd-passing hop, and the semantics are
  identical for Unix and TCP sockets.  Because the supervisor keeps the
  listener open, a dying worker never produces connection-refused: pending
  connections just queue until a sibling (or the respawned replacement)
  accepts them.
* Each worker runs its own :class:`~repro.service.frontend.ServiceFrontend`
  event loop over a **private** :class:`~repro.service.server.RequestCore` —
  session pools, coder caches, the decoder, quarantine, and backend health
  are all per-process, so workers share no locks and scale linearly until
  the socket or the disk runs out.
* The supervisor reaps dead workers (crash, OOM, injected ``SIGKILL``) and
  respawns them within a restart budget.  In-flight requests on a dead
  worker surface to clients as a torn connection; ``ServiceClient`` retries
  them against the next worker to accept.
* **Stats aggregate across processes.**  Every worker pushes a periodic
  snapshot over its control socketpair; a ``stats`` request received by any
  worker is answered with the supervisor's merged view (summed counters,
  per-digest session occupancy, per-worker rows) — one scrape sees the
  whole plane, whichever process happens to serve it.

Fault injection composes per the standing policy: ``worker_fault_json`` arms
a :class:`~repro.reliability.faults.FaultPlan` inside each *initially
spawned* worker (the inherited-arming hazard is impossible — forked children
always start disarmed, see ``faults._faults_after_fork``), and respawned
replacements come up clean unless ``fault_respawns=True`` — a kill rule
cannot crash-loop the plane.
"""
from __future__ import annotations

import os
import selectors
import signal
import socket
import struct
import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional

import msgpack

from . import protocol as P
from .frontend import ServiceFrontend
from .ratelimit import RateLimiter
from .registry import PlanRegistry
from .server import RequestCore

__all__ = ["ServicePlane"]

#: Seconds between worker snapshot pushes (staleness bound on aggregates).
HEARTBEAT_S = 0.5


# ---------------------------------------------------------------- messaging
class _MsgChannel:
    """Length-prefixed msgpack messages over one socketpair end.

    ``send`` is locked (the worker's loop thread heartbeats while a compute
    thread runs a stats query); reads come in two flavors — ``poll`` for the
    non-blocking selector side, ``recv_blocking`` for request/reply.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._wlock = threading.Lock()
        self._rlock = threading.Lock()
        self._rbuf = bytearray()

    def send(self, obj) -> None:
        blob = msgpack.packb(obj, use_bin_type=True)
        with self._wlock:
            self.sock.sendall(struct.pack("<I", len(blob)) + blob)

    def recv_blocking(self, timeout: Optional[float]):
        """One message, blocking -> object (None on EOF/timeout)."""
        with self._rlock:
            self.sock.settimeout(timeout)
            try:
                while True:
                    msg = self._parse_one()
                    if msg is not None:
                        return msg
                    piece = self.sock.recv(65536)
                    if not piece:
                        return None
                    self._rbuf += piece
            except (socket.timeout, OSError):
                return None
            finally:
                try:
                    self.sock.settimeout(None)
                except OSError:
                    pass

    def poll(self) -> list:
        """Drain whatever is readable right now -> complete messages, with a
        trailing ``None`` sentinel when the peer is gone (EOF/reset)."""
        eof = False
        with self._rlock:
            try:
                while True:
                    piece = self.sock.recv(65536)
                    if not piece:
                        eof = True
                        break
                    self._rbuf += piece
            except (BlockingIOError, InterruptedError):
                pass
            except OSError:
                eof = True
            out = []
            while True:
                msg = self._parse_one()
                if msg is None:
                    break
                out.append(msg)
        if eof:
            out.append(None)
        return out

    def _parse_one(self):
        if len(self._rbuf) < 4:
            return None
        n = struct.unpack("<I", bytes(self._rbuf[:4]))[0]
        if len(self._rbuf) < 4 + n:
            return None
        blob = bytes(self._rbuf[4 : 4 + n])
        del self._rbuf[: 4 + n]
        return msgpack.unpackb(blob, raw=False)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def _merge_numeric(into: dict, snap: dict) -> dict:
    """Merge ``snap`` into ``into``: numbers add, bools OR, dicts recurse,
    anything else last-wins.  The shape every per-worker counter dict shares."""
    for k, v in snap.items():
        if isinstance(v, dict):
            base = into.get(k)
            into[k] = _merge_numeric(base if isinstance(base, dict) else {}, v)
        elif isinstance(v, bool):
            into[k] = bool(into.get(k)) or v
        elif isinstance(v, (int, float)):
            prev = into.get(k)
            into[k] = (prev if isinstance(prev, (int, float)) else 0) + v
        else:
            into[k] = v
    return into


class _Worker:
    __slots__ = ("idx", "pid", "ctrl", "stat", "snap", "alive", "faulted")

    def __init__(self, idx, pid, ctrl, stat, faulted):
        self.idx = idx
        self.pid = pid
        self.ctrl = ctrl
        self.stat = stat
        self.snap: Optional[dict] = None
        self.alive = True
        self.faulted = faulted

    @property
    def ident(self) -> str:
        return f"w{self.idx}:{self.pid}"


# -------------------------------------------------------------------- plane
class ServicePlane:
    """Supervisor for a pre-forked pool of session-worker processes."""

    def __init__(
        self,
        registry: Optional[PlanRegistry] = None,
        *,
        socket_path: Optional[str] = None,
        host: Optional[str] = None,
        port: int = 0,
        workers: int = 2,
        max_clients: int = 512,
        compute_threads: int = 4,
        sessions_per_plan: int = 2,
        n_workers: Optional[int] = None,
        window: Optional[int] = None,
        request_timeout: float = 60.0,
        idle_timeout: float = 300.0,
        spool_bytes: int = 32 << 20,
        max_body_bytes: int = 1 << 30,
        admission_timeout: Optional[float] = None,
        backend: Optional[str] = None,
        quarantine_threshold: int = 3,
        quarantine_cooldown_s: float = 10.0,
        rate_limit: Optional[float] = None,
        rate_burst: Optional[float] = None,
        max_restarts: int = 8,
        worker_fault_json: Optional[str] = None,
        fault_respawns: bool = False,
    ):
        if (socket_path is None) == (host is None):
            raise ValueError("pass exactly one of socket_path= or host=")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.registry = registry if registry is not None else PlanRegistry()
        self.workers = workers
        self.max_clients = max_clients
        self.compute_threads = compute_threads
        self.request_timeout = request_timeout
        self.idle_timeout = idle_timeout
        self.rate_limit = rate_limit
        self.rate_burst = rate_burst
        self.max_restarts = max_restarts
        self.worker_fault_json = worker_fault_json
        self.fault_respawns = fault_respawns
        self._core_kw = dict(
            sessions_per_plan=sessions_per_plan,
            n_workers=n_workers,
            window=window,
            request_timeout=request_timeout,
            spool_bytes=spool_bytes,
            max_body_bytes=max_body_bytes,
            admission_timeout=admission_timeout,
            backend=backend,
            quarantine_threshold=quarantine_threshold,
            quarantine_cooldown_s=quarantine_cooldown_s,
        )
        self._workers: List[_Worker] = []
        self._lock = threading.Lock()
        self._stopping = threading.Event()
        self._supervisor: Optional[threading.Thread] = None
        self._started = time.monotonic()
        self.worker_restarts = 0

        if socket_path is not None:
            self.socket_path: Optional[str] = str(socket_path)
            Path(self.socket_path).unlink(missing_ok=True)
            self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._listener.bind(self.socket_path)
            self.address = f"unix:{self.socket_path}"
        else:
            self.socket_path = None
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            bound_host, bound_port = self._listener.getsockname()[:2]
            self.address = f"{bound_host}:{bound_port}"
        self._listener.listen(max(128, max_clients))

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "ServicePlane":
        for idx in range(self.workers):
            self._spawn(idx, self.worker_fault_json)
        self._supervisor = threading.Thread(
            target=self._supervise, name="ozl-plane-supervisor", daemon=True
        )
        self._supervisor.start()
        return self

    def __enter__(self) -> "ServicePlane":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        self._stopping.set()
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            if w.alive:
                try:
                    w.ctrl.send({"type": "stop"})
                except OSError:
                    pass
        deadline = time.monotonic() + 5.0
        for w in workers:
            if not w.alive:
                continue
            while time.monotonic() < deadline:
                try:
                    pid, _status = os.waitpid(w.pid, os.WNOHANG)
                except ChildProcessError:
                    # the supervisor's reaper won the waitpid race — done
                    w.alive = False
                    break
                if pid == w.pid:
                    w.alive = False
                    break
                time.sleep(0.02)
            if w.alive:
                try:
                    os.kill(w.pid, signal.SIGKILL)
                    os.waitpid(w.pid, 0)
                except (OSError, ChildProcessError):
                    pass
                w.alive = False
        if self._supervisor is not None:
            self._supervisor.join(timeout=5)
        for w in workers:
            w.ctrl.close()
            w.stat.close()
        try:
            self._listener.close()
        except OSError:
            pass
        if self.socket_path:
            Path(self.socket_path).unlink(missing_ok=True)

    # --------------------------------------------------------------- forking
    def _spawn(self, idx: int, fault_json: Optional[str]) -> None:
        ctrl_parent, ctrl_child = socket.socketpair()
        stat_parent, stat_child = socket.socketpair()
        # quiesce the registry across the fork so the child never inherits a
        # lock held mid-operation by some other parent thread
        reg_lock = getattr(self.registry, "_lock", None)
        if reg_lock is not None:
            reg_lock.acquire()
        try:
            pid = os.fork()
        finally:
            if reg_lock is not None:
                reg_lock.release()
        if pid == 0:
            # ---- child: never returns
            try:
                ctrl_parent.close()
                stat_parent.close()
                with self._lock:
                    inherited = list(self._workers)
                for w in inherited:
                    w.ctrl.close()
                    w.stat.close()
                self._worker_main(idx, ctrl_child, stat_child, fault_json)
                code = 0
            except BaseException as err:  # noqa: BLE001 - child must exit
                try:
                    sys.stderr.write(f"[ozl-worker w{idx}] died: {err!r}\n")
                    sys.stderr.flush()
                except OSError:
                    pass
                code = 70
            os._exit(code)
        # ---- parent
        ctrl_child.close()
        stat_child.close()
        worker = _Worker(
            idx, pid, _MsgChannel(ctrl_parent), _MsgChannel(stat_parent),
            faulted=fault_json is not None,
        )
        stat_parent.setblocking(False)
        with self._lock:
            self._workers.append(worker)

    # ---------------------------------------------------------- child process
    def _worker_main(self, idx, ctrl_sock, stat_sock, fault_json) -> None:
        signal.signal(signal.SIGINT, signal.SIG_IGN)  # the parent coordinates
        core = RequestCore(self.registry, **self._core_kw)
        limiter = (
            RateLimiter(self.rate_limit, self.rate_burst)
            if self.rate_limit
            else None
        )
        frontend = ServiceFrontend(
            core,
            self._listener,
            max_conns=self.max_clients,
            compute_threads=self.compute_threads,
            idle_timeout=self.idle_timeout,
            request_timeout=self.request_timeout,
            rate_limiter=limiter,
            name=f"ozl-w{idx}",
        )
        ctrl = _MsgChannel(ctrl_sock)
        stat = _MsgChannel(stat_sock)
        ident = f"w{idx}:{os.getpid()}"
        last_beat = [0.0]

        def snapshot() -> dict:
            snap = {**core.stats(), **frontend.transport_stats()}
            if limiter is not None:
                snap["rate_limiter"] = limiter.stats()
            return snap

        def aggregated_stats() -> dict:
            # compute-thread path: ship our fresh snapshot with the query so
            # the supervisor's merge always includes the serving worker
            try:
                stat.send(
                    {"type": "stats_query", "ident": ident, "snap": snapshot()}
                )
                reply = stat.recv_blocking(timeout=5.0)
            except OSError:
                reply = None
            if not reply or "aggregate" not in reply:
                return snapshot()  # supervisor gone: degrade to our own view
            return reply["aggregate"]

        def on_control() -> None:
            for msg in ctrl.poll():
                if msg is None or msg.get("type") == "stop":
                    frontend.stop()
                    return

        def heartbeat() -> None:
            now = time.monotonic()
            if now - last_beat[0] < HEARTBEAT_S:
                return
            last_beat[0] = now
            try:
                stat.send({"type": "snap", "ident": ident, "snap": snapshot()})
            except OSError:
                frontend.stop()  # supervisor is gone; no point serving on

        core.stats_provider = aggregated_stats
        frontend.add_reader(ctrl_sock, on_control)
        frontend.on_tick = heartbeat

        signal.signal(signal.SIGTERM, lambda *_: frontend.stop())

        if fault_json:
            from repro.reliability.faults import FaultPlan

            plan = FaultPlan.from_json(fault_json)
            with plan.arm(all_threads=True):
                frontend.serve_forever()
        else:
            frontend.serve_forever()
        core.close()

    # ------------------------------------------------------------ supervisor
    def _supervise(self) -> None:
        sel = selectors.DefaultSelector()
        registered: Dict[int, _Worker] = {}
        while not self._stopping.is_set():
            with self._lock:
                workers = list(self._workers)
            for w in workers:
                if w.alive and w.stat.sock.fileno() >= 0:
                    if w.stat.sock.fileno() not in registered:
                        try:
                            sel.register(w.stat.sock, selectors.EVENT_READ, w)
                            registered[w.stat.sock.fileno()] = w
                        except (KeyError, ValueError, OSError):
                            pass
            for key, _mask in sel.select(timeout=0.2):
                w = key.data
                for msg in w.stat.poll():
                    if msg is None:
                        # worker end gone: close our end too, or the selector
                        # would re-register and spin on a readable EOF
                        try:
                            sel.unregister(w.stat.sock)
                        except (KeyError, ValueError, OSError):
                            pass
                        registered.pop(key.fd, None)
                        w.stat.close()
                        break
                    if msg.get("snap") is not None:
                        w.snap = msg["snap"]
                    if msg.get("type") == "stats_query":
                        try:
                            w.stat.send({"aggregate": self._aggregate()})
                        except OSError:
                            pass
            self._reap(sel, registered)
        sel.close()

    def _reap(self, sel, registered) -> None:
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            if not w.alive:
                continue
            try:
                pid, _status = os.waitpid(w.pid, os.WNOHANG)
            except ChildProcessError:
                pid = w.pid
            if pid != w.pid:
                continue
            w.alive = False
            try:
                sel.unregister(w.stat.sock)
            except (KeyError, ValueError, OSError):
                pass
            registered.pop(w.stat.sock.fileno(), None)
            w.ctrl.close()
            w.stat.close()
            if self._stopping.is_set():
                continue
            if self.worker_restarts >= self.max_restarts:
                continue  # restart budget exhausted: shrink rather than loop
            self.worker_restarts += 1
            self._spawn(
                w.idx,
                self.worker_fault_json if self.fault_respawns else None,
            )

    # ----------------------------------------------------------------- stats
    def worker_pids(self) -> List[int]:
        with self._lock:
            return [w.pid for w in self._workers if w.alive]

    def stats(self) -> dict:
        """Parent-side aggregate from the latest worker snapshots."""
        return self._aggregate()

    def _aggregate(self) -> dict:
        with self._lock:
            workers = list(self._workers)
        alive = [w for w in workers if w.alive]
        snaps = [(w.ident, w.snap) for w in workers if w.snap is not None]
        merged: dict = {}
        latencies: List[dict] = []
        per_worker: Dict[str, dict] = {}
        for ident, snap in snaps:
            body = {
                k: v
                for k, v in snap.items()
                if k
                not in (
                    "ok", "protocol_version", "plans", "uptime_s", "pid",
                    "registry", "latency",
                )
            }
            _merge_numeric(merged, body)
            latencies.append(snap.get("latency") or {})
            per_worker[ident] = {
                "pid": snap.get("pid"),
                "uptime_s": snap.get("uptime_s"),
                "requests": snap.get("requests"),
                "sessions": snap.get("sessions"),
                "coder_cache": snap.get("coder_cache"),
                "active_connections": snap.get("active_connections"),
            }
        return {
            "ok": True,
            "protocol_version": P.PROTOCOL_VERSION,
            "plans": len(self.registry),
            "uptime_s": round(time.monotonic() - self._started, 3),
            "address": self.address,
            "workers": self.workers,
            "workers_alive": len(alive),
            "worker_restarts": self.worker_restarts,
            **merged,
            "latency": _merge_latency(latencies),
            "registry": self.registry.entries(),
            "per_worker": per_worker,
        }


def _merge_latency(latencies: List[dict]) -> dict:
    """Cross-worker latency merge: counts and rates add, p50 is the
    count-weighted mean (an approximation), p99 is the worst worker's."""
    out: Dict[str, dict] = {}
    for lat in latencies:
        for verb, row in (lat or {}).items():
            agg = out.setdefault(
                verb, {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "req_s": 0.0}
            )
            n = row.get("n") or 0
            agg["p50_ms"] += (row.get("p50_ms") or 0.0) * n
            agg["p99_ms"] = max(agg["p99_ms"], row.get("p99_ms") or 0.0)
            agg["req_s"] += row.get("req_s") or 0.0
            agg["n"] += n
    for agg in out.values():
        if agg["n"]:
            agg["p50_ms"] = round(agg["p50_ms"] / agg["n"], 3)
        agg["req_s"] = round(agg["req_s"], 3)
    return out
