"""The plan registry: named, content-addressed compression configurations.

The paper's deployment story (§VIII) is one universal decoder plus *registered
trained configurations*: a service operator registers ``.ozp`` plans and named
profiles once, and every client addresses them by a short id or by content
digest — the sha256 of the canonical serialized plan, so two registries that
loaded the same plan agree on its address and a client pinning a digest can
never be served a silently different compressor.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Union

from repro.core import Compressor
from repro.core.serialize import plan_digest

__all__ = ["PlanRegistry", "RegisteredPlan"]


@dataclass(frozen=True)
class RegisteredPlan:
    """One registry entry: a deployable compressor plus its addresses."""

    plan_id: str
    digest: str
    name: str
    source: str
    compressor: Compressor = field(compare=False, repr=False)

    def describe(self) -> dict:
        return {
            "plan_id": self.plan_id,
            "digest": self.digest,
            "name": self.name,
            "source": self.source,
            "format_version": self.compressor.format_version,
            "level": self.compressor.level,
            "n_nodes": len(self.compressor.plan.nodes),
        }


class PlanRegistry:
    """Thread-safe id/digest -> compressor mapping for the service."""

    def __init__(self):
        self._lock = threading.Lock()
        self._by_id: Dict[str, RegisteredPlan] = {}
        self._by_digest: Dict[str, RegisteredPlan] = {}

    # ---------------------------------------------------------- registration
    def register_compressor(
        self,
        comp: Compressor,
        plan_id: Optional[str] = None,
        *,
        source: str = "api",
    ) -> RegisteredPlan:
        # fail closed: an ill-typed plan would die mid-request on the first
        # matching payload — reject it at the door with the full diagnosis
        from repro.analysis import PlanTypeError, check_plan

        report = check_plan(comp.plan, format_version=comp.format_version)
        if not report.ok:
            raise PlanTypeError(
                f"plan {comp.name or comp.plan.name or '?'!s} is ill-typed:"
                f" {'; '.join(str(d) for d in report.errors)}",
                report.errors,
            )
        digest = plan_digest(
            comp.plan, format_version=comp.format_version, level=comp.level
        )
        plan_id = plan_id or comp.name or comp.plan.name or digest[:12]
        entry = RegisteredPlan(plan_id, digest, comp.name, source, comp)
        with self._lock:
            existing = self._by_id.get(plan_id)
            if existing is not None:
                if existing.digest == digest:
                    return existing  # idempotent re-registration
                raise ValueError(
                    f"plan id {plan_id!r} already registered with a different"
                    f" plan (digest {existing.digest[:12]} != {digest[:12]})"
                )
            self._by_id[plan_id] = entry
            # first id to register a digest wins its digest address; later
            # aliases of the same plan stay resolvable by their own id
            self._by_digest.setdefault(digest, entry)
        return entry

    def register_file(
        self, path: Union[str, Path], plan_id: Optional[str] = None
    ) -> RegisteredPlan:
        """Load and register a serialized ``.ozp`` plan (id defaults to the
        file stem)."""
        path = Path(path)
        comp = Compressor.deserialize(path.read_bytes())
        return self.register_compressor(
            comp, plan_id or path.stem, source=f"file:{path}"
        )

    def register_profile(
        self, spec: str, plan_id: Optional[str] = None
    ) -> RegisteredPlan:
        """Register a named profile spec (``text``, ``struct:W1,W2``, ...).

        Raises ValueError on an unknown/malformed spec.
        """
        from repro.codecs.profiles import resolve_profile_spec

        comp = Compressor(resolve_profile_spec(spec), name=spec)
        return self.register_compressor(
            comp, plan_id or spec, source=f"profile:{spec}"
        )

    # ------------------------------------------------------------ resolution
    def resolve(self, key: str) -> RegisteredPlan:
        """Look up by plan id, full digest, or unique digest prefix (>= 8)."""
        with self._lock:
            entry = self._by_id.get(key) or self._by_digest.get(key)
            if entry is not None:
                return entry
            if len(key) >= 8:
                hits = [
                    e for d, e in self._by_digest.items() if d.startswith(key)
                ]
                if len(hits) == 1:
                    return hits[0]
                if len(hits) > 1:
                    raise KeyError(
                        f"digest prefix {key!r} is ambiguous"
                        f" ({len(hits)} plans)"
                    )
            known = ", ".join(sorted(self._by_id)) or "(none)"
        raise KeyError(f"unknown plan {key!r}; registered: {known}")

    def entries(self) -> List[dict]:
        with self._lock:
            return [
                e.describe() for _, e in sorted(self._by_id.items())
            ]

    def __len__(self) -> int:
        with self._lock:
            return len(self._by_id)

    def __contains__(self, key: str) -> bool:
        try:
            self.resolve(key)
            return True
        except KeyError:
            return False
