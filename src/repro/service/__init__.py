"""repro.service — the long-lived compression daemon (paper §VIII).

One universal decoder plus registered trained configurations, served: a
:class:`~repro.service.server.RequestCore` keeps a checkout pool of
:class:`~repro.core.engine.CompressorSession` objects per registered plan and
one shared :class:`~repro.core.engine.DecompressorSession`, so production
callers pay plan resolution, coder-table construction, and thread-pool spin-up
once — not per invocation, which is the deployment friction the one-shot CLI
carries.  Frames produced through the service are byte-identical to the
offline CLI for the same plan and chunk settings.

Two server embeddings share that core:

* :class:`~repro.service.server.CompressionServer` — thread per connection,
  blocking I/O; the simplest in-process embedding for tests and libraries.
* :class:`~repro.service.plane.ServicePlane` — the production shape: a
  supervisor pre-forks session-worker processes that all accept from one
  shared listener, each running a non-blocking
  :class:`~repro.service.frontend.ServiceFrontend` event loop.  Real cores,
  crash isolation, per-client rate limiting, aggregated Prometheus metrics
  through the ``stats`` verb.

Public API:
    Wire protocol ......... repro.service.protocol  (framing, fail-closed)
    Plan registry ......... repro.service.registry  (id + content digest)
    Verb engine ........... repro.service.server    (RequestCore)
    Threaded daemon ....... repro.service.server    (CompressionServer)
    Async frontend ........ repro.service.frontend  (ServiceFrontend)
    Multi-core plane ...... repro.service.plane     (ServicePlane)
    Blocking client ....... repro.service.client    (ServiceClient)
    Rate limiting ......... repro.service.ratelimit (RateLimiter)
    Metrics rendering ..... repro.service.metrics   (render_prometheus)
"""
from .protocol import (  # noqa: F401
    PROTOCOL_VERSION,
    ProtocolError,
    parse_address,
)
from .registry import PlanRegistry, RegisteredPlan  # noqa: F401
from .server import CompressionServer, RequestCore  # noqa: F401
from .client import (  # noqa: F401
    ConnectionLost,
    ServiceClient,
    ServiceUnavailable,
)
from .frontend import ServiceFrontend  # noqa: F401
from .plane import ServicePlane  # noqa: F401
from .ratelimit import RateLimiter  # noqa: F401
from .metrics import render_prometheus  # noqa: F401
