"""repro.service — the long-lived compression daemon (paper §VIII).

One universal decoder plus registered trained configurations, served: a
:class:`~repro.service.server.CompressionServer` keeps a checkout pool of
:class:`~repro.core.engine.CompressorSession` objects per registered plan and
one shared :class:`~repro.core.engine.DecompressorSession`, so production
callers pay plan resolution, coder-table construction, and thread-pool spin-up
once — not per invocation, which is the deployment friction the one-shot CLI
carries.  Frames produced through the service are byte-identical to the
offline CLI for the same plan and chunk settings.

Public API:
    Wire protocol ......... repro.service.protocol  (framing, fail-closed)
    Plan registry ......... repro.service.registry  (id + content digest)
    Daemon ................ repro.service.server    (CompressionServer)
    Blocking client ....... repro.service.client    (ServiceClient)
"""
from .protocol import (  # noqa: F401
    PROTOCOL_VERSION,
    ProtocolError,
    parse_address,
)
from .registry import PlanRegistry, RegisteredPlan  # noqa: F401
from .server import CompressionServer  # noqa: F401
from .client import ServiceClient, ServiceUnavailable  # noqa: F401
