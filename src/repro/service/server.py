"""The compression daemon: hot sessions behind a socket.

Two layers live here:

* :class:`RequestCore` — the transport-independent verb engine.  It owns
  exactly the state the one-shot CLI rebuilds on every invocation — resolved
  plans, coder-table caches, session pools, the shared decoder — plus the
  degradation machinery (plan quarantine, backend health, admission shedding)
  and per-verb latency accounting.  Every server flavor dispatches into the
  same ``handle()``: the threaded :class:`CompressionServer` below, the async
  frontend (``repro.service.frontend``), and the process-pool session workers
  of the multi-core plane (``repro.service.plane``).  Because it *is* the
  same ``stream_io`` code path as the offline CLI, frames are byte-identical
  everywhere.

* :class:`CompressionServer` — the original thread-per-connection daemon
  (Unix/TCP, persistent connections, blocking I/O).  It remains the simplest
  embedding for tests and libraries; production serving should prefer
  :class:`~repro.service.plane.ServicePlane`, which scales the same
  ``RequestCore`` across real cores.

Memory stays bounded under load from three directions: ``max_clients`` caps
concurrent requests, each compression session's in-flight ``window`` bounds
chunks per request (the server reads request blocks only as the window frees,
so TCP flow control pushes back on fast senders), and results spool to disk
past ``spool_bytes``.  A request that fails never wedges its worker: the body
is drained (or the connection dropped), an error response is attempted, and
the checked-out session is returned — or discarded, if it failed mid-use.
"""
from __future__ import annotations

import io
import os
import socket
import tempfile
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

from repro.core import DecompressorSession, ExecScratch, SessionPool
from repro.core import stream_io, wire
from repro.core.stream_io import DEFAULT_CHUNK_BYTES
from repro.reliability import BackendHealth, Quarantine
from repro.reliability.faults import crash_point

from . import protocol as P
from .metrics import CONTENT_TYPE as METRICS_CONTENT_TYPE
from .metrics import render_prometheus
from .ratelimit import RateLimiter

__all__ = ["CompressionServer", "RequestCore", "RequestError"]

MAX_CHUNK_BYTES = 256 << 20

#: Entries kept in each verb's sliding latency window (quantiles + req/s).
LATENCY_WINDOW = 1024


class RequestError(Exception):
    """Request-level failure that carries structured response-header fields.

    ``extra`` is merged into the error response header — the transport for
    machine-readable degradation signals (``error_kind``, ``retry_after``)
    without touching the version-locked protocol framing.
    """

    def __init__(self, message: str, **extra):
        super().__init__(message)
        self.extra = dict(extra)


# back-compat alias: the pre-plane name was private to this module
_RequestError = RequestError


class _Spool(tempfile.SpooledTemporaryFile):
    """SpooledTemporaryFile plus the io predicates Python 3.10 forgot
    (``seekable``/``readable``/``writable`` arrived in 3.11) — the
    unknown-length ``ContainerWriter`` probes them before backpatching."""

    def seekable(self) -> bool:
        return True

    def readable(self) -> bool:
        return True

    def writable(self) -> bool:
        return True


class RequestCore:
    """Transport-independent verb engine shared by every server flavor.

    ``handle(verb, header, body)`` runs one request to completion and returns
    ``(response_header, body_file_or_None)`` — the caller frames and writes
    the response (and closes the body file).  Failures *raise*: a
    :class:`RequestError` carries structured degradation fields
    (``error_kind``/``retry_after``), any other exception is a generic
    request failure, and protocol/transport errors propagate untouched so
    the transport can decide whether the connection is still usable.

    The ``body`` argument is duck-typed: anything with ``read``/``drain``/
    ``bytes_read``/``size_hint``/``limit`` works — the blocking servers pass
    a live :class:`~repro.service.protocol.BlockReader`, the async frontend
    passes an already-buffered spool wrapper.
    """

    def __init__(
        self,
        registry,
        *,
        sessions_per_plan: int = 2,
        n_workers: Optional[int] = None,
        window: Optional[int] = None,
        request_timeout: float = 60.0,
        spool_bytes: int = 32 << 20,
        max_body_bytes: int = 1 << 30,
        admission_timeout: Optional[float] = None,
        backend: Optional[str] = None,
        quarantine_threshold: int = 3,
        quarantine_cooldown_s: float = 10.0,
    ):
        self.registry = registry
        self.n_workers = n_workers
        self.window = window
        self.request_timeout = request_timeout
        self.spool_bytes = spool_bytes
        self.max_body_bytes = max_body_bytes
        # admission control: None keeps the original backpressure behavior
        # (block up to request_timeout for a pooled session); a float sheds
        # instead — waiters past the deadline get a structured "overloaded"
        # error with a retry_after hint rather than a connection drop
        self.admission_timeout = admission_timeout
        # backend override for every pooled compression session (None keeps
        # each registered compressor's own choice); the shared BackendHealth
        # quarantines a faulting device backend process-wide so one bad kernel
        # flips all sessions to bit-identical host execution at once
        self.backend = backend
        self.backend_health = BackendHealth()
        # per-plan-digest circuit breaker: a plan whose sessions keep dying
        # mid-request stops eating pool capacity until its cooldown expires
        self.quarantine = Quarantine(
            threshold=quarantine_threshold, cooldown_s=quarantine_cooldown_s
        )
        self.pool = SessionPool(max_per_key=sessions_per_plan)
        # one process-wide coder-table cache: every session (all plans, both
        # directions) shares it, so the stats verb's hit/miss counters
        # describe the whole process's table-build traffic
        self._scratch = ExecScratch()
        self._decoder = DecompressorSession(
            n_workers=n_workers, window=window, scratch=self._scratch
        )
        self.started = time.monotonic()
        # the owner may install a richer stats source (the threaded server
        # adds connection counters, a plane worker returns the cross-worker
        # aggregate); handle() serves whatever this returns
        self.stats_provider: Callable[[], dict] = self.stats
        self._stats_lock = threading.Lock()
        self._counters = {
            "errors": 0,
            "shed": 0,
            "rate_limited": 0,
            "requests": {name: 0 for name in P.VERBS.values()},
            "bytes_in": 0,
            "bytes_out": 0,
        }
        self._latency: Dict[str, deque] = {
            name: deque(maxlen=LATENCY_WINDOW) for name in P.VERBS.values()
        }

    # -------------------------------------------------------------- plumbing
    def bump(self, *, verb: Optional[str] = None, **deltas: int) -> None:
        with self._stats_lock:
            if verb is not None:
                self._counters["requests"][verb] += 1
            for k, v in deltas.items():
                self._counters[k] += v

    def record_latency(self, verb: str, seconds: float) -> None:
        with self._stats_lock:
            self._latency[verb].append((time.monotonic(), seconds))

    def _spool(self):
        return _Spool(max_size=self.spool_bytes)

    def session_key(self, entry) -> str:
        """Ensure a pool factory exists for this plan -> its digest key."""
        if entry.digest not in self.pool.keys():
            comp = entry.compressor
            kw = dict(
                chunk_bytes=None,
                n_workers=self.n_workers,
                window=self.window,
                scratch=self._scratch,
                failover=self.backend_health,
            )
            if self.backend is not None:
                kw["backend"] = self.backend
            self.pool.register(entry.digest, lambda: comp.session(**kw))
        return entry.digest

    def ping_header(self) -> dict:
        return {
            "ok": True,
            "protocol_version": P.PROTOCOL_VERSION,
            "plans": len(self.registry),
            "uptime_s": round(time.monotonic() - self.started, 3),
            "pid": os.getpid(),
        }

    # ------------------------------------------------------------- dispatch
    def handle(
        self, verb: int, header: dict, body
    ) -> Tuple[dict, Optional[io.IOBase]]:
        """Run one request -> (response header, body file or None).

        The caller owns (and must close) the returned body file.  Raises on
        any failure; no response bytes have been produced by then, so the
        transport can always frame a structured error instead.
        """
        self.bump(verb=P.VERBS[verb])
        t0 = time.perf_counter()
        if verb == P.VERB_PING:
            body.drain()
            out: Tuple[dict, Optional[io.IOBase]] = (self.ping_header(), None)
        elif verb == P.VERB_STATS:
            body.drain()
            out = self._do_stats(header)
        elif verb == P.VERB_COMPRESS:
            out = self._do_compress(header, body)
        elif verb == P.VERB_DECOMPRESS:
            out = self._do_decompress(header, body)
        else:  # unreachable: the request parser validated the verb
            raise P.ProtocolError(f"unknown verb {verb}")
        self.record_latency(P.VERBS[verb], time.perf_counter() - t0)
        return out

    def _do_stats(self, header: dict) -> Tuple[dict, Optional[io.IOBase]]:
        st = self.stats_provider()
        if header.get("format") == "prometheus":
            text = render_prometheus(st)
            return (
                {"content_type": METRICS_CONTENT_TYPE, "size": len(text)},
                io.BytesIO(text),
            )
        return st, None

    def _body_budget(self, body) -> Optional[int]:
        """Narrow the body budget to the declared size -> that size (if any).

        The transport already installed ``max_body_bytes`` as the hard
        ceiling; the client's declared ``size`` may only *narrow* it, never
        widen it — a hostile ``size=2**60`` is rejected up front (and the
        reject path's ``drain()`` stays bounded by the ceiling).
        """
        declared = body.size_hint
        if declared is not None:
            if declared > self.max_body_bytes:
                raise ValueError(
                    f"declared size {declared} exceeds the server's"
                    f" per-request limit of {self.max_body_bytes} bytes"
                )
            # cut a lying sender off at the first over-budget block — before
            # its body is buffered — on the bare-frame path too (which reads
            # the whole payload at once)
            body.limit = declared
        return declared

    def _do_compress(self, header: dict, body) -> Tuple[dict, io.IOBase]:
        key = header.get("plan")
        if not key or not isinstance(key, str):
            raise ValueError("compress request needs a 'plan' header")
        entry = self.registry.resolve(key)
        chunk_bytes = header.get("chunk_bytes")
        if chunk_bytes is None:
            chunk_bytes = DEFAULT_CHUNK_BYTES
        chunk_bytes = int(chunk_bytes)
        if chunk_bytes < 0 or chunk_bytes > MAX_CHUNK_BYTES:
            raise ValueError(f"bad chunk_bytes {chunk_bytes}")
        declared = self._body_budget(body)
        remaining = self.quarantine.blocked(entry.digest)
        if remaining is not None:
            raise RequestError(
                f"plan {key!r} is quarantined after repeated failures",
                error_kind="plan_quarantined",
                retry_after=round(remaining, 3),
            )
        pool_key = self.session_key(entry)
        admission = (
            self.request_timeout
            if self.admission_timeout is None
            else self.admission_timeout
        )
        crash_point("svc.request.compress.begin")
        out = self._spool()
        try:
            try:
                with self.pool.acquire(pool_key, timeout=admission) as sess:
                    stats = stream_io.compress_file(
                        body,
                        out,
                        entry.compressor.plan,
                        chunk_bytes=chunk_bytes or None,
                        session=sess,
                    )
            except TimeoutError:
                # every pooled session busy past the admission deadline: shed
                # with a structured signal instead of tying up the worker (or,
                # with shedding disabled, keep the historical generic error)
                if self.admission_timeout is None:
                    raise
                self.bump(shed=1)
                raise RequestError(
                    f"server overloaded: no free session for plan {key!r}"
                    f" within {admission:.3g}s",
                    error_kind="overloaded",
                    retry_after=round(max(admission, 0.05), 3),
                ) from None
            except (P.ProtocolError, OSError, socket.timeout):
                raise  # transport trouble, not the plan's fault
            except Exception:
                # the session died mid-request: charge the plan digest so a
                # poisoned plan trips its breaker instead of burning through
                # fresh pool sessions forever
                self.quarantine.record_failure(entry.digest)
                raise
            self.quarantine.record_success(entry.digest)
            # fail closed on size lies: compare the bytes that actually
            # arrived (not stats["bytes_in"], which on the known-size chunked
            # path *is* the declared value) against the declaration — a short
            # body must never be silently compressed as if complete
            body.drain()
            if declared is not None and body.bytes_read != declared:
                raise ValueError(
                    f"request declared size={declared} but sent"
                    f" {body.bytes_read} bytes"
                )
            crash_point("svc.request.compress.mid")
            self.bump(bytes_in=stats["bytes_in"], bytes_out=stats["bytes_out"])
            out.seek(0)
            return (
                {
                    **stats,
                    "plan_id": entry.plan_id,
                    "digest": entry.digest,
                    "size": stats["bytes_out"],
                },
                out,
            )
        except BaseException:
            out.close()
            raise

    def _do_decompress(self, header: dict, body) -> Tuple[dict, io.IOBase]:
        self._body_budget(body)
        crash_point("svc.request.decompress.begin")
        out = self._spool()
        try:
            stats = stream_io.decompress_file(body, out, session=self._decoder)
            if body.drain():
                raise wire.FrameError("trailing garbage after frame")
            self.bump(bytes_in=stats["bytes_in"], bytes_out=stats["bytes_out"])
            out.seek(0)
            return {**stats, "size": stats["bytes_out"]}, out
        except BaseException:
            out.close()
            raise

    # ----------------------------------------------------------------- stats
    def _latency_stats(self) -> Dict[str, dict]:
        now = time.monotonic()
        out: Dict[str, dict] = {}
        with self._stats_lock:
            windows = {verb: list(ring) for verb, ring in self._latency.items()}
        for verb, entries in windows.items():
            recent = [(t, s) for t, s in entries if now - t <= 60.0]
            if not recent:
                continue
            durs = sorted(s for _t, s in recent)

            def q(p: float) -> float:
                return durs[min(len(durs) - 1, int(round(p * (len(durs) - 1))))]

            span = max(now - min(t for t, _s in recent), 1e-9)
            out[verb] = {
                "n": len(durs),
                "p50_ms": round(q(0.50) * 1e3, 3),
                "p99_ms": round(q(0.99) * 1e3, 3),
                "req_s": round(len(durs) / span, 3),
            }
        return out

    def counters(self) -> dict:
        with self._stats_lock:
            return {
                "errors": self._counters["errors"],
                "shed": self._counters["shed"],
                "rate_limited": self._counters["rate_limited"],
                "requests": dict(self._counters["requests"]),
                "bytes_in": self._counters["bytes_in"],
                "bytes_out": self._counters["bytes_out"],
            }

    def stats(self) -> dict:
        from repro.core.engine import resolve_cache_info

        return {
            **self.ping_header(),
            **self.counters(),
            "registry": self.registry.entries(),
            "sessions": self.pool.stats(),
            "decoder": dict(self._decoder.stats),
            "latency": self._latency_stats(),
            # cache effectiveness: a cold resolve or coder-table rebuild per
            # request is exactly the kind of throughput cliff the blocked hot
            # paths exist to prevent — surface the counters so regressions
            # are observable in production
            "resolve_cache": resolve_cache_info(),
            "coder_cache": self._scratch.table_cache_info(),
            # degradation state: which device backends are benched, which plan
            # digests tripped their breaker, and how many requests were shed
            "backend_health": self.backend_health.stats(),
            "quarantine": self.quarantine.stats(),
        }

    def close(self) -> None:
        self.pool.close()
        self._decoder.close()


class CompressionServer:
    def __init__(
        self,
        registry: Optional["PlanRegistry"] = None,
        *,
        socket_path: Optional[str] = None,
        host: Optional[str] = None,
        port: int = 0,
        max_clients: int = 8,
        sessions_per_plan: int = 2,
        n_workers: Optional[int] = None,
        window: Optional[int] = None,
        request_timeout: float = 60.0,
        idle_timeout: float = 300.0,
        spool_bytes: int = 32 << 20,
        max_body_bytes: int = 1 << 30,
        admission_timeout: Optional[float] = None,
        backend: Optional[str] = None,
        quarantine_threshold: int = 3,
        quarantine_cooldown_s: float = 10.0,
        rate_limit: Optional[float] = None,
        rate_burst: Optional[float] = None,
    ):
        if (socket_path is None) == (host is None):
            raise ValueError("pass exactly one of socket_path= or host=")
        if registry is None:
            from .registry import PlanRegistry

            registry = PlanRegistry()
        self.core = RequestCore(
            registry,
            sessions_per_plan=sessions_per_plan,
            n_workers=n_workers,
            window=window,
            request_timeout=request_timeout,
            spool_bytes=spool_bytes,
            max_body_bytes=max_body_bytes,
            admission_timeout=admission_timeout,
            backend=backend,
            quarantine_threshold=quarantine_threshold,
            quarantine_cooldown_s=quarantine_cooldown_s,
        )
        self.core.stats_provider = self.stats
        self.registry = registry
        self.max_clients = max_clients
        self.request_timeout = request_timeout
        # a persistent client legitimately pauses between requests far longer
        # than any single request takes; conflating the two timeouts silently
        # severed idle-but-healthy connections
        self.idle_timeout = idle_timeout
        self.max_body_bytes = max_body_bytes
        # per-connection token buckets: Unix-socket peers are indistinct, so
        # the key is the connection itself — a flooding client starves only
        # its own budget, never a neighbor's
        self.rate_limiter = (
            RateLimiter(rate_limit, rate_burst) if rate_limit else None
        )
        self._shutdown = threading.Event()
        self._conn_lock = threading.Lock()
        self._conns: set = set()
        self._accept_thread: Optional[threading.Thread] = None
        self._stats_lock = threading.Lock()
        self._stats = {"connections": 0, "active_connections": 0}

        if socket_path is not None:
            self.socket_path: Optional[str] = str(socket_path)
            Path(self.socket_path).unlink(missing_ok=True)
            self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._listener.bind(self.socket_path)
            self.address = f"unix:{self.socket_path}"
        else:
            self.socket_path = None
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            bound_host, bound_port = self._listener.getsockname()[:2]
            self.address = f"{bound_host}:{bound_port}"
        self._listener.listen(max_clients * 2)
        # accept() must wake up for shutdown: closing a socket does not
        # reliably interrupt a thread blocked in accept(), so poll instead
        self._listener.settimeout(0.1)
        self._executor = ThreadPoolExecutor(
            max_workers=max_clients, thread_name_prefix="ozl-serve"
        )

    # convenience pass-throughs: the pre-RequestCore attribute surface
    @property
    def pool(self):
        return self.core.pool

    @property
    def backend_health(self):
        return self.core.backend_health

    @property
    def quarantine(self):
        return self.core.quarantine

    @property
    def admission_timeout(self):
        return self.core.admission_timeout

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "CompressionServer":
        """Accept connections on a background thread (returns immediately)."""
        self._accept_thread = threading.Thread(
            target=self.serve_forever, name="ozl-serve-accept", daemon=True
        )
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        while not self._shutdown.is_set():
            try:
                conn, _addr = self._listener.accept()
            except socket.timeout:
                continue  # periodic shutdown-flag check
            except OSError:
                break  # listener closed by shutdown()
            with self._conn_lock:
                if self._shutdown.is_set():
                    conn.close()
                    break
                self._conns.add(conn)
            self._bump(connections=1, active_connections=1)
            self._executor.submit(self._handle_conn, conn)

    def request_stop(self) -> None:
        """Ask the accept loop to exit (signal-handler safe, non-blocking).

        ``serve_forever`` returns shortly after; call :meth:`shutdown` (or let
        the ``finally`` around ``serve_forever`` do it) for the full cleanup.
        """
        self._shutdown.set()
        try:
            self._listener.close()
        except OSError:
            pass

    def shutdown(self) -> None:
        """Stop accepting, drop live connections, release every session."""
        self.request_stop()
        with self._conn_lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        self._executor.shutdown(wait=True)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
        self.core.close()
        if self.socket_path:
            Path(self.socket_path).unlink(missing_ok=True)

    def __enter__(self) -> "CompressionServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    # -------------------------------------------------------------- plumbing
    def _bump(self, **deltas: int) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self._stats[k] += v

    def _handle_conn(self, sock: socket.socket) -> None:
        r = sock.makefile("rb")
        w = sock.makefile("wb")
        conn_key = f"conn:{id(sock):x}"
        try:
            while not self._shutdown.is_set():
                # between requests the connection may sit idle for a long
                # time (idle_timeout); once a request has started, every
                # read must make progress within request_timeout
                sock.settimeout(self.idle_timeout)
                try:
                    first = r.read(1)
                except (OSError, socket.timeout):
                    # idle past idle_timeout, or hung up between requests:
                    # not an error — reclaim the worker quietly
                    return
                if not first:
                    return  # clean client hangup between requests
                sock.settimeout(self.request_timeout)
                try:
                    verb, header, body = P.read_request_rest(r, first)
                except (P.ProtocolError, OSError, socket.timeout):
                    # a *started* request that stalls or breaks is real
                    # malformed traffic
                    self.core.bump(errors=1)
                    self._try_error(w, "malformed request (connection dropped)")
                    return
                # hard cap installed before any dispatch or validation, so
                # *every* later drain — including error paths that reject the
                # request before its declared size is even looked at — is
                # bounded; a flood hits the limit and drops the connection
                body.limit = self.max_body_bytes
                try:
                    self._dispatch(verb, header, body, w, conn_key)
                except (P.ProtocolError, OSError, socket.timeout):
                    # framing is broken (or the peer vanished): no resync
                    # point exists, so drop the connection
                    self.core.bump(errors=1)
                    self._try_error(w, "request body unreadable")
                    return
                except Exception as err:
                    # request-level failure with intact framing: report and
                    # keep serving this connection
                    self.core.bump(errors=1)
                    # duck-typed: RequestError and analysis.PlanTypeError both
                    # carry ``extra`` (machine-readable error header keys)
                    extra = getattr(err, "extra", None)
                    if isinstance(extra, dict):
                        msg = str(err)
                    else:
                        msg, extra = f"{type(err).__name__}: {err}", None
                    try:
                        body.drain()
                    except (P.ProtocolError, OSError, socket.timeout):
                        self._try_error(w, msg, extra)
                        return
                    if not self._try_error(w, msg, extra):
                        return
        finally:
            for f in (w, r):
                try:
                    f.close()
                except OSError:
                    pass
            try:
                sock.close()
            except OSError:
                pass
            with self._conn_lock:
                self._conns.discard(sock)
            self._bump(active_connections=-1)

    def _try_error(self, w, message: str, extra: Optional[dict] = None) -> bool:
        try:
            P.write_response(w, P.STATUS_ERROR, {"error": message, **(extra or {})})
            return True
        except (OSError, ValueError):
            return False

    # ------------------------------------------------------------- dispatch
    def _dispatch(
        self, verb: int, header: dict, body: P.BlockReader, w, conn_key: str
    ) -> None:
        if self.rate_limiter is not None and verb in (
            P.VERB_COMPRESS, P.VERB_DECOMPRESS,
        ):
            ok, retry_after = self.rate_limiter.check(conn_key)
            if not ok:
                self.core.bump(verb=P.VERBS[verb], rate_limited=1)
                raise RequestError(
                    "rate limit exceeded for this client",
                    error_kind="rate_limited",
                    retry_after=round(max(retry_after, 0.001), 3),
                )
        resp_header, out = self.core.handle(verb, header, body)
        try:
            if out is None:
                P.write_response(w, P.STATUS_OK, resp_header)
            else:
                P.write_response(
                    w, P.STATUS_OK, resp_header, P.iter_body_blocks(out)
                )
        finally:
            if out is not None:
                out.close()

    # ----------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._stats_lock:
            conn_counters = dict(self._stats)
        st = {
            **self.core.stats(),
            "address": self.address,
            "max_clients": self.max_clients,
            **conn_counters,
        }
        if self.rate_limiter is not None:
            st["rate_limiter"] = self.rate_limiter.stats()
        return st
