"""Prometheus-style text rendering of the daemon's stats.

The ``stats`` verb grew a scrape format instead of a new verb: a request
header of ``{"format": "prometheus"}`` (an *additive* header key — the framed
protocol's magic, verbs, and layout are untouched, per the protocol-stability
policy) returns the same counters as the dict form, rendered as Prometheus
exposition text in the response body.  Old clients that never send the key
keep getting the msgpack dict header they always got.

Rendering is pure: ``render_prometheus(stats)`` takes the (possibly
cross-worker aggregated) stats dict and emits deterministic, sorted output —
scraping twice with no traffic in between yields identical bytes except for
``ozl_uptime_seconds``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["render_prometheus", "CONTENT_TYPE"]

#: Exposition-format content type, reported in the response header.
CONTENT_TYPE = "text/plain; version=0.0.4"


def _esc(value: str) -> str:
    return (
        str(value).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _fmt(value) -> str:
    if isinstance(value, bool):
        return "1" if value else "0"
    if isinstance(value, float):
        return repr(value)
    return str(value)


class _Writer:
    def __init__(self):
        self.lines: List[str] = []
        self._described: set = set()

    def sample(
        self,
        name: str,
        value,
        labels: Optional[Dict[str, str]] = None,
        *,
        help_: str = "",
        type_: str = "gauge",
    ) -> None:
        if value is None:
            return
        if name not in self._described:
            self._described.add(name)
            if help_:
                self.lines.append(f"# HELP {name} {help_}")
            self.lines.append(f"# TYPE {name} {type_}")
        if labels:
            inner = ",".join(
                f'{k}="{_esc(v)}"' for k, v in sorted(labels.items())
            )
            self.lines.append(f"{name}{{{inner}}} {_fmt(value)}")
        else:
            self.lines.append(f"{name} {_fmt(value)}")

    def render(self) -> bytes:
        return ("\n".join(self.lines) + "\n").encode()


def render_prometheus(stats: dict) -> bytes:
    """Render a server/plane stats dict as Prometheus exposition text.

    Unknown keys are ignored, missing keys are skipped — the renderer accepts
    both the single-process server's dict and the plane's aggregate (which
    adds ``workers``/``worker_restarts``/``per_worker``).
    """
    w = _Writer()
    w.sample(
        "ozl_uptime_seconds", stats.get("uptime_s"),
        help_="Seconds since the serving process started.",
    )
    w.sample(
        "ozl_plans", stats.get("plans"),
        help_="Registered compression plans.",
    )
    for verb, count in sorted((stats.get("requests") or {}).items()):
        w.sample(
            "ozl_requests_total", count, {"verb": verb},
            help_="Requests handled, by verb.", type_="counter",
        )
    w.sample(
        "ozl_errors_total", stats.get("errors"),
        help_="Requests answered with an error response.", type_="counter",
    )
    w.sample(
        "ozl_shed_total", stats.get("shed"),
        help_="Requests shed by admission control.", type_="counter",
    )
    w.sample(
        "ozl_rate_limited_total", stats.get("rate_limited"),
        help_="Requests rejected by per-client rate limiting.",
        type_="counter",
    )
    w.sample(
        "ozl_bytes_total", stats.get("bytes_in"), {"direction": "in"},
        help_="Payload bytes through the daemon.", type_="counter",
    )
    w.sample("ozl_bytes_total", stats.get("bytes_out"), {"direction": "out"})
    w.sample(
        "ozl_connections_total", stats.get("connections"),
        help_="Connections accepted.", type_="counter",
    )
    w.sample(
        "ozl_active_connections", stats.get("active_connections"),
        help_="Connections currently open.",
    )

    # latency quantiles + recent request rate, per verb
    for verb, lat in sorted((stats.get("latency") or {}).items()):
        for q_key, q_label in (("p50_ms", "0.5"), ("p99_ms", "0.99")):
            if lat.get(q_key) is not None:
                w.sample(
                    "ozl_request_duration_ms", lat[q_key],
                    {"verb": verb, "quantile": q_label},
                    help_="Recent request latency quantiles (ms), by verb.",
                    type_="summary",
                )
        w.sample(
            "ozl_requests_per_second", lat.get("req_s"), {"verb": verb},
            help_="Recent request rate over the sliding latency window.",
        )

    # session pool occupancy per plan digest
    for digest, counters in sorted((stats.get("sessions") or {}).items()):
        for state in ("created", "idle", "in_use"):
            w.sample(
                "ozl_sessions", counters.get(state),
                {"digest": digest[:12], "state": state},
                help_="Compressor-session pool occupancy, by plan digest.",
            )
        w.sample(
            "ozl_session_acquires_total", counters.get("acquires"),
            {"digest": digest[:12]},
            help_="Pool checkouts, by plan digest.", type_="counter",
        )

    # cache effectiveness
    for cache_key, metric in (
        ("resolve_cache", "ozl_resolve_cache"),
        ("coder_cache", "ozl_coder_cache"),
    ):
        info = stats.get(cache_key) or {}
        for event in ("hits", "misses"):
            w.sample(
                f"{metric}_total", info.get(event), {"event": event},
                help_=f"{cache_key} traffic.", type_="counter",
            )

    # degradation state
    for backend, health in sorted((stats.get("backend_health") or {}).items()):
        w.sample(
            "ozl_backend_quarantined",
            health.get("quarantined"),
            {"backend": backend},
            help_="1 while the backend is benched after repeated faults.",
        )
        w.sample(
            "ozl_backend_failovers_total", health.get("failovers"),
            {"backend": backend},
            help_="Requests re-executed on the host backend.", type_="counter",
        )
    quarantine = stats.get("quarantine") or {}
    w.sample(
        "ozl_quarantined_plans",
        sum(1 for q in quarantine.values() if q.get("quarantined")),
        help_="Plan digests with an open circuit breaker.",
    )
    for digest, q in sorted(quarantine.items()):
        w.sample(
            "ozl_plan_quarantine_trips_total", q.get("trips"),
            {"digest": digest[:12]},
            help_="Circuit-breaker trips, by plan digest.", type_="counter",
        )

    rl = stats.get("rate_limiter") or {}
    w.sample(
        "ozl_rate_limiter_clients", rl.get("clients"),
        help_="Client buckets currently tracked.",
    )

    # multi-process plane: per-worker liveness and counters
    if stats.get("workers") is not None:
        w.sample(
            "ozl_workers", stats.get("workers"),
            help_="Configured session-worker processes.",
        )
        w.sample(
            "ozl_workers_alive", stats.get("workers_alive"),
            help_="Session-worker processes currently alive.",
        )
        w.sample(
            "ozl_worker_restarts_total", stats.get("worker_restarts"),
            help_="Workers replaced after dying.", type_="counter",
        )
    for ident, snap in sorted((stats.get("per_worker") or {}).items()):
        labels = {"worker": str(ident)}
        for verb, count in sorted((snap.get("requests") or {}).items()):
            w.sample(
                "ozl_worker_requests_total", count, {**labels, "verb": verb},
                help_="Requests handled per worker process.", type_="counter",
            )
        in_use = sum(
            c.get("in_use", 0) for c in (snap.get("sessions") or {}).values()
        )
        w.sample(
            "ozl_worker_sessions_in_use", in_use, labels,
            help_="Checked-out sessions per worker process.",
        )
        coder = snap.get("coder_cache") or {}
        w.sample(
            "ozl_worker_coder_cache_hits_total", coder.get("hits"), labels,
            help_="Coder-table cache hits per worker process.",
            type_="counter",
        )
    return w.render()
