"""Non-blocking connection frontend: one event loop, no thread per socket.

The threaded :class:`~repro.service.server.CompressionServer` spends a whole
thread per connection, most of it blocked in ``recv`` — a few hundred idle
keep-alive clients exhaust the pool, and a slow-loris peer dribbling one byte
per second pins a worker for nothing.  This frontend multiplexes every
connection over a single ``selectors`` event loop instead:

* **Incremental parsing.**  :class:`FrameParser` consumes ``OZS1`` frames
  byte-at-a-time from whatever ``recv`` returns — magic, verb, varint header
  length, msgpack header, body blocks — holding only the current partial
  token plus a disk-spooled body.  A thousand half-open frames cost a
  thousand small buffers, not a thousand threads.
* **Buffered bodies, same compression path.**  A request's body is spooled
  to completion *before* dispatch, then handed to the shared
  :class:`~repro.service.server.RequestCore` as a seekable file.  It is the
  same ``stream_io`` path as the offline CLI — including the known-size
  container layout — so frames stay byte-identical.
* **Paused-read backpressure.**  While a request executes (on a small
  compute thread pool), its connection's read side is unregistered; the
  kernel socket buffer, and eventually the peer's TCP window, absorb any
  pipelined backlog.  Responses stream from the result spool through a
  bounded write buffer — a large result never materializes in memory.
* **Admission before work.**  Per-client token buckets reject over-budget
  requests at header-parse time (the body is discarded, never spooled),
  connection-count overload sheds at accept time with a structured
  ``overloaded`` frame, and the ``RequestCore`` keeps its session-pool
  admission timeout for the compute stage.
* **Deadlines.**  Idle connections get ``idle_timeout``; once a request's
  first byte arrives, the whole frame must land within ``request_timeout``
  — the slow-loris budget.

The loop is transport-only; verbs, counters, and degradation live in
``RequestCore``.  The multi-core plane (``repro.service.plane``) runs one of
these loops per forked session worker, all accepting from one shared
listener.
"""
from __future__ import annotations

import collections
import io
import selectors
import socket
import threading
from concurrent.futures import ThreadPoolExecutor
from time import monotonic
from typing import Callable, Deque, Dict, Iterator, Optional, Tuple

from repro.core.wire import write_varint

from . import protocol as P
from .ratelimit import RateLimiter
from .server import RequestCore, RequestError

__all__ = ["FrameParser", "BufferedBody", "ServiceFrontend"]

#: Write-buffer high watermark: pull response chunks only while below this.
_OUT_WATERMARK = 256 << 10
_RECV_BYTES = 64 << 10

# parser states
_MAGIC, _VERB, _HLEN, _HEADER, _BLEN, _BLOCK = range(6)


class BufferedBody:
    """A fully-received request body: quacks like ``BlockReader`` for
    :class:`RequestCore` (``size_hint``/``limit``/``bytes_read``/``drain``)
    and like a seekable file for ``stream_io`` (known-size container path).
    """

    def __init__(self, f, total: int, size_hint: Optional[int]):
        self._f = f  # spool at position 0; None for a discarded body
        self.size_hint = size_hint
        self.limit: Optional[int] = None  # cap already enforced at parse time
        self.bytes_read = total  # the whole body has already arrived

    def read(self, n: int = -1) -> bytes:
        return self._f.read(n) if self._f is not None else b""

    def seekable(self) -> bool:
        return self._f is not None

    def seek(self, pos: int, whence: int = 0) -> int:
        return self._f.seek(pos, whence)

    def tell(self) -> int:
        return self._f.tell()

    def drain(self) -> int:
        return 0  # nothing unread remains on the wire

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class FrameParser:
    """Incremental ``OZS1`` request parser.

    ``feed(data, on_header)`` consumes whatever arrived and returns the list
    of *completed* requests as ``(verb, header, BufferedBody, reject)``
    tuples.  ``on_header(verb, header)`` runs the moment a header is fully
    parsed — before any body byte is buffered; returning a truthy value
    (e.g. a rate-limit rejection) switches the body to discard mode and is
    passed through as ``reject``.  Malformed input raises
    :class:`~repro.service.protocol.ProtocolError`; the connection owns no
    resync point past that.
    """

    def __init__(self, *, max_body_bytes: int, spool_factory: Callable[[], io.IOBase]):
        self._buf = bytearray()
        self._spool_factory = spool_factory
        self.max_body_bytes = max_body_bytes
        self._reset_request()

    def _reset_request(self) -> None:
        self._state = _MAGIC
        self._need = len(P.REQUEST_MAGIC)
        self._varint = 0
        self._shift = 0
        self._verb: Optional[int] = None
        self._header: Optional[dict] = None
        self._spool = None
        self._body_bytes = 0
        self._reject = None

    @property
    def mid_request(self) -> bool:
        """True once any byte of the next request has been consumed."""
        return self._state != _MAGIC or len(self._buf) > 0

    @property
    def buffered(self) -> int:
        return len(self._buf)

    def _take_varint(self) -> Optional[int]:
        while self._buf:
            b = self._buf[0]
            del self._buf[:1]
            self._varint |= (b & 0x7F) << self._shift
            if not (b & 0x80):
                v = self._varint
                self._varint = 0
                self._shift = 0
                return v
            self._shift += 7
            if self._shift > 63:
                raise P.ProtocolError("varint overflow")
        return None

    def feed(self, data: bytes, on_header=None) -> list:
        self._buf += data
        out = []
        while True:
            if self._state == _MAGIC:
                if len(self._buf) < self._need:
                    break
                got = bytes(self._buf[: self._need])
                del self._buf[: self._need]
                if got != P.REQUEST_MAGIC:
                    raise P.ProtocolError(
                        f"bad magic {got!r} (expected {P.REQUEST_MAGIC!r}; wrong"
                        f" endpoint or a protocol-version mismatch)"
                    )
                self._state = _VERB
            elif self._state == _VERB:
                if not self._buf:
                    break
                verb = self._buf[0]
                del self._buf[:1]
                if verb not in P.VERBS:
                    raise P.ProtocolError(f"unknown verb {verb}")
                self._verb = verb
                self._state = _HLEN
            elif self._state == _HLEN:
                v = self._take_varint()
                if v is None:
                    break
                if v > P.MAX_HEADER_BYTES:
                    raise P.ProtocolError(f"header too large ({v} bytes)")
                self._need = v
                self._state = _HEADER
            elif self._state == _HEADER:
                if len(self._buf) < self._need:
                    break
                blob = bytes(self._buf[: self._need])
                del self._buf[: self._need]
                self._header = P._unpack_header(blob)
                if on_header is not None:
                    self._reject = on_header(self._verb, self._header)
                if self._reject is None:
                    self._spool = self._spool_factory()
                self._state = _BLEN
            elif self._state == _BLEN:
                v = self._take_varint()
                if v is None:
                    break
                if v == 0:
                    out.append(self._finish())
                    continue
                if v > P.MAX_BLOCK_BYTES:
                    raise P.ProtocolError(f"body block too large ({v} bytes)")
                if self._body_bytes + v > self.max_body_bytes:
                    raise P.ProtocolError(
                        f"body exceeds its limit of {self.max_body_bytes}"
                        f" bytes ({self._body_bytes + v}+ sent)"
                    )
                self._need = v
                self._state = _BLOCK
            elif self._state == _BLOCK:
                if not self._buf:
                    break
                take = min(self._need, len(self._buf))
                piece = self._buf[:take]
                del self._buf[:take]
                if self._spool is not None:
                    self._spool.write(piece)
                self._body_bytes += take
                self._need -= take
                if self._need == 0:
                    self._state = _BLEN
        return out

    def _finish(self):
        spool = self._spool
        if spool is not None:
            spool.seek(0)
        body = BufferedBody(
            spool, self._body_bytes, (self._header or {}).get("size")
        )
        req = (self._verb, self._header, body, self._reject)
        self._spool = None
        self._reset_request()
        return req

    def abandon(self) -> None:
        """Drop any partially-spooled body (connection is going away)."""
        if self._spool is not None:
            self._spool.close()
            self._spool = None


def _response_chunks(
    status: int, header: dict, body_file, block_bytes: int = P.DEFAULT_BLOCK_BYTES
) -> Iterator[bytes]:
    """Frame a response lazily — byte-identical to ``protocol.write_response``
    but pulled chunk-by-chunk so a spooled result never sits in memory."""
    blob = P._pack_header(header)
    head = bytearray()
    head += P.RESPONSE_MAGIC
    head.append(status & 0xFF)
    write_varint(head, len(blob))
    head += blob
    yield bytes(head)
    if body_file is not None:
        while True:
            piece = body_file.read(block_bytes)
            if not piece:
                break
            prefix = bytearray()
            write_varint(prefix, len(piece))
            yield bytes(prefix) + piece
    yield b"\x00"


class _Conn:
    __slots__ = (
        "sock", "key", "parser", "out", "source", "body_file", "pending",
        "executing", "close_after_write", "last_activity", "request_started",
        "events",
    )

    def __init__(self, sock: socket.socket, key: str, parser: FrameParser, now: float):
        self.sock = sock
        self.key = key
        self.parser = parser
        self.out = bytearray()
        self.source: Optional[Iterator[bytes]] = None
        self.body_file = None
        self.pending: Deque = collections.deque()
        self.executing = False
        self.close_after_write = False
        self.last_activity = now
        self.request_started: Optional[float] = None
        self.events = 0  # current selector registration (0 = parked)


class ServiceFrontend:
    """One selector loop serving many connections against a ``RequestCore``.

    The listener is *borrowed*: the caller binds it (and, in the plane,
    shares the same fd across forked workers) and decides its lifetime;
    ``owns_listener=True`` closes it on stop for standalone use.
    """

    def __init__(
        self,
        core: RequestCore,
        listener: socket.socket,
        *,
        max_conns: int = 512,
        compute_threads: int = 4,
        idle_timeout: float = 300.0,
        request_timeout: float = 60.0,
        rate_limiter: Optional[RateLimiter] = None,
        owns_listener: bool = False,
        name: str = "ozl-frontend",
    ):
        self.core = core
        self.max_conns = max_conns
        self.idle_timeout = idle_timeout
        self.request_timeout = request_timeout
        self.rate_limiter = rate_limiter
        self._owns_listener = owns_listener
        self._sel = selectors.DefaultSelector()
        self._listener = listener
        listener.setblocking(False)
        self._sel.register(listener, selectors.EVENT_READ, ("accept", None))
        # self-pipe: compute threads finish off-loop and must wake the
        # selector to deliver their results
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._sel.register(self._wake_r, selectors.EVENT_READ, ("wake", None))
        self._completed: Deque = collections.deque()
        self._executor = ThreadPoolExecutor(
            max_workers=compute_threads, thread_name_prefix=name
        )
        self._conns: Dict[socket.socket, _Conn] = {}
        self._aux: Dict[socket.socket, Callable[[], None]] = {}
        self._stopping = threading.Event()
        self._conn_seq = 0
        self._last_scan = 0.0
        #: optional per-iteration hook (the plane worker's heartbeat push);
        #: runs on the loop thread, at most every selector tick
        self.on_tick: Optional[Callable[[], None]] = None
        self.counters = {
            "connections": 0,
            "active_connections": 0,
            "shed_connections": 0,
        }
        # prebuilt accept-overload frame: one optimistic send, then close
        buf = io.BytesIO()
        P.write_response(
            buf,
            P.STATUS_ERROR,
            {
                "error": "server overloaded: connection limit reached",
                "error_kind": "overloaded",
                "retry_after": 0.5,
            },
        )
        self._shed_frame = buf.getvalue()
        # serve transport counters through the stats verb unless the owner
        # (e.g. a plane worker, which aggregates) installs a richer provider
        core.stats_provider = self._default_stats

    def _default_stats(self) -> dict:
        st = {**self.core.stats(), **self.transport_stats()}
        if self.rate_limiter is not None:
            st["rate_limiter"] = self.rate_limiter.stats()
        return st

    # ------------------------------------------------------------ aux readers
    def add_reader(self, sock, callback: Callable[[], None]) -> None:
        """Poll an extra socket (the plane's worker control channel) on this
        loop; ``callback`` runs on the loop thread when it turns readable."""
        sock.setblocking(False)
        self._aux[sock] = callback
        self._sel.register(sock, selectors.EVENT_READ, ("aux", sock))

    # --------------------------------------------------------------- running
    def stop(self) -> None:
        """Ask the loop to exit (thread- and signal-safe)."""
        self._stopping.set()
        self._wake()

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"\x01")
        except (BlockingIOError, BrokenPipeError, OSError):
            pass  # pipe full == a wakeup is already pending

    def serve_forever(self) -> None:
        try:
            while not self._stopping.is_set():
                events = self._sel.select(timeout=0.2)
                for sel_key, _mask in events:
                    kind, payload = sel_key.data
                    if kind == "accept":
                        self._on_accept()
                    elif kind == "wake":
                        self._drain_wake()
                    elif kind == "aux":
                        self._aux[payload]()
                    else:  # a connection
                        conn = payload
                        if _mask & selectors.EVENT_WRITE:
                            self._pump_write(conn)
                        if (
                            _mask & selectors.EVENT_READ
                            and conn.sock in self._conns
                        ):
                            self._on_readable(conn)
                self._drain_completed()
                self._scan_deadlines()
                if self.on_tick is not None:
                    self.on_tick()
        finally:
            self._cleanup()

    def _cleanup(self) -> None:
        for conn in list(self._conns.values()):
            self._close_conn(conn)
        # compute threads may still be running requests; let them finish so
        # pooled sessions are checked back in before the core is torn down
        self._executor.shutdown(wait=True)
        self._drain_completed()  # discard results for already-closed conns
        for sock in list(self._aux):
            try:
                self._sel.unregister(sock)
            except (KeyError, ValueError):
                pass
        try:
            self._sel.unregister(self._wake_r)
        except (KeyError, ValueError):
            pass
        self._wake_r.close()
        self._wake_w.close()
        try:
            self._sel.unregister(self._listener)
        except (KeyError, ValueError):
            pass
        if self._owns_listener:
            try:
                self._listener.close()
            except OSError:
                pass
        self._sel.close()

    # ---------------------------------------------------------------- accept
    def _on_accept(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return  # listener closed (shutdown) or transient accept error
            if self._stopping.is_set():
                sock.close()
                return
            if len(self._conns) >= self.max_conns:
                # shed at the door with a structured frame: one optimistic
                # non-blocking send (the frame is tiny), never a stall
                self.counters["shed_connections"] += 1
                self.core.bump(shed=1)
                try:
                    sock.setblocking(False)
                    sock.send(self._shed_frame)
                except OSError:
                    pass
                sock.close()
                continue
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass  # AF_UNIX
            self._conn_seq += 1
            if isinstance(addr, tuple):
                key = str(addr[0])  # per-peer-IP budget on TCP
            else:
                key = f"conn:{self._conn_seq}"  # Unix peers are indistinct
            now = monotonic()
            conn = _Conn(
                sock,
                key,
                FrameParser(
                    max_body_bytes=self.core.max_body_bytes,
                    spool_factory=self.core._spool,
                ),
                now,
            )
            self._conns[sock] = conn
            self.counters["connections"] += 1
            self.counters["active_connections"] += 1
            self._sel.register(sock, selectors.EVENT_READ, ("conn", conn))
            conn.events = selectors.EVENT_READ

    # ----------------------------------------------------------------- close
    def _close_conn(self, conn: _Conn, *, error: bool = False) -> None:
        if conn.sock not in self._conns:
            return
        if error:
            self.core.bump(errors=1)
        del self._conns[conn.sock]
        self.counters["active_connections"] -= 1
        if conn.events != 0:
            try:
                self._sel.unregister(conn.sock)
            except (KeyError, ValueError):
                pass
            conn.events = 0
        try:
            conn.sock.close()
        except OSError:
            pass
        conn.parser.abandon()
        for _verb, _header, body, _reject in conn.pending:
            body.close()
        conn.pending.clear()
        if conn.body_file is not None:
            conn.body_file.close()
            conn.body_file = None
        conn.source = None
        # an executing request keeps running; _drain_completed sees the conn
        # is gone and just discards the result

    # ------------------------------------------------------------- selectors
    def _update_events(self, conn: _Conn) -> None:
        if conn.sock not in self._conns:
            return
        want = 0
        if conn.out or conn.source is not None:
            want = selectors.EVENT_WRITE
        elif not conn.executing and not conn.pending:
            want = selectors.EVENT_READ
        # executing, or queued behind an in-flight request: parked entirely —
        # backpressure is the kernel socket buffer filling up; deadlines and
        # reset detection resume when the conn re-registers
        if want == conn.events:
            return
        if conn.events != 0:
            self._sel.unregister(conn.sock)
        if want != 0:
            self._sel.register(conn.sock, want, ("conn", conn))
        conn.events = want

    # ------------------------------------------------------------------ read
    def _on_readable(self, conn: _Conn) -> None:
        try:
            data = conn.sock.recv(_RECV_BYTES)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn, error=conn.parser.mid_request)
            return
        if not data:
            # clean hangup between requests is normal; mid-frame it's an error
            self._close_conn(conn, error=conn.parser.mid_request)
            return
        now = monotonic()
        conn.last_activity = now
        self._feed(conn, data, now)

    def _feed(self, conn: _Conn, data: bytes, now: float) -> None:
        try:
            reqs = conn.parser.feed(
                data, on_header=lambda v, h: self._on_header(conn, v, h)
            )
        except P.ProtocolError as err:
            self.core.bump(errors=1)
            self._respond(
                conn,
                P.STATUS_ERROR,
                {"error": f"malformed request: {err}"},
                None,
                close_after=True,
            )
            return
        conn.pending.extend(reqs)
        # the request clock covers the *current partial frame* only
        if conn.parser.mid_request:
            if conn.request_started is None:
                conn.request_started = now
        else:
            conn.request_started = None
        self._maybe_dispatch(conn)

    def _on_header(self, conn: _Conn, verb: int, header: dict):
        if self.rate_limiter is not None and verb in (
            P.VERB_COMPRESS, P.VERB_DECOMPRESS,
        ):
            ok, retry_after = self.rate_limiter.check(conn.key)
            if not ok:
                self.core.bump(verb=P.VERBS[verb], rate_limited=1)
                return (
                    "rate limit exceeded for this client",
                    {
                        "error_kind": "rate_limited",
                        "retry_after": round(max(retry_after, 0.001), 3),
                    },
                )
        return None

    # -------------------------------------------------------------- dispatch
    def _maybe_dispatch(self, conn: _Conn) -> None:
        if (
            conn.executing
            or conn.source is not None
            or conn.out
            or not conn.pending
        ):
            self._update_events(conn)
            return
        verb, header, body, reject = conn.pending.popleft()
        if reject is not None:
            body.close()
            msg, extra = reject
            self.core.bump(errors=1)
            self._respond(conn, P.STATUS_ERROR, {"error": msg, **extra}, None)
            return
        conn.executing = True
        self._update_events(conn)  # reads pause while the request runs
        self._executor.submit(self._execute, conn, verb, header, body)

    def _execute(self, conn: _Conn, verb: int, header: dict, body) -> None:
        """Runs on a compute thread; results travel back via the self-pipe."""
        try:
            try:
                resp_header, out = self.core.handle(verb, header, body)
                result = (P.STATUS_OK, resp_header, out)
            except RequestError as err:
                self.core.bump(errors=1)
                result = (P.STATUS_ERROR, {"error": str(err), **err.extra}, None)
            except Exception as err:  # noqa: BLE001 - answered, not fatal
                self.core.bump(errors=1)
                result = (
                    P.STATUS_ERROR,
                    {"error": f"{type(err).__name__}: {err}"},
                    None,
                )
        finally:
            body.close()
        self._completed.append((conn, result))
        self._wake()

    def _drain_wake(self) -> None:
        try:
            while self._wake_r.recv(4096):
                pass
        except (BlockingIOError, InterruptedError):
            pass
        except OSError:
            pass

    def _drain_completed(self) -> None:
        while self._completed:
            conn, (status, header, out) = self._completed.popleft()
            conn.executing = False
            if conn.sock not in self._conns:
                if out is not None:
                    out.close()
                continue
            self._respond(conn, status, header, out)

    # ----------------------------------------------------------------- write
    def _respond(
        self, conn: _Conn, status: int, header: dict, out, *, close_after=False
    ) -> None:
        conn.body_file = out
        conn.source = _response_chunks(status, header, out)
        conn.close_after_write = conn.close_after_write or close_after
        self._pump_write(conn)

    def _pump_write(self, conn: _Conn) -> None:
        if conn.sock not in self._conns:
            return
        while True:
            while conn.source is not None and len(conn.out) < _OUT_WATERMARK:
                try:
                    conn.out += next(conn.source)
                except StopIteration:
                    conn.source = None
            if not conn.out:
                break
            try:
                n = conn.sock.send(conn.out)
            except (BlockingIOError, InterruptedError):
                self._update_events(conn)
                return
            except OSError:
                self._close_conn(conn, error=False)
                return
            if n <= 0:
                break
            conn.last_activity = monotonic()  # write progress arms the clock
            del conn.out[:n]
        if conn.source is None and not conn.out:
            # response fully flushed
            if conn.body_file is not None:
                conn.body_file.close()
                conn.body_file = None
            if conn.close_after_write:
                self._close_conn(conn)
                return
            conn.last_activity = monotonic()
            # pipelined bytes may already hold the next request
            self._feed(conn, b"", conn.last_activity)
        else:
            self._update_events(conn)

    # ------------------------------------------------------------- deadlines
    def _scan_deadlines(self) -> None:
        now = monotonic()
        if now - self._last_scan < 0.1:
            return
        self._last_scan = now
        for conn in list(self._conns.values()):
            if conn.executing:
                continue  # compute has its own timeouts (pool admission)
            if conn.source is not None or conn.out:
                # a peer that stops reading its response: no write progress
                # within request_timeout means the conn is wedged, not slow
                if now - conn.last_activity > self.request_timeout:
                    self._close_conn(conn, error=True)
            elif conn.request_started is not None:
                if now - conn.request_started > self.request_timeout:
                    # slow-loris: a frame that cannot finish in time
                    self._close_conn(conn, error=True)
            elif now - conn.last_activity > self.idle_timeout:
                self._close_conn(conn)

    # ----------------------------------------------------------------- stats
    def transport_stats(self) -> dict:
        return dict(self.counters)
