"""Blocking client for the compression daemon.

One :class:`ServiceClient` holds one persistent connection; every call is a
complete request/response exchange, so a client object is safe to reuse for
many operations (and cheap: connection setup happens once).  A connection the
server closed cleanly between exchanges (its idle timeout, or a restart) is
re-established transparently: every verb is stateless on the server, so the
request is simply resent once on a fresh connection.  File payloads stream
through in protocol blocks — the client never loads a file whole — and file
outputs are written with the same temp-file + atomic-rename discipline as
``stream_io`` (``client compress F -o F`` is safe).

    with ServiceClient("unix:/tmp/ozl.sock") as c:
        frame, info = c.compress_bytes(b"...", plan="text")
        data, info = c.decompress_bytes(frame)
        c.compress_file("corpus.bin", "corpus.ozl", plan="logs")
        print(c.stats()["requests"])
"""
from __future__ import annotations

import os
import socket
from typing import Callable, Iterable, Optional, Tuple, Union

from repro.core.stream_io import DEFAULT_CHUNK_BYTES, _atomic_sink, _open

from . import protocol as P

__all__ = ["ServiceClient"]

PathOrBytes = Union[bytes, bytearray, memoryview]

# a request body is always passed as a zero-arg factory returning the block
# iterable, so a transparent reconnect can rebuild (and resend) it
BodyFactory = Callable[[], Iterable[bytes]]


class ServiceClient:
    def __init__(
        self,
        address: Union[str, Tuple[str, int]],
        *,
        timeout: float = 60.0,
        block_bytes: int = P.DEFAULT_BLOCK_BYTES,
    ):
        self.address = address
        self.timeout = timeout
        self.block_bytes = block_bytes
        self._connect()

    def _connect(self) -> None:
        family, target = P.parse_address(self.address)
        self._sock = socket.socket(family, socket.SOCK_STREAM)
        self._sock.settimeout(self.timeout)
        self._sock.connect(target)
        self._r = self._sock.makefile("rb")
        self._w = self._sock.makefile("wb")

    # -------------------------------------------------------------- exchange
    def _call(
        self,
        verb: int,
        header: dict,
        body: Optional[BodyFactory] = None,
    ) -> Tuple[dict, P.BlockReader]:
        """One request/response -> (response header, body reader).

        Raises RuntimeError on a server-reported error, ProtocolError on
        malformed traffic.  The caller must drain the returned body before
        issuing the next call.

        A server that closed the connection cleanly before answering (idle
        timeout, restart) gets one transparent retry on a fresh connection —
        the protocol is stateless, so a resend is always safe.  A truncation
        mid-response stays a hard error: fail closed, never guess.
        """
        got = None
        for attempt in (0, 1):
            try:
                P.write_request(
                    self._w, verb, header, body() if body is not None else None
                )
                got = P.read_response_or_eof(self._r)
            except (BrokenPipeError, ConnectionResetError):
                got = None
            if got is not None:
                break
            if attempt:
                raise P.ProtocolError(
                    "server closed the connection before responding"
                )
            self.close()
            self._connect()
        status, resp, rbody = got
        if status == P.STATUS_ERROR:
            rbody.drain()
            raise RuntimeError(
                f"service error: {resp.get('error', 'unknown error')}"
            )
        return resp, rbody

    @staticmethod
    def _nbytes(data: PathOrBytes) -> int:
        # len(memoryview) counts elements, not bytes, for itemsize > 1
        return memoryview(data).nbytes

    def _bytes_body(self, data: PathOrBytes) -> BodyFactory:
        return lambda: P.iter_body_blocks(data, self.block_bytes)

    def _file_body(self, fin) -> BodyFactory:
        """Body factory over an open file; rewinds for a reconnect retry when
        the source is seekable, and refuses the retry (fail closed, with the
        real cause) when it is not."""
        try:
            pos = fin.tell() if fin.seekable() else None
        except (AttributeError, OSError, ValueError):
            pos = None
        used = [False]

        def factory() -> Iterable[bytes]:
            if used[0]:
                if pos is None:
                    raise P.ProtocolError(
                        "connection lost and the request body is not"
                        " rewindable (non-seekable source)"
                    )
                fin.seek(pos)
            used[0] = True
            return P.iter_body_blocks(fin, self.block_bytes)

        return factory

    # -------------------------------------------------------------- commands
    def ping(self) -> dict:
        resp, body = self._call(P.VERB_PING, {})
        body.drain()
        return resp

    def stats(self) -> dict:
        resp, body = self._call(P.VERB_STATS, {})
        body.drain()
        return resp

    def compress_bytes(
        self,
        data: PathOrBytes,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> Tuple[bytes, dict]:
        """Compress an in-memory payload -> (wire frame, server stats)."""
        header = {
            "plan": plan,
            "size": self._nbytes(data),
            "chunk_bytes": int(chunk_bytes or 0),
        }
        resp, body = self._call(P.VERB_COMPRESS, header, self._bytes_body(data))
        return body.read(), resp

    def decompress_bytes(self, frame: PathOrBytes) -> Tuple[bytes, dict]:
        """Universal decode of an in-memory frame -> (content bytes, stats)."""
        resp, body = self._call(
            P.VERB_DECOMPRESS, {"size": self._nbytes(frame)}, self._bytes_body(frame)
        )
        return body.read(), resp

    def compress_file(
        self,
        src,
        dst,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> dict:
        """Stream a file through the daemon -> stats dict (atomic dst)."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {"plan": plan, "chunk_bytes": int(chunk_bytes or 0)}
        if size is not None:
            header["size"] = size
        with _open(src, "rb") as fin:
            resp, body = self._call(P.VERB_COMPRESS, header, self._file_body(fin))
        self._body_to_file(body, dst)
        return resp

    def decompress_file(self, src, dst) -> dict:
        """Stream any frame/container through the universal decoder -> stats."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {} if size is None else {"size": size}
        with _open(src, "rb") as fin:
            resp, body = self._call(
                P.VERB_DECOMPRESS, header, self._file_body(fin)
            )
        self._body_to_file(body, dst)
        return resp

    def _body_to_file(self, body: P.BlockReader, dst) -> None:
        with _atomic_sink(dst) as fout:
            while True:
                piece = body.read(self.block_bytes)
                if not piece:
                    break
                fout.write(piece)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        for f in (self._w, self._r):
            try:
                f.close()
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
