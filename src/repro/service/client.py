"""Blocking client for the compression daemon.

One :class:`ServiceClient` holds one persistent connection; every call is a
complete request/response exchange, so a client object is safe to reuse for
many operations (and cheap: connection setup happens once).  A connection the
server closed cleanly between exchanges (its idle timeout, or a restart) is
re-established transparently: every verb is stateless on the server, so the
request is simply resent once on a fresh connection.  File payloads stream
through in protocol blocks — the client never loads a file whole — and file
outputs are written with the same temp-file + atomic-rename discipline as
``stream_io`` (``client compress F -o F`` is safe).

    with ServiceClient("unix:/tmp/ozl.sock") as c:
        frame, info = c.compress_bytes(b"...", plan="text")
        data, info = c.decompress_bytes(frame)
        c.compress_file("corpus.bin", "corpus.ozl", plan="logs")
        print(c.stats()["requests"])
"""
from __future__ import annotations

import os
import random
import socket
import time
from typing import Callable, Iterable, Optional, Tuple, Union

from repro.core.stream_io import DEFAULT_CHUNK_BYTES, _atomic_sink, _open

from . import protocol as P

__all__ = ["ServiceClient", "ServiceUnavailable", "ConnectionLost"]

PathOrBytes = Union[bytes, bytearray, memoryview]

# a request body is always passed as a zero-arg factory returning the block
# iterable, so a transparent reconnect can rebuild (and resend) it
BodyFactory = Callable[[], Iterable[bytes]]

# server-reported error kinds that mean "try again later", not "your request
# is wrong" — the bounded-retry loop only ever retries these
RETRYABLE_ERROR_KINDS = frozenset(
    {"overloaded", "plan_quarantined", "rate_limited"}
)


class ServiceUnavailable(RuntimeError):
    """The server answered, but declined the request for now (shedding under
    overload, or the plan's circuit breaker is open).  Carries the server's
    ``retry_after`` hint in seconds when one was sent."""

    def __init__(
        self,
        message: str,
        *,
        kind: Optional[str] = None,
        retry_after: Optional[float] = None,
    ):
        super().__init__(message)
        self.kind = kind
        self.retry_after = retry_after


class ConnectionLost(P.ProtocolError):
    """The connection died before a complete response arrived — a server
    restart or a crashed session worker.  Every verb is stateless and a
    request that never got a response is safe to resend, so clients that
    opted into ``retries=`` treat this exactly like an ``overloaded`` answer:
    back off, reconnect, try again (the plane's replacement worker, or a
    surviving sibling on the shared listener, picks the retry up)."""


class ServiceClient:
    def __init__(
        self,
        address: Union[str, Tuple[str, int]],
        *,
        timeout: float = 60.0,
        block_bytes: int = P.DEFAULT_BLOCK_BYTES,
        retries: int = 0,
        backoff_base: float = 0.05,
        backoff_max: float = 2.0,
        rng: Optional[random.Random] = None,
    ):
        self.address = address
        self.timeout = timeout
        self.block_bytes = block_bytes
        # bounded retries for *retryable* server refusals (overload shedding,
        # plan quarantine): exponential backoff with full jitter, floored at
        # the server's retry_after hint.  retries=0 (default) keeps every
        # refusal a hard ServiceUnavailable.
        if retries < 0:
            raise ValueError("retries must be >= 0")
        self.retries = retries
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self._rng = rng if rng is not None else random.Random()
        self._connect()

    def _connect(self) -> None:
        family, target = P.parse_address(self.address)
        sock = socket.socket(family, socket.SOCK_STREAM)
        try:
            sock.settimeout(self.timeout)
            sock.connect(target)
        except BaseException:
            sock.close()
            raise
        self._sock = sock
        self._r = self._sock.makefile("rb")
        self._w = self._sock.makefile("wb")

    # -------------------------------------------------------------- exchange
    def _call(
        self,
        verb: int,
        header: dict,
        body: Optional[BodyFactory] = None,
    ) -> Tuple[dict, P.BlockReader]:
        """One request/response (with bounded retries) -> (header, body).

        Raises :class:`ServiceUnavailable` when the server sheds or the
        plan is quarantined and the retry budget is spent, RuntimeError on any
        other server-reported error, ProtocolError on malformed traffic.
        Connection-level failures — refused while a worker restarts, reset
        when one dies mid-exchange — retry under the same jittered budget.
        The caller must drain the returned body before issuing the next call.
        """
        for attempt in range(self.retries + 1):
            try:
                return self._call_once(verb, header, body)
            except ServiceUnavailable as err:
                if attempt >= self.retries:
                    raise
                self._backoff(attempt, err.retry_after)
            except (ConnectionError, ConnectionLost):
                # ECONNREFUSED / ECONNRESET / died-before-response: the far
                # side is restarting or a worker crashed.  Drop the dead
                # connection now; the next attempt redials from scratch.
                if attempt >= self.retries:
                    raise
                self.close()
                self._backoff(attempt, None)
        raise AssertionError("unreachable")

    def _backoff(self, attempt: int, retry_after: Optional[float]) -> None:
        # full jitter (uniform over [0, cap]) decorrelates a thundering herd
        # of shed clients; the server's retry_after hint is a *floor* — it
        # knows how long the congestion it saw actually lasts
        cap = min(self.backoff_max, self.backoff_base * (2 ** attempt))
        delay = self._rng.uniform(0.0, cap)
        if retry_after:
            delay = max(delay, float(retry_after))
        time.sleep(delay)

    def _call_once(
        self,
        verb: int,
        header: dict,
        body: Optional[BodyFactory] = None,
    ) -> Tuple[dict, P.BlockReader]:
        """A single exchange on the wire.

        A server that closed the connection cleanly before answering (idle
        timeout, restart) gets one transparent retry on a fresh connection —
        the protocol is stateless, so a resend is always safe.  A truncation
        mid-response stays a hard error: fail closed, never guess.
        """
        if self._sock is None:
            self._connect()
        got = None
        for attempt in (0, 1):
            try:
                P.write_request(
                    self._w, verb, header, body() if body is not None else None
                )
                got = P.read_response_or_eof(self._r)
            except (BrokenPipeError, ConnectionResetError):
                got = None
            if got is not None:
                break
            if attempt:
                raise ConnectionLost(
                    "server closed the connection before responding"
                )
            self.close()
            self._connect()
        status, resp, rbody = got
        if status == P.STATUS_ERROR:
            rbody.drain()
            message = f"service error: {resp.get('error', 'unknown error')}"
            kind = resp.get("error_kind")
            if kind in RETRYABLE_ERROR_KINDS:
                retry_after = resp.get("retry_after")
                raise ServiceUnavailable(
                    message,
                    kind=kind,
                    retry_after=None if retry_after is None else float(retry_after),
                )
            raise RuntimeError(message)
        return resp, rbody

    @staticmethod
    def _nbytes(data: PathOrBytes) -> int:
        # len(memoryview) counts elements, not bytes, for itemsize > 1
        return memoryview(data).nbytes

    def _bytes_body(self, data: PathOrBytes) -> BodyFactory:
        return lambda: P.iter_body_blocks(data, self.block_bytes)

    def _file_body(self, fin) -> BodyFactory:
        """Body factory over an open file; rewinds for a reconnect retry when
        the source is seekable, and refuses the retry (fail closed, with the
        real cause) when it is not."""
        try:
            pos = fin.tell() if fin.seekable() else None
        except (AttributeError, OSError, ValueError):
            pos = None
        used = [False]

        def factory() -> Iterable[bytes]:
            if used[0]:
                if pos is None:
                    raise P.ProtocolError(
                        "connection lost and the request body is not"
                        " rewindable (non-seekable source)"
                    )
                fin.seek(pos)
            used[0] = True
            return P.iter_body_blocks(fin, self.block_bytes)

        return factory

    # -------------------------------------------------------------- commands
    def ping(self) -> dict:
        resp, body = self._call(P.VERB_PING, {})
        body.drain()
        return resp

    def stats(self) -> dict:
        resp, body = self._call(P.VERB_STATS, {})
        body.drain()
        return resp

    def metrics(self) -> bytes:
        """Prometheus exposition text (the stats verb with an additive
        ``format`` header key — same counters, scrape-ready rendering)."""
        resp, body = self._call(P.VERB_STATS, {"format": "prometheus"})
        return body.read()

    def compress_bytes(
        self,
        data: PathOrBytes,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> Tuple[bytes, dict]:
        """Compress an in-memory payload -> (wire frame, server stats)."""
        header = {
            "plan": plan,
            "size": self._nbytes(data),
            "chunk_bytes": int(chunk_bytes or 0),
        }
        resp, body = self._call(P.VERB_COMPRESS, header, self._bytes_body(data))
        return body.read(), resp

    def decompress_bytes(self, frame: PathOrBytes) -> Tuple[bytes, dict]:
        """Universal decode of an in-memory frame -> (content bytes, stats)."""
        resp, body = self._call(
            P.VERB_DECOMPRESS, {"size": self._nbytes(frame)}, self._bytes_body(frame)
        )
        return body.read(), resp

    def compress_file(
        self,
        src,
        dst,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> dict:
        """Stream a file through the daemon -> stats dict (atomic dst)."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {"plan": plan, "chunk_bytes": int(chunk_bytes or 0)}
        if size is not None:
            header["size"] = size
        with _open(src, "rb") as fin:
            resp, body = self._call(P.VERB_COMPRESS, header, self._file_body(fin))
        self._body_to_file(body, dst)
        return resp

    def decompress_file(self, src, dst) -> dict:
        """Stream any frame/container through the universal decoder -> stats."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {} if size is None else {"size": size}
        with _open(src, "rb") as fin:
            resp, body = self._call(
                P.VERB_DECOMPRESS, header, self._file_body(fin)
            )
        self._body_to_file(body, dst)
        return resp

    def _body_to_file(self, body: P.BlockReader, dst) -> None:
        with _atomic_sink(dst) as fout:
            while True:
                piece = body.read(self.block_bytes)
                if not piece:
                    break
                fout.write(piece)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._sock is None:
            return
        for f in (self._w, self._r):
            try:
                f.close()
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = None  # _call_once redials on the next use

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
