"""Blocking client for the compression daemon.

One :class:`ServiceClient` holds one persistent connection; every call is a
complete request/response exchange, so a client object is safe to reuse for
many operations (and cheap: connection setup happens once).  File payloads
stream through in protocol blocks — the client never loads a file whole —
and file outputs are written with the same temp-file + atomic-rename
discipline as ``stream_io`` (``client compress F -o F`` is safe).

    with ServiceClient("unix:/tmp/ozl.sock") as c:
        frame, info = c.compress_bytes(b"...", plan="text")
        data, info = c.decompress_bytes(frame)
        c.compress_file("corpus.bin", "corpus.ozl", plan="logs")
        print(c.stats()["requests"])
"""
from __future__ import annotations

import os
import socket
from typing import Iterable, Optional, Tuple, Union

from repro.core.stream_io import DEFAULT_CHUNK_BYTES, _atomic_sink, _open

from . import protocol as P

__all__ = ["ServiceClient"]

PathOrBytes = Union[bytes, bytearray, memoryview]


class ServiceClient:
    def __init__(
        self,
        address: Union[str, Tuple[str, int]],
        *,
        timeout: float = 60.0,
        block_bytes: int = P.DEFAULT_BLOCK_BYTES,
    ):
        family, target = P.parse_address(address)
        self.address = address
        self.block_bytes = block_bytes
        self._sock = socket.socket(family, socket.SOCK_STREAM)
        self._sock.settimeout(timeout)
        self._sock.connect(target)
        self._r = self._sock.makefile("rb")
        self._w = self._sock.makefile("wb")

    # -------------------------------------------------------------- exchange
    def _call(
        self,
        verb: int,
        header: dict,
        body: Optional[Iterable[bytes]] = None,
    ) -> Tuple[dict, P.BlockReader]:
        """One request/response -> (response header, body reader).

        Raises RuntimeError on a server-reported error, ProtocolError on
        malformed traffic.  The caller must drain the returned body before
        issuing the next call.
        """
        P.write_request(self._w, verb, header, body)
        status, resp, rbody = P.read_response(self._r)
        if status == P.STATUS_ERROR:
            rbody.drain()
            raise RuntimeError(
                f"service error: {resp.get('error', 'unknown error')}"
            )
        return resp, rbody

    # -------------------------------------------------------------- commands
    def ping(self) -> dict:
        resp, body = self._call(P.VERB_PING, {})
        body.drain()
        return resp

    def stats(self) -> dict:
        resp, body = self._call(P.VERB_STATS, {})
        body.drain()
        return resp

    def compress_bytes(
        self,
        data: PathOrBytes,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> Tuple[bytes, dict]:
        """Compress an in-memory payload -> (wire frame, server stats)."""
        header = {
            "plan": plan,
            "size": len(data),
            "chunk_bytes": int(chunk_bytes or 0),
        }
        resp, body = self._call(
            P.VERB_COMPRESS, header, P.iter_body_blocks(data, self.block_bytes)
        )
        return body.read(), resp

    def decompress_bytes(self, frame: PathOrBytes) -> Tuple[bytes, dict]:
        """Universal decode of an in-memory frame -> (content bytes, stats)."""
        resp, body = self._call(
            P.VERB_DECOMPRESS,
            {"size": len(frame)},
            P.iter_body_blocks(frame, self.block_bytes),
        )
        return body.read(), resp

    def compress_file(
        self,
        src,
        dst,
        plan: str,
        *,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> dict:
        """Stream a file through the daemon -> stats dict (atomic dst)."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {"plan": plan, "chunk_bytes": int(chunk_bytes or 0)}
        if size is not None:
            header["size"] = size
        with _open(src, "rb") as fin:
            resp, body = self._call(
                P.VERB_COMPRESS, header, P.iter_body_blocks(fin, self.block_bytes)
            )
        self._body_to_file(body, dst)
        return resp

    def decompress_file(self, src, dst) -> dict:
        """Stream any frame/container through the universal decoder -> stats."""
        size = os.path.getsize(src) if isinstance(src, (str, os.PathLike)) else None
        header = {} if size is None else {"size": size}
        with _open(src, "rb") as fin:
            resp, body = self._call(
                P.VERB_DECOMPRESS, header, P.iter_body_blocks(fin, self.block_bytes)
            )
        self._body_to_file(body, dst)
        return resp

    def _body_to_file(self, body: P.BlockReader, dst) -> None:
        with _atomic_sink(dst) as fout:
            while True:
                piece = body.read(self.block_bytes)
                if not piece:
                    break
                fout.write(piece)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        for f in (self._w, self._r):
            try:
                f.close()
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
