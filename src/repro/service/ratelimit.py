"""Per-client token-bucket rate limiting for the service frontend.

A :class:`TokenBucket` meters one client; a :class:`RateLimiter` keeps a
bounded map of buckets keyed by client identity (peer address for TCP, a
per-connection key for Unix sockets, where every peer is local and equally
trusted).  The frontend consults the limiter once per *parsed request header*
— before any body byte is buffered — so a client over its budget costs one
header parse and a drained (never stored) body, not a compression slot.

Rejections are structured, not silent: the frontend answers with
``error_kind="rate_limited"`` and a ``retry_after`` hint computed from the
bucket's actual refill horizon, so well-behaved clients (``ServiceClient``
with ``retries=``) back off for exactly as long as the budget needs.

The clock is injectable for deterministic tests; production uses
``time.monotonic``.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional, Tuple

__all__ = ["TokenBucket", "RateLimiter"]


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/second, ``burst`` capacity.

    ``try_take()`` is O(1) and lock-free (the owner serializes calls — the
    frontend's event loop is single-threaded per process).
    """

    __slots__ = ("rate", "burst", "tokens", "updated")

    def __init__(self, rate: float, burst: float, now: float):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.updated = now

    def try_take(self, now: float, cost: float = 1.0) -> Tuple[bool, float]:
        """Spend ``cost`` tokens -> (allowed, retry_after_seconds).

        ``retry_after`` is 0 when allowed, else the time until the bucket will
        hold ``cost`` tokens again at the configured refill rate.
        """
        elapsed = max(0.0, now - self.updated)
        self.tokens = min(self.burst, self.tokens + elapsed * self.rate)
        self.updated = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True, 0.0
        return False, (cost - self.tokens) / self.rate


class RateLimiter:
    """Bounded map of per-client token buckets.

    ``max_clients`` caps the table: when full, the stalest bucket (oldest
    ``updated``) is evicted — an idle client's budget resets, never an active
    one's.  Thread-safe: the plane's workers each own a limiter, but the
    threaded ``CompressionServer`` consults one from many handler threads.
    """

    def __init__(
        self,
        rate: float,
        burst: Optional[float] = None,
        *,
        max_clients: int = 4096,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(1.0, 2.0 * rate)
        self.max_clients = max_clients
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: Dict[str, TokenBucket] = {}
        self.rejected = 0
        self.allowed = 0

    def check(self, key: str, cost: float = 1.0) -> Tuple[bool, float]:
        """Charge ``cost`` against ``key``'s bucket -> (allowed, retry_after)."""
        now = self._clock()
        with self._lock:
            bucket = self._buckets.get(key)
            if bucket is None:
                if len(self._buckets) >= self.max_clients:
                    stalest = min(
                        self._buckets, key=lambda k: self._buckets[k].updated
                    )
                    del self._buckets[stalest]
                bucket = TokenBucket(self.rate, self.burst, now)
                self._buckets[key] = bucket
            ok, retry_after = bucket.try_take(now, cost)
            if ok:
                self.allowed += 1
            else:
                self.rejected += 1
            return ok, retry_after

    def stats(self) -> dict:
        with self._lock:
            return {
                "rate": self.rate,
                "burst": self.burst,
                "clients": len(self._buckets),
                "allowed": self.allowed,
                "rejected": self.rejected,
            }
