"""Automated compressor training (paper §VI-C): greedy stream clustering +
NSGA-II genetic search over backend graphs + Pareto merge."""
from .cluster import Clustering, cluster_streams  # noqa: F401
from .gp import GNode, compile_genome, crossover, mutate, random_genome  # noqa: F401
from .nsga2 import nsga2, nondominated_sort, pareto_prune  # noqa: F401
from .trainer import (  # noqa: F401
    CsvFrontend,
    Frontend,
    MultiStreamFrontend,
    NumericFrontend,
    StructFrontend,
    TradeoffPoint,
    TrainedCompressor,
    train,
)
