"""Automated compressor training (paper §VI-C): greedy stream clustering +
parallel NSGA-II genetic search over backend graphs + Pareto merge, behind a
deterministic session-backed evaluation service (``TrainerService``)."""
from .cluster import Clustering, cluster_streams  # noqa: F401
from .gp import GNode, compile_genome, crossover, mutate, random_genome  # noqa: F401
from .nsga2 import (  # noqa: F401
    crowding_distance,
    nondominated_sort,
    nsga2,
    pareto_prune,
    rng_stream,
)
from .trainer import (  # noqa: F401
    CsvFrontend,
    Frontend,
    GraphFrontend,
    MultiStreamFrontend,
    NumericFrontend,
    StructFrontend,
    TradeoffPoint,
    TrainedCompressor,
    TrainerService,
    detect_frontend,
    train,
)
