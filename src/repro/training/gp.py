"""Genetic-programming operators over compression graphs (paper §VI-C).

A backend *genome* is a typed tree: each node applies a codec to its input
stream and routes every codec output to a child subtree (terminal = store).
Because a compression graph is "just a reversible computation graph", the
classic GP crossover (swap type-compatible subtrees) and mutation (replace /
insert / delete / re-param) apply directly — the paper's observation.

Type discipline: every edge has a (SType, width) signature; codec menus are
keyed by signature so random genomes are valid by construction.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import GraphBuilder, Plan
from repro.core.message import SType

Sig = Tuple[int, int]  # (stype, width)


@dataclass
class GNode:
    """Genome node: codec applied to one input; children per codec output."""

    codec: str
    params: dict = field(default_factory=dict)
    children: List[Optional["GNode"]] = field(default_factory=list)  # None=store

    def copy(self) -> "GNode":
        return GNode(
            self.codec,
            dict(self.params),
            [c.copy() if c else None for c in self.children],
        )

    def size(self) -> int:
        return 1 + sum(c.size() for c in self.children if c)


# ---------------------------------------------------------------- type rules
def _out_sigs(codec: str, params: dict, sig: Sig) -> Optional[List[Sig]]:
    """Output signatures of `codec` applied to a stream of signature `sig`.
    None => inapplicable.  Mirrors the codec implementations."""
    stype, w = sig
    N, S = int(SType.NUMERIC), int(SType.SERIAL)
    T, G = int(SType.STRUCT), int(SType.STRING)
    if codec == "store":
        return []
    if codec == "delta" or codec == "zigzag":
        return [sig] if stype == N else None
    if codec == "transpose":
        return [(S, 1)] if stype in (N, T) and w > 1 else None
    if codec == "transpose_split":
        return [(S, 1)] * w if stype in (N, T) and w > 1 else None
    if codec == "bitpack" or codec == "range_pack":
        return [(S, 1)] if stype == N else None
    if codec == "rle":
        return [sig, (N, 4)] if stype in (N, S, T) else None
    if codec == "tokenize":
        if stype in (N, S, T):
            return [sig, (N, 4)]  # index width varies; 4 is the upper bound
        if stype == G:
            return [sig, (N, 4)]
        return None
    if codec == "huffman" or codec == "fse":
        return [(S, 1), (N, 8 if codec == "huffman" else 4)] if (
            stype == S or (stype == N and w == 1) or (stype == T and w == 1)
        ) else None
    if codec == "lz77":
        return [(S, 1), (N, 4), (N, 4), (N, 4)] if stype in (S, N, T) else None
    if codec in ("zlib_backend", "lzma_backend", "bz2_backend"):
        return [(S, 1)] if stype != G else None
    if codec == "float_split":
        if stype == N and w in (2, 4, 8):
            return [(S, 1), (N, 2 if w == 8 else 1), (N, {2: 1, 4: 4, 8: 8}[w])]
        return None
    if codec == "interpret_numeric":
        want = params.get("width", w)
        return [(N, want)] if stype in (S, T) and want in (1, 2, 4, 8) else None
    if codec == "string_split":
        return [(S, 1), (N, 4)] if stype == G else None
    if codec == "parse_numeric":
        return [(S, 1), (N, 8), (G, 1)] if stype == G else None
    return None


MENU: Dict[int, List[str]] = {
    int(SType.NUMERIC): [
        "store",
        "delta",
        "zigzag",
        "transpose",
        "transpose_split",
        "bitpack",
        "range_pack",
        "rle",
        "tokenize",
        "huffman",
        "fse",
        "zlib_backend",
        "lzma_backend",
        "bz2_backend",
        "float_split",
        "lz77",
    ],
    int(SType.SERIAL): ["store", "huffman", "fse", "zlib_backend", "lzma_backend", "bz2_backend", "lz77", "rle", "tokenize"],
    int(SType.STRUCT): ["store", "transpose", "transpose_split", "interpret_numeric", "tokenize", "zlib_backend", "lzma_backend", "bz2_backend"],
    int(SType.STRING): ["store", "tokenize", "string_split", "parse_numeric"],
}

_VARIADIC_OUT = {"transpose_split": lambda sig: sig[1]}
_FIXED_OUT = {
    "store": 0, "delta": 1, "zigzag": 1, "transpose": 1, "bitpack": 1,
    "range_pack": 1, "rle": 2, "tokenize": 2, "huffman": 2, "fse": 2,
    "lz77": 4, "zlib_backend": 1, "lzma_backend": 1, "bz2_backend": 1, "float_split": 3, "interpret_numeric": 1,
    "string_split": 2, "parse_numeric": 3,
}


def n_out_for(codec: str, params: dict, sig: Sig) -> int:
    if codec in _VARIADIC_OUT:
        return _VARIADIC_OUT[codec](sig)
    return _FIXED_OUT[codec]


def _default_params(codec: str, sig: Sig, rng: random.Random) -> dict:
    if codec == "zlib_backend":
        return {"level": rng.choice([1, 6, 9])}
    if codec == "lzma_backend":
        return {"preset": rng.choice([0, 6, 9])}
    if codec == "bz2_backend":
        return {"level": 9}
    if codec == "fse":
        return {"table_log": rng.choice([10, 11, 12])}
    if codec == "interpret_numeric":
        w = sig[1]
        return {"width": w if w in (1, 2, 4, 8) else 1}
    if codec == "float_split":
        return {"fmt": {2: 0, 4: 2, 8: 3}.get(sig[1], 2)}
    return {}


def random_genome(sig: Sig, rng: random.Random, depth: int = 0, max_depth: int = 3) -> Optional[GNode]:
    """Random typed genome; None = store terminal."""
    if depth >= max_depth or rng.random() < 0.25 * depth:
        return None
    menu = [c for c in MENU.get(sig[0], ["store"]) if _out_sigs(c, {}, sig) is not None]
    if not menu:
        return None
    codec = rng.choice(menu)
    if codec == "store":
        return None
    params = _default_params(codec, sig, rng)
    outs = _out_sigs(codec, params, sig)
    if outs is None:
        return None
    node = GNode(codec, params)
    node.children = [random_genome(o, rng, depth + 1, max_depth) for o in outs]
    return node


# --------------------------------------------------------- genome -> Plan
def emit_genome(g: GraphBuilder, genome: Optional[GNode], edge: int, sig: Sig) -> None:
    """Inline a genome into an existing builder, rooted at `edge`.

    Permissive: a codec applied off its `_out_sigs` menu still *emits* (with
    children typed best-effort) — the compiled plan is ill-typed, and either
    the trainer's static pruning or the trial compression rejects it.  This
    keeps "can this genome be built?" (syntax) separate from "is it typed?"
    (the analyzer's job), so pruning measurably replaces failed encodes
    instead of hiding behind a construction-time raise.
    """
    if genome is None:
        return  # terminal: stream stored as-is
    outs_sigs = _out_sigs(genome.codec, genome.params, sig)
    n_out = n_out_for(genome.codec, genome.params, sig)
    if outs_sigs is None:
        outs_sigs = [sig] * n_out
    outs = g.add(genome.codec, edge, n_out=n_out, **genome.params)
    if isinstance(outs, int):
        outs = [outs]
    kids = genome.children + [None] * (len(outs) - len(genome.children))
    for child, oe, osig in zip(kids, outs, outs_sigs):
        emit_genome(g, child, oe, osig)


def compile_genome(genome: Optional[GNode], sig: Sig, n_inputs: int = 1) -> Plan:
    g = GraphBuilder(n_inputs)
    src = g.input(0)
    if n_inputs > 1:  # cluster grouping: concat first (paper §IV grouping)
        src = g.add("concat", *[g.input(i) for i in range(n_inputs)])
    emit_genome(g, genome, src, sig)
    return g.build("genome")


# ------------------------------------------------------------- GP operators
def _collect(node: GNode, sig: Sig, path=()):
    """Yield (path, node, sig) for every genome node."""
    yield path, node, sig
    outs = _out_sigs(node.codec, node.params, sig) or []
    for k, (child, osig) in enumerate(zip(node.children, outs)):
        if child is not None:
            yield from _collect(child, osig, path + (k,))


def _get(node: GNode, path):
    for k in path:
        node = node.children[k]
    return node


def _set(root: Optional[GNode], path, value: Optional[GNode]) -> Optional[GNode]:
    if not path:
        return value
    root = root.copy()
    cur = root
    for k in path[:-1]:
        cur.children[k] = cur.children[k].copy()
        cur = cur.children[k]
    cur.children[path[-1]] = value
    return root


def mutate(genome: Optional[GNode], sig: Sig, rng: random.Random) -> Optional[GNode]:
    if genome is None:
        return random_genome(sig, rng, depth=1)
    nodes = list(_collect(genome, sig))
    path, node, nsig = rng.choice(nodes)
    op = rng.random()
    if op < 0.4:  # replace subtree with a fresh random one
        return _set(genome, path, random_genome(nsig, rng, depth=1))
    if op < 0.6:  # delete (prune to terminal)
        return _set(genome, path, None)
    if op < 0.8:  # re-param
        new = node.copy()
        new.params = _default_params(node.codec, nsig, rng)
        return _set(genome, path, new)
    # insert: wrap subtree under a new compatible node (child 0)
    menu = [c for c in MENU.get(nsig[0], []) if c != "store" and _out_sigs(c, _default_params(c, nsig, rng), nsig)]
    if not menu:
        return genome
    codec = rng.choice(menu)
    params = _default_params(codec, nsig, rng)
    outs = _out_sigs(codec, params, nsig)
    wrapper = GNode(codec, params, [None] * len(outs))
    if outs and outs[0] == nsig:
        wrapper.children[0] = node.copy()
    return _set(genome, path, wrapper)


def crossover(
    a: Optional[GNode], b: Optional[GNode], sig: Sig, rng: random.Random
) -> Optional[GNode]:
    if a is None or b is None:
        return (b or a).copy() if (b or a) else None
    na = list(_collect(a, sig))
    nb = list(_collect(b, sig))
    # pick a donor subtree from b whose signature matches a cut point in a
    rng.shuffle(na)
    for path, _node, nsig in na:
        donors = [n for _, n, s in nb if s == nsig]
        if donors:
            return _set(a, path, rng.choice(donors).copy())
    return a.copy()
