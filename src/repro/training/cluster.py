"""Greedy stream clustering (paper §VI-C, first training stage).

Initially every parsed stream is its own cluster; the trainer greedily merges
the pair whose combined compressed size is smaller than the sum of the
individual sizes, repeating until a local minimum.  Only same-signature
streams may merge (concat requires it), which also bounds the pair set.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import CompressionCtx, compress
from repro.core.graph import GraphBuilder, Plan
from repro.core.message import Stream, SType


def _concat_streams(streams: Sequence[Stream]) -> Stream:
    s0 = streams[0]
    if len(streams) == 1:
        return s0
    if s0.stype == SType.STRING:
        return Stream(
            np.concatenate([s.data for s in streams]),
            SType.STRING,
            1,
            np.concatenate([s.lengths for s in streams]).astype(np.uint32),
        )
    # unsigned bit views: mixed i64/u64 would promote to f64 (lossy!)
    parts = [
        s.as_unsigned().data if s.stype == SType.NUMERIC else s.data for s in streams
    ]
    return Stream(np.concatenate(parts), s0.stype, s0.width)


def _probe_plan(sig: Tuple[int, int]) -> Plan:
    """Cheap, codec-agnostic size probe used for cluster decisions: the
    generic auto selector at a fast level."""
    g = GraphBuilder(1)
    g.select("generic_auto", g.input(0))
    return g.build("probe")


def _size_of(streams: Sequence[Stream], level: int) -> int:
    s = _concat_streams(streams)
    sig = (int(s.stype), s.width)
    try:
        # bypass the resolve cache: probes compare selector choices across
        # many same-shape streams, so each must expand on its own data
        return len(
            compress(
                _probe_plan(sig),
                [s],
                ctx=CompressionCtx(level=level),
                use_resolve_cache=False,
            )
        )
    except Exception:
        return s.nbytes + 64


@dataclass
class Clustering:
    clusters: List[List[int]]  # stream indices per cluster
    sizes: List[int]  # probe compressed size per cluster

    def assignment(self) -> Dict[int, int]:
        return {i: c for c, idxs in enumerate(self.clusters) for i in idxs}


def cluster_streams(
    streams: Sequence[Stream],
    *,
    level: int = 5,
    max_rounds: int = 64,
    pool_map: Optional[Callable[[Callable, Sequence], List]] = None,
) -> Clustering:
    """Greedy same-signature merging; ``pool_map`` (an ordered parallel map,
    e.g. ``TrainerService.map``) fans the per-round candidate-pair probes
    out.  Probe sizes are a pure function of the streams, and the winning
    pair is picked from the ordered result list, so the clustering is
    identical with or without a pool."""
    pool_map = pool_map or (lambda fn, items: [fn(x) for x in items])
    sigs = [(int(s.stype), s.width) for s in streams]
    clusters: List[List[int]] = [[i] for i in range(len(streams))]
    sizes: List[int] = pool_map(
        lambda i: _size_of([streams[i]], level), range(len(streams))
    )

    for _ in range(max_rounds):
        pairs = [
            (a, b)
            for a in range(len(clusters))
            for b in range(a + 1, len(clusters))
            if sigs[clusters[a][0]] == sigs[clusters[b][0]]
        ]
        msizes = pool_map(
            lambda ab: _size_of(
                [streams[i] for i in clusters[ab[0]] + clusters[ab[1]]], level
            ),
            pairs,
        )
        best = None  # (gain, a, b, merged_size)
        for (a, b), msize in zip(pairs, msizes):
            gain = sizes[a] + sizes[b] - msize
            if gain > 0 and (best is None or gain > best[0]):
                best = (gain, a, b, msize)
        if best is None:
            break  # local minimum (paper: "repeats until local minimum")
        _, a, b, msize = best
        clusters[a] = clusters[a] + clusters[b]
        sizes[a] = msize
        del clusters[b], sizes[b]
    return Clustering(clusters, sizes)
