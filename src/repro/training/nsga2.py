"""NSGA-II (Deb et al. 2002) — the multi-objective engine behind the paper's
backend graph generator (§VI-C): fast nondominated sort, crowding distance,
binary tournament, elitist environmental selection."""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Generic, List, Sequence, Tuple, TypeVar

T = TypeVar("T")

Objectives = Tuple[float, ...]  # minimized


def dominates(a: Objectives, b: Objectives) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def nondominated_sort(objs: Sequence[Objectives]) -> List[List[int]]:
    n = len(objs)
    S = [[] for _ in range(n)]
    dom_count = [0] * n
    fronts: List[List[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if dominates(objs[p], objs[q]):
                S[p].append(q)
            elif dominates(objs[q], objs[p]):
                dom_count[p] += 1
        if dom_count[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                dom_count[q] -= 1
                if dom_count[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return fronts[:-1]


def crowding_distance(objs: Sequence[Objectives], front: Sequence[int]) -> dict:
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: math.inf for i in front}
    m = len(objs[0])
    for k in range(m):
        srt = sorted(front, key=lambda i: objs[i][k])
        lo, hi = objs[srt[0]][k], objs[srt[-1]][k]
        dist[srt[0]] = dist[srt[-1]] = math.inf
        if hi == lo:
            continue
        for j in range(1, len(srt) - 1):
            dist[srt[j]] += (objs[srt[j + 1]][k] - objs[srt[j - 1]][k]) / (hi - lo)
    return dist


def pareto_prune(
    items: List[T], objs: List[Objectives], keep: int
) -> Tuple[List[T], List[Objectives]]:
    """The paper's merge step: keep `keep` items, preferring better fronts and
    within a front the highest crowding distance (§VI-C last paragraph)."""
    fronts = nondominated_sort(objs)
    out_idx: List[int] = []
    for front in fronts:
        if len(out_idx) + len(front) <= keep:
            out_idx.extend(front)
        else:
            dist = crowding_distance(objs, front)
            ranked = sorted(front, key=lambda i: -dist[i])
            out_idx.extend(ranked[: keep - len(out_idx)])
            break
    return [items[i] for i in out_idx], [objs[i] for i in out_idx]


@dataclass
class NSGA2Result(Generic[T]):
    pareto: List[T]
    pareto_objs: List[Objectives]
    evaluations: int


def nsga2(
    seed_pop: List[T],
    evaluate: Callable[[T], Objectives],
    mutate: Callable[[T, random.Random], T],
    crossover: Callable[[T, T, random.Random], T],
    *,
    pop_size: int = 20,
    generations: int = 10,
    rng: random.Random = None,
) -> NSGA2Result:
    rng = rng or random.Random(0)
    pop: List[T] = list(seed_pop)[:pop_size]
    while len(pop) < pop_size:
        pop.append(mutate(rng.choice(seed_pop), rng))
    objs = [evaluate(p) for p in pop]
    evals = len(pop)

    def tournament() -> T:
        i, j = rng.randrange(len(pop)), rng.randrange(len(pop))
        return pop[i] if dominates(objs[i], objs[j]) or rng.random() < 0.5 else pop[j]

    for _gen in range(generations):
        children: List[T] = []
        while len(children) < pop_size:
            a, b = tournament(), tournament()
            c = crossover(a, b, rng) if rng.random() < 0.7 else a
            if rng.random() < 0.6:
                c = mutate(c, rng)
            children.append(c)
        child_objs = [evaluate(c) for c in children]
        evals += len(children)
        merged = pop + children
        merged_objs = objs + child_objs
        pop, objs = pareto_prune(merged, merged_objs, pop_size)

    fronts = nondominated_sort(objs)
    first = fronts[0] if fronts else []
    return NSGA2Result([pop[i] for i in first], [objs[i] for i in first], evals)
