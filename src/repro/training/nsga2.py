"""NSGA-II (Deb et al. 2002) — the multi-objective engine behind the paper's
backend graph generator (§VI-C): fast nondominated sort, crowding distance,
binary tournament, elitist environmental selection.

Evaluation is *batched*: each generation hands the full child population to
one ``evaluate_batch`` callable, which is free to fan the candidates out
across a worker pool.  Variation is driven by per-child RNG streams derived
from ``(seed, generation, child_index)`` — never from a shared sequential RNG
interleaved with evaluation — so the evolved population is a pure function of
the seed, independent of worker count or evaluation completion order.
"""
from __future__ import annotations

import hashlib
import math
import random
from dataclasses import dataclass
from typing import Callable, Generic, List, Sequence, Tuple, TypeVar

T = TypeVar("T")

Objectives = Tuple[float, ...]  # minimized


def rng_stream(seed: int, *key) -> random.Random:
    """A deterministic, independent RNG stream for ``(seed, *key)``.

    Stable across processes and Python versions (keyed blake2b, not
    ``hash()``), so identically seeded runs replay identical genomes no
    matter how evaluation is scheduled.
    """
    digest = hashlib.blake2b(
        repr((int(seed),) + tuple(key)).encode(), digest_size=8
    ).digest()
    return random.Random(int.from_bytes(digest, "big"))


def dominates(a: Objectives, b: Objectives) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def nondominated_sort(objs: Sequence[Objectives]) -> List[List[int]]:
    n = len(objs)
    S = [[] for _ in range(n)]
    dom_count = [0] * n
    fronts: List[List[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if dominates(objs[p], objs[q]):
                S[p].append(q)
            elif dominates(objs[q], objs[p]):
                dom_count[p] += 1
        if dom_count[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                dom_count[q] -= 1
                if dom_count[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return fronts[:-1]


def crowding_distance(objs: Sequence[Objectives], front: Sequence[int]) -> dict:
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: math.inf for i in front}
    m = len(objs[0])
    for k in range(m):
        srt = sorted(front, key=lambda i: objs[i][k])
        lo, hi = objs[srt[0]][k], objs[srt[-1]][k]
        dist[srt[0]] = dist[srt[-1]] = math.inf
        if hi == lo:
            continue
        for j in range(1, len(srt) - 1):
            dist[srt[j]] += (objs[srt[j + 1]][k] - objs[srt[j - 1]][k]) / (hi - lo)
    return dist


def pareto_prune(
    items: List[T], objs: List[Objectives], keep: int
) -> Tuple[List[T], List[Objectives]]:
    """The paper's merge step: keep `keep` items, preferring better fronts and
    within a front the highest crowding distance (§VI-C last paragraph)."""
    fronts = nondominated_sort(objs)
    out_idx: List[int] = []
    for front in fronts:
        if len(out_idx) + len(front) <= keep:
            out_idx.extend(front)
        else:
            dist = crowding_distance(objs, front)
            ranked = sorted(front, key=lambda i: -dist[i])
            out_idx.extend(ranked[: keep - len(out_idx)])
            break
    return [items[i] for i in out_idx], [objs[i] for i in out_idx]


@dataclass
class NSGA2Result(Generic[T]):
    pareto: List[T]
    pareto_objs: List[Objectives]
    evaluations: int


def nsga2(
    seed_pop: List[T],
    evaluate_batch: Callable[[List[T]], List[Objectives]],
    mutate: Callable[[T, random.Random], T],
    crossover: Callable[[T, T, random.Random], T],
    *,
    pop_size: int = 20,
    generations: int = 10,
    seed: int = 0,
) -> NSGA2Result:
    """Evolve ``seed_pop`` under batched evaluation.

    ``evaluate_batch(pop) -> [objectives]`` must be a pure function of each
    candidate (it may run candidates concurrently and in any order).  Given
    that, the returned Pareto set is byte-identical for any scheduling of the
    batch — the determinism contract ``repro train`` relies on.
    """
    pop: List[T] = list(seed_pop)[:pop_size]
    for i in range(len(pop), pop_size):
        r = rng_stream(seed, "fill", i)
        pop.append(mutate(r.choice(seed_pop), r))
    objs = list(evaluate_batch(pop))
    evals = len(pop)

    def tournament(r: random.Random) -> T:
        i, j = r.randrange(len(pop)), r.randrange(len(pop))
        return pop[i] if dominates(objs[i], objs[j]) or r.random() < 0.5 else pop[j]

    for gen in range(generations):
        children: List[T] = []
        for i in range(pop_size):
            r = rng_stream(seed, "child", gen, i)
            a, b = tournament(r), tournament(r)
            c = crossover(a, b, r) if r.random() < 0.7 else a
            if r.random() < 0.6:
                c = mutate(c, r)
            children.append(c)
        child_objs = list(evaluate_batch(children))
        evals += len(children)
        merged = pop + children
        merged_objs = objs + child_objs
        pop, objs = pareto_prune(merged, merged_objs, pop_size)

    fronts = nondominated_sort(objs)
    first = fronts[0] if fronts else []
    return NSGA2Result([pop[i] for i in first], [objs[i] for i in first], evals)
