"""End-to-end compressor training (paper §VI-C) — the ``zli-train`` analogue.

Pipeline: frontend-parse sample files into streams -> greedy clustering ->
per-cluster NSGA-II backend search (objectives: compressed bytes, encode
cost) -> iterative Pareto merge across clusters pruned by crowding
distance -> a set of deployable tradeoff-point compressors (serializable
Plans, paper §V-D).

Candidate evaluation runs through :class:`TrainerService`: a persistent
worker pool fanning genome evaluations out over long-lived
:class:`~repro.core.engine.CompressorSession` objects that share one
coder-table :class:`~repro.core.engine.ExecScratch` and the engine's resolve
cache (keyed per compiled genome, so elitist survivors re-evaluate without
re-resolving).  Training is *deterministic*: the NSGA-II speed objective is a
calibrated per-codec cost model over the executed step trace — a pure
function of (genome, sample) — never a wall-clock measurement, and variation
uses per-genome RNG streams (:func:`~repro.training.nsga2.rng_stream`).  The
same seed therefore yields byte-identical Pareto plans for any worker count.
Wall-clock per-candidate timings (``time.perf_counter``, the benchmarks'
clock path) are still recorded — in ``stats`` — for reporting.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.codec import get_codec
from repro.core.engine import (
    CompressionCtx,
    CompressorSession,
    DecompressorSession,
    ExecScratch,
)
from repro.core.graph import GraphBuilder, Plan
from repro.core.message import Stream, SType

from .cluster import Clustering, _concat_streams, cluster_streams
from .gp import GNode, compile_genome, crossover, emit_genome, mutate, random_genome
from .nsga2 import nsga2, pareto_prune, rng_stream

SAMPLE_LIMIT = 1 << 18  # per-cluster evaluation sample (256 KiB)

INVALID = (float("inf"), float("inf"))  # objectives of a broken genome


# ------------------------------------------------------------------ frontends
@dataclass
class Frontend:
    """How raw input bytes become typed streams + the plan prefix for it."""

    name: str = "raw"

    @property
    def n_inputs(self) -> int:
        return 1

    def parse(self, inputs: Sequence[Stream]) -> List[Stream]:
        return list(inputs)

    def emit(self, g: GraphBuilder) -> List[int]:
        return [g.input(i) for i in range(self.n_inputs)]


@dataclass
class CsvFrontend(Frontend):
    n_cols: int = 0
    sep: str = ","
    name: str = "csv"

    def parse(self, inputs):
        outs, _ = get_codec("csv_split").run_encode(
            list(inputs), {"sep": self.sep}
        )
        if len(outs) != self.n_cols:
            raise ValueError(f"csv has {len(outs)} cols, expected {self.n_cols}")
        return outs

    def emit(self, g):
        cols = g.add("csv_split", g.input(0), n_out=self.n_cols, sep=self.sep)
        return cols if isinstance(cols, list) else [cols]


@dataclass
class StructFrontend(Frontend):
    widths: Tuple[int, ...] = ()
    name: str = "struct"

    def parse(self, inputs):
        outs, _ = get_codec("field_split").run_encode(
            list(inputs), {"widths": list(self.widths)}
        )
        return outs

    def emit(self, g):
        fields = g.add(
            "field_split", g.input(0), n_out=len(self.widths), widths=list(self.widths)
        )
        return fields if isinstance(fields, list) else [fields]


@dataclass
class NumericFrontend(Frontend):
    width: int = 4
    name: str = "numeric"

    def parse(self, inputs):
        outs, _ = get_codec("interpret_numeric").run_encode(
            list(inputs), {"width": self.width}
        )
        return outs

    def emit(self, g):
        return [g.add("interpret_numeric", g.input(0), width=self.width)]


@dataclass
class GraphFrontend(Frontend):
    """Edge-list graphs: ``edge_list``/``edge_list_bin`` + ``adj_gap`` so the
    genome search runs over Zuckerli-shaped streams (nodes, degrees, refs,
    copy-bits, gaps [, parse bitmap, exception lines]) instead of raw text."""

    sep: str = "auto"
    window: int = 8
    binary_width: int = 0  # 0 = text edge list; 2/4/8 = binary (u, v) pairs
    name: str = "graph"

    def parse(self, inputs):
        if self.binary_width:
            cols, _ = get_codec("edge_list_bin").run_encode(
                list(inputs), {"width": self.binary_width}
            )
            src, dst = cols
            extra: List[Stream] = []
        else:
            outs, _ = get_codec("edge_list").run_encode(
                list(inputs), {"sep": self.sep}
            )
            src, dst, bitmap, exc = outs
            extra = [bitmap, exc]
        adj, _ = get_codec("adj_gap").run_encode([src, dst], {"window": self.window})
        return list(adj) + extra

    def emit(self, g):
        if self.binary_width:
            src, dst = g.add("edge_list_bin", g.input(0), width=self.binary_width)
            extra = []
        else:
            src, dst, bitmap, exc = g.add("edge_list", g.input(0), sep=self.sep)
            extra = [bitmap, exc]
        adj = g.add("adj_gap", src, dst, window=self.window)
        return list(adj) + extra


@dataclass
class MultiStreamFrontend(Frontend):
    """Inputs are already typed streams (e.g. Parquet-decoded columns)."""

    k: int = 1
    name: str = "multistream"

    @property
    def n_inputs(self) -> int:
        return self.k


def detect_frontend(raw: bytes) -> Frontend:
    """``--frontend auto``: pick a frontend by sniffing sample bytes.

    Detection order encodes signal strength: text edge lists first (two
    canonical integers per line under a whitespace separator is stricter
    than any CSV rule — comma edge files still sniff as CSV, which subsumes
    them), then rectangular CSV, then binary interleaved (src, dst) edge
    pairs, then *sorted* fixed-width integers, then fixed-size records
    (split into per-offset byte columns so clustering and the per-cluster
    search see each field position on its own), then bounded integers, and
    finally raw bytes.  Binary edge pairs outrank sorted-numeric because a
    source-sorted u32 pair stream re-read at width 8 *is* mostly monotone
    (the neighbor column dominates the high half); sorted-numeric outranks
    struct because a sorted array is itself lag-periodic; bounded-numeric
    ranks below struct because multi-field records also show a constant top
    byte.  Heuristics live in :mod:`repro.codecs.parse` next to the parser
    codecs they route to.
    """
    from repro.codecs.parse import (
        sniff_csv,
        sniff_edge_list,
        sniff_edge_list_bin,
        sniff_numeric_width,
        sniff_struct_width,
    )

    sep = sniff_edge_list(raw)
    if sep is not None:
        return GraphFrontend(sep=sep)
    csv = sniff_csv(raw)
    if csv is not None:
        return CsvFrontend(n_cols=csv[0], sep=csv[1])
    bw = sniff_edge_list_bin(raw)
    if bw is not None:
        return GraphFrontend(binary_width=bw)
    width = sniff_numeric_width(raw, require_monotone=True)
    if width is not None:
        return NumericFrontend(width=width)
    rec = sniff_struct_width(raw)
    if rec is not None:
        # a "record" of a numeric storage width whose values also read as
        # bounded integers is an integer column, not a struct
        if rec in (2, 4, 8) and sniff_numeric_width(raw, widths=(rec,)) == rec:
            return NumericFrontend(width=rec)
        return StructFrontend(widths=(1,) * rec)
    width = sniff_numeric_width(raw)
    if width is not None:
        return NumericFrontend(width=width)
    return Frontend()


# ----------------------------------------------------------- trained result
@dataclass
class TradeoffPoint:
    genomes: List[Optional[GNode]]  # one per cluster
    est_size: float  # compressed bytes of the training sample
    est_time: float  # deterministic encode-cost estimate, seconds (cost model)


@dataclass
class TrainedCompressor:
    frontend: Frontend
    clustering: Clustering
    sigs: List[Tuple[int, int]]  # signature per cluster
    points: List[TradeoffPoint]  # Pareto tradeoff points (size-ordered)
    stats: Dict[str, float] = field(default_factory=dict)

    def build_plan(self, point: TradeoffPoint) -> Plan:
        g = GraphBuilder(self.frontend.n_inputs)
        stream_edges = self.frontend.emit(g)
        for ci, idxs in enumerate(self.clustering.clusters):
            edges = [stream_edges[i] for i in idxs]
            src = edges[0] if len(edges) == 1 else g.add("concat", *edges)
            emit_genome(g, point.genomes[ci], src, self.sigs[ci])
        return g.build(f"trained_{self.frontend.name}")

    def best_ratio_plan(self) -> Plan:
        return self.build_plan(min(self.points, key=lambda p: p.est_size))

    def fastest_plan(self) -> Plan:
        return self.build_plan(min(self.points, key=lambda p: p.est_time))

    def pareto_plans(self) -> List[Tuple[Plan, float, float]]:
        return [
            (self.build_plan(p), p.est_size, p.est_time)
            for p in sorted(self.points, key=lambda p: p.est_size)
        ]


# ------------------------------------------------------------------- training
def _sample_stream(s: Stream, limit: int = SAMPLE_LIMIT) -> Stream:
    if s.nbytes <= limit:
        return s
    if s.stype == SType.STRING:
        cut = int(np.searchsorted(np.cumsum(s.lengths), limit)) + 1
        cut = min(cut, int(s.lengths.size))
        nb = int(s.lengths[:cut].sum())
        return Stream(s.data[:nb], SType.STRING, 1, s.lengths[:cut])
    n_elts = max(limit // max(s.width, 1), 1)
    if s.stype == SType.NUMERIC:
        return Stream(s.data[:n_elts], s.stype, s.width)
    take = n_elts * (s.width if s.stype == SType.STRUCT else 1)
    return Stream(s.data[:take], s.stype, s.width)


def _seed_genomes(sig: Tuple[int, int]) -> List[Optional[GNode]]:
    """Paper: "population is seeded with simple but commonly effective
    compression graphs"."""
    N, S, T, G = (int(x) for x in (SType.NUMERIC, SType.SERIAL, SType.STRUCT, SType.STRING))
    stype, w = sig
    seeds: List[Optional[GNode]] = [
        None,
        GNode("zlib_backend", {"level": 6}),
    ]
    if stype != G:
        seeds.append(GNode("lzma_backend", {"preset": 6}))
        seeds.append(GNode("bz2_backend", {"level": 9}))
    if stype == N:
        seeds += [
            GNode("range_pack"),
            GNode("delta", {}, [GNode("range_pack")]),
            GNode("transpose", {}, [GNode("huffman")]),
            GNode("delta", {}, [GNode("transpose", {}, [GNode("fse", {"table_log": 11})])]),
            GNode("delta", {}, [GNode("transpose", {}, [GNode("lzma_backend", {"preset": 6})])]),
            GNode("delta", {}, [GNode("lzma_backend", {"preset": 6})]),
            GNode("tokenize", {}, [None, GNode("range_pack")]),
            # sparse/run-heavy data (era5 snow/precip): RLE first
            GNode("rle", {}, [GNode("lzma_backend", {"preset": 6}), GNode("range_pack")]),
        ]
        if w in (2, 4, 8):
            seeds.append(GNode("float_split", {"fmt": {2: 0, 4: 2, 8: 3}[w]}))
    elif stype in (S,) or (stype == T and w == 1):
        seeds += [
            GNode("huffman"),
            GNode("fse", {"table_log": 11}),
            GNode("lz77", {}, [GNode("huffman"), GNode("range_pack"), GNode("range_pack"), GNode("range_pack")]),
        ]
    elif stype == T:
        seeds += [
            GNode("transpose", {}, [GNode("huffman")]),
            GNode("interpret_numeric", {"width": w if w in (1, 2, 4, 8) else 1}),
        ]
    elif stype == G:
        seeds += [
            GNode("tokenize"),
            GNode("string_split", {}, [GNode("zlib_backend", {"level": 6}), GNode("delta", {}, [GNode("range_pack")])]),
            GNode("parse_numeric", {}, [None, GNode("delta", {}, [GNode("transpose", {}, [GNode("huffman")])]), None]),
        ]
    return seeds


# ----------------------------------------------------- deterministic cost
# Per-codec encode cost in ns/input-byte, loosely calibrated against the
# host measurements in results/BENCH_codecs.json (and stdlib backend docs).
# This is the NSGA-II *speed objective*: a pure function of the executed
# step trace, so identically seeded training runs rank candidates
# identically on any machine and worker count.  Absolute accuracy matters
# far less than a stable, roughly-proportional ordering.
COST_NS_PER_BYTE: Dict[str, float] = {
    "store": 0.05,
    "dup": 0.1,
    "constant": 0.1,
    "interpret_numeric": 0.1,
    "split_n": 0.2,
    "concat": 0.3,
    "delta": 0.3,
    "zigzag": 0.3,
    "transpose": 0.5,
    "string_split": 0.5,
    "transpose_split": 0.6,
    "fused_delta_bitpack": 0.6,
    "bitpack": 0.8,
    "range_pack": 0.9,
    "field_split": 1.0,
    "float_split": 1.0,
    "rle": 1.2,
    "tokenize": 2.0,
    "huffman": 9.0,
    "fse": 11.0,
    "zlib_backend": 30.0,
    "lz77": 45.0,
    "parse_numeric": 60.0,
    "csv_split": 80.0,
    "edge_list": 90.0,
    "edge_list_bin": 0.3,
    "adj_gap": 6.0,
    "bz2_backend": 90.0,
    "lzma_backend": 450.0,
}
COST_DEFAULT_NS_PER_BYTE = 8.0  # unlisted codecs: mid-range transform
COST_NS_PER_NODE = 20_000.0  # fixed per-node dispatch/header overhead


def trace_cost_seconds(trace: Sequence[Tuple[str, int]]) -> float:
    """Deterministic encode-cost estimate (seconds) of an executed trace."""
    ns = 0.0
    for name, nbytes in trace:
        ns += COST_NS_PER_NODE + COST_NS_PER_BYTE.get(
            name, COST_DEFAULT_NS_PER_BYTE
        ) * nbytes
    return ns / 1e9


# ------------------------------------------------------------- the service
class TrainerService:
    """Parallel, session-backed genome evaluation (the trainer's engine room).

    Owns a persistent thread pool (numpy/zlib/lzma encoders release the GIL),
    one shared :class:`ExecScratch` so every candidate reuses the same
    coder-table cache, an LRU of per-genome :class:`CompressorSession`
    objects (elitist survivors are re-evaluated every generation — their
    sessions, and through them the engine resolve cache entries keyed on the
    compiled plan, persist across generations and clusters), and one
    :class:`DecompressorSession` for the mandatory losslessness check.

    ``evaluate_batch`` is order-independent and side-effect-free w.r.t. the
    returned objectives: ``(compressed_bytes, trace_cost_seconds)`` is a pure
    function of (genome, sample).  Wall-clock per-candidate timing
    (``time.perf_counter``) is accumulated in :attr:`stats` for reporting
    only.  A service instance may be reused across ``train()`` calls — a
    long-running training endpoint pays for pool/cache spin-up once.
    """

    def __init__(
        self,
        workers: Optional[int] = None,
        *,
        level: int = 5,
        session_cache_size: int = 1024,
        table_cache_size: int = 512,
        static_prune: bool = True,
    ):
        self.workers = int(workers) if workers else len(os.sched_getaffinity(0))
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        self.level = level
        self.static_prune = bool(static_prune)
        self.scratch = ExecScratch(table_cache_size)
        self._dec = DecompressorSession(scratch=self.scratch)
        self._sessions: "OrderedDict[Plan, CompressorSession]" = OrderedDict()
        self._session_cache_size = session_cache_size
        self._check_cache: "OrderedDict[tuple, bool]" = OrderedDict()
        self._lock = threading.Lock()
        self._pool = None
        self.stats: Dict[str, float] = {
            "evaluations": 0,
            "invalid": 0,
            "pruned_static": 0,
            "eval_wall_seconds": 0.0,
            "session_hits": 0,
            "session_misses": 0,
        }

    # ------------------------------------------------------------- plumbing
    def _pool_get(self):
        from concurrent.futures import ThreadPoolExecutor

        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers, thread_name_prefix="ozl-train"
                )
            return self._pool

    def map(self, fn, items) -> list:
        """Ordered parallel map; strictly serial when ``workers == 1`` (so
        worker-count determinism tests compare genuinely different paths)."""
        items = list(items)
        if self.workers <= 1 or len(items) <= 1:
            return [fn(x) for x in items]
        return list(self._pool_get().map(fn, items))

    def _session_for(self, plan: Plan) -> CompressorSession:
        with self._lock:
            sess = self._sessions.get(plan)
            if sess is not None:
                self._sessions.move_to_end(plan)
                self.stats["session_hits"] += 1
                return sess
            self.stats["session_misses"] += 1
            sess = CompressorSession(
                plan, ctx=CompressionCtx(level=self.level), scratch=self.scratch
            )
            self._sessions[plan] = sess
            while len(self._sessions) > self._session_cache_size:
                _, old = self._sessions.popitem(last=False)
                old.close()
            return sess

    def _bump(self, **deltas: float) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.stats[k] += v

    # ------------------------------------------------------------ evaluation
    def _statically_rejected(self, plan: Plan, sig: Tuple[int, int]) -> bool:
        """True when the analyzer proves the plan cannot encode a stream of
        this signature.  Cached per (plan, sig): elites recur every
        generation.  The analyzer is *definite* — it only errors on plans the
        encoder would refuse — so pruning changes which candidates get trial
        compressions, never their objectives (INVALID either way)."""
        key = (plan, tuple(sig))
        with self._lock:
            hit = self._check_cache.get(key)
            if hit is not None:
                self._check_cache.move_to_end(key)
                return hit
        from repro.analysis import check_plan  # lazy: trainer has no cycle

        rejected = not check_plan(plan, input_atoms=[tuple(sig)]).ok
        with self._lock:
            self._check_cache[key] = rejected
            while len(self._check_cache) > self._session_cache_size:
                self._check_cache.popitem(last=False)
        return rejected

    def _evaluate_plan(
        self, plan: Plan, sample: Stream, sig: Tuple[int, int]
    ) -> Tuple[float, float]:
        if self.static_prune and self._statically_rejected(plan, sig):
            self._bump(evaluations=1, invalid=1, pruned_static=1)
            return INVALID
        try:
            sess = self._session_for(plan)
            frame, trace, wall = sess.compress_traced([sample])
        except Exception:
            self._bump(evaluations=1, invalid=1)
            return INVALID
        self._bump(evaluations=1, eval_wall_seconds=wall)
        try:
            (back,) = self._dec.decompress(frame)
            ok = (
                back.content_bytes() == sample.content_bytes()
                and back.stype == sample.stype
                and back.width == sample.width  # type-faithfulness required
                and (
                    sample.stype != SType.STRING
                    or np.array_equal(back.lengths, sample.lengths)
                )
            )
        except Exception:
            ok = False
        if not ok:
            self._bump(invalid=1)
            return INVALID
        return (float(len(frame)), trace_cost_seconds(trace))

    def evaluate_genome(
        self, genome: Optional[GNode], sample: Stream, sig: Tuple[int, int]
    ) -> Tuple[float, float]:
        """One candidate -> ``(compressed_bytes, deterministic cost seconds)``.

        Broken genomes (compile/encode refusals, or any losslessness or
        type-fidelity failure) score ``(inf, inf)`` and are discarded by
        selection.
        """
        try:
            plan = compile_genome(genome, sig)
        except Exception:
            self._bump(evaluations=1, invalid=1)
            return INVALID
        return self._evaluate_plan(plan, sample, sig)

    def evaluate_batch(
        self,
        genomes: Sequence[Optional[GNode]],
        sample: Stream,
        sig: Tuple[int, int],
    ) -> List[Tuple[float, float]]:
        """Batch evaluation: compile, dedupe by compiled plan (elites and
        crossover clones recur every generation), fan the unique plans out
        over the pool, and scatter results back in order."""
        plans: List[Optional[Plan]] = []
        for g in genomes:
            try:
                plans.append(compile_genome(g, sig))
            except Exception:
                self._bump(evaluations=1, invalid=1)
                plans.append(None)
        unique = list(OrderedDict.fromkeys(p for p in plans if p is not None))
        objs = self.map(lambda p: self._evaluate_plan(p, sample, sig), unique)
        table = dict(zip(unique, objs))
        return [INVALID if p is None else table[p] for p in plans]

    # -------------------------------------------------------------- lifecycle
    def close(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
            sessions = list(self._sessions.values())
            self._sessions.clear()
        if pool is not None:
            pool.shutdown(wait=True)
        for s in sessions:
            s.close()
        self._dec.close()

    def __enter__(self) -> "TrainerService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def train(
    sample_inputs: List[List[Stream]],
    frontend: Frontend,
    *,
    pop_size: int = 16,
    generations: int = 6,
    n_points: int = 8,
    seed: int = 0,
    workers: Optional[int] = None,
    service: Optional[TrainerService] = None,
    static_prune: bool = True,
    verbose: bool = False,
) -> TrainedCompressor:
    """Train a compressor from sample inputs (each a list of input streams).

    ``workers`` sizes the evaluation pool (default: all CPUs); pass an
    existing ``service`` instead to amortize pool/cache spin-up across calls.
    Identical ``seed`` ⇒ identical result — including serialized plan bytes —
    for any ``workers`` value.
    """
    t_start = time.perf_counter()
    own_service = service is None
    if service is None:
        service = TrainerService(workers, static_prune=static_prune)
    try:
        # 1. parse every sample and concatenate slot-wise
        parsed = [frontend.parse(s) for s in sample_inputs]
        n_slots = len(parsed[0])
        if any(len(p) != n_slots for p in parsed):
            raise ValueError("inconsistent stream counts across samples")
        streams = [
            _concat_streams([p[i] for p in parsed]) for i in range(n_slots)
        ]
        total_bytes = sum(s.nbytes for s in streams)

        # 2. greedy clustering (paper: trainer merges clusters while it
        # shrinks); merge-candidate probes fan out over the same pool
        clustering = cluster_streams(streams, pool_map=service.map)
        if verbose:
            print(f"[train] {n_slots} streams -> {len(clustering.clusters)} clusters")

        # 3. per-cluster NSGA-II backend search
        sigs: List[Tuple[int, int]] = []
        per_cluster: List[Tuple[List[Optional[GNode]], List[Tuple[float, float]]]] = []
        for ci, idxs in enumerate(clustering.clusters):
            merged = _concat_streams([streams[i] for i in idxs])
            sig = (int(merged.stype), merged.width)
            sigs.append(sig)
            sample = _sample_stream(merged)
            res = nsga2(
                _seed_genomes(sig),
                lambda genomes: service.evaluate_batch(genomes, sample, sig),
                lambda gno, r: mutate(gno, sig, r),
                lambda a, b, r: crossover(a, b, sig, r),
                pop_size=pop_size,
                generations=generations,
                seed=rng_stream(seed, "cluster", ci).getrandbits(32),
            )
            # drop invalid entries
            pareto = [
                (g, o)
                for g, o in zip(res.pareto, res.pareto_objs)
                if o[0] != float("inf")
            ] or [(None, service.evaluate_genome(None, sample, sig))]
            genomes, objs = zip(*pareto)
            per_cluster.append((list(genomes), list(objs)))
            if verbose:
                print(
                    f"[train] cluster {ci} ({len(idxs)} streams, sig {sig}):"
                    f" {len(genomes)} pareto pts, best {min(o[0] for o in objs):.0f}B"
                )

        # 4. iterative Pareto merge across clusters (paper §VI-C last paragraph)
        points: List[TradeoffPoint] = [TradeoffPoint([], 0.0, 0.0)]
        for genomes, objs in per_cluster:
            expanded: List[TradeoffPoint] = []
            seen_objs = set()  # identical objectives => redundant tradeoff
            for pt in points:
                for gno, (sz, tm) in zip(genomes, objs):
                    key = (pt.est_size + sz, pt.est_time + tm)
                    if key in seen_objs:
                        continue
                    seen_objs.add(key)
                    expanded.append(TradeoffPoint(pt.genomes + [gno], *key))
            objs2 = [(p.est_size, p.est_time) for p in expanded]
            points, _ = pareto_prune(expanded, objs2, n_points)

        dt = time.perf_counter() - t_start
        return TrainedCompressor(
            frontend,
            clustering,
            sigs,
            sorted(points, key=lambda p: p.est_size),
            stats={
                "train_seconds": dt,
                "train_bytes": float(total_bytes),
                "train_speed_mib_min": total_bytes / (1 << 20) / (dt / 60.0)
                if dt
                else 0.0,
                "n_clusters": float(len(clustering.clusters)),
                "n_streams": float(n_slots),
                "workers": float(service.workers),
                "evaluations": float(service.stats["evaluations"]),
                "invalid_evaluations": float(service.stats["invalid"]),
                "pruned_static": float(service.stats["pruned_static"]),
                "eval_wall_seconds": float(service.stats["eval_wall_seconds"]),
                "session_hits": float(service.stats["session_hits"]),
                "session_misses": float(service.stats["session_misses"]),
            },
        )
    finally:
        if own_service:
            service.close()
