"""End-to-end compressor training (paper §VI-C) — the ``zli-train`` analogue.

Pipeline: frontend-parse sample files into streams -> greedy clustering ->
per-cluster NSGA-II backend search (objectives: compressed bytes, encode
seconds) -> iterative Pareto merge across clusters pruned by crowding
distance -> a set of deployable tradeoff-point compressors (serializable
Plans, paper §V-D).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.codec import get_codec
from repro.core.engine import CompressionCtx, Compressor, compress
from repro.core.graph import GraphBuilder, Plan
from repro.core.message import Stream, SType

from .cluster import Clustering, _concat_streams, cluster_streams
from .gp import GNode, compile_genome, crossover, emit_genome, mutate, random_genome
from .nsga2 import nsga2, pareto_prune

SAMPLE_LIMIT = 1 << 18  # per-cluster evaluation sample (256 KiB)


# ------------------------------------------------------------------ frontends
@dataclass
class Frontend:
    """How raw input bytes become typed streams + the plan prefix for it."""

    name: str = "raw"

    @property
    def n_inputs(self) -> int:
        return 1

    def parse(self, inputs: Sequence[Stream]) -> List[Stream]:
        return list(inputs)

    def emit(self, g: GraphBuilder) -> List[int]:
        return [g.input(i) for i in range(self.n_inputs)]


@dataclass
class CsvFrontend(Frontend):
    n_cols: int = 0
    sep: str = ","
    name: str = "csv"

    def parse(self, inputs):
        outs, _ = get_codec("csv_split").run_encode(
            list(inputs), {"sep": self.sep}
        )
        if len(outs) != self.n_cols:
            raise ValueError(f"csv has {len(outs)} cols, expected {self.n_cols}")
        return outs

    def emit(self, g):
        cols = g.add("csv_split", g.input(0), n_out=self.n_cols, sep=self.sep)
        return cols if isinstance(cols, list) else [cols]


@dataclass
class StructFrontend(Frontend):
    widths: Tuple[int, ...] = ()
    name: str = "struct"

    def parse(self, inputs):
        outs, _ = get_codec("field_split").run_encode(
            list(inputs), {"widths": list(self.widths)}
        )
        return outs

    def emit(self, g):
        fields = g.add(
            "field_split", g.input(0), n_out=len(self.widths), widths=list(self.widths)
        )
        return fields if isinstance(fields, list) else [fields]


@dataclass
class NumericFrontend(Frontend):
    width: int = 4
    name: str = "numeric"

    def parse(self, inputs):
        outs, _ = get_codec("interpret_numeric").run_encode(
            list(inputs), {"width": self.width}
        )
        return outs

    def emit(self, g):
        return [g.add("interpret_numeric", g.input(0), width=self.width)]


@dataclass
class MultiStreamFrontend(Frontend):
    """Inputs are already typed streams (e.g. Parquet-decoded columns)."""

    k: int = 1
    name: str = "multistream"

    @property
    def n_inputs(self) -> int:
        return self.k


# ----------------------------------------------------------- trained result
@dataclass
class TradeoffPoint:
    genomes: List[Optional[GNode]]  # one per cluster
    est_size: float
    est_time: float


@dataclass
class TrainedCompressor:
    frontend: Frontend
    clustering: Clustering
    sigs: List[Tuple[int, int]]  # signature per cluster
    points: List[TradeoffPoint]  # Pareto tradeoff points (size-ordered)
    stats: Dict[str, float] = field(default_factory=dict)

    def build_plan(self, point: TradeoffPoint) -> Plan:
        g = GraphBuilder(self.frontend.n_inputs)
        stream_edges = self.frontend.emit(g)
        for ci, idxs in enumerate(self.clustering.clusters):
            edges = [stream_edges[i] for i in idxs]
            src = edges[0] if len(edges) == 1 else g.add("concat", *edges)
            emit_genome(g, point.genomes[ci], src, self.sigs[ci])
        return g.build(f"trained_{self.frontend.name}")

    def best_ratio_plan(self) -> Plan:
        return self.build_plan(min(self.points, key=lambda p: p.est_size))

    def fastest_plan(self) -> Plan:
        return self.build_plan(min(self.points, key=lambda p: p.est_time))

    def pareto_plans(self) -> List[Tuple[Plan, float, float]]:
        return [
            (self.build_plan(p), p.est_size, p.est_time)
            for p in sorted(self.points, key=lambda p: p.est_size)
        ]


# ------------------------------------------------------------------- training
def _sample_stream(s: Stream, limit: int = SAMPLE_LIMIT) -> Stream:
    if s.nbytes <= limit:
        return s
    if s.stype == SType.STRING:
        cut = int(np.searchsorted(np.cumsum(s.lengths), limit)) + 1
        cut = min(cut, int(s.lengths.size))
        nb = int(s.lengths[:cut].sum())
        return Stream(s.data[:nb], SType.STRING, 1, s.lengths[:cut])
    n_elts = max(limit // max(s.width, 1), 1)
    if s.stype == SType.NUMERIC:
        return Stream(s.data[:n_elts], s.stype, s.width)
    take = n_elts * (s.width if s.stype == SType.STRUCT else 1)
    return Stream(s.data[:take], s.stype, s.width)


def _seed_genomes(sig: Tuple[int, int]) -> List[Optional[GNode]]:
    """Paper: "population is seeded with simple but commonly effective
    compression graphs"."""
    N, S, T, G = (int(x) for x in (SType.NUMERIC, SType.SERIAL, SType.STRUCT, SType.STRING))
    stype, w = sig
    seeds: List[Optional[GNode]] = [
        None,
        GNode("zlib_backend", {"level": 6}),
    ]
    if stype != G:
        seeds.append(GNode("lzma_backend", {"preset": 6}))
        seeds.append(GNode("bz2_backend", {"level": 9}))
    if stype == N:
        seeds += [
            GNode("range_pack"),
            GNode("delta", {}, [GNode("range_pack")]),
            GNode("transpose", {}, [GNode("huffman")]),
            GNode("delta", {}, [GNode("transpose", {}, [GNode("fse", {"table_log": 11})])]),
            GNode("delta", {}, [GNode("transpose", {}, [GNode("lzma_backend", {"preset": 6})])]),
            GNode("delta", {}, [GNode("lzma_backend", {"preset": 6})]),
            GNode("tokenize", {}, [None, GNode("range_pack")]),
            # sparse/run-heavy data (era5 snow/precip): RLE first
            GNode("rle", {}, [GNode("lzma_backend", {"preset": 6}), GNode("range_pack")]),
        ]
        if w in (2, 4, 8):
            seeds.append(GNode("float_split", {"fmt": {2: 0, 4: 2, 8: 3}[w]}))
    elif stype in (S,) or (stype == T and w == 1):
        seeds += [
            GNode("huffman"),
            GNode("fse", {"table_log": 11}),
            GNode("lz77", {}, [GNode("huffman"), GNode("range_pack"), GNode("range_pack"), GNode("range_pack")]),
        ]
    elif stype == T:
        seeds += [
            GNode("transpose", {}, [GNode("huffman")]),
            GNode("interpret_numeric", {"width": w if w in (1, 2, 4, 8) else 1}),
        ]
    elif stype == G:
        seeds += [
            GNode("tokenize"),
            GNode("string_split", {}, [GNode("zlib_backend", {"level": 6}), GNode("delta", {}, [GNode("range_pack")])]),
            GNode("parse_numeric", {}, [None, GNode("delta", {}, [GNode("transpose", {}, [GNode("huffman")])]), None]),
        ]
    return seeds


def _evaluate_genome(genome, sample: Stream, sig) -> Tuple[float, float]:
    try:
        plan = compile_genome(genome, sig)
        t0 = time.perf_counter()
        frame = compress(plan, [sample], ctx=CompressionCtx(level=5))
        dt = time.perf_counter() - t0
        # verify losslessness on the sample — broken genomes are discarded
        from repro.core.engine import decompress

        (back,) = decompress(frame)
        if back.content_bytes() != sample.content_bytes():
            return (float("inf"), float("inf"))
        if back.stype != sample.stype or back.width != sample.width:
            return (float("inf"), float("inf"))  # type-faithfulness required
        if sample.stype == SType.STRING and not np.array_equal(
            back.lengths, sample.lengths
        ):
            return (float("inf"), float("inf"))
        return (float(len(frame)), float(dt))
    except Exception:
        return (float("inf"), float("inf"))


def train(
    sample_inputs: List[List[Stream]],
    frontend: Frontend,
    *,
    pop_size: int = 16,
    generations: int = 6,
    n_points: int = 8,
    seed: int = 0,
    verbose: bool = False,
) -> TrainedCompressor:
    """Train a compressor from sample inputs (each a list of input streams)."""
    t_start = time.perf_counter()
    rng = random.Random(seed)

    # 1. parse every sample and concatenate slot-wise
    parsed = [frontend.parse(s) for s in sample_inputs]
    n_slots = len(parsed[0])
    if any(len(p) != n_slots for p in parsed):
        raise ValueError("inconsistent stream counts across samples")
    streams = [
        _concat_streams([p[i] for p in parsed]) for i in range(n_slots)
    ]
    total_bytes = sum(s.nbytes for s in streams)

    # 2. greedy clustering (paper: trainer merges clusters while it shrinks)
    clustering = cluster_streams(streams)
    if verbose:
        print(f"[train] {n_slots} streams -> {len(clustering.clusters)} clusters")

    # 3. per-cluster NSGA-II backend search
    sigs: List[Tuple[int, int]] = []
    per_cluster: List[Tuple[List[Optional[GNode]], List[Tuple[float, float]]]] = []
    for ci, idxs in enumerate(clustering.clusters):
        merged = _concat_streams([streams[i] for i in idxs])
        sig = (int(merged.stype), merged.width)
        sigs.append(sig)
        sample = _sample_stream(merged)
        res = nsga2(
            _seed_genomes(sig),
            lambda gno: _evaluate_genome(gno, sample, sig),
            lambda gno, r: mutate(gno, sig, r),
            lambda a, b, r: crossover(a, b, sig, r),
            pop_size=pop_size,
            generations=generations,
            rng=random.Random(rng.randrange(1 << 30)),
        )
        # drop invalid entries
        pareto = [
            (g, o) for g, o in zip(res.pareto, res.pareto_objs) if o[0] != float("inf")
        ] or [(None, _evaluate_genome(None, sample, sig))]
        genomes, objs = zip(*pareto)
        per_cluster.append((list(genomes), list(objs)))
        if verbose:
            print(
                f"[train] cluster {ci} ({len(idxs)} streams, sig {sig}):"
                f" {len(genomes)} pareto pts, best {min(o[0] for o in objs):.0f}B"
            )

    # 4. iterative Pareto merge across clusters (paper §VI-C last paragraph)
    points: List[TradeoffPoint] = [TradeoffPoint([], 0.0, 0.0)]
    for genomes, objs in per_cluster:
        expanded: List[TradeoffPoint] = []
        for pt in points:
            for gno, (sz, tm) in zip(genomes, objs):
                expanded.append(
                    TradeoffPoint(pt.genomes + [gno], pt.est_size + sz, pt.est_time + tm)
                )
        objs2 = [(p.est_size, p.est_time) for p in expanded]
        points, _ = pareto_prune(expanded, objs2, n_points)

    dt = time.perf_counter() - t_start
    return TrainedCompressor(
        frontend,
        clustering,
        sigs,
        sorted(points, key=lambda p: p.est_size),
        stats={
            "train_seconds": dt,
            "train_bytes": float(total_bytes),
            "train_speed_mib_min": total_bytes / (1 << 20) / (dt / 60.0) if dt else 0.0,
            "n_clusters": float(len(clustering.clusters)),
            "n_streams": float(n_slots),
        },
    )
