"""Floating-point plane splitting (paper §VIII: "PyTorch model checkpoints",
"Embedding storage").

Traditional byte compressors barely shrink float tensors (the paper quotes
~10% for Zstd).  Splitting sign / exponent / mantissa into separate planes
exposes the low-entropy exponent stream — the paper reports 17% savings on
fp32 checkpoints and 30% on bf16 embeddings from exactly this transform.

``float_split`` accepts NUMERIC(2) (bf16/f16 bit patterns) or NUMERIC(4)
(f32) or NUMERIC(8) (f64) and emits:
    out0: packed sign bits (SERIAL)
    out1: exponent stream (u8 for bf16/f16/f32; u16 for f64)
    out2: mantissa stream (u8 bf16 / u16 f16 / u32 f32 / u64 f64)
"""
from __future__ import annotations

import numpy as np

from repro.core.codec import (
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_backend_codec,
    register_codec,
)
from repro.core.message import Stream, SType

from ._util import (
    HeaderReader,
    HeaderWriter,
    device_available,
    device_use_pallas,
    numeric_stream,
)

# fmt tag -> (width, exp_bits, man_bits)
FORMATS = {
    0: (2, 8, 7),   # bfloat16
    1: (2, 5, 10),  # float16
    2: (4, 8, 23),  # float32
    3: (8, 11, 52), # float64
}
_FMT_BY_WIDTH = {2: 0, 4: 2, 8: 3}  # default fmt per width (bf16 for w=2)
_EXP_DTYPE = {0: np.uint8, 1: np.uint8, 2: np.uint8, 3: np.uint16}
_MAN_DTYPE = {0: np.uint8, 1: np.uint16, 2: np.uint32, 3: np.uint64}


def _pack_sign_bits(sign: np.ndarray) -> np.ndarray:
    pad = (-sign.size) % 8
    padded = np.concatenate([sign, np.zeros(pad, dtype=sign.dtype)])
    return np.packbits(padded.astype(np.uint8))


def _float_split_enc(streams, params):
    s = streams[0]
    if s.stype != SType.NUMERIC or s.width not in (2, 4, 8):
        raise ValueError("float_split wants numeric(2/4/8) bit patterns")
    fmt = int(params.get("fmt", _FMT_BY_WIDTH[s.width]))
    width, exp_bits, man_bits = FORMATS[fmt]
    if width != s.width:
        raise ValueError(f"float_split fmt {fmt} expects width {width}")
    u = s.data.view({2: np.uint16, 4: np.uint32, 8: np.uint64}[s.width])
    tot = exp_bits + man_bits
    sign = (u >> np.uint64(tot)).astype(np.uint8) & 1
    exp = ((u >> np.uint64(man_bits)) & np.uint64((1 << exp_bits) - 1)).astype(
        _EXP_DTYPE[fmt]
    )
    man = (u & np.uint64((1 << man_bits) - 1)).astype(_MAN_DTYPE[fmt])
    h = HeaderWriter().u8(fmt).varint(u.size).done()
    return [
        Stream(_pack_sign_bits(sign), SType.SERIAL, 1),
        numeric_stream(exp),
        numeric_stream(man),
    ], h


def _float_split_dec(outs, header):
    signs_s, exp_s, man_s = outs
    r = HeaderReader(header)
    fmt = r.u8()
    n = r.varint()
    r.expect_end()
    width, exp_bits, man_bits = FORMATS[fmt]
    sign = np.unpackbits(signs_s.data)[:n].astype(np.uint64)
    exp = exp_s.data.astype(np.uint64)
    man = man_s.data.astype(np.uint64)
    u = (sign << np.uint64(exp_bits + man_bits)) | (exp << np.uint64(man_bits)) | man
    out = u.astype(np.uint64).astype(
        {2: np.uint16, 4: np.uint32, 8: np.uint64}[width]
    )
    return [numeric_stream(out)]


def _float_split_transfer(atoms, params, n_out):
    st, w = atoms[0]
    fmt = params.get("fmt")
    if fmt is None:
        if w is None:
            return [(int(SType.SERIAL), 1), (int(SType.NUMERIC), None),
                    (int(SType.NUMERIC), None)]
        fmt = _FMT_BY_WIDTH.get(w)
    if fmt not in FORMATS:
        return None
    fmt_w = FORMATS[fmt][0]
    if w is not None and w != fmt_w:
        return None  # fmt tag must match the stream width
    return [
        (int(SType.SERIAL), 1),
        (int(SType.NUMERIC), int(np.dtype(_EXP_DTYPE[fmt]).itemsize)),
        (int(SType.NUMERIC), int(np.dtype(_MAN_DTYPE[fmt]).itemsize)),
    ]


register_codec(
    CodecSpec(
        "float_split",
        codec_id=18,
        encode=_float_split_enc,
        decode=_float_split_dec,
        n_outputs=3,
        min_version=3,
        doc="sign/exponent/mantissa planes (paper §VIII checkpoint compression)",
        sig=CodecSig(
            inputs=(InPort(frozenset((int(SType.NUMERIC),)), frozenset((2, 4, 8))),),
            transfer=_float_split_transfer,
            params=(ParamSpec("fmt", "int", choices=(0, 1, 2, 3),
                              doc="0=bf16 1=f16 2=f32 3=f64 (default by width)"),),
            expansion=1.3,  # planes widen to whole dtypes + packed sign bits
        ),
    )
)


# --------------------------------------------------------------- device twin
# The float_split Pallas kernel works on u32 lanes, i.e. fmt 2 (float32);
# other formats fall back to the host encoder.  Output planes and header are
# bit-identical to the host path.
def _float_split_applies_device(streams, params):
    s = streams[0]
    if not (device_available() and s.stype == SType.NUMERIC and s.width == 4):
        return False
    return int(params.get("fmt", _FMT_BY_WIDTH.get(s.width, -1))) == 2


def _float_split_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops

    s = streams[0]
    fmt = 2
    _width, exp_bits, man_bits = FORMATS[fmt]
    u = s.data.view(np.uint32)
    sign, exp, man = ops.float_split(
        jnp.asarray(u), exp_bits, man_bits, use_pallas=device_use_pallas()
    )
    h = HeaderWriter().u8(fmt).varint(u.size).done()
    return [
        Stream(_pack_sign_bits(np.asarray(sign, np.uint8)), SType.SERIAL, 1),
        numeric_stream(np.asarray(exp).astype(_EXP_DTYPE[fmt], copy=False)),
        numeric_stream(np.asarray(man).astype(_MAN_DTYPE[fmt], copy=False)),
    ], h


register_backend_codec(
    "device", "float_split", _float_split_enc_device, _float_split_applies_device
)
