"""Standard selectors (function graphs, paper §III-E).

The workhorse is the *trial selector*: given a menu of candidate backend
graphs, compress a bounded sample of the stream with each and commit to the
winner.  This is what lets non-experts get expert-shaped graphs (paper §VI-C)
and what the automated trainer seeds from.

Selectors never appear on the wire — expansion happens at compression time
and the frame records only the chosen codecs.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.engine import CompressionCtx, compress
from repro.core.graph import GraphBuilder, Plan, pipeline
from repro.core.message import Stream, SType
from repro.core.codec import ANY_STYPES, FIXED_STYPES, InPort
from repro.core.selector import SelectorSig, SelectorSpec, register_selector

_ANY_SIG = SelectorSig(inputs=(InPort(ANY_STYPES),))
# designed for byte-shaped streams (the trial menus degrade to store elsewhere)
_BYTES_SIG = SelectorSig(inputs=(InPort(FIXED_STYPES),))
_NUM_SIG = SelectorSig(inputs=(InPort(frozenset((int(SType.NUMERIC),))),))

SAMPLE_BYTES = 1 << 16  # trial compressions run on a bounded prefix


def _sample(s: Stream) -> Stream:
    if s.stype == SType.STRING:
        if s.data.size <= SAMPLE_BYTES:
            return s
        keep = int(np.searchsorted(np.cumsum(s.lengths), SAMPLE_BYTES)) + 1
        keep = min(keep, int(s.lengths.size))
        nb = int(s.lengths[:keep].sum())
        return Stream(s.data[:nb], SType.STRING, 1, s.lengths[:keep])
    n_elts = min(s.n_elts, max(SAMPLE_BYTES // max(s.width, 1), 1))
    if s.stype == SType.NUMERIC:
        return Stream(s.data[:n_elts], s.stype, s.width)
    return Stream(s.data[: n_elts * (s.width if s.stype == SType.STRUCT else 1)], s.stype, s.width)


def _trial_size(plan: Plan, s: Stream, ctx: CompressionCtx) -> int:
    try:
        return len(compress(plan, [s], ctx=CompressionCtx(ctx.format_version, ctx.level)))
    except Exception:
        return 1 << 62  # candidate inapplicable to this data


def choose_best(candidates: Sequence[Tuple[str, Plan]], streams, ctx) -> Plan:
    s = streams[0]
    sample = _sample(s)
    best_name, best_plan, best_sz = None, None, 1 << 63
    for name, plan in candidates:
        sz = _trial_size(plan, sample, ctx)
        if sz < best_sz:
            best_name, best_plan, best_sz = name, plan, sz
    if best_plan is None:
        return pipeline("store")
    return best_plan


# ---------------------------------------------------------------- candidates
def entropy_candidates(level: int) -> List[Tuple[str, Plan]]:
    cands = [("store", pipeline("store")), ("huffman", pipeline("huffman"))]
    if level >= 3:
        cands.append(("fse", pipeline("fse")))
    if level >= 5:
        cands.append(("zlib", pipeline(("zlib_backend", {"level": min(level, 9)}))))
    if level >= 7:
        cands.append(("lzma", pipeline(("lzma_backend", {"preset": 6}))))
    return cands


def numeric_candidates(level: int) -> List[Tuple[str, Plan]]:
    def chain(*steps):
        return pipeline(*steps)

    cands: List[Tuple[str, Plan]] = [
        ("store", chain("store")),
        ("range_pack", chain("range_pack")),
        ("delta+range_pack", chain("delta", "range_pack")),
        ("transpose+huffman", chain("transpose", "huffman")),
        ("delta+transpose+huffman", chain("delta", "transpose", "huffman")),
    ]
    if level >= 3:
        g = GraphBuilder(1)
        alpha, idx = g.add("tokenize", g.input(0))
        g.add("transpose", alpha)
        g.add("range_pack", idx)
        cands.append(("tokenize", g.build("tokenize_backend")))
        cands.append(("delta+zigzag+range_pack", chain("delta", "zigzag", "range_pack")))
    if level >= 5:
        cands.append(("transpose+zlib", chain("transpose", ("zlib_backend", {"level": min(level, 9)}))))
        cands.append(
            ("delta+transpose+zlib", chain("delta", "transpose", ("zlib_backend", {"level": min(level, 9)})))
        )
    return cands


def bytes_candidates(level: int) -> List[Tuple[str, Plan]]:
    cands = entropy_candidates(level)
    if level >= 4:
        g = GraphBuilder(1)
        lit, runs, mls, offs = g.add("lz77", g.input(0))
        g.add("huffman", lit)
        g.add("range_pack", runs)
        g.add("range_pack", mls)
        g.add("range_pack", offs)
        cands.append(("lz77+entropy", g.build("lz_backend")))
    return cands


# ------------------------------------------------------------ the selectors
def _entropy_auto(streams, params, ctx):
    return choose_best(entropy_candidates(ctx.level), streams, ctx)


def _numeric_auto(streams, params, ctx):
    return choose_best(numeric_candidates(ctx.level), streams, ctx)


def _bytes_auto(streams, params, ctx):
    return choose_best(bytes_candidates(ctx.level), streams, ctx)


def _generic_auto(streams, params, ctx):
    """Dispatch on stream type — the "just compress it" entry point."""
    s = streams[0]
    if s.stype == SType.NUMERIC:
        return _numeric_auto(streams, params, ctx)
    if s.stype == SType.STRING:
        g = GraphBuilder(1)
        content, lens = g.add("string_split", g.input(0))
        g.select("bytes_auto", content)
        g.select("numeric_auto", lens)
        return g.build("string_backend")
    if s.stype == SType.STRUCT and s.width > 1:
        if s.width in (2, 4, 8):
            # numeric reinterpretation usually dominates; let the numeric
            # menu (which includes transpose chains) pick the backend
            g = GraphBuilder(1)
            num = g.add("interpret_numeric", g.input(0), width=s.width)
            g.select("numeric_auto", num)
            return g.build("struct_numeric")
        return choose_best(
            [
                ("transpose+huffman", pipeline("transpose", "huffman")),
                ("transpose+fse", pipeline("transpose", "fse")),
                ("huffman", pipeline("huffman") if s.width == 1 else pipeline("transpose", "huffman")),
            ],
            streams,
            ctx,
        )
    return _bytes_auto(streams, params, ctx)


register_selector(SelectorSpec(
    "entropy_auto", _entropy_auto, doc="store/huffman/fse/zlib by trial",
    sig=_BYTES_SIG,
))
register_selector(SelectorSpec(
    "numeric_auto", _numeric_auto, doc="numeric backend by trial",
    sig=_NUM_SIG,
))
register_selector(SelectorSpec(
    "bytes_auto", _bytes_auto, doc="byte backend by trial",
    sig=_BYTES_SIG,
))
register_selector(SelectorSpec(
    "generic_auto", _generic_auto, doc="type-dispatching default backend",
    sig=_ANY_SIG,
))
