"""Prebuilt compression graphs ("profiles") for common data families.

These mirror OpenZL's shipped profiles: the §IV SAO graph, float/bfloat16
checkpoint graphs (§VIII), a generic numeric graph, a text graph, and a CSV
graph.  Profiles are ordinary Plans — serializable, trainable, composable.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.graph import GraphBuilder, Plan, pipeline

__all__ = [
    "generic_profile",
    "numeric_profile",
    "text_profile",
    "float32_profile",
    "bfloat16_profile",
    "float64_profile",
    "sao_profile",
    "csv_profile",
    "struct_profile",
    "graph_profile",
    "graph_bin_profile",
    "named_profiles",
    "resolve_profile_spec",
]


def generic_profile() -> Plan:
    g = GraphBuilder(1)
    g.select("generic_auto", g.input(0))
    return g.build("generic")


def numeric_profile() -> Plan:
    g = GraphBuilder(1)
    g.select("numeric_auto", g.input(0))
    return g.build("numeric")


def text_profile(level: int = 6) -> Plan:
    return pipeline(("zlib_backend", {"level": level}), name="text")


def _float_profile(fmt: int, name: str) -> Plan:
    """float_split -> per-plane backends (paper §VIII checkpoint trick).

    signs: usually balanced -> store raw.  exponents: very low entropy -> fse.
    mantissae: near-random low bytes; transpose exposes the near-constant top
    byte(s) -> per-plane entropy choice.
    """
    g = GraphBuilder(1)
    signs, exp, man = g.add("float_split", g.input(0), fmt=fmt)
    g.select("bytes_auto", signs)
    g.select("entropy_auto", exp)
    g.select("numeric_auto", man)
    return g.build(name)


def float32_profile() -> Plan:
    return _float_profile(2, "float32")


def bfloat16_profile() -> Plan:
    return _float_profile(0, "bfloat16")


def float64_profile() -> Plan:
    return _float_profile(3, "float64")


# --------------------------------------------------------------- SAO (§IV)
SAO_FIELDS = [  # (name, width-bytes) — 28-byte records, 6 fields
    ("SRA0", 8),
    ("SDEC0", 8),
    ("IS", 2),
    ("MAG", 2),
    ("XRPM", 4),
    ("XDPM", 4),
]
SAO_HEADER_BYTES = 28


def sao_profile() -> Plan:
    """The paper's worked example (§IV, Table I), as a graph:

    header passthrough + field_split into the 6 star-record fields;
    SRA0 (mostly sorted)  -> interpret u64 -> delta -> transpose_split -> entropy
    SDEC0 (bounded)       -> interpret u64 -> transpose_split -> entropy/plane
    IS/MAG/XRPM/XDPM (low cardinality) -> tokenize; alphabet and indices get
    separate backends (sparse vs dense-bounded — paper §IV last bullet).
    """
    widths = [w for _, w in SAO_FIELDS]
    rec = sum(widths)
    g = GraphBuilder(1)
    header, body = g.add(
        "split_n", g.input(0), n_out=2, sizes=[SAO_HEADER_BYTES, -1]
    )
    # header: tiny, store raw
    fields = g.add("field_split", body, n_out=len(widths), widths=widths)
    sra0, sdec0, is_f, mag, xrpm, xdpm = fields

    sra_num = g.add("interpret_numeric", sra0, width=8)
    sra_d = g.add("delta", sra_num)
    sra_planes = g.add("transpose_split", sra_d, n_out=8)
    for p in sra_planes:
        g.select("entropy_auto", p)

    sdec_num = g.add("interpret_numeric", sdec0, width=8)
    sdec_planes = g.add("transpose_split", sdec_num, n_out=8)
    for p in sdec_planes:
        g.select("entropy_auto", p)

    for f, w in ((is_f, 2), (mag, 2), (xrpm, 4), (xdpm, 4)):
        alpha, idx = g.add("tokenize", f)
        g.add("transpose", alpha)  # sparse dictionary: byte planes then store
        g.select("numeric_auto", idx)  # dense bounded ints
    return g.build("sao")


def csv_profile(n_cols: int, sep: str = ",") -> Plan:
    """CSV frontend + per-column parse_numeric + auto backends (§VI-C)."""
    if n_cols < 1:
        raise ValueError(f"csv profile: column count must be >= 1, got {n_cols}")
    if not sep:
        raise ValueError("csv profile: separator must be non-empty")
    if "\n" in sep or "\r" in sep:
        raise ValueError("csv profile: separator cannot contain newlines")
    g = GraphBuilder(1)
    cols = g.add("csv_split", g.input(0), n_out=n_cols, sep=sep)
    if isinstance(cols, int):
        cols = [cols]
    for c in cols:
        bitmap, vals, exc = g.add("parse_numeric", c)
        g.select("bytes_auto", bitmap)
        g.select("numeric_auto", vals)
        exc_content, exc_lens = g.add("string_split", exc)
        g.select("bytes_auto", exc_content)
        g.select("numeric_auto", exc_lens)
    return g.build(f"csv{n_cols}")


def graph_profile(sep: str = "auto", window: int = 8) -> Plan:
    """Edge-list graph frontend: degree + delta-gap + reference coding.

    ``edge_list`` shreds ``u<sep>v`` lines into (src, dst) columns plus a
    parse bitmap and byte-exact exception lines (comments, blank lines);
    ``adjacency_auto`` then decides by trial whether Zuckerli-style
    reference/copy-list coding, plain gap coding, or raw columns wins for
    this graph's neighborhood structure.
    """
    g = GraphBuilder(1)
    src, dst, bitmap, exc = g.add("edge_list", g.input(0), sep=sep)
    g.select("adjacency_auto", src, dst, window=window)
    g.select("bytes_auto", bitmap)
    exc_content, exc_lens = g.add("string_split", exc)
    g.select("bytes_auto", exc_content)
    g.select("numeric_auto", exc_lens)
    return g.build("graph")


def graph_bin_profile(width: int = 4, window: int = 8) -> Plan:
    """CSR/binary edge-list graph frontend: interleaved fixed-width pairs."""
    if width not in (2, 4, 8):
        raise ValueError(f"graph:bin profile: width must be 2, 4 or 8, got {width}")
    g = GraphBuilder(1)
    src, dst = g.add("edge_list_bin", g.input(0), width=width)
    g.select("adjacency_auto", src, dst, window=window)
    return g.build(f"graph_bin{width}")


def struct_profile(widths: Sequence[int]) -> Plan:
    """Generic record format: field_split + per-field auto backend."""
    g = GraphBuilder(1)
    fields = g.add("field_split", g.input(0), n_out=len(widths), widths=list(widths))
    if isinstance(fields, int):
        fields = [fields]
    for f in fields:
        g.select("generic_auto", f)
    return g.build("struct" + "_".join(map(str, widths)))


# ------------------------------------------------------------ spec resolution
def named_profiles():
    """Parameterless named profiles: name -> (factory, one-line description).

    The single catalogue behind the CLI's ``--profile``/``profiles`` and the
    service registry's ``register_profile`` — add a profile here and every
    surface picks it up.
    """
    out = {}
    for name, fn, desc in [
        ("generic", generic_profile, "auto selector over any byte stream"),
        ("numeric", numeric_profile, "auto selector tuned for integer arrays"),
        ("text", text_profile, "LZ-style text graph (zlib backend)"),
        ("float32", float32_profile, "float_split fp32 checkpoint graph"),
        ("bfloat16", bfloat16_profile, "float_split bf16 embedding graph"),
        ("float64", float64_profile, "float_split fp64 graph"),
        ("sao", sao_profile, "the paper's SAO star-catalog graph (§IV)"),
        ("graph", graph_profile, "edge-list adjacency graph (Zuckerli-style)"),
    ]:
        doc = (fn.__doc__ or "").strip().splitlines()
        out[name] = (fn, doc[0] if doc and doc[0] else desc)
    return out


def resolve_profile_spec(spec: str) -> Plan:
    """Resolve a profile spec — a named profile, ``struct:W1,W2,..``,
    ``csv:N[:sep]`` or ``graph[:bin:W]`` — to a Plan.  Raises ValueError on
    an unknown or malformed spec (library-safe: callers decide how to exit)."""
    if spec.startswith("graph:"):
        parts = spec.split(":")
        if parts[1] == "bin":
            try:
                width = int(parts[2]) if len(parts) > 2 and parts[2] else 4
            except ValueError:
                raise ValueError(f"profile {spec!r}: bad pair width") from None
            if width not in (2, 4, 8) or len(parts) > 3:
                raise ValueError(
                    f"profile {spec!r}: expected graph:bin:W with W in 2/4/8"
                )
            return graph_bin_profile(width)
        sep = ":".join(parts[1:])  # "graph:::" means the separator is "::"
        if not sep or "\n" in sep or "\r" in sep:
            raise ValueError(
                f"profile {spec!r}: separator must be non-empty, newline-free"
            )
        return graph_profile(sep)
    if spec.startswith("struct:"):
        try:
            widths = [int(w) for w in spec[len("struct:") :].split(",") if w]
        except ValueError:
            raise ValueError(f"profile {spec!r}: bad field widths") from None
        if not widths or any(w < 1 for w in widths):
            raise ValueError(f"profile {spec!r}: field widths must be >= 1")
        return struct_profile(widths)
    if spec.startswith("csv:"):
        parts = spec.split(":")
        try:
            n_cols = int(parts[1])
        except (IndexError, ValueError):
            raise ValueError(f"profile {spec!r}: bad column count") from None
        # "csv:3::" means the separator is ":" — everything past the count
        # is the separator verbatim; csv_profile validates it (non-empty,
        # newline-free), turning the old IndexError path into ValueError
        sep = ":".join(parts[2:]) if len(parts) > 2 else ","
        try:
            return csv_profile(n_cols, sep)
        except ValueError as e:
            raise ValueError(f"profile {spec!r}: {e}") from None
    reg = named_profiles()
    if spec not in reg:
        raise ValueError(
            f"unknown profile {spec!r}; known: {', '.join(sorted(reg))},"
            f" struct:W1,W2,.., csv:N"
        )
    return reg[spec][0]()
