"""Device-backend twins of the entropy coders (huffman / fse).

Whole-codec encoders routed through the jit'd kernel wrappers
(``repro.kernels.ops``): exact device histogram -> host table construction
(the same O(256) functions the host encoder uses, so wire descriptors match
byte-for-byte) -> device map/scan -> device scatter-add bit packing straight
into the concatenated wire layout.  Bit-identity with the host encoders
holds end to end: identical tables give identical per-symbol codes and bit
offsets, the packer writes exactly the bits the host bit-matrix writer does
(every output bit has one writer), and unwritten bits are zero on both
paths.  Verified by the device-backend golden-vector conformance suite.

Decode stays on the host universal-decoder path by design
(``register_backend_codec`` is encode-only); the decode kernels' twins are
exercised by the kernel equivalence tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.codec import register_backend_codec
from repro.core.message import Stream, SType

from ._util import HeaderWriter, device_available, device_use_pallas, numeric_stream
from .entropy import (
    BLOCK_LOG,
    FSE_BLOCK_LOG,
    _as_u8,
    _fse_tables_cached,
    _huffman_code_lengths,
    _huffman_codes_cached,
    _normalize_counts,
)

# Routability window: below _DEV_MIN the transfer + dispatch overhead beats
# any kernel win; above _DEV_MAX the int32 bit-offset cumsums (15 bits/code
# max) would overflow.  The engine's host fallback covers both ends.
_DEV_MIN = 1 << 10
_DEV_MAX = 1 << 27


def _bytes_ok(s: Stream) -> bool:
    return s.stype == SType.SERIAL or (
        s.stype in (SType.NUMERIC, SType.STRUCT) and s.width == 1
    )


def _dev_entropy_ready(streams) -> bool:
    s = streams[0]
    return (
        device_available()
        and _bytes_ok(s)
        and _DEV_MIN <= s.n_elts <= _DEV_MAX
    )


def _cap_bucket(nbytes: int) -> int:
    """Power-of-two capacity for the packer's static output shape: bounds
    jit recompiles to one per bucket instead of one per content size."""
    return 1 << max(12, (nbytes - 1).bit_length())


# ------------------------------------------------------------------- huffman
def _huffman_applies_device(streams, params):
    return _dev_entropy_ready(streams)


def _huffman_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops

    x = _as_u8(streams[0], "huffman")
    n = x.size
    xj = jnp.asarray(x)
    up = device_use_pallas()
    counts = np.asarray(ops.histogram_exact(xj)).astype(np.int64)
    lens = _huffman_code_lengths(counts)
    codes = _huffman_codes_cached(lens)
    code, _nb, offs = ops.huffman_map(
        xj, jnp.asarray(codes), jnp.asarray(lens.astype(np.int32)), use_pallas=up
    )
    total = int(offs[-1])
    total_bytes = (total + 7) >> 3
    packed = np.asarray(
        ops.pack_bits(code, offs[:-1], _cap_bucket(total_bytes))
    )[:total_bytes]
    block = 1 << BLOCK_LOG
    block_offs = np.asarray(offs[: n : block]).astype(np.uint64)
    h = HeaderWriter().varint(n).u8(BLOCK_LOG).u8(int(streams[0].stype))
    nib = (lens[0::2] | (lens[1::2] << 4)).astype(np.uint8)
    h.bytes_(nib.tobytes())
    return [
        Stream(packed, SType.SERIAL, 1),
        numeric_stream(block_offs),
    ], h.done()


register_backend_codec(
    "device", "huffman", _huffman_enc_device, _huffman_applies_device
)


# ----------------------------------------------------------------------- fse
def _fse_applies_device(streams, params):
    return _dev_entropy_ready(streams)


def _fse_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops

    x = _as_u8(streams[0], "fse")
    n = x.size
    table_log = int(params.get("table_log", 11))
    stype_tag = int(streams[0].stype)
    xj = jnp.asarray(x)
    up = device_use_pallas()
    counts = np.asarray(ops.histogram_exact(xj)).astype(np.int64)
    norm = _normalize_counts(counts, table_log)
    _ds, _dn, _db, enc_table, nb0t, thrt, st0t = _fse_tables_cached(norm, table_log)
    total = 1 << table_log
    width = enc_table.shape[1]

    block = 1 << FSE_BLOCK_LOG
    n_blocks = (n + block - 1) // block
    padded = np.zeros(n_blocks * block, dtype=np.uint8)
    padded[:n] = x
    lanesT = padded.reshape(n_blocks, block).T
    rem = np.minimum(
        n - np.arange(n_blocks, dtype=np.int64) * block, block
    ).astype(np.int32)
    vals, goffs, state, bitpos, byte_off = ops.fse_encode(
        jnp.asarray(lanesT),
        jnp.asarray(rem),
        jnp.asarray(nb0t.astype(np.int32)),
        jnp.asarray(thrt.astype(np.int32)),
        jnp.asarray(st0t.astype(np.int32)),
        jnp.asarray(norm.astype(np.int32)),
        jnp.asarray(enc_table.reshape(-1)),
        width,
        total,
        use_pallas=up,
    )
    total_bytes = int(byte_off[-1])
    stream_out = np.asarray(
        ops.pack_bits(
            vals.reshape(-1), goffs.reshape(-1), _cap_bucket(total_bytes)
        )
    )[:total_bytes]
    meta = np.empty(n_blocks * 2, dtype=np.uint32)
    meta[0::2] = np.asarray(bitpos).astype(np.uint32)
    meta[1::2] = np.asarray(state).astype(np.uint32)

    h = HeaderWriter().varint(n).u8(FSE_BLOCK_LOG).u8(table_log).u8(stype_tag)
    nz = np.nonzero(norm)[0]
    hw = HeaderWriter()
    hw.varint(nz.size)
    for s in nz:
        hw.varint(int(s))
        hw.varint(int(norm[s]))
    h.bytes_(hw.done())
    return [Stream(stream_out, SType.SERIAL, 1), numeric_stream(meta)], h.done()


register_backend_codec("device", "fse", _fse_enc_device, _fse_applies_device)
