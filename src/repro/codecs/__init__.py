"""The standard codec + selector suite.  Importing this package registers
every codec (wire-stable ids), every device-backend encoder twin, and every
selector with the core registries.

Codec id map (never reuse):
   1 store        2 dup          3 delta         4 zigzag       5 transpose
   6 bitpack      7 rle          8 constant      9 tokenize    10 field_split
  11 split_n     12 concat      13 range_pack   14 huffman     15 fse
  16 lz77        17 zlib_backend 18 float_split 19 parse_numeric
  20 csv_split   21 string_split 22 transpose_split 23 interpret_numeric
  24 lzma_backend  25 bz2_backend 26 fused_delta_bitpack (v4)
  27 edge_list (v4)  28 adj_gap (v4)  29 edge_list_bin (v4)
"""
from . import coder_cache  # noqa: F401
from . import basic  # noqa: F401
from . import numeric  # noqa: F401
from . import convert  # noqa: F401
from . import entropy  # noqa: F401
from . import entropy_device  # noqa: F401
from . import lz  # noqa: F401
from . import floats  # noqa: F401
from . import parse  # noqa: F401
from . import selectors  # noqa: F401
from . import graph  # noqa: F401
from . import profiles  # noqa: F401

from .coder_cache import (  # noqa: F401
    coder_cache_clear,
    coder_cache_info,
)
from .profiles import (  # noqa: F401
    bfloat16_profile,
    csv_profile,
    float32_profile,
    float64_profile,
    generic_profile,
    graph_bin_profile,
    graph_profile,
    numeric_profile,
    sao_profile,
    struct_profile,
    text_profile,
)
