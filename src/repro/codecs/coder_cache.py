"""LRU caches for entropy-coder tables (huffman LUTs, tANS/FSE tables).

Building a tANS table is ``O(2^table_log)`` and a Huffman decode LUT is
``O(2^15)`` — both strictly larger than the per-block decode work for small
chunks, so rebuilding them per call dominated chunked and repeated
compression before this cache existed.  Tables are pure functions of small
wire-visible descriptors (nibble-packed code lengths / normalized counts +
table_log), which makes them perfectly cacheable:

  * huffman encode:  key = code-length bytes        -> canonical codes
  * huffman decode:  key = code-length bytes        -> (codes, LUT sym, LUT len)
  * fse enc+dec:     key = (norm bytes, table_log)  -> (dec_sym, dec_nb,
                                                        dec_base, enc_table, ...)

Thread safety: every cache is guarded by a lock; values are immutable numpy
arrays (writeable=False) shared read-only across the engine's ``chunk_bytes``
thread pool.  The engine threads a per-``execute()`` scope through
:func:`scoped` (see ``core/engine.py``) so one compression call — including
all of its parallel chunks — shares a single table namespace; with no scope
active, a process-wide default cache is used.

``coder_cache_info()`` / ``coder_cache_clear()`` mirror the engine's
``resolve_cache_info()`` counters.  ``coder_cache_disabled()`` is a test hook
proving frames are bit-identical with caching on or off.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
from collections import OrderedDict
from typing import Callable, Dict, Tuple

__all__ = [
    "CoderCache",
    "active_cache",
    "scoped",
    "coder_cache_info",
    "coder_cache_clear",
    "coder_cache_disabled",
]


class CoderCache:
    """A small thread-safe LRU mapping table descriptors to built tables.

    One instance holds *all* coder-table families, namespaced by a string tag
    in the key, so a single object can be shared across the chunk pool.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self._data: "OrderedDict[tuple, object]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._enabled = True

    def get_or_build(self, key: tuple, builder: Callable[[], object]):
        """Return the cached value for ``key``, building (and caching) on miss.

        The builder runs outside the lock: table construction is the expensive
        part, and two threads racing on the same key simply both build —
        last-write-wins is harmless because tables are value-deterministic.
        """
        if not self._enabled:
            return builder()
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.move_to_end(key)
                self._hits += 1
                return hit
            self._misses += 1
        value = builder()
        with self._lock:
            self._data[key] = value
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
        return value

    def info(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self._hits,
                "misses": self._misses,
                "size": len(self._data),
                "maxsize": self.maxsize,
            }

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._hits = 0
            self._misses = 0


_GLOBAL = CoderCache()

# Per-execute() override, set by the engine so one compression call (and all
# of its chunk-pool threads) shares a scope.  A contextvar — not a bare
# thread-local — so nested scopes unwind correctly.
_ACTIVE: "contextvars.ContextVar[CoderCache | None]" = contextvars.ContextVar(
    "repro_coder_cache", default=None
)


def active_cache() -> CoderCache:
    """The cache coder implementations should consult right now."""
    return _ACTIVE.get() or _GLOBAL


@contextlib.contextmanager
def scoped(cache: CoderCache):
    """Make ``cache`` the active table cache for the enclosed block."""
    token = _ACTIVE.set(cache)
    try:
        yield cache
    finally:
        _ACTIVE.reset(token)


@contextlib.contextmanager
def coder_cache_disabled():
    """Disable the *global* cache (scoped caches are unaffected) — test hook."""
    prev = _GLOBAL._enabled
    _GLOBAL._enabled = False
    try:
        yield
    finally:
        _GLOBAL._enabled = prev


def coder_cache_info() -> Dict[str, int]:
    """Hit/miss counters of the process-wide default cache."""
    return _GLOBAL.info()


def coder_cache_clear() -> None:
    _GLOBAL.clear()
