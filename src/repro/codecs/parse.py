"""Parser frontends (paper §IV "Frontend", §VI-C).

``csv_split``     — lossless rectangular CSV -> per-column STRING streams.
``parse_numeric`` — STRING of ASCII decimal ints -> (bitmap, i64 values,
                    exception strings).  Canonical integers go numeric; any
                    string that would not round-trip exactly stays an
                    exception — losslessness beats parsing coverage.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.codec import CodecSpec, register_codec
from repro.core.message import Stream, SType, strings as mk_strings

from ._util import HeaderReader, HeaderWriter, numeric_stream


# ----------------------------------------------------------------- csv_split
def _csv_split_enc(streams, params):
    s = streams[0]
    if s.stype != SType.SERIAL:
        raise ValueError("csv_split wants serial bytes")
    sep = params.get("sep", ",")
    sep_b = sep.encode() if isinstance(sep, str) else bytes([sep])
    raw = s.data.tobytes()
    trailing_nl = raw.endswith(b"\n")
    body = raw[:-1] if trailing_nl else raw
    lines = body.split(b"\n") if body else []
    if not lines:
        raise ValueError("csv_split: empty input")
    rows = [ln.split(sep_b) for ln in lines]
    n_cols = len(rows[0])
    if any(len(r) != n_cols for r in rows):
        raise ValueError("csv_split: ragged rows (rectangular CSV only)")
    outs: List[Stream] = []
    for c in range(n_cols):
        outs.append(mk_strings([r[c] for r in rows]))
    h = (
        HeaderWriter()
        .u8(sep_b[0])
        .u8(1 if trailing_nl else 0)
        .varint(n_cols)
        .varint(len(rows))
        .done()
    )
    return outs, h


def _csv_split_dec(outs, header):
    r = HeaderReader(header)
    sep = bytes([r.u8()])
    trailing_nl = r.u8()
    n_cols = r.varint()
    n_rows = r.varint()
    r.expect_end()
    cols = [o.to_strings() for o in outs]
    if len(cols) != n_cols or any(len(c) != n_rows for c in cols):
        raise ValueError("csv_split: corrupt columns")
    lines = [sep.join(cols[c][i] for c in range(n_cols)) for i in range(n_rows)]
    raw = b"\n".join(lines) + (b"\n" if trailing_nl else b"")
    return [Stream(np.frombuffer(raw, dtype=np.uint8), SType.SERIAL, 1)]


register_codec(
    CodecSpec(
        "csv_split",
        codec_id=20,
        encode=_csv_split_enc,
        decode=_csv_split_dec,
        n_outputs=-1,
        min_version=2,
        doc="rectangular CSV -> per-column string streams (frontend, §IV)",
    )
)


# ------------------------------------------------------------- parse_numeric
def _canonical_int(b: bytes):
    """Return int value if `b` is a canonical decimal i64 rendering, else None."""
    if not b or len(b) > 20:
        return None
    neg = b[0:1] == b"-"
    digits = b[1:] if neg else b
    if not digits or not digits.isdigit():
        return None
    if len(digits) > 1 and digits[0:1] == b"0":
        return None  # leading zeros don't round-trip
    if neg and digits == b"0":
        return None  # "-0" doesn't round-trip
    v = int(b)
    if not (-(1 << 63) <= v < (1 << 63)):
        return None
    return v


def _parse_numeric_enc(streams, params):
    s = streams[0]
    if s.stype != SType.STRING:
        raise ValueError("parse_numeric wants a string stream")
    items = s.to_strings()
    is_num = np.zeros(len(items), dtype=np.uint8)
    values: List[int] = []
    exceptions: List[bytes] = []
    for i, it in enumerate(items):
        v = _canonical_int(it)
        if v is None:
            exceptions.append(it)
        else:
            is_num[i] = 1
            values.append(v)
    vals = np.asarray(values, dtype=np.int64).view(np.uint64)
    bitmap = np.packbits(is_num) if len(items) else np.zeros(0, np.uint8)
    h = HeaderWriter().varint(len(items)).done()
    return [
        Stream(bitmap, SType.SERIAL, 1),
        numeric_stream(vals),
        mk_strings(exceptions),
    ], h


def _parse_numeric_dec(outs, header):
    bitmap_s, vals_s, exc_s = outs
    r = HeaderReader(header)
    n = r.varint()
    r.expect_end()
    is_num = np.unpackbits(bitmap_s.data)[:n].astype(bool)
    vals = vals_s.data.view(np.int64)
    exceptions = exc_s.to_strings()
    items: List[bytes] = []
    vi = ei = 0
    for i in range(n):
        if is_num[i]:
            items.append(str(int(vals[vi])).encode())
            vi += 1
        else:
            items.append(exceptions[ei])
            ei += 1
    return [mk_strings(items)]


register_codec(
    CodecSpec(
        "parse_numeric",
        codec_id=19,
        encode=_parse_numeric_enc,
        decode=_parse_numeric_dec,
        n_outputs=3,
        min_version=2,
        doc="ASCII ints -> (bitmap, i64 values, exceptions); lossless always",
    )
)
