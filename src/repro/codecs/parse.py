"""Parser frontends (paper §IV "Frontend", §VI-C).

``csv_split``     — lossless rectangular CSV -> per-column STRING streams.
``parse_numeric`` — STRING of ASCII decimal ints -> (bitmap, i64 values,
                    exception strings).  Canonical integers go numeric; any
                    string that would not round-trip exactly stays an
                    exception — losslessness beats parsing coverage.

The module also hosts the *format sniffers* (``sniff_csv``,
``sniff_numeric_width``, ``sniff_struct_width``) behind the trainer's
``--frontend auto``: cheap, bounded-probe heuristics that decide which
frontend codec would parse a sample byte blob.  They share this module
because they are the detection side of the same parsing model — ``sniff_csv``
applies exactly ``csv_split``'s rectangularity rule.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.codec import (
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_codec,
)
from repro.core.message import Stream, SType, strings as mk_strings

from ._util import HeaderReader, HeaderWriter, numeric_stream


# ----------------------------------------------------------------- csv_split
# Header extension flag bits (appended after n_rows only when non-zero, so
# single-byte-separator LF frames stay byte-identical to the frozen vectors):
_CSV_EXT_CRLF = 1  # lines were CRLF-terminated; decode rejoins with \r\n
_CSV_EXT_MB_SEP = 2  # separator is multi-byte; the tail follows as bytes_


def _csv_sep_bytes(sep) -> bytes:
    sep_b = (
        bytes([sep])
        if isinstance(sep, int)
        else (sep.encode() if isinstance(sep, str) else bytes(sep))
    )
    if not sep_b:
        raise ValueError("csv_split: separator must be non-empty")
    if b"\n" in sep_b or b"\r" in sep_b:
        raise ValueError("csv_split: separator cannot contain newlines")
    return sep_b


def _csv_split_enc(streams, params):
    s = streams[0]
    if s.stype != SType.SERIAL:
        raise ValueError("csv_split wants serial bytes")
    sep_b = _csv_sep_bytes(params.get("sep", ","))
    raw = s.data.tobytes()
    trailing_nl = raw.endswith(b"\n")
    body = raw[:-1] if trailing_nl else raw
    lines = body.split(b"\n") if body else []
    if not lines:
        raise ValueError("csv_split: empty input")
    # CRLF mode: when every newline-terminated line carries a \r, treat the
    # file as CRLF-terminated (strip the \r from fields, rejoin with \r\n on
    # decode) — otherwise stray \r stay glued to the last field, which still
    # round-trips but pollutes the column streams (the sniff_csv bug twin)
    crlf = bool(trailing_nl and all(ln.endswith(b"\r") for ln in lines))
    if crlf:
        lines = [ln[:-1] for ln in lines]
    rows = [ln.split(sep_b) for ln in lines]
    n_cols = len(rows[0])
    if any(len(r) != n_cols for r in rows):
        raise ValueError("csv_split: ragged rows (rectangular CSV only)")
    outs: List[Stream] = []
    for c in range(n_cols):
        outs.append(mk_strings([r[c] for r in rows]))
    h = (
        HeaderWriter()
        .u8(sep_b[0])
        .u8(1 if trailing_nl else 0)
        .varint(n_cols)
        .varint(len(rows))
    )
    flags = (_CSV_EXT_CRLF if crlf else 0) | (
        _CSV_EXT_MB_SEP if len(sep_b) > 1 else 0
    )
    if flags:
        h.u8(flags)
        if flags & _CSV_EXT_MB_SEP:
            h.bytes_(sep_b[1:])
    return outs, h.done()


def _csv_split_dec(outs, header):
    r = HeaderReader(header)
    sep = bytes([r.u8()])
    trailing_nl = r.u8()
    n_cols = r.varint()
    n_rows = r.varint()
    eol = b"\n"
    if r.pos < len(r.buf):  # extension byte (absent in pre-extension frames)
        flags = r.u8()
        if flags & _CSV_EXT_MB_SEP:
            sep += r.bytes_()
        if flags & _CSV_EXT_CRLF:
            eol = b"\r\n"
    r.expect_end()
    cols = [o.to_strings() for o in outs]
    if len(cols) != n_cols or any(len(c) != n_rows for c in cols):
        raise ValueError("csv_split: corrupt columns")
    lines = [sep.join(cols[c][i] for c in range(n_cols)) for i in range(n_rows)]
    raw = eol.join(lines) + (eol if trailing_nl else b"")
    return [Stream(np.frombuffer(raw, dtype=np.uint8), SType.SERIAL, 1)]


register_codec(
    CodecSpec(
        "csv_split",
        codec_id=20,
        encode=_csv_split_enc,
        decode=_csv_split_dec,
        n_outputs=-1,
        min_version=2,
        doc="rectangular CSV -> per-column string streams (frontend, §IV)",
        sig=CodecSig(
            inputs=(InPort(frozenset((int(SType.SERIAL),))),),
            transfer=lambda atoms, params, n_out: [(int(SType.STRING), 1)] * n_out,
            params=(ParamSpec("sep", "str", doc="column separator (default ',')"),),
            expansion=2.0,  # per-cell u32 lengths replace the separators
        ),
    )
)


# ------------------------------------------------------------- parse_numeric
def _canonical_int(b: bytes):
    """Return int value if `b` is a canonical decimal i64 rendering, else None."""
    if not b or len(b) > 20:
        return None
    neg = b[0:1] == b"-"
    digits = b[1:] if neg else b
    if not digits or not digits.isdigit():
        return None
    if len(digits) > 1 and digits[0:1] == b"0":
        return None  # leading zeros don't round-trip
    if neg and digits == b"0":
        return None  # "-0" doesn't round-trip
    v = int(b)
    if not (-(1 << 63) <= v < (1 << 63)):
        return None
    return v


def _parse_numeric_enc(streams, params):
    s = streams[0]
    if s.stype != SType.STRING:
        raise ValueError("parse_numeric wants a string stream")
    items = s.to_strings()
    is_num = np.zeros(len(items), dtype=np.uint8)
    values: List[int] = []
    exceptions: List[bytes] = []
    for i, it in enumerate(items):
        v = _canonical_int(it)
        if v is None:
            exceptions.append(it)
        else:
            is_num[i] = 1
            values.append(v)
    vals = np.asarray(values, dtype=np.int64).view(np.uint64)
    bitmap = np.packbits(is_num) if len(items) else np.zeros(0, np.uint8)
    h = HeaderWriter().varint(len(items)).done()
    return [
        Stream(bitmap, SType.SERIAL, 1),
        numeric_stream(vals),
        mk_strings(exceptions),
    ], h


def _parse_numeric_dec(outs, header):
    bitmap_s, vals_s, exc_s = outs
    r = HeaderReader(header)
    n = r.varint()
    r.expect_end()
    is_num = np.unpackbits(bitmap_s.data)[:n].astype(bool)
    vals = vals_s.data.view(np.int64)
    exceptions = exc_s.to_strings()
    items: List[bytes] = []
    vi = ei = 0
    for i in range(n):
        if is_num[i]:
            items.append(str(int(vals[vi])).encode())
            vi += 1
        else:
            items.append(exceptions[ei])
            ei += 1
    return [mk_strings(items)]


register_codec(
    CodecSpec(
        "parse_numeric",
        codec_id=19,
        encode=_parse_numeric_enc,
        decode=_parse_numeric_dec,
        n_outputs=3,
        min_version=2,
        doc="ASCII ints -> (bitmap, i64 values, exceptions); lossless always",
        sig=CodecSig(
            inputs=(InPort(frozenset((int(SType.STRING),))),),
            transfer=lambda atoms, params, n_out: [
                (int(SType.SERIAL), 1),
                (int(SType.NUMERIC), 8),
                (int(SType.STRING), 1),
            ],
            expansion=2.0,  # short digit strings widen to 8-byte values
        ),
    )
)


# -------------------------------------------------------------- sniffers
SNIFF_PROBE_BYTES = 1 << 16  # all sniffing runs on a bounded prefix

_PRINTABLE_MASK = np.zeros(256, dtype=bool)
_PRINTABLE_MASK[32:127] = True
_PRINTABLE_MASK[[9, 10, 13]] = True  # tab / newline / carriage return

_NUMERIC_SNIFF_DTYPES = {2: np.uint16, 4: np.uint32, 8: np.uint64}


def sniff_csv(
    raw: bytes,
    *,
    seps: Tuple[bytes, ...] = (b",", b"\t", b";", b"|"),
    max_probe: int = SNIFF_PROBE_BYTES,
) -> Optional[Tuple[int, str]]:
    """Detect a rectangular CSV prefix -> ``(n_cols, sep)``, else None.

    The acceptance rule is ``csv_split``'s own: every probed (complete) line
    must split into the same column count under one separator.  Of the
    separators that pass, the one yielding the most columns wins — a file
    whose fields contain no separator at all still parses as 1 column, so
    at least 2 columns are required to call it CSV.

    CRLF files are handled exactly as ``csv_split`` does: when every probed
    line ends with ``\\r`` the terminator is stripped before the
    rectangularity check, so a CRLF file no longer trains a plan whose last
    column drags a ``\\r`` suffix through every row.  A lone ``\\r`` inside
    a line (mixed endings) still counts as field bytes, matching the codec.
    """
    probe = bytes(raw[:max_probe])
    if len(probe) < 8:
        return None
    arr = np.frombuffer(probe, dtype=np.uint8)
    if float(_PRINTABLE_MASK[arr].mean()) < 0.95:
        return None
    cut = probe.rfind(b"\n")
    if cut <= 0:
        return None
    lines = probe[:cut].split(b"\n")
    if all(ln.endswith(b"\r") for ln in lines):
        lines = [ln[:-1] for ln in lines]
    if len(lines) < 2 or any(not ln for ln in lines):
        return None
    best: Optional[Tuple[int, bytes]] = None
    for sep in seps:
        n_cols = lines[0].count(sep) + 1
        if n_cols < 2:
            continue
        if any(ln.count(sep) + 1 != n_cols for ln in lines[1:]):
            continue
        if best is None or n_cols > best[0]:
            best = (n_cols, sep)
    if best is None:
        return None
    return best[0], best[1].decode()


def sniff_edge_list(
    raw: bytes,
    *,
    seps: Tuple[bytes, ...] = (b"\t", b" "),
    max_probe: int = SNIFF_PROBE_BYTES,
) -> Optional[str]:
    """Detect a SNAP-style text edge list -> separator, else None.

    Acceptance: mostly printable, >= 32 non-comment probed lines of which
    >= 95% split into exactly two canonical decimal integers under one
    separator (``#`` comment lines are ignored, as ``edge_list`` routes them
    to its exception stream).  Only whitespace separators are probed — a
    two-integer-column *comma* file keeps sniffing as CSV, which subsumes it.
    """
    probe = bytes(raw[:max_probe])
    if len(probe) < 16:
        return None
    arr = np.frombuffer(probe, dtype=np.uint8)
    if float(_PRINTABLE_MASK[arr].mean()) < 0.95:
        return None
    cut = probe.rfind(b"\n")
    if cut <= 0:
        return None
    lines = probe[:cut].split(b"\n")
    data = [ln for ln in lines if ln and not ln.startswith(b"#")]
    if len(data) < 32:
        return None
    best: Optional[Tuple[int, bytes]] = None
    for sep in seps:
        n_ok = 0
        for ln in data:
            parts = ln.split(sep)
            if (
                len(parts) == 2
                and _canonical_int(parts[0]) is not None
                and _canonical_int(parts[1]) is not None
            ):
                n_ok += 1
        if n_ok >= max(32, int(0.95 * len(data))) and (
            best is None or n_ok > best[0]
        ):
            best = (n_ok, sep)
    if best is None:
        return None
    return best[1].decode()


def sniff_edge_list_bin(
    raw: bytes,
    *,
    widths: Tuple[int, ...] = (4, 8),
    max_probe: int = SNIFF_PROBE_BYTES,
) -> Optional[int]:
    """Detect a binary interleaved (src, dst) edge array -> pair width.

    Signals, probed narrowest-first like ``sniff_numeric_width``: the src
    column is >= 98% non-decreasing (CSR dumps sort by source), src repeats
    often enough to form adjacency runs (>= 20%), and neighbors within a run
    are >= 90% increasing (sorted adjacency lists).  Plain sorted integer
    arrays fail the run test, so the numeric sniffer still claims them.
    """
    n = len(raw)
    for w in widths:
        if n % (2 * w) or n // (2 * w) < 64:
            continue
        take = (min(n, max_probe) // (2 * w)) * (2 * w)
        pairs = np.frombuffer(raw[:take], dtype=_NUMERIC_SNIFF_DTYPES[w]).reshape(
            -1, 2
        )
        src, dst = pairs[:, 0], pairs[:, 1]
        if float(np.mean(src[1:] >= src[:-1])) < 0.98:
            continue
        same = src[1:] == src[:-1]
        if float(same.mean()) < 0.2:
            continue
        if float(np.mean(dst[1:][same] > dst[:-1][same])) < 0.9:
            continue
        return w
    return None


def sniff_numeric_width(
    raw: bytes,
    *,
    widths: Tuple[int, ...] = (2, 4, 8),
    require_monotone: bool = False,
    max_probe: int = SNIFF_PROBE_BYTES,
) -> Optional[int]:
    """Detect a fixed-width little-endian integer array -> element width.

    Two independent signals, probed narrowest-first (a sorted w-wide array
    read at width 2w still looks sorted — its high halves carry the order —
    while a 2w-wide array read at w interleaves random low halves, so the
    narrowest width that fires is the true one): *sortedness* (>= 90% of
    adjacent deltas non-negative — index-like columns) and *bounded range*
    (>= 95% of the values share one top byte — measurements far narrower
    than their storage width).  ``require_monotone=True`` keeps only the
    strong first signal; the bounded-range signal also fires on multi-field
    records, so callers try struct detection in between.
    """
    n = len(raw)
    for w in widths:
        if n % w or n // w < 64:
            continue
        take = (min(n, max_probe) // w) * w
        a = np.frombuffer(raw[:take], dtype=_NUMERIC_SNIFF_DTYPES[w])
        mono = float(np.mean(a[1:] >= a[:-1]))
        if mono >= 0.9:
            return w
        if require_monotone:
            continue
        top = np.frombuffer(raw[:take], dtype=np.uint8).reshape(-1, w)[:, -1]
        counts = np.bincount(top, minlength=256)
        if (
            float(counts.max()) / top.size >= 0.95
            or int((counts > 0).sum()) <= 2
        ):
            return w
    return None


def sniff_struct_width(
    raw: bytes,
    *,
    min_width: int = 2,
    max_width: int = 16,
    max_probe: int = SNIFF_PROBE_BYTES,
) -> Optional[int]:
    """Detect a fixed-size record layout -> record width, else None.

    Signal: byte equality at lag ``w`` (same field offset, adjacent records)
    far above the lag-1 baseline — fixed-width records repeat their
    near-constant field bytes with period exactly ``w``.  The smallest width
    within 95% of the best score wins, so a ``2w`` multiple never shadows
    the true record size.
    """
    n = len(raw)
    x = np.frombuffer(raw[:max_probe], dtype=np.uint8).astype(np.int16)
    if x.size < 64:
        return None
    base = float(np.mean(x[1:] == x[:-1]))
    scores = {}
    for w in range(min_width, max_width + 1):
        if n % w or n // w < 16 or x.size <= 2 * w:
            continue
        scores[w] = float(np.mean(x[w:] == x[:-w]))
    if not scores:
        return None
    best_w = min(scores, key=lambda w: (-scores[w], w))
    if scores[best_w] < max(0.35, 1.5 * base):
        return None
    for w in sorted(scores):
        if scores[w] >= 0.95 * scores[best_w]:
            return w
    return best_w
