"""Structural codecs: store, dup, constant, split_n, concat, field_split,
string_split.  These carry no compression on their own — they are the glue
that routes data through the graph (paper §III-C, §IV "grouping")."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.codec import (
    ANY_STYPES,
    FIXED_STYPES,
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_codec,
)
from repro.core.message import Stream, SType, from_wire

from ._util import HeaderReader, HeaderWriter

_SERIAL = int(SType.SERIAL)
_STRUCT = int(SType.STRUCT)
_NUMERIC = int(SType.NUMERIC)
_STRING = int(SType.STRING)

# --------------------------------------------------------------------- store
def _store_enc(streams, params):
    return [streams[0]], b""


def _store_dec(outs, header):
    return [outs[0]]


register_codec(
    CodecSpec(
        "store",
        codec_id=1,
        encode=_store_enc,
        decode=_store_dec,
        doc="identity; terminal passthrough (useful as a GP mutation target)",
        sig=CodecSig(
            inputs=(InPort(ANY_STYPES),),
            transfer=lambda atoms, params, n_out: [atoms[0]],
        ),
    )
)


# ----------------------------------------------------------------------- dup
def _dup_enc(streams, params):
    s = streams[0]
    return [s, Stream(s.data.copy(), s.stype, s.width, s.lengths)], b""


def _dup_dec(outs, header):
    return [outs[0]]


register_codec(
    CodecSpec(
        "dup",
        codec_id=2,
        encode=_dup_enc,
        decode=_dup_dec,
        n_outputs=2,
        doc="explicit fan-out: one input, two identical outputs",
        sig=CodecSig(
            inputs=(InPort(ANY_STYPES),),
            transfer=lambda atoms, params, n_out: [atoms[0], atoms[0]],
            expansion=2.0,
        ),
    )
)


# ------------------------------------------------------------------ constant
def _constant_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("constant codec: fixed-width streams only")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width
    n = s.n_elts
    if n == 0:
        value = b""
    else:
        rec = raw.reshape(n, -1) if s.stype != SType.SERIAL else raw.reshape(n, 1)
        if not (rec == rec[0]).all():
            raise ValueError("constant codec: stream is not constant")
        value = rec[0].tobytes()
    h = (
        HeaderWriter()
        .u8(int(s.stype))
        .varint(w)
        .varint(n)
        .bytes_(value)
        .done()
    )
    return [], h


def _constant_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    w = r.varint()
    n = r.varint()
    value = r.bytes_()
    r.expect_end()
    payload = value * n
    return [from_wire(stype, w, payload, None)]


register_codec(
    CodecSpec(
        "constant",
        codec_id=8,
        encode=_constant_enc,
        decode=_constant_dec,
        n_outputs=0,
        doc="all-equal stream -> header only (value + count); zero outputs",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [],
        ),
    )
)


# ------------------------------------------------------------------- split_n
def _split_n_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("split_n: fixed-width streams only")
    sizes = list(params["sizes"])  # element counts per chunk; -1 => rest (last)
    n = s.n_elts
    if sizes and sizes[-1] == -1:
        sizes[-1] = n - sum(sizes[:-1])
    if sum(sizes) != n or any(sz < 0 for sz in sizes):
        raise ValueError(f"split_n sizes {sizes} != {n} elements")
    outs: List[Stream] = []
    off = 0
    for sz in sizes:
        outs.append(Stream(s.data[off * _eltw(s) : (off + sz) * _eltw(s)], s.stype, s.width))
        off += sz
    h = HeaderWriter()
    h.varint(len(sizes))
    return outs, h.done()


def _eltw(s: Stream) -> int:
    # elements of `data` per logical element (NUMERIC arrays are 1 datum/elt)
    if s.stype == SType.NUMERIC:
        return 1
    if s.stype == SType.STRUCT:
        return s.width
    return 1


def _split_n_dec(outs, header):
    r = HeaderReader(header)
    k = r.varint()
    r.expect_end()
    if len(outs) != k:
        raise ValueError("split_n: wrong output count")
    s0 = outs[0]
    data = np.concatenate([o.data for o in outs])
    return [Stream(data, s0.stype, s0.width)]


register_codec(
    CodecSpec(
        "split_n",
        codec_id=11,
        encode=_split_n_enc,
        decode=_split_n_dec,
        n_outputs=-1,
        doc="split a stream into contiguous chunks (params: sizes=[...])",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: (
                None
                if "sizes" in params and len(params["sizes"]) != n_out
                else [atoms[0]] * n_out
            ),
            params=(ParamSpec("sizes", "int_list", required=True,
                              doc="element counts per chunk; -1 => rest (last)"),),
        ),
    )
)


# -------------------------------------------------------------------- concat
def _concat_enc(streams, params):
    if not streams:
        raise ValueError("concat: needs >=1 input")
    s0 = streams[0]
    for s in streams:
        if s.stype != s0.stype or s.width != s0.width:
            raise ValueError("concat: mixed stream types")
    h = HeaderWriter()
    h.varint(len(streams))
    if s0.stype == SType.STRING:
        content = np.concatenate([s.data for s in streams])
        lengths = np.concatenate(
            [s.lengths if s.lengths is not None else np.zeros(0, np.uint32) for s in streams]
        ).astype(np.uint32)
        for s in streams:
            h.varint(int(s.lengths.size))
        out = Stream(content, SType.STRING, 1, lengths)
    else:
        for s in streams:
            h.varint(int(s.data.size))
        # NUMERIC streams may mix signedness (i64 vs u64): concatenate the
        # UNSIGNED bit views — np.concatenate would promote mixed int64/uint64
        # to float64 and silently round large values (lossless bug!)
        parts = [
            s.as_unsigned().data if s.stype == SType.NUMERIC else s.data
            for s in streams
        ]
        out = Stream(np.concatenate(parts), s0.stype, s0.width)
    return [out], h.done()


def _concat_dec(outs, header):
    s = outs[0]
    r = HeaderReader(header)
    k = r.varint()
    sizes = [r.varint() for _ in range(k)]
    r.expect_end()
    res: List[Stream] = []
    if s.stype == SType.STRING:
        off_s = 0
        off_c = 0
        for sz in sizes:
            lens = s.lengths[off_s : off_s + sz]
            nb = int(lens.sum())
            res.append(Stream(s.data[off_c : off_c + nb], SType.STRING, 1, lens))
            off_s += sz
            off_c += nb
    else:
        off = 0
        for sz in sizes:
            res.append(Stream(s.data[off : off + sz], s.stype, s.width))
            off += sz
    return res


def _concat_transfer(atoms, params, n_out):
    # every input must share one (stype, width); unknowns stay compatible
    stypes = {st for st, _ in atoms if st is not None}
    widths = {w for _, w in atoms if w is not None}
    if len(stypes) > 1 or len(widths) > 1:
        return None
    st = next(iter(stypes)) if stypes else None
    w = next(iter(widths)) if widths else None
    return [(st, w)]


register_codec(
    CodecSpec(
        "concat",
        codec_id=12,
        encode=_concat_enc,
        decode=_concat_dec,
        n_inputs=-1,
        n_outputs=1,
        doc="merge same-typed streams (the paper's cluster 'grouping' step)",
        sig=CodecSig(
            inputs=(InPort(ANY_STYPES),),
            transfer=_concat_transfer,
        ),
    )
)


# --------------------------------------------------------------- field_split
def _field_split_enc(streams, params):
    s = streams[0]
    widths = list(params["widths"])
    if s.stype not in (SType.STRUCT, SType.SERIAL):
        raise ValueError("field_split wants struct/serial input")
    rec_w = s.width if s.stype == SType.STRUCT else int(sum(widths))
    if sum(widths) != rec_w:
        raise ValueError(f"field widths {widths} != record width {rec_w}")
    raw = s.data
    if raw.size % rec_w:
        raise ValueError("input not a whole number of records")
    mat = raw.reshape(-1, rec_w)
    outs: List[Stream] = []
    off = 0
    for w in widths:
        col = np.ascontiguousarray(mat[:, off : off + w]).reshape(-1)
        outs.append(Stream(col, SType.STRUCT if w > 1 else SType.SERIAL, max(w, 1)))
        off += w
    h = HeaderWriter().u8(int(s.stype)).varint(rec_w).varint(len(widths))
    for w in widths:
        h.varint(w)
    return outs, h.done()


def _field_split_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    rec_w = r.varint()
    k = r.varint()
    widths = [r.varint() for _ in range(k)]
    r.expect_end()
    n = outs[0].data.size // widths[0]
    mat = np.empty((n, rec_w), dtype=np.uint8)
    off = 0
    for w, o in zip(widths, outs):
        mat[:, off : off + w] = o.data.reshape(n, w)
        off += w
    return [Stream(mat.reshape(-1), stype, rec_w if stype == SType.STRUCT else 1)]


def _field_split_transfer(atoms, params, n_out):
    st, w = atoms[0]
    widths = params.get("widths")
    if widths is None:
        # params unknown (e.g. inferring from a wire frame): columns are
        # struct-or-serial of unknown width
        return [(None, None)] * n_out
    widths = list(widths)
    if len(widths) != n_out or any(x < 1 for x in widths):
        return None
    if st == _STRUCT and w is not None and sum(widths) != w:
        return None  # field widths must tile the record exactly
    return [(_STRUCT, x) if x > 1 else (_SERIAL, 1) for x in widths]


register_codec(
    CodecSpec(
        "field_split",
        codec_id=10,
        encode=_field_split_enc,
        decode=_field_split_dec,
        n_outputs=-1,
        doc="record frontend: struct(k) -> per-field columns (params: widths=[...])",
        sig=CodecSig(
            inputs=(InPort(frozenset((_STRUCT, _SERIAL))),),
            transfer=_field_split_transfer,
            params=(ParamSpec("widths", "int_list", required=True,
                              doc="byte widths per field; must sum to the record width"),),
        ),
    )
)


# -------------------------------------------------------------- string_split
def _string_split_enc(streams, params):
    s = streams[0]
    if s.stype != SType.STRING:
        raise ValueError("string_split wants a string stream")
    content = Stream(s.data, SType.SERIAL, 1)
    lens = Stream(s.lengths.astype(np.uint32), SType.NUMERIC, 4)
    return [content, lens], b""


def _string_split_dec(outs, header):
    content, lens = outs
    return [Stream(content.data, SType.STRING, 1, lens.data.astype(np.uint32))]


register_codec(
    CodecSpec(
        "string_split",
        codec_id=21,
        encode=_string_split_enc,
        decode=_string_split_dec,
        n_outputs=2,
        doc="string -> (content bytes, u32 lengths) so each can be compressed",
        sig=CodecSig(
            inputs=(InPort(frozenset((_STRING,))),),
            transfer=lambda atoms, params, n_out: [(_SERIAL, 1), (_NUMERIC, 4)],
            expansion=2.0,  # 4 length bytes per (possibly empty) string
        ),
    )
)
