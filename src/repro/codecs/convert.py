"""Type conversions between message kinds (paper §V-A's typed streams)."""
from __future__ import annotations

import numpy as np

from repro.core.codec import CodecSpec, register_codec
from repro.core.message import Stream, SType, from_wire

from ._util import UNSIGNED, HeaderReader, HeaderWriter


def _interpret_numeric_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("interpret_numeric: fixed-width streams only")
    w = int(params.get("width", s.width if s.stype != SType.SERIAL else 1))
    if w not in UNSIGNED:
        raise ValueError(f"interpret_numeric: width {w} not in 1/2/4/8")
    raw = s.content_bytes()
    if len(raw) % w:
        raise ValueError("interpret_numeric: size not divisible by width")
    out = Stream(np.frombuffer(raw, dtype=UNSIGNED[w]), SType.NUMERIC, w)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [out], h


def _interpret_numeric_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    return [from_wire(stype, width, outs[0].content_bytes(), None)]


register_codec(
    CodecSpec(
        "interpret_numeric",
        codec_id=23,
        encode=_interpret_numeric_enc,
        decode=_interpret_numeric_dec,
        doc="reinterpret struct/serial bytes as host-endian numeric(w)",
    )
)
