"""Type conversions between message kinds (paper §V-A's typed streams)."""
from __future__ import annotations

import numpy as np

from repro.core.codec import (
    FIXED_STYPES,
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_codec,
)
from repro.core.message import Stream, SType, from_wire

from ._util import UNSIGNED, HeaderReader, HeaderWriter


def _interpret_numeric_transfer(atoms, params, n_out):
    st, w = atoms[0]
    want = params.get("width")
    if want is None:
        # default: reinterpret at the stream's own width (1 for serial)
        if st == int(SType.SERIAL):
            want = 1
        elif w is not None:
            want = w
        else:
            return [(int(SType.NUMERIC), None)]
    if int(want) not in UNSIGNED:
        return None
    return [(int(SType.NUMERIC), int(want))]


def _interpret_numeric_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("interpret_numeric: fixed-width streams only")
    w = int(params.get("width", s.width if s.stype != SType.SERIAL else 1))
    if w not in UNSIGNED:
        raise ValueError(f"interpret_numeric: width {w} not in 1/2/4/8")
    raw = s.content_bytes()
    if len(raw) % w:
        raise ValueError("interpret_numeric: size not divisible by width")
    out = Stream(np.frombuffer(raw, dtype=UNSIGNED[w]), SType.NUMERIC, w)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [out], h


def _interpret_numeric_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    return [from_wire(stype, width, outs[0].content_bytes(), None)]


register_codec(
    CodecSpec(
        "interpret_numeric",
        codec_id=23,
        encode=_interpret_numeric_enc,
        decode=_interpret_numeric_dec,
        doc="reinterpret struct/serial bytes as host-endian numeric(w)",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=_interpret_numeric_transfer,
            params=(ParamSpec("width", "int", choices=(1, 2, 4, 8),
                              doc="target numeric width (default: stream width)"),),
        ),
    )
)
