"""Graph-structured data codecs — the ``graph:`` profile family's node set.

Adjacency lists compress dramatically better under degree + delta-gap +
reference coding than under generic LZ (Zuckerli, arXiv:2009.01353; the
Besta/Hoefler survey, arXiv:1806.01799, catalogs the structural redundancies
these codecs exploit).  Three ordinary codecs plus one selector:

``edge_list``      — text edge lists (SNAP style: ``u<sep>v`` lines, ``#``
                     comments) -> (src, dst, bitmap, exception-lines).  Like
                     ``parse_numeric``, losslessness beats coverage: any line
                     that is not two canonical decimal i64s stays a byte-exact
                     exception string, so *every* input round-trips.
``edge_list_bin``  — the binary variant: interleaved fixed-width (u, v)
                     pairs -> (src, dst).  After ``adj_gap`` this is the CSR
                     view (degrees + neighbors) of the same graph.
``adj_gap``        — (src, dst) edge columns -> (nodes, degrees, refs,
                     copy-bits, gaps): run-length groups the src column into
                     per-node adjacency lists, gap-codes each list (first
                     neighbor relative to the source node, then neighbor-to-
                     neighbor deltas, zigzagged so unsorted lists stay
                     lossless), and optionally encodes a list as a *diff
                     against a similar earlier list* — Zuckerli's
                     reference/copy trick — when a byte-cost model says that
                     is cheaper.
``adjacency_auto`` — the selector that decides, by trial compression on a
                     bounded sample, whether the reference window pays for
                     this graph (vs plain gap coding vs raw columns).

Everything decode needs lives in the per-node headers and output streams, so
the universal decoder stays parameter-free (paper §III-D).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.codec import (
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_codec,
)
from repro.core.engine import CompressionCtx, compress
from repro.core.graph import GraphBuilder, Plan
from repro.core.message import Stream, SType, strings as mk_strings
from repro.core.selector import SelectorSig, SelectorSpec, register_selector

from ._util import UNSIGNED, HeaderReader, HeaderWriter, numeric_stream
from .parse import _canonical_int

EDGE_SEPS = (b"\t", b" ", b",", b";")  # auto-sniff candidates, most-SNAP first

_U64_ONE = np.uint64(1)
_U64_SEVEN = np.uint64(7)


# ------------------------------------------------------------------ helpers
def _zigzag_u64(duw: np.ndarray) -> np.ndarray:
    """Zigzag the wrapped u64 difference (two's-complement representative)."""
    x = duw.view(np.int64)
    return (duw << _U64_ONE) ^ (x >> np.int64(63)).view(np.uint64)


def _unzigzag_u64(zz: np.ndarray) -> np.ndarray:
    """Inverse of :func:`_zigzag_u64` — signed delta as wrapped u64."""
    return (zz >> _U64_ONE) ^ (np.zeros_like(zz) - (zz & _U64_ONE))


def _varint_lens(zz: np.ndarray) -> np.ndarray:
    """Byte cost of each value under 7-bit varint coding (the cost model)."""
    nb = np.ones(zz.shape, np.int64)
    v = zz >> _U64_SEVEN
    while v.any():
        nb += v != 0
        v = v >> _U64_SEVEN
    return nb


def _gap_code(vals: np.ndarray, base: np.uint64) -> np.ndarray:
    """Gap-code a list: first element relative to ``base``, then deltas."""
    prev = np.empty_like(vals)
    if vals.size:
        prev[0] = base
        prev[1:] = vals[:-1]
    return _zigzag_u64(vals - prev)


def _gap_decode(zz: np.ndarray, base: np.uint64) -> np.ndarray:
    d = _unzigzag_u64(zz)
    with np.errstate(over="ignore"):
        if d.size:
            d[0] += base
        return np.cumsum(d, dtype=np.uint64)


# ----------------------------------------------------------------- edge_list
def _edge_list_enc(streams, params):
    s = streams[0]
    if s.stype != SType.SERIAL:
        raise ValueError("edge_list wants serial bytes")
    sep = params.get("sep", "auto")
    raw = s.data.tobytes()
    trailing_nl = raw.endswith(b"\n")
    body = raw[:-1] if trailing_nl else raw
    lines = body.split(b"\n") if body else []

    def parse_with(sep_b: bytes):
        src: List[int] = []
        dst: List[int] = []
        ok = np.zeros(len(lines), dtype=np.uint8)
        exceptions: List[bytes] = []
        for i, ln in enumerate(lines):
            parts = ln.split(sep_b)
            if len(parts) == 2:
                u = _canonical_int(parts[0])
                v = _canonical_int(parts[1])
                if u is not None and v is not None:
                    ok[i] = 1
                    src.append(u)
                    dst.append(v)
                    continue
            exceptions.append(ln)
        return src, dst, ok, exceptions

    if sep == "auto":
        sep_b, parsed = EDGE_SEPS[0], None
        for cand in EDGE_SEPS:
            got = parse_with(cand)
            if parsed is None or len(got[0]) > len(parsed[0]):
                sep_b, parsed = cand, got
    else:
        sep_b = sep.encode() if isinstance(sep, str) else bytes(sep)
        if not sep_b:
            raise ValueError("edge_list: separator must be non-empty")
        if b"\n" in sep_b:
            raise ValueError("edge_list: separator cannot contain newlines")
        parsed = parse_with(sep_b)
    src, dst, ok, exceptions = parsed
    h = (
        HeaderWriter()
        .varint(len(lines))
        .u8(1 if trailing_nl else 0)
        .bytes_(sep_b)
        .done()
    )
    bitmap = np.packbits(ok) if len(lines) else np.zeros(0, np.uint8)
    return [
        numeric_stream(np.asarray(src, dtype=np.int64).view(np.uint64)),
        numeric_stream(np.asarray(dst, dtype=np.int64).view(np.uint64)),
        Stream(bitmap, SType.SERIAL, 1),
        mk_strings(exceptions),
    ], h


def _edge_list_dec(outs, header):
    src_s, dst_s, bitmap_s, exc_s = outs
    r = HeaderReader(header)
    n_lines = r.varint()
    trailing_nl = r.u8()
    sep_b = r.bytes_()
    r.expect_end()
    is_edge = np.unpackbits(bitmap_s.data)[:n_lines].astype(bool)
    src = src_s.data.view(np.int64)
    dst = dst_s.data.view(np.int64)
    exceptions = exc_s.to_strings()
    if int(is_edge.sum()) != src.size or src.size != dst.size:
        raise ValueError("edge_list: corrupt bitmap/columns")
    lines: List[bytes] = []
    ei = xi = 0
    for i in range(n_lines):
        if is_edge[i]:
            lines.append(b"%d%s%d" % (int(src[ei]), sep_b, int(dst[ei])))
            ei += 1
        else:
            lines.append(exceptions[xi])
            xi += 1
    raw = b"\n".join(lines) + (b"\n" if trailing_nl else b"")
    return [Stream(np.frombuffer(raw, dtype=np.uint8), SType.SERIAL, 1)]


register_codec(
    CodecSpec(
        "edge_list",
        codec_id=27,
        encode=_edge_list_enc,
        decode=_edge_list_dec,
        n_outputs=4,
        min_version=4,
        doc="text edge list -> (src, dst, bitmap, exceptions); lossless always",
        sig=CodecSig(
            inputs=(InPort(frozenset((int(SType.SERIAL),))),),
            transfer=lambda atoms, params, n_out: [
                (int(SType.NUMERIC), 8),
                (int(SType.NUMERIC), 8),
                (int(SType.SERIAL), 1),
                (int(SType.STRING), 1),
            ],
            params=(ParamSpec("sep", "str",
                              doc="edge separator; 'auto' probes tab/space/,/;"),),
            expansion=3.0,  # short decimal ids widen to u64 columns
        ),
    )
)


# ------------------------------------------------------------- edge_list_bin
def _edge_list_bin_enc(streams, params):
    s = streams[0]
    if s.stype != SType.SERIAL:
        raise ValueError("edge_list_bin wants serial bytes")
    w = int(params.get("width", 4))
    if w not in (2, 4, 8):
        raise ValueError("edge_list_bin: width must be 2/4/8")
    if s.data.size % (2 * w):
        raise ValueError(
            f"edge_list_bin: {s.data.size} bytes is not (u, v) pairs of width {w}"
        )
    pairs = np.frombuffer(s.data.tobytes(), dtype=UNSIGNED[w]).reshape(-1, 2)
    return [
        numeric_stream(np.ascontiguousarray(pairs[:, 0])),
        numeric_stream(np.ascontiguousarray(pairs[:, 1])),
    ], b""


def _edge_list_bin_dec(outs, header):
    src_s, dst_s = outs
    if src_s.width != dst_s.width or src_s.n_elts != dst_s.n_elts:
        raise ValueError("edge_list_bin: corrupt columns")
    pairs = np.empty((src_s.n_elts, 2), dtype=UNSIGNED[src_s.width])
    pairs[:, 0] = src_s.data.view(UNSIGNED[src_s.width])
    pairs[:, 1] = dst_s.data.view(UNSIGNED[dst_s.width])
    return [Stream(np.frombuffer(pairs.tobytes(), dtype=np.uint8), SType.SERIAL, 1)]


register_codec(
    CodecSpec(
        "edge_list_bin",
        codec_id=29,
        encode=_edge_list_bin_enc,
        decode=_edge_list_bin_dec,
        n_outputs=2,
        min_version=4,
        doc="interleaved fixed-width (u, v) pairs -> (src, dst) columns",
        sig=CodecSig(
            inputs=(InPort(frozenset((int(SType.SERIAL),))),),
            transfer=lambda atoms, params, n_out: (
                None
                if int(params.get("width", 4)) not in (2, 4, 8)
                else [(int(SType.NUMERIC), int(params.get("width", 4)))] * 2
            ),
            params=(ParamSpec("width", "int", choices=(2, 4, 8),
                              doc="bytes per node id (default 4)"),),
        ),
    )
)


# -------------------------------------------------------------------- adj_gap
def _adj_gap_transfer(atoms, params, n_out):
    # both columns must share one concrete width; unknowns stay compatible
    widths = {w for _, w in atoms if w is not None}
    if len(widths) > 1 or int(params.get("window", 0) or 0) < 0:
        return None
    N = int(SType.NUMERIC)
    return [(N, 8), (N, 8), (N, 8), (int(SType.SERIAL), 1), (N, 8)]


def _adj_runs(src: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run-length group the src column -> (run_starts, nodes, degrees)."""
    n = src.size
    if not n:
        e = np.zeros(0, np.int64)
        return e, np.zeros(0, np.uint64), np.zeros(0, np.uint64)
    new_run = np.empty(n, bool)
    new_run[0] = True
    np.not_equal(src[1:], src[:-1], out=new_run[1:])
    run_starts = np.flatnonzero(new_run)
    degrees = np.diff(np.append(run_starts, n)).astype(np.uint64)
    return run_starts, src[run_starts].copy(), degrees


def _adj_gap_enc(streams, params):
    s_src, s_dst = streams
    for s in (s_src, s_dst):
        if s.stype != SType.NUMERIC:
            raise ValueError("adj_gap wants numeric (src, dst) streams")
    if s_src.width != s_dst.width or s_src.n_elts != s_dst.n_elts:
        raise ValueError("adj_gap: src/dst width or length mismatch")
    window = int(params.get("window", 0))
    if window < 0:
        raise ValueError("adj_gap: window must be >= 0")
    w = s_src.width
    src = s_src.data.view(UNSIGNED[w]).astype(np.uint64)
    dst = s_dst.data.view(UNSIGNED[w]).astype(np.uint64)
    run_starts, nodes, degrees = _adj_runs(src)
    n = src.size
    n_runs = nodes.size

    # plain per-edge gaps, fully vectorized (valid for every run)
    prev = np.empty_like(dst)
    if n:
        prev[0] = src[0]
        prev[1:] = dst[:-1]
        prev[run_starts] = src[run_starts]
    plain_zz = _zigzag_u64(dst - prev)

    refs = np.zeros(n_runs, np.uint64)
    if window == 0 or n_runs == 0:
        gaps = plain_zz
        copybits = np.zeros(0, np.uint8)
    else:
        # which runs are strictly increasing (reference coding is only
        # reversible over sorted, duplicate-free lists — Zuckerli's domain)
        inc = np.empty(n, bool)
        inc[run_starts] = True
        if n > 1:
            rest = np.ones(n, bool)
            rest[run_starts] = False
            inc[rest] = dst[1:][rest[1:]] > dst[:-1][rest[1:]]
        run_inc = np.logical_and.reduceat(inc, run_starts)
        plain_cost = np.add.reduceat(_varint_lens(plain_zz), run_starts)

        lists = [dst[s : s + int(d)] for s, d in zip(run_starts, degrees)]
        degs_l = degrees.tolist()
        starts_l = run_starts.tolist()
        inc_l = run_inc.tolist()
        pcost_l = plain_cost.tolist()
        nodes_l = nodes.tolist()
        gap_chunks: List[np.ndarray] = []
        copy_chunks: List[np.ndarray] = []
        for i in range(n_runs):
            d_i = degs_l[i]
            best = None  # (cost, ref_off, copy_mask, residual_zz)
            if inc_l[i] and d_i >= 3 and pcost_l[i] > 4:
                L_i = lists[i]
                best_cost = pcost_l[i]  # hurdle: must beat plain gaps
                for r in range(1, min(window, i) + 1):
                    j = i - r
                    if not inc_l[j]:
                        continue
                    L_j = lists[j]
                    if not L_j.size or L_j.size > 4 * d_i:
                        continue  # the copy bitmap alone would dominate
                    # both lists are sorted + duplicate-free, so membership is
                    # a binary search, not np.isin's sort-merge
                    pos = np.minimum(np.searchsorted(L_i, L_j), d_i - 1)
                    copied = L_i[pos] == L_j
                    n_res = d_i - int(copied.sum())
                    # each residual gap is >= 1 varint byte: cheap lower bound
                    # prunes the exact gap-coding cost for hopeless candidates
                    lb = 1 + (L_j.size + 7) // 8 + n_res
                    if lb >= best_cost:
                        continue
                    keep = np.ones(d_i, bool)
                    keep[pos[copied]] = False  # matched L_i slots, lists unique
                    resid = L_i[keep]
                    zz_r = _gap_code(resid, nodes_l[i])
                    cost = 1 + (L_j.size + 7) // 8 + int(_varint_lens(zz_r).sum())
                    if cost < best_cost:
                        best_cost = cost
                        best = (cost, r, copied, zz_r)
            if best is None:
                st = starts_l[i]
                gap_chunks.append(plain_zz[st : st + d_i])
            else:
                refs[i] = best[1]
                copy_chunks.append(best[2])
                gap_chunks.append(best[3])
        gaps = (
            np.concatenate(gap_chunks) if gap_chunks else np.zeros(0, np.uint64)
        )
        copybits = (
            np.packbits(np.concatenate(copy_chunks))
            if copy_chunks
            else np.zeros(0, np.uint8)
        )
    h = HeaderWriter().u8(w).done()
    return [
        numeric_stream(nodes),
        numeric_stream(degrees),
        numeric_stream(refs),
        Stream(copybits, SType.SERIAL, 1),
        numeric_stream(gaps),
    ], h


def _adj_gap_dec(outs, header):
    nodes_s, degs_s, refs_s, bits_s, gaps_s = outs
    r = HeaderReader(header)
    w = r.u8()
    r.expect_end()
    if w not in UNSIGNED:
        raise ValueError("adj_gap: bad width")
    nodes = nodes_s.data.view(np.uint64)
    degrees = degs_s.data.view(np.uint64)
    refs = refs_s.data.view(np.uint64)
    bits = np.unpackbits(bits_s.data)
    gaps = gaps_s.data.view(np.uint64)
    if not (nodes.size == degrees.size == refs.size):
        raise ValueError("adj_gap: corrupt run streams")
    # one global unzigzag + prefix sum; a run's gap-decode is then just
    # P[a:b] - P[a-1] + base under wrapping u64 arithmetic (identical to
    # per-run _gap_decode, without 2 numpy passes per adjacency list)
    deltas = _unzigzag_u64(gaps)
    with np.errstate(over="ignore"):
        prefix = np.cumsum(deltas, dtype=np.uint64)
    nodes_l = nodes.tolist()

    def _seg_decode(a: int, b: int, base: int) -> np.ndarray:
        with np.errstate(over="ignore"):
            off = np.uint64(base) - (prefix[a - 1] if a else np.uint64(0))
            return prefix[a:b] + off

    lists: List[np.ndarray] = []
    gpos = bpos = 0
    for i in range(nodes.size):
        d_i = int(degrees[i])
        ref = int(refs[i])
        if ref == 0:
            if gpos + d_i > gaps.size:
                raise ValueError("adj_gap: gap stream exhausted")
            L = _seg_decode(gpos, gpos + d_i, nodes_l[i])
            gpos += d_i
        else:
            if ref > i:
                raise ValueError("adj_gap: reference before first run")
            L_j = lists[i - ref]
            if bpos + L_j.size > bits.size:
                raise ValueError("adj_gap: copy-bit stream exhausted")
            copied = L_j[bits[bpos : bpos + L_j.size].astype(bool)]
            bpos += L_j.size
            n_res = d_i - copied.size
            if n_res < 0 or gpos + n_res > gaps.size:
                raise ValueError("adj_gap: corrupt reference run")
            resid = _seg_decode(gpos, gpos + n_res, nodes_l[i])
            gpos += n_res
            # copied and residuals are disjoint increasing subsequences of a
            # strictly increasing list: their sorted union is the list
            L = np.sort(np.concatenate([copied, resid]))
        lists.append(L)
    if gpos != gaps.size:
        raise ValueError("adj_gap: trailing gap values")
    with np.errstate(over="ignore"):
        src = np.repeat(nodes, degrees.astype(np.int64))
        dst = (
            np.concatenate(lists) if lists else np.zeros(0, np.uint64)
        )
    U = UNSIGNED[w]
    return [
        numeric_stream(src.astype(U)),
        numeric_stream(dst.astype(U)),
    ]


register_codec(
    CodecSpec(
        "adj_gap",
        codec_id=28,
        encode=_adj_gap_enc,
        decode=_adj_gap_dec,
        n_inputs=2,
        n_outputs=5,
        min_version=4,
        doc="edge columns -> degree + delta-gap + reference coding (Zuckerli)",
        sig=CodecSig(
            inputs=(
                InPort(frozenset((int(SType.NUMERIC),))),
                InPort(frozenset((int(SType.NUMERIC),))),
            ),
            transfer=_adj_gap_transfer,
            params=(ParamSpec("window", "int",
                              doc="reference-list search window (0 = plain gaps)"),),
            expansion=3.0,  # narrow ids widen to u64 planes + copy bitmap
        ),
    )
)


# ------------------------------------------------------------ adjacency_auto
ADJ_SAMPLE_EDGES = 1 << 13  # trial compressions run on a bounded edge prefix


def adj_backend(window: int) -> Plan:
    """The adjacency backend graph: adj_gap + per-stream auto selectors."""
    g = GraphBuilder(2)
    nodes, degs, refs, bits, gaps = g.add(
        "adj_gap", g.input(0), g.input(1), window=window
    )
    g.select("numeric_auto", nodes)
    g.select("numeric_auto", degs)
    g.select("numeric_auto", refs)
    g.select("entropy_auto", bits)
    g.select("numeric_auto", gaps)
    return g.build(f"adj_gap_w{window}")


def _columns_backend() -> Plan:
    g = GraphBuilder(2)
    g.select("numeric_auto", g.input(0))
    g.select("numeric_auto", g.input(1))
    return g.build("edge_columns")


def _adjacency_auto(streams, params, ctx):
    """Pick plain gap coding, reference coding, or raw columns by trial.

    The reference/copy-list trick only pays on graphs whose neighborhoods
    repeat (webs, social graphs); on near-random graphs the copy bitmaps are
    pure overhead, and on unsorted edge dumps run grouping itself buys
    nothing.  A bounded aligned sample of the (src, dst) columns is
    compressed under each candidate and the smallest wins — the frame only
    ever records the chosen codecs.
    """
    window = int(params.get("window", 8))
    s_src, s_dst = streams
    k = min(s_src.n_elts, ADJ_SAMPLE_EDGES)
    samples = [
        Stream(s.data[:k], SType.NUMERIC, s.width) for s in (s_src, s_dst)
    ]
    candidates = [("columns", _columns_backend()), ("plain", adj_backend(0))]
    if window > 0:
        candidates.append(("refs", adj_backend(window)))
    best_plan, best_sz = None, 1 << 63
    for _name, plan in candidates:
        try:
            sz = len(
                compress(
                    plan, samples, ctx=CompressionCtx(ctx.format_version, ctx.level)
                )
            )
        except Exception:
            continue
        if sz < best_sz:
            best_plan, best_sz = plan, sz
    return best_plan if best_plan is not None else _columns_backend()


register_selector(
    SelectorSpec(
        "adjacency_auto",
        _adjacency_auto,
        n_inputs=2,
        doc="adjacency backend by trial: reference vs plain gaps vs columns",
        sig=SelectorSig(inputs=(
            InPort(frozenset((int(SType.NUMERIC),))),
            InPort(frozenset((int(SType.NUMERIC),))),
        )),
    )
)
