"""Shared helpers for codec implementations: header packing, width logic."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.message import Stream, SType
from repro.core.wire import read_varint, write_varint

UNSIGNED = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}
SIGNED = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}


class HeaderWriter:
    def __init__(self):
        self.buf = bytearray()

    def u8(self, v: int) -> "HeaderWriter":
        self.buf.append(v & 0xFF)
        return self

    def varint(self, v: int) -> "HeaderWriter":
        write_varint(self.buf, int(v))
        return self

    def svarint(self, v: int) -> "HeaderWriter":
        v = int(v)
        return self.varint((v << 1) ^ (v >> 63) if v >= 0 else ((-v) << 1) - 1)

    def bytes_(self, b: bytes) -> "HeaderWriter":
        self.varint(len(b))
        self.buf += b
        return self

    def done(self) -> bytes:
        return bytes(self.buf)


class HeaderReader:
    def __init__(self, header: bytes):
        self.buf = header
        self.pos = 0

    def u8(self) -> int:
        v = self.buf[self.pos]
        self.pos += 1
        return v

    def varint(self) -> int:
        v, self.pos = read_varint(self.buf, self.pos)
        return v

    def svarint(self) -> int:
        v = self.varint()
        return (v >> 1) ^ -(v & 1)

    def bytes_(self) -> bytes:
        n = self.varint()
        b = self.buf[self.pos : self.pos + n]
        self.pos += n
        return b

    def expect_end(self) -> None:
        if self.pos != len(self.buf):
            raise ValueError("trailing bytes in codec header")


# ------------------------------------------------------- device-backend glue
_JAX_OK: bool = None  # tri-state: None = not probed yet


def device_available() -> bool:
    """True when jax is importable (the device backend can be offered)."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax  # noqa: F401

            _JAX_OK = True
        except Exception:  # pragma: no cover - container always has jax
            _JAX_OK = False
    return _JAX_OK


def device_use_pallas() -> bool:
    """Real Mosaic kernels on TPU; the jit'd jnp oracle elsewhere (Pallas
    interpret mode is a correctness tool, far too slow for the data path)."""
    import jax

    return jax.default_backend() == "tpu"


def min_uint_width(max_value: int) -> int:
    if max_value < 1 << 8:
        return 1
    if max_value < 1 << 16:
        return 2
    if max_value < 1 << 32:
        return 4
    return 8


def numeric_stream(arr: np.ndarray) -> Stream:
    """Wrap an unsigned/signed integer array as a NUMERIC stream."""
    return Stream(np.ascontiguousarray(arr.ravel()), SType.NUMERIC, arr.dtype.itemsize)


def fixed_records(s: Stream) -> Tuple[np.ndarray, int]:
    """View a fixed-width stream (SERIAL/STRUCT/NUMERIC) as (n, width) uint8."""
    if s.stype == SType.STRING:
        raise ValueError("fixed_records on string stream")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width if s.stype != SType.SERIAL else 1
    return raw.reshape(-1, w), w


def rebuild_like(template_stype: SType, width: int, raw: np.ndarray) -> Stream:
    """Rebuild a stream of (stype, width) from raw little-endian bytes."""
    from repro.core.message import from_wire

    return from_wire(template_stype, width, raw.tobytes(), None)
