"""Entropy coders (paper §II-A): canonical Huffman and tANS (FSE).

Both are implemented **block-parallel**: the input is cut into fixed-size
blocks; each block carries its own bit offset (and, for tANS, final state) in
a *separate output stream*.  Decoding then runs one vectorized "lane" per
block — the per-symbol loop is over positions-within-block while every block
advances simultaneously.  This is the TPU adaptation of OpenZL's byte-serial
CPU kernels (DESIGN.md §2): on TPU the lanes map onto the 8×128 VPU; on this
CPU host they map onto numpy vectors.  The block-offset stream is itself a
numeric stream, so a graph can delta+bitpack it — metadata is just more data
for the graph to compress (very much in the paper's spirit).

Lane-refill scheme
------------------
Every lane keeps a bit cursor into its block's bitstream.  One decode step
refills all lanes' 64-bit windows with a *single* gather — an 8-byte
``sliding_window_view`` row per lane, viewed as one little-endian ``uint64``
— instead of the historical 8-iteration per-byte loop.  A refilled window
holds >= 57 valid bits after cursor alignment, so Huffman decode consumes up
to three symbols (3 x 15-bit max codes = 45 bits) per refill.  Tail lanes
are handled mask-free: every lane is full except the last, so the hot loop
runs unmasked and the final partial lane is trimmed at concatenation (the
bitstream buffer is padded so overrunning lanes read zeros, never OOB).
``repro.kernels.ops.lane_refill`` is the device-backend twin of the gather.

Coder-table cache
-----------------
Decode LUTs (2^15 entries) and tANS spread/state tables (2^table_log) are
pure functions of wire-visible descriptors (code lengths / normalized
counts), so they are memoized in ``repro.codecs.coder_cache`` — repeated
chunks and the engine's ``chunk_bytes=N`` thread pool stop rebuilding
identical tables per chunk.  All table construction is vectorized; no
``O(2^table_log)`` Python loops remain on any per-call path.

Wire layout per codec (unchanged — frames are bit-identical to the
pre-vectorization implementation):
  huffman: outputs = [bitstream SERIAL, block_bit_offsets NUMERIC u64]
           header  = n_symbols, block_size_log, 256 nibble-packed code lengths
  fse:     outputs = [bitstream SERIAL, block_meta NUMERIC u32 (offset, state)]
           header  = n_symbols, block_size_log, table_log, normalized counts
"""
from __future__ import annotations

import heapq
from typing import List, Tuple

import numpy as np

from repro.core.codec import CodecSig, CodecSpec, InPort, register_codec
from repro.core.message import Stream, SType

from ._stages import stage as _stage
from ._util import HeaderReader, HeaderWriter, numeric_stream
from .coder_cache import active_cache

BLOCK_LOG = 12  # 4096 symbols per lane-block
MAX_CODE_LEN = 15

# Cache blocking (same story as codecs/lz.py): the histogram, the bit-matrix
# writer and the lane decoders chunk their passes so per-pass scratch stays
# LLC-resident — at tens of MiB the unblocked versions streamed multi-hundred
# MiB index/scratch arrays per pass and went DRAM-bound.
_HIST_CHUNK = 1 << 20  # bytes per histogram pass (bincount's intp temp stays small)
_WRITE_CHUNK = 1 << 18  # symbols per bit-writer pass
_DEC_GROUP_BYTES = 1 << 22  # decoded bytes per lane-decoder group

_U64_1 = np.uint64(1)

# byte streams only: serial, numeric(1), struct(1) — exactly what _as_u8 takes
_BYTE_PORT = InPort(
    frozenset((int(SType.SERIAL), int(SType.NUMERIC), int(SType.STRUCT))),
    frozenset((1,)),
)
_U64_7 = np.uint64(7)
_U64_3 = np.uint64(3)


def _as_u8(s: Stream, op: str) -> np.ndarray:
    if s.stype == SType.SERIAL or (s.stype == SType.NUMERIC and s.width == 1):
        return np.frombuffer(s.content_bytes(), dtype=np.uint8)
    if s.stype == SType.STRUCT and s.width == 1:
        return s.data
    raise ValueError(f"{op}: byte streams only (serial / numeric(1)); transpose first")


def _rebuild(stype_tag: int, result: np.ndarray) -> Stream:
    """Type-faithful reconstruction (codecs are bijections INCLUDING type)."""
    from repro.core.message import from_wire

    return from_wire(SType(stype_tag), 1, result.tobytes(), None)


def _freeze(*arrays: np.ndarray) -> Tuple[np.ndarray, ...]:
    """Mark cached tables read-only: they are shared across pool threads."""
    for a in arrays:
        a.setflags(write=False)
    return arrays


def _hist_u8(x: np.ndarray) -> np.ndarray:
    """256-bin byte histogram, chunked.  ``np.bincount`` widens its input to
    intp first; chunking keeps that 8-bytes-per-symbol temporary cache-sized
    instead of materializing it for the whole stream."""
    counts = np.zeros(256, dtype=np.int64)
    for lo in range(0, x.size, _HIST_CHUNK):
        counts += np.bincount(x[lo : lo + _HIST_CHUNK], minlength=256)
    return counts


# =====================================================================
# Canonical Huffman
# =====================================================================
def _huffman_code_lengths(counts: np.ndarray) -> np.ndarray:
    """Package-merge-free Huffman with length cap via count flattening."""
    sym = np.nonzero(counts)[0]
    if sym.size == 0:
        return np.zeros(256, dtype=np.uint8)
    if sym.size == 1:
        lens = np.zeros(256, dtype=np.uint8)
        lens[sym[0]] = 1
        return lens
    c = counts.astype(np.float64)
    for _ in range(16):  # flatten until the cap holds
        heap: List[Tuple[float, int]] = [(c[s], int(s)) for s in sym]
        heapq.heapify(heap)
        parent = {}
        next_id = 256
        while len(heap) > 1:
            a = heapq.heappop(heap)
            b = heapq.heappop(heap)
            parent[a[1]] = next_id
            parent[b[1]] = next_id
            heapq.heappush(heap, (a[0] + b[0], next_id))
            next_id += 1
        lens = np.zeros(256, dtype=np.uint8)
        for s in sym:
            d = 0
            node = int(s)
            while node in parent:
                node = parent[node]
                d += 1
            lens[s] = d
        if lens.max() <= MAX_CODE_LEN:
            return lens
        c = np.maximum(c, c[sym].sum() / (1 << MAX_CODE_LEN))  # flatten tail
    raise AssertionError("huffman length cap failed to converge")


def _canonical_order(lens: np.ndarray) -> np.ndarray:
    """Present symbols sorted by (code length, symbol) — canonical order."""
    order = np.lexsort((np.arange(256), lens))
    return order[np.count_nonzero(lens == 0) :]


def _canonical_codes(lens: np.ndarray) -> np.ndarray:
    """Assign canonical codes; returned bit-reversed for LSB-first packing."""
    codes = np.zeros(256, dtype=np.uint32)
    order = _canonical_order(lens)
    if order.size == 0:
        return codes
    ol = lens[order].astype(np.int64)
    # canonical recurrence code(k) = (code(k-1) + 1) << (L_k - L_{k-1}) in
    # closed form via MSB start positions: start_k = sum over earlier symbols
    # of 2^(15 - L_j), code_k = start_k >> (15 - L_k) — exact because
    # canonical codes tile [0, 2^15) contiguously in canonical order
    widths = (np.int64(1) << (MAX_CODE_LEN - ol)).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(widths)[:-1]])
    code = (starts >> (MAX_CODE_LEN - ol)).astype(np.int64)
    # bit-reverse each code over its own length: reverse over 15 bits, then
    # shift out the (15 - L) low zeros
    rev = np.zeros_like(code)
    c = code.copy()
    for _ in range(MAX_CODE_LEN):
        rev = (rev << 1) | (c & 1)
        c >>= 1
    codes[order] = (rev >> (MAX_CODE_LEN - ol)).astype(np.uint32)
    return codes


def _rev15_table() -> np.ndarray:
    """idx -> its 15-bit reversal; built once, module-cached."""
    global _REV15
    try:
        return _REV15
    except NameError:
        pass
    x = np.arange(1 << MAX_CODE_LEN, dtype=np.int32)
    r = np.zeros_like(x)
    for _ in range(MAX_CODE_LEN):
        r = (r << 1) | (x & 1)
        x >>= 1
    _REV15 = r
    return _REV15


def _huffman_codes_cached(lens: np.ndarray) -> np.ndarray:
    return active_cache().get_or_build(
        ("huff_enc", lens.tobytes()),
        lambda: _freeze(_canonical_codes(lens))[0],
    )


def _huffman_decode_lut(lens: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(lut_sym u8, lut_len u64): LSB-first 15-bit decode LUT, vectorized.

    Canonical codes tile the MSB-first index space contiguously in canonical
    order, so the MSB-first LUT is a single ``np.repeat``; the LSB-first LUT
    (what the lane decoder indexes with its low window bits) is that table
    permuted by 15-bit reversal.
    """
    order = _canonical_order(lens)
    lut_sym = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
    lut_len = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint64)
    if order.size:
        widths = (np.int64(1) << (MAX_CODE_LEN - lens[order].astype(np.int64)))
        total = int(widths.sum())
        msb_sym = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
        msb_len = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
        msb_sym[:total] = np.repeat(order.astype(np.uint8), widths)
        msb_len[:total] = np.repeat(lens[order], widths)
        rev = _rev15_table()
        lut_sym = msb_sym[rev]
        lut_len = msb_len[rev].astype(np.uint64)
    return _freeze(lut_sym, lut_len)


def _write_bits_blocked(
    values: np.ndarray, nbits: np.ndarray, block: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack (value, nbits) pairs LSB-first; returns (bytes, per-symbol bit offs).

    Bit-matrix writer: global bit offsets by cumsum, then one masked scatter
    per bit plane (<= MAX_CODE_LEN planes, each target bit index unique) and
    a single ``np.packbits(bitorder="little")``.  Replaces the historical
    4-round ``bitwise_or.at`` packer, whose buffered ufunc scatter was the
    encode bottleneck at tens of MiB — output bytes are identical.
    """
    n = values.size
    offs = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nbits, out=offs[1:])
    total = int(offs[-1])
    out = np.zeros((total + 7) // 8, dtype=np.uint8)
    # chunked by symbols: the unpacked bit matrix, gather indices and plane
    # masks for one chunk stay cache-resident (the full-stream versions were
    # the encode bottleneck at tens of MiB).  A chunk's bit range is aligned
    # down to a byte; the shared boundary byte is OR-merged — exact, because
    # every output bit is written by exactly one symbol.
    for lo in range(0, n, _WRITE_CHUNK):
        hi = min(lo + _WRITE_CHUNK, n)
        base_bit = int(offs[lo]) & ~7
        nbits_c = nbits[lo:hi]
        values_c = values[lo:hi]
        start = offs[lo:hi] - base_bit
        local = int(offs[hi]) - base_bit
        bits = np.zeros((local + 7) // 8 * 8, dtype=np.uint8)
        min_nb = int(nbits_c.min()) if hi > lo else 0
        for b in range(int(nbits_c.max()) if hi > lo else 0):
            if b < min_nb:  # plane present in every symbol: mask-free
                bits[start + b] = (values_c >> b) & 1
            else:
                m = nbits_c > b
                bits[start[m] + b] = (values_c[m] >> b) & 1
        packed = np.packbits(bits, bitorder="little")
        byte0 = base_bit >> 3
        if packed.size:
            out[byte0] |= packed[0]
            out[byte0 + 1 : byte0 + packed.size] = packed[1:]
    return out, offs


def _huffman_enc(streams, params):
    x = _as_u8(streams[0], "huffman")
    n = x.size
    with _stage("table_build"):
        counts = _hist_u8(x)
        lens = _huffman_code_lengths(counts)
        codes = _huffman_codes_cached(lens)
    with _stage("bit_io"):
        nbits = lens[x].astype(np.int64)
        packed, offs = _write_bits_blocked(codes[x], nbits, 1 << BLOCK_LOG)
    block = 1 << BLOCK_LOG
    block_offs = offs[:-1:block] if n else np.zeros(0, np.int64)
    h = HeaderWriter().varint(n).u8(BLOCK_LOG).u8(int(streams[0].stype))
    nib = (lens[0::2] | (lens[1::2] << 4)).astype(np.uint8)  # nibble-pack lengths
    h.bytes_(nib.tobytes())
    return [
        Stream(packed, SType.SERIAL, 1),
        numeric_stream(block_offs.astype(np.uint64)),
    ], h.done()


def _huffman_dec(outs, header):
    bitstream, block_offs_s = outs
    r = HeaderReader(header)
    n = r.varint()
    block_log = r.u8()
    stype_tag = r.u8()
    nib_raw = r.bytes_()
    r.expect_end()
    nib = np.frombuffer(nib_raw, dtype=np.uint8)
    lens = np.zeros(256, dtype=np.uint8)
    lens[0::2] = nib & 0xF
    lens[1::2] = nib >> 4
    with _stage("table_build"):
        lut_sym, lut_len = active_cache().get_or_build(
            ("huff_dec", nib_raw if isinstance(nib_raw, bytes) else bytes(nib_raw)),
            lambda: _huffman_decode_lut(lens),
        )

    block = 1 << block_log
    n_blocks = (n + block - 1) // block
    pos_all = block_offs_s.data.astype(np.uint64).copy()
    if pos_all.size != n_blocks:
        raise ValueError("huffman: block offset count mismatch")
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)
    max_rem = int(rem.max()) if n_blocks else 0
    # mask-free loop: exhausted lanes keep decoding zero bits from the pad
    # region (never OOB; the pad absorbs <= 15 bits/symbol of overrun) and
    # their surplus columns are trimmed at concatenation.
    pad = 16 + ((MAX_CODE_LEN * max_rem + 7) >> 3)
    buf = np.zeros(bitstream.data.size + pad, dtype=np.uint8)
    buf[: bitstream.data.size] = bitstream.data
    sliding = np.lib.stride_tricks.sliding_window_view(buf, 8)
    out = np.empty((block, n_blocks), dtype=np.uint8)  # row-major hot stores
    low_mask = np.uint64((1 << MAX_CODE_LEN) - 1)
    # lanes decode in groups so one group's bitstream range and output
    # columns stay cache-resident; small inputs are one group (no change)
    G = max(1, _DEC_GROUP_BYTES // block)
    with _stage("bit_io"):
        for g0 in range(0, n_blocks, G):
            g1 = min(g0 + G, n_blocks)
            pos = pos_all[g0:g1].copy()
            max_rem_g = int(rem[g0:g1].max())
            i = 0
            while i < max_rem_g:
                # one gather refills >= 57 valid bits -> up to 3 symbols/refill
                w = sliding[(pos >> _U64_3)].view(np.uint64)[:, 0]
                w >>= pos & _U64_7
                low = w & low_mask
                ln = lut_len[low]
                out[i, g0:g1] = lut_sym[low]
                if i + 1 < max_rem_g:
                    w >>= ln
                    low = w & low_mask
                    l2 = lut_len[low]
                    out[i + 1, g0:g1] = lut_sym[low]
                    ln += l2
                    if i + 2 < max_rem_g:
                        w >>= l2
                        low = w & low_mask
                        out[i + 2, g0:g1] = lut_sym[low]
                        ln += lut_len[low]
                        pos += ln
                        i += 3
                        continue
                    pos += ln
                    i += 2
                    continue
                pos += ln
                i += 1
    if n_blocks:
        lanes = out.T  # (n_blocks, block); full lanes except possibly the last
        result = np.concatenate(
            [np.ascontiguousarray(lanes[:-1]).reshape(-1), lanes[-1, : rem[-1]]]
        )
    else:
        result = np.zeros(0, np.uint8)
    return [_rebuild(stype_tag, result)]


register_codec(
    CodecSpec(
        "huffman",
        codec_id=14,
        encode=_huffman_enc,
        decode=_huffman_dec,
        n_outputs=2,
        min_version=2,
        doc="canonical Huffman, lane-blocked for parallel decode",
        sig=CodecSig(
            inputs=(_BYTE_PORT,),
            transfer=lambda atoms, params, n_out: [
                (int(SType.SERIAL), 1),
                (int(SType.NUMERIC), 8),
            ],
            expansion=2.0,  # <= 15 bits/byte worst case + lane offsets
            packed_outputs=(0,),
        ),
    )
)


# =====================================================================
# FSE / tANS
# =====================================================================
FSE_BLOCK_LOG = 10  # 1024 symbols/lane-block (encode loops positions, not lanes)


def _normalize_counts(counts: np.ndarray, table_log: int) -> np.ndarray:
    """Largest-remainder normalization of symbol counts to sum 2^table_log."""
    total = 1 << table_log
    n = counts.sum()
    if n == 0:
        raise ValueError("fse: empty input")
    scaled = counts.astype(np.float64) * total / n
    norm = np.floor(scaled).astype(np.int64)
    norm[(counts > 0) & (norm == 0)] = 1  # every present symbol needs a slot
    diff = total - norm.sum()
    if diff > 0:
        order = np.argsort(-(scaled - norm))
        for i in range(int(diff)):
            norm[order[i % order.size]] += 1
    elif diff < 0:
        # remove from the largest (keeping >=1 for present symbols)
        for _ in range(int(-diff)):
            cand = np.argmax(norm - (counts > 0))
            if norm[cand] <= 1:
                cand = int(np.argmax(norm))
            norm[cand] -= 1
    assert norm.sum() == total and (norm[counts > 0] >= 1).all()
    return norm


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Vectorized int bit_length for small non-negative ints (exact)."""
    return np.ceil(np.log2(x.astype(np.float64) + 1.0)).astype(np.int64)


def _spread_symbols(norm: np.ndarray, table_log: int) -> np.ndarray:
    """tANS symbol spread — vectorized: occurrence k lands at (k*step) & mask."""
    total = 1 << table_log
    step = (total >> 1) + (total >> 3) + 3
    positions = (np.arange(total, dtype=np.int64) * step) & (total - 1)
    spread = np.zeros(total, dtype=np.int64)
    spread[positions] = np.repeat(np.arange(norm.size, dtype=np.int64), norm)
    return spread


def _build_tables(norm: np.ndarray, table_log: int):
    """Build tANS encode/decode tables from normalized counts (vectorized).

    Slot-order occurrence ranks come from a stable argsort of the spread:
    slots grouped by symbol, slot order preserved inside each group — which
    is exactly the x' = norm[s]+k numbering of the serial construction.
    """
    total = 1 << table_log
    spread = _spread_symbols(norm, table_log)
    order = np.argsort(spread, kind="stable")
    sym_sorted = spread[order]
    group_start = np.concatenate([[0], np.cumsum(norm)[:-1]])
    rank = np.arange(total, dtype=np.int64) - group_start[sym_sorted]
    x = norm[sym_sorted] + rank  # x' in [norm[s], 2*norm[s])
    nb_sorted = table_log - (_bit_length(x) - 1)
    dec_sym = spread.astype(np.uint8)
    # int32 throughout: slot ids / bases / bit counts all fit, and table
    # memory is what bounds the coder cache's footprint
    dec_nb = np.zeros(total, dtype=np.int32)
    dec_base = np.zeros(total, dtype=np.int32)
    dec_nb[order] = nb_sorted
    dec_base[order] = (x << nb_sorted) - total
    width = int(norm.max()) if norm.max() else 1
    enc_table = np.zeros((norm.size, width), dtype=np.int32)
    enc_table[sym_sorted, rank] = order
    return dec_sym, dec_nb, dec_base, enc_table


def _fse_tables_cached(norm: np.ndarray, table_log: int):
    """All FSE tables for (norm, table_log), memoized in the active cache.

    Returns (dec_sym, dec_nb, dec_base, enc_table, nb0, thr, st0): the last
    three are the per-symbol encode helpers — nb0/thr give the emitted bit
    count as ``nb0 - (X < thr)`` without any per-position bit-length loop,
    st0 is the lane-start state.
    """

    def build():
        dec_sym, dec_nb, dec_base, enc_table = _build_tables(norm, table_log)
        bl = _bit_length(norm)
        nb0 = (table_log + 1) - bl
        thr = norm << np.maximum(nb0, 0)
        st0 = enc_table[:, 0].copy()
        return _freeze(dec_sym, dec_nb, dec_base, enc_table, nb0, thr, st0)

    return active_cache().get_or_build(
        ("fse", norm.tobytes(), table_log), build
    )


def _fse_enc(streams, params):
    x = _as_u8(streams[0], "fse")
    n = x.size
    table_log = int(params.get("table_log", 11))
    stype_tag = int(streams[0].stype)
    if n == 0:
        h = (
            HeaderWriter().varint(0).u8(FSE_BLOCK_LOG).u8(table_log)
            .u8(stype_tag).bytes_(b"").done()
        )
        return [Stream(np.zeros(0, np.uint8), SType.SERIAL, 1), numeric_stream(np.zeros(0, np.uint32))], h
    with _stage("table_build"):
        counts = _hist_u8(x)
        norm = _normalize_counts(counts, table_log)
        (
            _dec_sym, _dec_nb, _dec_base, enc_table, nb0t, thrt, st0t,
        ) = _fse_tables_cached(norm, table_log)
    total = 1 << table_log

    block = 1 << FSE_BLOCK_LOG
    n_blocks = (n + block - 1) // block
    padded = np.zeros(n_blocks * block, dtype=np.uint8)
    padded[:n] = x
    # transposed lanes: the hot loop reads one *contiguous* row per position
    lanesT = np.ascontiguousarray(padded.reshape(n_blocks, block).T)
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)
    max_rem = int(rem.max())

    # tANS encodes backward; every lane is full except the last, so the
    # closed-form masks below replace the historical started/newly state:
    # a lane of length r initializes at position r-1 and emits for i < r-1.
    width = enc_table.shape[1]
    enc_flat = enc_table.reshape(-1)
    state = np.zeros(n_blocks, dtype=np.int64)
    max_bits_per_sym = table_log + 1
    max_flush_bytes = (7 + max_bits_per_sym) // 8
    cap = (block * max_bits_per_sym + 7) // 8 + 8
    bitbuf = np.zeros((n_blocks, cap), dtype=np.uint8)
    flat = bitbuf.reshape(-1)
    lane_base = np.arange(n_blocks, dtype=np.int64) * cap
    acc = np.zeros(n_blocks, dtype=np.uint64)  # pending bits, LSB = oldest
    cnt = np.zeros(n_blocks, dtype=np.int64)  # live bits in acc (< 8 + tl+1)
    bytepos = np.zeros(n_blocks, dtype=np.int64)
    with _stage("bit_io"):
        for i in range(max_rem - 1, -1, -1):
            s = lanesT[i].astype(np.int64)
            emit = rem > i + 1
            X = state + total  # representative value in [total, 2*total)
            nb = nb0t[s] - (X < thrt[s])
            nbe = np.where(emit, nb, 0)
            nbe_u = nbe.astype(np.uint64)
            val = X.astype(np.uint64) & ((_U64_1 << nbe_u) - _U64_1)
            acc |= val << cnt.astype(np.uint64)
            cnt += nbe
            nfl = cnt >> 3
            m = nfl > 0
            if m.any():
                # cnt < 8 + (table_log+1), so a step flushes up to
                # (8 + table_log) // 8 whole bytes — loop the slots, not two
                for slot in range(max_flush_bytes):
                    if slot and not (nfl > slot).any():
                        break
                    ms = m if slot == 0 else nfl > slot
                    flat[lane_base[ms] + bytepos[ms] + slot] = (
                        (acc[ms] >> np.uint64(8 * slot)) & np.uint64(0xFF)
                    ).astype(np.uint8)
                acc >>= (nfl << 3).astype(np.uint64)
                bytepos += nfl
                cnt -= nfl << 3
            # state transition (masked: emitting lanes step, new lanes init)
            xprime = np.clip((X >> nb) - norm[s], 0, width - 1)
            new_state = enc_flat[s * width + xprime]
            state = np.where(
                emit, new_state, np.where(rem == i + 1, st0t[s], state)
            )
        # final partial byte per lane (zero-padded high bits, as the
        # OR-writer did)
        mfin = cnt > 0
        if mfin.any():
            flat[lane_base[mfin] + bytepos[mfin]] = acc[mfin].astype(np.uint8)
        bitpos = (bytepos << 3) + cnt

        # concatenate lane bitstreams: one ragged gather instead of a
        # per-lane Python loop (the loop was ~n/1024 iterations — real time
        # at tens of MiB)
        nbytes = bytepos + (cnt > 0)
        offsets = np.zeros(n_blocks + 1, dtype=np.int64)
        np.cumsum(nbytes, out=offsets[1:])
        total_bytes = int(offsets[-1])
        intra = np.arange(total_bytes, dtype=np.int64) - np.repeat(
            offsets[:-1], nbytes
        )
        stream_out = flat[np.repeat(lane_base, nbytes) + intra]
    # block meta: (bit length, final state) as u32 pairs
    meta = np.empty(n_blocks * 2, dtype=np.uint32)
    meta[0::2] = bitpos.astype(np.uint32)
    meta[1::2] = state.astype(np.uint32)

    h = HeaderWriter().varint(n).u8(FSE_BLOCK_LOG).u8(table_log).u8(stype_tag)
    nz = np.nonzero(norm)[0]
    hw = HeaderWriter()
    hw.varint(nz.size)
    for s in nz:
        hw.varint(int(s))
        hw.varint(int(norm[s]))
    h.bytes_(hw.done())
    return [Stream(stream_out, SType.SERIAL, 1), numeric_stream(meta)], h.done()


def _fse_dec(outs, header):
    bitstream, meta_s = outs
    r = HeaderReader(header)
    n = r.varint()
    block_log = r.u8()
    table_log = r.u8()
    stype_tag = r.u8()
    tbl = HeaderReader(r.bytes_())
    r.expect_end()
    if n == 0:
        return [_rebuild(stype_tag, np.zeros(0, np.uint8))]
    norm = np.zeros(256, dtype=np.int64)
    for _ in range(tbl.varint()):
        s = tbl.varint()
        norm[s] = tbl.varint()
    with _stage("table_build"):
        dec_sym, dec_nb, dec_base, _enc, _nb0, _thr, _st0 = _fse_tables_cached(
            norm, table_log
        )

    block = 1 << block_log
    n_blocks = (n + block - 1) // block
    meta = meta_s.data.astype(np.int64)
    bitlen = meta[0::2]
    state_all = meta[1::2]
    nbytes = (bitlen + 7) // 8
    offsets = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(nbytes, out=offsets[1:])
    # per-lane padded buffers for vectorized backward reads, filled with one
    # ragged scatter (the historical per-lane Python loop was ~n/1024
    # iterations — real time at tens of MiB)
    cap = int(nbytes.max()) + 16 if n_blocks else 16
    bitbuf = np.zeros((n_blocks, cap), dtype=np.uint8)
    flat = bitbuf.reshape(-1)
    lane_base = np.arange(n_blocks, dtype=np.int64) * cap
    total_bytes = int(offsets[-1])
    intra = np.arange(total_bytes, dtype=np.int64) - np.repeat(
        offsets[:-1], nbytes
    )
    flat[np.repeat(lane_base, nbytes) + intra] = bitstream.data
    sliding = np.lib.stride_tricks.sliding_window_view(flat, 8)
    rem = np.minimum(n - np.arange(n_blocks, dtype=np.int64) * block, block)
    out = np.empty((block, n_blocks), dtype=np.uint8)
    # mask-free: exhausted lanes walk garbage states over the zero pad —
    # always in-table (base+bits stays in [0, total)), trimmed at the end.
    # Lanes decode in groups so one group's bitstream slice and output
    # columns stay cache-resident; small inputs are one group (no change).
    G = max(1, _DEC_GROUP_BYTES // block)
    with _stage("bit_io"):
        for g0 in range(0, n_blocks, G):
            g1 = min(g0 + G, n_blocks)
            state = state_all[g0:g1].copy()
            cursor = bitlen[g0:g1].copy()  # read backward from the end
            lb = lane_base[g0:g1]
            for i in range(int(rem[g0:g1].max())):
                out[i, g0:g1] = dec_sym[state]
                nb = dec_nb[state]
                base = dec_base[state]
                cursor -= nb
                byte0 = np.maximum(cursor >> 3, 0)
                w = sliding[lb + byte0].view(np.uint64)[:, 0]
                bits = (w >> (cursor & 7).astype(np.uint64)) & (
                    (_U64_1 << nb.astype(np.uint64)) - _U64_1
                )
                state = base + bits.astype(np.int64)
    lanes = out.T
    result = np.concatenate(
        [np.ascontiguousarray(lanes[:-1]).reshape(-1), lanes[-1, : rem[-1]]]
    )
    return [_rebuild(stype_tag, result)]


register_codec(
    CodecSpec(
        "fse",
        codec_id=15,
        encode=_fse_enc,
        decode=_fse_dec,
        n_outputs=2,
        min_version=2,
        doc="tANS (FSE): table-driven ANS, lane-blocked (paper §II-A; Duda/Collet)",
        sig=CodecSig(
            inputs=(_BYTE_PORT,),
            transfer=lambda atoms, params, n_out: [
                (int(SType.SERIAL), 1),
                (int(SType.NUMERIC), 4),
            ],
            expansion=2.0,
            packed_outputs=(0,),
        ),
    )
)
