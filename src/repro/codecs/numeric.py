"""Numeric transforms (paper §II-B/C, §IV): delta, zigzag, transpose,
transpose_split, bitpack, range_pack, rle, tokenize.

All are reversible; delta/zigzag are *reversible transforms*, rle/tokenize/
bitpack/range_pack are *reductive*.  Everything is numpy-vectorized — these
are the host twins of the Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.codec import CodecSpec, register_codec
from repro.core.message import Stream, SType, from_wire

from ._util import (
    UNSIGNED,
    HeaderReader,
    HeaderWriter,
    min_uint_width,
    numeric_stream,
)


def _require_numeric(s: Stream, op: str) -> np.ndarray:
    if s.stype != SType.NUMERIC:
        raise ValueError(f"{op}: numeric streams only, got {s.stype.name}")
    return s.data.view(UNSIGNED[s.width])


# --------------------------------------------------------------------- delta
def _delta_enc(streams, params):
    x = _require_numeric(streams[0], "delta")
    d = np.empty_like(x)
    if x.size:
        d[0] = x[0]
        # wrapping subtraction on the unsigned view: always reversible
        np.subtract(x[1:], x[:-1], out=d[1:])
    return [numeric_stream(d)], b""


def _delta_dec(outs, header):
    d = _require_numeric(outs[0], "delta")
    with np.errstate(over="ignore"):
        x = np.cumsum(d, dtype=d.dtype)
    return [numeric_stream(x)]


register_codec(
    CodecSpec(
        "delta",
        codec_id=3,
        encode=_delta_enc,
        decode=_delta_dec,
        doc="wrapping first-difference on the unsigned view (paper §II-B)",
    )
)


# -------------------------------------------------------------------- zigzag
def _zigzag_enc(streams, params):
    s = streams[0]
    u = _require_numeric(s, "zigzag")
    bits = s.width * 8
    x = u.view(np.dtype(f"int{bits}"))
    zz = (u << u.dtype.type(1)) ^ (x >> (bits - 1)).view(u.dtype)
    return [numeric_stream(zz)], b""


def _zigzag_dec(outs, header):
    s = outs[0]
    u = _require_numeric(s, "zigzag")
    one = u.dtype.type(1)
    x = (u >> one) ^ (np.zeros_like(u) - (u & one))
    return [numeric_stream(x)]


register_codec(
    CodecSpec(
        "zigzag",
        codec_id=4,
        encode=_zigzag_enc,
        decode=_zigzag_dec,
        doc="signed -> small-unsigned mapping ((x<<1) ^ (x>>w-1))",
    )
)


# ----------------------------------------------------------------- transpose
def _transpose_enc(streams, params):
    s = streams[0]
    if s.stype not in (SType.STRUCT, SType.NUMERIC):
        raise ValueError("transpose wants struct/numeric input")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width
    planes = np.ascontiguousarray(raw.reshape(-1, w).T).reshape(-1)
    h = HeaderWriter().u8(int(s.stype)).varint(w).done()
    return [Stream(planes, SType.SERIAL, 1)], h


def _transpose_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    w = r.varint()
    r.expect_end()
    planes = outs[0].data
    n = planes.size // w
    raw = np.ascontiguousarray(planes.reshape(w, n).T).reshape(-1)
    return [from_wire(stype, w, raw.tobytes(), None)]


register_codec(
    CodecSpec(
        "transpose",
        codec_id=5,
        encode=_transpose_enc,
        decode=_transpose_dec,
        doc="byte-plane shuffle (Blosc-style); makes high bytes runs (paper §IV)",
    )
)


# ----------------------------------------------------------- transpose_split
def _transpose_split_enc(streams, params):
    s = streams[0]
    if s.stype not in (SType.STRUCT, SType.NUMERIC):
        raise ValueError("transpose_split wants struct/numeric input")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width
    mat = raw.reshape(-1, w)
    outs = [Stream(np.ascontiguousarray(mat[:, j]), SType.SERIAL, 1) for j in range(w)]
    h = HeaderWriter().u8(int(s.stype)).varint(w).done()
    return outs, h


def _transpose_split_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    w = r.varint()
    r.expect_end()
    n = outs[0].data.size
    mat = np.empty((n, w), dtype=np.uint8)
    for j, o in enumerate(outs):
        mat[:, j] = o.data
    return [from_wire(stype, w, mat.reshape(-1).tobytes(), None)]


register_codec(
    CodecSpec(
        "transpose_split",
        codec_id=22,
        encode=_transpose_split_enc,
        decode=_transpose_split_dec,
        n_outputs=-1,
        doc="byte planes as separate outputs so each plane gets its own backend",
    )
)


# ------------------------------------------------------------------- bitpack
def _pack_bits(vals: np.ndarray, bits: int) -> np.ndarray:
    """Pack unsigned values (< 2^bits) LSB-first into bytes.  bits <= 57 so a
    single unaligned 8-byte window always covers a value (see _unpack_bits)."""
    if bits > 57:
        raise ValueError("bitpack supports <= 57 bits per value; store instead")
    n = vals.size
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8 + 8, dtype=np.uint8)
    offs = np.arange(n, dtype=np.int64) * bits
    v = vals.astype(np.uint64)
    # each value touches at most ceil(bits/8)+1 bytes
    for b in range((bits + 7) // 8 + 1):
        byte_idx = (offs >> 3) + b
        shift = (np.int64(b) << 3) - (offs & 7)
        pos = shift >= 0
        # two-sided shift without UB: clamp each direction's amount to >= 0
        contrib = np.where(
            pos,
            v >> np.where(pos, shift, 0).clip(max=63).astype(np.uint64),
            v << np.where(~pos, -shift, 0).astype(np.uint64),
        )
        contrib = np.where(shift >= 64, 0, contrib)  # avoid x86 shift-mod-64 UB
        np.bitwise_or.at(out, byte_idx, (contrib & 0xFF).astype(np.uint8))
    return out[: (total_bits + 7) // 8]


def _unpack_bits(buf: np.ndarray, bits: int, n: int, out_width: int) -> np.ndarray:
    padded = np.zeros(buf.size + 8, dtype=np.uint8)
    padded[: buf.size] = buf
    offs = np.arange(n, dtype=np.int64) * bits
    byte0 = offs >> 3
    # gather 8 consecutive bytes -> u64 window, shift, mask
    gathered = np.zeros(n, dtype=np.uint64)
    for b in range(8):
        gathered |= padded[byte0 + b].astype(np.uint64) << np.uint64(8 * b)
    vals = (gathered >> (offs & 7).astype(np.uint64)) & np.uint64((1 << bits) - 1)
    return vals.astype(UNSIGNED[out_width])


def _bitpack_enc(streams, params):
    s = streams[0]
    x = _require_numeric(s, "bitpack")
    maxv = int(x.max()) if x.size else 0
    bits = int(params.get("bits", 0)) or max(int(maxv).bit_length(), 1)
    if maxv >= (1 << bits):
        raise ValueError(f"bitpack: values need more than {bits} bits")
    packed = _pack_bits(x, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).done()
    return [Stream(packed, SType.SERIAL, 1)], h


def _bitpack_dec(outs, header):
    r = HeaderReader(header)
    bits = r.u8()
    width = r.u8()
    n = r.varint()
    r.expect_end()
    vals = _unpack_bits(outs[0].data, bits, n, width)
    return [numeric_stream(vals)]


register_codec(
    CodecSpec(
        "bitpack",
        codec_id=6,
        encode=_bitpack_enc,
        decode=_bitpack_dec,
        doc="pack values into ceil(log2(max+1)) bits, LSB-first",
    )
)


# ---------------------------------------------------------------- range_pack
def _range_pack_enc(streams, params):
    s = streams[0]
    x = _require_numeric(s, "range_pack")
    lo = int(x.min()) if x.size else 0
    shifted = (x - x.dtype.type(lo)).astype(np.uint64)
    maxv = int(shifted.max()) if x.size else 0
    bits = max(int(maxv).bit_length(), 1)
    packed = _pack_bits(shifted, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).varint(lo).done()
    return [Stream(packed, SType.SERIAL, 1)], h


def _range_pack_dec(outs, header):
    r = HeaderReader(header)
    bits = r.u8()
    width = r.u8()
    n = r.varint()
    lo = r.varint()
    r.expect_end()
    vals = _unpack_bits(outs[0].data, bits, n, 8)
    vals = (vals + np.uint64(lo)).astype(UNSIGNED[width])
    return [numeric_stream(vals)]


register_codec(
    CodecSpec(
        "range_pack",
        codec_id=13,
        encode=_range_pack_enc,
        decode=_range_pack_dec,
        doc="bounded ints: subtract min then bitpack (paper §IV SDEC0 idea)",
    )
)


# ----------------------------------------------------------------------- rle
def _rle_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("rle: fixed-width streams only")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width if s.stype != SType.SERIAL else 1
    mat = raw.reshape(-1, w)
    n = mat.shape[0]
    if n == 0:
        starts = np.zeros(0, dtype=np.int64)
    else:
        change = np.any(mat[1:] != mat[:-1], axis=1)
        starts = np.concatenate([[0], np.nonzero(change)[0] + 1])
    runs = np.diff(np.concatenate([starts, [n]])).astype(np.uint32)
    values_raw = np.ascontiguousarray(mat[starts]).reshape(-1)
    values = from_wire(s.stype, s.width, values_raw.tobytes(), None)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [values, numeric_stream(runs)], h


def _rle_dec(outs, header):
    values, runs = outs
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    w = width if stype != SType.SERIAL else 1
    mat = np.frombuffer(values.content_bytes(), dtype=np.uint8).reshape(-1, w)
    rep = np.repeat(mat, runs.data.astype(np.int64), axis=0).reshape(-1)
    return [from_wire(stype, width, rep.tobytes(), None)]


register_codec(
    CodecSpec(
        "rle",
        codec_id=7,
        encode=_rle_enc,
        decode=_rle_dec,
        n_outputs=2,
        doc="run-length: (values, u32 run lengths) (paper §II-C)",
    )
)


# ------------------------------------------------------------------ tokenize
def _tokenize_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        items = s.to_strings()
        seen = {}
        order: List[bytes] = []
        idx = np.empty(len(items), dtype=np.int64)
        for i, it in enumerate(items):
            j = seen.get(it)
            if j is None:
                j = len(order)
                seen[it] = j
                order.append(it)
            idx[i] = j
        from repro.core.message import strings as mk_strings

        alphabet = mk_strings(order)
        # indices are ALWAYS u32: predictable output types keep the graph
        # type system static (downstream bitpack/range_pack reclaim the bits)
        indices = numeric_stream(idx.astype(np.uint32))
        h = HeaderWriter().u8(1).u8(4).done()
        return [alphabet, indices], h
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width if s.stype != SType.SERIAL else 1
    mat = raw.reshape(-1, w)
    # first-occurrence ordering keeps the alphabet stable for delta-friendly ids
    uniq, first_idx, inv = np.unique(mat, axis=0, return_index=True, return_inverse=True)
    order = np.argsort(first_idx, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.size)
    inv = rank[inv]
    uniq = uniq[order]
    alphabet = from_wire(s.stype, s.width, np.ascontiguousarray(uniq).tobytes(), None)
    indices = numeric_stream(inv.astype(np.uint32))  # always u32 (see above)
    h = HeaderWriter().u8(0).u8(4).done()
    return [alphabet, indices], h


def _tokenize_dec(outs, header):
    alphabet, indices = outs
    r = HeaderReader(header)
    is_string = r.u8()
    _iw = r.u8()
    r.expect_end()
    idx = indices.data.astype(np.int64)
    if is_string:
        items = alphabet.to_strings()
        from repro.core.message import strings as mk_strings

        return [mk_strings([items[i] for i in idx.tolist()])]
    w = alphabet.width if alphabet.stype != SType.SERIAL else 1
    mat = np.frombuffer(alphabet.content_bytes(), dtype=np.uint8).reshape(-1, w)
    out = np.ascontiguousarray(mat[idx]).reshape(-1)
    return [from_wire(alphabet.stype, alphabet.width, out.tobytes(), None)]


register_codec(
    CodecSpec(
        "tokenize",
        codec_id=9,
        encode=_tokenize_enc,
        decode=_tokenize_dec,
        n_outputs=2,
        min_version=2,
        doc="(alphabet, indices) split — the paper's motivating codec (§III-C)",
    )
)
