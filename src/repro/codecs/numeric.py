"""Numeric transforms (paper §II-B/C, §IV): delta, zigzag, transpose,
transpose_split, bitpack, range_pack, rle, tokenize, fused_delta_bitpack.

All are reversible; delta/zigzag are *reversible transforms*, rle/tokenize/
bitpack/range_pack are *reductive*.  Everything is numpy-vectorized.

Device twins: for the transform nodes that have Pallas kernels
(``repro.kernels.ops``) this module also registers *device-backend* encoders
(``register_backend_codec``) that are bit-exact with the host encoders — same
output streams, same headers — so frames are byte-identical regardless of
which backend produced them.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.codec import (
    ANY_STYPES,
    FIXED_STYPES,
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_backend_codec,
    register_codec,
)
from repro.core.message import Stream, SType, from_wire

from ._util import (
    UNSIGNED,
    HeaderReader,
    HeaderWriter,
    device_available,
    device_use_pallas,
    min_uint_width,
    numeric_stream,
)


def _require_numeric(s: Stream, op: str) -> np.ndarray:
    if s.stype != SType.NUMERIC:
        raise ValueError(f"{op}: numeric streams only, got {s.stype.name}")
    return s.data.view(UNSIGNED[s.width])


_SERIAL = int(SType.SERIAL)
_NUMERIC = int(SType.NUMERIC)
_NUM_PORT = InPort(frozenset((_NUMERIC,)))
_BYTEPLANE_PORT = InPort(frozenset((int(SType.STRUCT), _NUMERIC)))


# --------------------------------------------------------------------- delta
def _delta_enc(streams, params):
    x = _require_numeric(streams[0], "delta")
    d = np.empty_like(x)
    if x.size:
        d[0] = x[0]
        # wrapping subtraction on the unsigned view: always reversible
        np.subtract(x[1:], x[:-1], out=d[1:])
    return [numeric_stream(d)], b""


def _delta_dec(outs, header):
    d = _require_numeric(outs[0], "delta")
    with np.errstate(over="ignore"):
        x = np.cumsum(d, dtype=d.dtype)
    return [numeric_stream(x)]


register_codec(
    CodecSpec(
        "delta",
        codec_id=3,
        encode=_delta_enc,
        decode=_delta_dec,
        doc="wrapping first-difference on the unsigned view (paper §II-B)",
        sig=CodecSig(
            inputs=(_NUM_PORT,),
            transfer=lambda atoms, params, n_out: [atoms[0]],
        ),
    )
)


# -------------------------------------------------------------------- zigzag
def _zigzag_enc(streams, params):
    s = streams[0]
    u = _require_numeric(s, "zigzag")
    bits = s.width * 8
    x = u.view(np.dtype(f"int{bits}"))
    zz = (u << u.dtype.type(1)) ^ (x >> (bits - 1)).view(u.dtype)
    return [numeric_stream(zz)], b""


def _zigzag_dec(outs, header):
    s = outs[0]
    u = _require_numeric(s, "zigzag")
    one = u.dtype.type(1)
    x = (u >> one) ^ (np.zeros_like(u) - (u & one))
    return [numeric_stream(x)]


register_codec(
    CodecSpec(
        "zigzag",
        codec_id=4,
        encode=_zigzag_enc,
        decode=_zigzag_dec,
        doc="signed -> small-unsigned mapping ((x<<1) ^ (x>>w-1))",
        sig=CodecSig(
            inputs=(_NUM_PORT,),
            transfer=lambda atoms, params, n_out: [atoms[0]],
        ),
    )
)


# ----------------------------------------------------------------- transpose
def _transpose_enc(streams, params):
    s = streams[0]
    if s.stype not in (SType.STRUCT, SType.NUMERIC):
        raise ValueError("transpose wants struct/numeric input")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width
    planes = np.ascontiguousarray(raw.reshape(-1, w).T).reshape(-1)
    h = HeaderWriter().u8(int(s.stype)).varint(w).done()
    return [Stream(planes, SType.SERIAL, 1)], h


def _transpose_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    w = r.varint()
    r.expect_end()
    planes = outs[0].data
    n = planes.size // w
    raw = np.ascontiguousarray(planes.reshape(w, n).T).reshape(-1)
    return [from_wire(stype, w, raw.tobytes(), None)]


register_codec(
    CodecSpec(
        "transpose",
        codec_id=5,
        encode=_transpose_enc,
        decode=_transpose_dec,
        doc="byte-plane shuffle (Blosc-style); makes high bytes runs (paper §IV)",
        sig=CodecSig(
            inputs=(_BYTEPLANE_PORT,),
            transfer=lambda atoms, params, n_out: [(_SERIAL, 1)],
        ),
    )
)


# ----------------------------------------------------------- transpose_split
def _transpose_split_enc(streams, params):
    s = streams[0]
    if s.stype not in (SType.STRUCT, SType.NUMERIC):
        raise ValueError("transpose_split wants struct/numeric input")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width
    mat = raw.reshape(-1, w)
    outs = [Stream(np.ascontiguousarray(mat[:, j]), SType.SERIAL, 1) for j in range(w)]
    h = HeaderWriter().u8(int(s.stype)).varint(w).done()
    return outs, h


def _transpose_split_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    w = r.varint()
    r.expect_end()
    n = outs[0].data.size
    mat = np.empty((n, w), dtype=np.uint8)
    for j, o in enumerate(outs):
        mat[:, j] = o.data
    return [from_wire(stype, w, mat.reshape(-1).tobytes(), None)]


register_codec(
    CodecSpec(
        "transpose_split",
        codec_id=22,
        encode=_transpose_split_enc,
        decode=_transpose_split_dec,
        n_outputs=-1,
        doc="byte planes as separate outputs so each plane gets its own backend",
        sig=CodecSig(
            inputs=(_BYTEPLANE_PORT,),
            transfer=lambda atoms, params, n_out: (
                None
                if atoms[0][1] is not None and atoms[0][1] != n_out
                else [(_SERIAL, 1)] * n_out
            ),
        ),
    )
)


# ------------------------------------------------------------------- bitpack
def _pack_bits(vals: np.ndarray, bits: int) -> np.ndarray:
    """Pack unsigned values (< 2^bits) LSB-first into bytes.  bits <= 57 so a
    single unaligned 8-byte window always covers a value (see _unpack_bits)."""
    if bits > 57:
        raise ValueError("bitpack supports <= 57 bits per value; store instead")
    n = vals.size
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8 + 8, dtype=np.uint8)
    offs = np.arange(n, dtype=np.int64) * bits
    v = vals.astype(np.uint64)
    # each value touches at most ceil(bits/8)+1 bytes
    for b in range((bits + 7) // 8 + 1):
        byte_idx = (offs >> 3) + b
        shift = (np.int64(b) << 3) - (offs & 7)
        pos = shift >= 0
        # two-sided shift without UB: clamp each direction's amount to >= 0
        contrib = np.where(
            pos,
            v >> np.where(pos, shift, 0).clip(max=63).astype(np.uint64),
            v << np.where(~pos, -shift, 0).astype(np.uint64),
        )
        contrib = np.where(shift >= 64, 0, contrib)  # avoid x86 shift-mod-64 UB
        np.bitwise_or.at(out, byte_idx, (contrib & 0xFF).astype(np.uint8))
    return out[: (total_bits + 7) // 8]


def _unpack_bits(buf: np.ndarray, bits: int, n: int, out_width: int) -> np.ndarray:
    padded = np.zeros(buf.size + 8, dtype=np.uint8)
    padded[: buf.size] = buf
    offs = np.arange(n, dtype=np.int64) * bits
    byte0 = offs >> 3
    # gather 8 consecutive bytes -> u64 window, shift, mask
    gathered = np.zeros(n, dtype=np.uint64)
    for b in range(8):
        gathered |= padded[byte0 + b].astype(np.uint64) << np.uint64(8 * b)
    vals = (gathered >> (offs & 7).astype(np.uint64)) & np.uint64((1 << bits) - 1)
    return vals.astype(UNSIGNED[out_width])


def _bitpack_enc(streams, params):
    s = streams[0]
    x = _require_numeric(s, "bitpack")
    maxv = int(x.max()) if x.size else 0
    bits = int(params.get("bits", 0)) or max(int(maxv).bit_length(), 1)
    if maxv >= (1 << bits):
        raise ValueError(f"bitpack: values need more than {bits} bits")
    packed = _pack_bits(x, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).done()
    return [Stream(packed, SType.SERIAL, 1)], h


def _bitpack_dec(outs, header):
    r = HeaderReader(header)
    bits = r.u8()
    width = r.u8()
    n = r.varint()
    r.expect_end()
    vals = _unpack_bits(outs[0].data, bits, n, width)
    return [numeric_stream(vals)]


register_codec(
    CodecSpec(
        "bitpack",
        codec_id=6,
        encode=_bitpack_enc,
        decode=_bitpack_dec,
        doc="pack values into ceil(log2(max+1)) bits, LSB-first",
        sig=CodecSig(
            inputs=(_NUM_PORT,),
            transfer=lambda atoms, params, n_out: [(_SERIAL, 1)],
            params=(ParamSpec("bits", "int", doc="explicit bits/value (0 = fit to max)"),),
            packed_outputs=(0,),
        ),
    )
)


# ---------------------------------------------------------------- range_pack
def _range_pack_enc(streams, params):
    s = streams[0]
    x = _require_numeric(s, "range_pack")
    lo = int(x.min()) if x.size else 0
    shifted = (x - x.dtype.type(lo)).astype(np.uint64)
    maxv = int(shifted.max()) if x.size else 0
    bits = max(int(maxv).bit_length(), 1)
    packed = _pack_bits(shifted, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).varint(lo).done()
    return [Stream(packed, SType.SERIAL, 1)], h


def _range_pack_dec(outs, header):
    r = HeaderReader(header)
    bits = r.u8()
    width = r.u8()
    n = r.varint()
    lo = r.varint()
    r.expect_end()
    vals = _unpack_bits(outs[0].data, bits, n, 8)
    vals = (vals + np.uint64(lo)).astype(UNSIGNED[width])
    return [numeric_stream(vals)]


register_codec(
    CodecSpec(
        "range_pack",
        codec_id=13,
        encode=_range_pack_enc,
        decode=_range_pack_dec,
        doc="bounded ints: subtract min then bitpack (paper §IV SDEC0 idea)",
        sig=CodecSig(
            inputs=(_NUM_PORT,),
            transfer=lambda atoms, params, n_out: [(_SERIAL, 1)],
            packed_outputs=(0,),
        ),
    )
)


# ----------------------------------------------------------------------- rle
def _rle_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("rle: fixed-width streams only")
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width if s.stype != SType.SERIAL else 1
    mat = raw.reshape(-1, w)
    n = mat.shape[0]
    if n == 0:
        starts = np.zeros(0, dtype=np.int64)
    else:
        change = np.any(mat[1:] != mat[:-1], axis=1)
        starts = np.concatenate([[0], np.nonzero(change)[0] + 1])
    runs = np.diff(np.concatenate([starts, [n]])).astype(np.uint32)
    values_raw = np.ascontiguousarray(mat[starts]).reshape(-1)
    values = from_wire(s.stype, s.width, values_raw.tobytes(), None)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [values, numeric_stream(runs)], h


def _rle_dec(outs, header):
    values, runs = outs
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    w = width if stype != SType.SERIAL else 1
    mat = np.frombuffer(values.content_bytes(), dtype=np.uint8).reshape(-1, w)
    rep = np.repeat(mat, runs.data.astype(np.int64), axis=0).reshape(-1)
    return [from_wire(stype, width, rep.tobytes(), None)]


register_codec(
    CodecSpec(
        "rle",
        codec_id=7,
        encode=_rle_enc,
        decode=_rle_dec,
        n_outputs=2,
        doc="run-length: (values, u32 run lengths) (paper §II-C)",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [atoms[0], (_NUMERIC, 4)],
            expansion=5.0,  # worst case: no runs -> values + 4B/element
        ),
    )
)


# ------------------------------------------------------------------ tokenize
def _tokenize_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        items = s.to_strings()
        seen = {}
        order: List[bytes] = []
        idx = np.empty(len(items), dtype=np.int64)
        for i, it in enumerate(items):
            j = seen.get(it)
            if j is None:
                j = len(order)
                seen[it] = j
                order.append(it)
            idx[i] = j
        from repro.core.message import strings as mk_strings

        alphabet = mk_strings(order)
        # indices are ALWAYS u32: predictable output types keep the graph
        # type system static (downstream bitpack/range_pack reclaim the bits)
        indices = numeric_stream(idx.astype(np.uint32))
        h = HeaderWriter().u8(1).u8(4).done()
        return [alphabet, indices], h
    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    w = s.width if s.stype != SType.SERIAL else 1
    mat = raw.reshape(-1, w)
    # first-occurrence ordering keeps the alphabet stable for delta-friendly ids
    uniq, first_idx, inv = np.unique(mat, axis=0, return_index=True, return_inverse=True)
    order = np.argsort(first_idx, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.size)
    inv = rank[inv]
    uniq = uniq[order]
    alphabet = from_wire(s.stype, s.width, np.ascontiguousarray(uniq).tobytes(), None)
    indices = numeric_stream(inv.astype(np.uint32))  # always u32 (see above)
    h = HeaderWriter().u8(0).u8(4).done()
    return [alphabet, indices], h


def _tokenize_dec(outs, header):
    alphabet, indices = outs
    r = HeaderReader(header)
    is_string = r.u8()
    _iw = r.u8()
    r.expect_end()
    idx = indices.data.astype(np.int64)
    if is_string:
        items = alphabet.to_strings()
        from repro.core.message import strings as mk_strings

        return [mk_strings([items[i] for i in idx.tolist()])]
    w = alphabet.width if alphabet.stype != SType.SERIAL else 1
    mat = np.frombuffer(alphabet.content_bytes(), dtype=np.uint8).reshape(-1, w)
    out = np.ascontiguousarray(mat[idx]).reshape(-1)
    return [from_wire(alphabet.stype, alphabet.width, out.tobytes(), None)]


register_codec(
    CodecSpec(
        "tokenize",
        codec_id=9,
        encode=_tokenize_enc,
        decode=_tokenize_dec,
        n_outputs=2,
        min_version=2,
        doc="(alphabet, indices) split — the paper's motivating codec (§III-C)",
        sig=CodecSig(
            inputs=(InPort(ANY_STYPES),),
            transfer=lambda atoms, params, n_out: [atoms[0], (_NUMERIC, 4)],
            expansion=5.0,  # worst case: all-unique u8 -> alphabet + 4B indices
        ),
    )
)


# ------------------------------------------------- fused delta+bitpack (K1)
# Wire twin of kernels/fused_delta_bitpack.py: one HBM pass instead of two.
# Semantics are fixed in the u32 domain (matching the kernel): d[0] = x[0],
# d[i] = (x[i] - x[i-1]) mod 2^32, packed LSB-first at `bits` per value with
# bits | 32 — which makes the packed words' little-endian bytes identical to
# the host bitpack's continuous bitstream.
FUSED_BITS_CHOICES = (1, 2, 4, 8, 16, 32)
# dynamic bit selection stops here: packing >16 bits per delta loses to
# running delta+bitpack separately (which adapts to the stream width)
_FUSED_DYNAMIC_MAX_BITS = 16


def _u32_delta(s: Stream) -> np.ndarray:
    x = s.data.view(UNSIGNED[s.width]).astype(np.uint32, copy=False)
    d = np.empty_like(x)
    if x.size:
        d[0] = x[0]
        np.subtract(x[1:], x[:-1], out=d[1:])
    return d


def _bits_for_need(need: int, explicit_bits: int) -> Optional[int]:
    """Packing width for a max-delta bit length, or None to refuse.

    Dynamic selection only fuses when the width is *exact* (need is itself a
    32-divisor <= 16): rounding 3 bits up to 4 would inflate the packed
    stream vs separate delta+bitpack, and the device backend guarantees
    frames never larger than the host's.  Explicit widths are the caller's
    ratio decision and are honored as long as the kernel can express them.
    """
    if explicit_bits:
        if explicit_bits not in FUSED_BITS_CHOICES or need > explicit_bits:
            return None
        return explicit_bits
    if need in FUSED_BITS_CHOICES and need <= _FUSED_DYNAMIC_MAX_BITS:
        return need
    return None


def _bits_for_delta(d: np.ndarray, explicit_bits: int) -> Optional[int]:
    maxd = int(d.max()) if d.size else 0
    return _bits_for_need(max(maxd.bit_length(), 1), explicit_bits)


def fused_bits_for(s: Stream, explicit_bits: int = 0) -> Optional[int]:
    """Packing width if the fused kernel's lossless precondition holds.

    Returns None when the node must run as separate delta+bitpack: non-numeric
    or u64 input, a wrapped u32 delta that does not fit, an explicit width the
    32-bit-word kernel cannot express, or (dynamic case) a width where fusion
    stops paying for itself.
    """
    if s.stype != SType.NUMERIC or s.width not in (1, 2, 4):
        return None
    return _bits_for_delta(_u32_delta(s), explicit_bits)


def _fused_enc(streams, params):
    s = streams[0]
    if s.stype != SType.NUMERIC or s.width not in (1, 2, 4):
        raise ValueError("fused_delta_bitpack: numeric(1/2/4) streams only")
    d = _u32_delta(s)  # computed once: precondition check and packing share it
    bits = _bits_for_delta(d, int(params.get("bits", 0)))
    if bits is None:
        raise ValueError(
            "fused_delta_bitpack: lossless precondition failed (delta too wide)"
        )
    packed = _pack_bits(d, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(s.n_elts).done()
    return [Stream(packed, SType.SERIAL, 1)], h


def _fused_dec(outs, header):
    r = HeaderReader(header)
    bits = r.u8()
    width = r.u8()
    n = r.varint()
    r.expect_end()
    d = _unpack_bits(outs[0].data, bits, n, 4)
    with np.errstate(over="ignore"):
        x = np.cumsum(d, dtype=np.uint32)
    return [numeric_stream(x.astype(UNSIGNED[width], copy=False))]


register_codec(
    CodecSpec(
        "fused_delta_bitpack",
        codec_id=26,
        encode=_fused_enc,
        decode=_fused_dec,
        min_version=4,
        doc="single-pass delta+bitpack (device kernel K1); u32-domain deltas",
        sig=CodecSig(
            inputs=(InPort(frozenset((_NUMERIC,)), frozenset((1, 2, 4))),),
            transfer=lambda atoms, params, n_out: [(_SERIAL, 1)],
            params=(ParamSpec("bits", "int", choices=FUSED_BITS_CHOICES,
                              doc="explicit packing width (0 = dynamic exact fit)"),),
            packed_outputs=(0,),
        ),
    )
)


# --------------------------------------------------------------- device twins
# Encoders routed through the jit'd Pallas wrappers (kernels/ops.py).  Each
# `applies` predicate gates on exactly the shapes the kernel expresses; the
# engine falls back to the host encoder otherwise.  Outputs and headers are
# bit-identical to the host path — verified by tests/test_engine_phases.py.
def _dev_ready(s: Stream, widths=(1, 2, 4)) -> bool:
    return device_available() and s.stype == SType.NUMERIC and s.width in widths


def _delta_applies_device(streams, params):
    return _dev_ready(streams[0])


def _delta_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops

    s = streams[0]
    x = s.data.view(UNSIGNED[s.width])
    d32 = np.asarray(
        ops.delta_encode(
            jnp.asarray(x.astype(np.uint32, copy=False)),
            use_pallas=device_use_pallas(),
        )
    )
    # truncating back to the stream width is exact: subtraction mod 2^32
    # then mod 2^(8w) equals subtraction mod 2^(8w)
    return [numeric_stream(d32.astype(UNSIGNED[s.width], copy=False))], b""


register_backend_codec("device", "delta", _delta_enc_device, _delta_applies_device)


def _bitpack_applies_device(streams, params):
    """One max() pass decides routability; the chosen bits are stashed in
    ``params`` (run_encode_via passes the same dict to applies and encode) so
    the encoder does not rescan the array."""
    s = streams[0]
    if not _dev_ready(s):
        return False
    x = s.data.view(UNSIGNED[s.width])
    maxv = int(x.max()) if x.size else 0
    bits = int(params.get("bits", 0)) or max(maxv.bit_length(), 1)
    # the kernel packs u32 words: bits must divide 32 and values must fit
    if bits not in FUSED_BITS_CHOICES or maxv >= (1 << bits):
        return False
    params["_device_bits"] = bits
    return True


def _packed_words_to_bytes(words: np.ndarray, n: int, bits: int) -> np.ndarray:
    """LE word bytes truncated to the host codec's ceil(n*bits/8) length."""
    nbytes = (n * bits + 7) // 8
    return np.ascontiguousarray(words.view(np.uint8)[:nbytes])


def _bitpack_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops

    s = streams[0]
    x = s.data.view(UNSIGNED[s.width])
    bits = params.get("_device_bits") or int(params.get("bits", 0)) or max(
        (int(x.max()) if x.size else 0).bit_length(), 1
    )
    words = np.asarray(
        ops.bitpack(
            jnp.asarray(x.astype(np.uint32, copy=False)),
            bits,
            use_pallas=device_use_pallas(),
        )
    )
    packed = _packed_words_to_bytes(words, x.size, bits)
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).done()
    return [Stream(packed, SType.SERIAL, 1)], h


register_backend_codec("device", "bitpack", _bitpack_enc_device, _bitpack_applies_device)


def _fused_applies_device(streams, params):
    # static checks only; the encoder validates the data-dependent lossless
    # precondition itself and raises a refusal (the executor's lowering signal)
    explicit = int(params.get("bits", 0))
    return _dev_ready(streams[0]) and (
        not explicit or explicit in FUSED_BITS_CHOICES
    )


def _fused_enc_device(streams, params):
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    s = streams[0]
    if s.stype != SType.NUMERIC or s.width not in (1, 2, 4):
        raise ValueError("fused_delta_bitpack: numeric(1/2/4) streams only")
    x = s.data.view(UNSIGNED[s.width]).astype(np.uint32, copy=False)
    xj = jnp.asarray(x)
    # precondition check stays on device — the host never touches the deltas
    maxd = int(jnp.max(ref.delta_encode(xj))) if x.size else 0
    bits = _bits_for_need(max(maxd.bit_length(), 1), int(params.get("bits", 0)))
    if bits is None:
        raise ValueError(
            "fused_delta_bitpack: lossless precondition failed (delta too wide)"
        )
    words = np.asarray(
        ops.fused_delta_bitpack(xj, bits, use_pallas=device_use_pallas())
    )
    packed = _packed_words_to_bytes(words, x.size, bits).copy()
    # the kernel zero-pads the *input*, so the padding deltas (0 - x[-1]) can
    # smear garbage into the final partial byte; the host bitstream is zero
    # there — mask to stay bit-identical
    tail_bits = (x.size * bits) % 8
    if tail_bits and packed.size:
        packed[-1] &= (1 << tail_bits) - 1
    h = HeaderWriter().u8(bits).u8(s.width).varint(x.size).done()
    return [Stream(packed, SType.SERIAL, 1)], h


register_backend_codec(
    "device", "fused_delta_bitpack", _fused_enc_device, _fused_applies_device
)


def _shuffle_planes(s: Stream) -> np.ndarray:
    """(w, n) byte planes of a fixed-width stream via the byteshuffle kernel."""
    import jax.numpy as jnp

    from repro.kernels import ops

    raw = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    mat = raw.reshape(-1, s.width)
    return np.asarray(ops.byteshuffle(jnp.asarray(mat), use_pallas=device_use_pallas()))


def _transpose_applies_device(streams, params):
    s = streams[0]
    return (
        device_available()
        and s.stype in (SType.STRUCT, SType.NUMERIC)
        and s.width >= 1
    )


def _transpose_enc_device(streams, params):
    s = streams[0]
    planes = _shuffle_planes(s)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.ascontiguousarray(planes).reshape(-1), SType.SERIAL, 1)], h


register_backend_codec(
    "device", "transpose", _transpose_enc_device, _transpose_applies_device
)


def _transpose_split_enc_device(streams, params):
    s = streams[0]
    planes = _shuffle_planes(s)
    outs = [
        Stream(np.ascontiguousarray(planes[j]), SType.SERIAL, 1)
        for j in range(s.width)
    ]
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return outs, h


register_backend_codec(
    "device", "transpose_split", _transpose_split_enc_device, _transpose_applies_device
)
