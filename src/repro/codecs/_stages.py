"""Opt-in per-stage wall-clock attribution for codec hot paths.

The codec bench (``engine_bench --codecs``) needs to *attribute* the
throughput cliff — match finding vs coder-table builds vs bit I/O — not just
measure it.  Codecs wrap their phases in ``with stage("name")``; unless a
caller has an enclosing ``with collect() as timings`` on the same thread the
stage body runs untimed (one thread-local read of overhead, nanoseconds
against multi-millisecond passes), so the production path pays nothing.

Stage names used by the suite: ``match_find`` (lz77 chain build + greedy
walk), ``table_build`` (histogram + code lengths / normalization + coder
tables), ``bit_io`` (bitstream pack/unpack and lane walks), ``match_replay``
(lz77 decode-side copy replay).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, Iterator, Optional

_tls = threading.local()


@contextlib.contextmanager
def collect() -> Iterator[Dict[str, float]]:
    """Collect stage timings (seconds, summed per name) on this thread."""
    prev: Optional[Dict[str, float]] = getattr(_tls, "sink", None)
    sink: Dict[str, float] = {}
    _tls.sink = sink
    try:
        yield sink
    finally:
        _tls.sink = prev


@contextlib.contextmanager
def stage(name: str) -> Iterator[None]:
    """Attribute the enclosed block to ``name`` when a collector is active."""
    sink = getattr(_tls, "sink", None)
    if sink is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        sink[name] = sink.get(name, 0.0) + (time.perf_counter() - t0)
