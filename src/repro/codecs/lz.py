"""LZ-family reductive codecs (paper §II-C/D).

``lz77``  — a from-scratch greedy LZ parser, fully vectorized.

Match finding is a rolling-hash + hash-chain scheme: 16-bit Knuth
multiplicative hashes of every 4-gram (unaligned little-endian ``uint32``
views, no per-byte assembly), chained by a stable counting sort into a
``prev[]`` array — for each position, the most recent earlier position with
the same hash.  The chain depth is fixed at 1 so the greedy parse (and
therefore every emitted frame) stays **bit-identical** to the historical
scalar implementation; the chain arrays support deeper probing if a future
format revision wants stronger matches.

The greedy walk itself is the serial bottleneck classic LZ coders take
byte-by-byte.  Here it runs as a *segment-parallel lockstep walk*: the input
is cut into a few hundred segments and one speculative greedy walk starts at
every segment boundary, all walks advancing one token per step as plain
numpy vector ops (candidate lookup via a precomputed next-match array, match
lengths via batched 8-byte-word compare probing with doubling chunks).
Greedy parses are memoryless — the token sequence from any position is a
fixed function of that position — so the true parse is recovered by splicing
speculative chains end-to-end: follow chain 0, and wherever the parse lands,
a position index says which chain (and step) continues it.  The rare gaps
between chains are walked scalar with exact bytes-compare extension; a
mismatch only costs time, never changes the parse.  Decode is a batched
copy loop: literals land in one vectorized masked scatter, matches replay
through memcpy-speed ``bytearray`` slices with the overlapping case
(``dist < length``) replicating its period.

Output follows the Zstd factoring the paper cites: separate literal /
literal-length / match-length / offset streams — so each stream can take its
own backend (entropy) codec downstream, exactly the graph-model story.

``zlib_backend`` — stdlib DEFLATE as a leaf codec.  OpenZL similarly embeds
battle-tested C kernels for the generic LZ stage; in this offline container
zlib stands in for those (DESIGN.md §6).
"""
from __future__ import annotations

import zlib
from typing import List, Tuple

import numpy as np

from repro.core.codec import (
    FIXED_STYPES,
    CodecSig,
    CodecSpec,
    InPort,
    ParamSpec,
    register_codec,
)
from repro.core.message import Stream, SType

from ._stages import stage as _stage
from ._util import HeaderReader, HeaderWriter, numeric_stream

MIN_MATCH = 4
MAX_MATCH = 1 << 16

_HASH_MUL = np.uint32(2654435761)  # Knuth multiplicative hash -> 16 bits
_EXT_CHUNK_MAX = 4096  # doubling cap for batched extension gathers

# Cache blocking: the chain build, candidate validation and lockstep walk all
# process the input in fixed-size windows so their index/metadata working set
# (a handful of 4-8-byte-per-position arrays plus the window's bytes) stays
# cache-resident instead of strided over the whole input.  Sizes were swept
# empirically (2x gains on the chain build at 16 MiB); above ~16 MiB the
# unblocked versions went DRAM/TLB-bound and lost >2x throughput.
_PREV_BLOCK = 1 << 19  # positions per blocked chain-sort window
_WALK_WINDOW = 1 << 21  # input bytes per lockstep walk window
_SEG = 1024  # bytes per speculative lane segment inside a window


def _grams(data: np.ndarray) -> np.ndarray:
    """Little-endian uint32 4-grams at every position i <= n-4.

    Four phase-shifted unaligned ``uint32`` views replace the historical
    shift-and-or assembly (x86/TPU hosts are little-endian; numpy handles
    the unaligned access).
    """
    n = data.size
    ng = n - 3
    pad = np.zeros(n + 8, dtype=np.uint8)
    pad[:n] = data
    g = np.empty(ng, dtype=np.uint32)
    for k in range(4):
        cnt = g[k::4].size
        g[k::4] = pad[k : k + 4 * cnt].view("<u4")[:cnt]
    return g


def _chain_half(h: np.ndarray, prev: np.ndarray, lo: int, hi: int):
    """Stable-sort positions [lo, hi) by hash and link each to its most
    recent same-hash predecessor *within the half* (disjoint ``prev`` writes,
    so two halves can run on a thread pool).  Returns the sorted-order and
    sorted-hash arrays for cross-half stitching."""
    o = np.argsort(h[lo:hi], kind="stable").astype(np.int32)  # radix, 16-bit
    if lo:
        o += np.int32(lo)
    sh = h[o]
    same = np.empty(hi - lo, dtype=bool)
    same[0] = False
    same[1:] = sh[1:] == sh[:-1]
    shifted = np.empty(hi - lo, dtype=np.int32)
    shifted[0] = 0
    shifted[1:] = o[:-1]
    prev[o] = np.where(same, shifted, -1)
    return o, sh, same


def _build_prev(h: np.ndarray, n: int, ng: int) -> np.ndarray:
    """prev[i] = most recent j < i with h[j] == h[i] (else -1), int32.

    Large inputs are chained in ``_PREV_BLOCK``-position windows (the blocked
    generalization of the historical two-half split): each window is stably
    sorted on its own — small enough that the sort indices and hash gathers
    stay cache-resident — and a 2^16-entry last-occurrence table, updated
    window by window, re-links each window's bucket-first positions to the
    most recent same-hash position in any earlier window.  Semantics are
    identical to one global stable sort; the next window's sort overlaps the
    previous window's stitch on a 2-deep thread pipeline (argsort and the
    gathers release the GIL).
    """
    prev = np.empty(n, dtype=np.int32)
    prev[ng:] = -1
    if ng <= _PREV_BLOCK:
        _chain_half(h, prev, 0, ng)
        return prev
    from collections import deque
    from concurrent.futures import ThreadPoolExecutor

    last = np.full(1 << 16, -1, dtype=np.int32)

    def _stitch(lo: int, fut) -> None:
        o, sh, same = fut.result()
        if lo:
            fpos = o[~same]  # window positions with no in-window predecessor
            prev[fpos] = last[h[fpos]]
        end = np.empty(sh.size, dtype=bool)
        end[-1] = True
        end[:-1] = sh[1:] != sh[:-1]
        last[sh[end]] = o[end]  # unique hashes: guaranteed scatter

    with ThreadPoolExecutor(1) as pool:
        pending = deque()
        for lo in range(0, ng, _PREV_BLOCK):
            hi = min(lo + _PREV_BLOCK, ng)
            pending.append((lo, pool.submit(_chain_half, h, prev, lo, hi)))
            if len(pending) > 1:
                _stitch(*pending.popleft())
        while pending:
            _stitch(*pending.popleft())
    return prev


def _prev_occurrence(data: np.ndarray) -> np.ndarray:
    """For each position i, the most recent j<i with the same 4-gram hash."""
    n = data.size
    if n < MIN_MATCH:
        return np.full(n, -1, dtype=np.int32)
    g = _grams(data)
    h = ((g * _HASH_MUL) >> np.uint32(16)).astype(np.uint16)
    return _build_prev(h, n, n - 3)


def _first_diff_byte(x: np.ndarray) -> np.ndarray:
    """Index of the lowest differing byte in each nonzero LE uint64 word."""
    low = x & (np.uint64(0) - x)
    return np.log2(low.astype(np.float64)).astype(np.int64) >> 3


_U64_ONE = np.uint64(1)
_U64_63 = np.uint64(63)


def _gather_u64(U: np.ndarray, off: np.ndarray) -> np.ndarray:
    """Unaligned LE uint64 loads at byte offsets ``off`` from ``U`` (the
    aligned u64 view of the padded data): two contiguous-array gathers plus
    branchless shift stitching — far faster than per-byte window gathers."""
    q = off >> 3
    r = ((off & 7) << 3).astype(np.uint64)
    # (hi << 1) << (63 - r) == hi << (64 - r), well-defined at r == 0
    return (U[q] >> r) | ((U[q + 1] << _U64_ONE) << (_U64_63 - r))


def _batch_extend(
    pad: np.ndarray,
    U: np.ndarray,
    iv: np.ndarray,
    jv: np.ndarray,
    limit: np.ndarray,
) -> np.ndarray:
    """Vectorized longest-common-extension: first mismatch of pad[iv+t] vs
    pad[jv+t], per element, capped at ``limit``.

    Chunks of doubling size are gathered as 64-bit words; mismatch offsets
    come from the lowest differing byte of the first differing word.  Reads
    may run into the zero pad past the real data — spurious pad-vs-pad
    matches are cut off by the ``limit`` cap, so results stay exact.  The
    first round (one 8-byte word, which resolves the vast majority of
    matches) uses stitched unaligned u64 loads from the aligned view ``U``.
    """
    na = iv.size
    L = np.zeros(na, dtype=np.int64)
    if not na:
        return L
    x = _gather_u64(U, jv) ^ _gather_u64(U, iv)
    miss = x != 0
    L[:] = 8
    if miss.any():
        L[miss] = _first_diff_byte(x[miss])
    np.minimum(L, limit, out=L)
    act = np.nonzero(~miss & (limit > 8))[0]
    if act.size:  # second round specialized: two stitched words, no views
        bj = jv[act] + 8
        bi = iv[act] + 8
        x1 = _gather_u64(U, bj) ^ _gather_u64(U, bi)
        x2 = _gather_u64(U, bj + 8) ^ _gather_u64(U, bi + 8)
        m1 = x1 != 0
        m2 = x2 != 0
        done = m1 | m2
        off = np.where(
            m1,
            _first_diff_byte(np.where(m1, x1, 1)),
            np.int64(8) + _first_diff_byte(np.where(m2, x2, 1)),
        )
        new_l = np.minimum(np.where(done, 8 + off, 24), limit[act])
        L[act] = new_l
        act = act[~done & (new_l < limit[act])]
    chunk = 32
    while act.size:
        sw = np.lib.stride_tricks.sliding_window_view(pad, chunk)
        A = sw[jv[act] + L[act]].view(np.uint64)
        B = sw[iv[act] + L[act]].view(np.uint64)
        x = A ^ B
        neq = x != 0
        done = neq.any(axis=1)
        if done.any():
            d_rows = np.nonzero(done)[0]
            wi = np.argmax(neq[d_rows], axis=1)
            xw = x[d_rows, wi]
            fin = act[d_rows]
            L[fin] = np.minimum(
                L[fin] + (wi.astype(np.int64) << 3) + _first_diff_byte(xw),
                limit[fin],
            )
            act = act[~done]
        L[act] += chunk
        over = L[act] >= limit[act]
        if over.any():
            capped = act[over]
            L[capped] = limit[capped]
            act = act[~over]
        chunk = min(chunk * 2, _EXT_CHUNK_MAX)
    return L


def _extend_scalar(buf: bytes, j: int, i: int, n: int) -> int:
    """Exact scalar extension (bytes memcmp with doubling + bisect)."""
    limit = min(n - i, MAX_MATCH)
    L = 0
    step = 32
    while L < limit:
        c = min(step, limit - L)
        if buf[j + L : j + L + c] == buf[i + L : i + L + c]:
            L += c
            step = min(step * 2, 1 << 14)
        else:
            while c > 1:
                half = c >> 1
                if buf[j + L : j + L + half] == buf[i + L : i + L + half]:
                    L += half
                    c -= half
                else:
                    c = half
            return L
    return L


def _find_tokens(data: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The greedy parse: (match_starts, match_lens, offsets), int64, in order.

    Exactly reproduces the scalar walk ``i += L on match else i += 1`` with
    chain-depth-1 candidates — see the module docstring for the lockstep
    segment scheme.
    """
    n = data.size
    ng = n - 3
    empty = (np.zeros(0, np.int64),) * 3
    if ng <= 0:
        return empty
    g = _grams(data)
    h = ((g * _HASH_MUL) >> np.uint32(16)).astype(np.uint16)
    prev = _build_prev(h, n, ng)
    # candidate positions: the chained position repeats this 4-gram exactly
    BIG = np.int32(np.iinfo(np.int32).max)
    cand = np.empty(ng, dtype=np.int32)

    def _cand_slice(lo: int, hi: int) -> None:
        pv = prev[lo:hi]
        ok = (pv >= 0) & (g[pv] == g[lo:hi])  # negative pv wraps: masked out
        cand[lo:hi] = np.where(ok, np.arange(lo, hi, dtype=np.int32), BIG)

    for lo in range(0, ng, _PREV_BLOCK):  # blocked: slice stays LLC-resident
        _cand_slice(lo, min(lo + _PREV_BLOCK, ng))
    nxt = np.empty(n + 1, dtype=np.int32)
    nxt[ng:] = BIG
    nxt[:ng] = np.minimum.accumulate(cand[::-1])[::-1]
    if int(nxt[0]) == int(BIG):
        return empty  # no matches anywhere: all-literal stream

    # --- lockstep speculative walks, one per segment ---------------------
    # Full-width and mask-free: a lane whose walk passes its segment end
    # parks itself at p = n (where nxt is the sentinel), after which every
    # per-step op degenerates to a no-op for it (extension limit 0, state
    # writes gated by `has`).  No per-step lane compression.
    #
    # Cache-blocked: lanes run one _WALK_WINDOW of input at a time, so every
    # per-step gather (nxt, prev, chain scatter, most extension reads) lands
    # in that window's slice of the metadata arrays instead of striding the
    # whole input.  Each window's chains are kept with a global base index;
    # the splice below walks windows in parse order.  Inputs <= one window
    # behave exactly like the historical unblocked walk.
    S = -(-min(n, _WALK_WINDOW) // _SEG)  # lanes per window
    pad = np.zeros((n + _EXT_CHUNK_MAX + 23) & ~7, dtype=np.uint8)
    pad[:n] = data
    U = pad.view(np.uint64)
    n_i = np.int64(n)
    m2idx = np.full(ng, -1, dtype=np.int32)
    windows = []  # (chain_m, chain_l, steps, tail) per walk window
    bases = []  # global chain-index base per window
    base = 0
    for wlo in range(0, n, _WALK_WINDOW):
        steps = np.zeros(S, dtype=np.int64)
        cap = max(64, _SEG // 5)
        chain_m = np.zeros((cap, S), dtype=np.int32)
        chain_l = np.zeros((cap, S), dtype=np.int32)
        # lane starts past n (last window) clamp to n — they begin parked
        p = np.minimum(wlo + np.arange(S, dtype=np.int64) * _SEG, n)
        lend = np.minimum(p + _SEG, n)
        t = 0
        while True:
            ma = nxt[p].astype(np.int64)
            has = ma < ng
            if not has.any():
                break
            if t == cap:
                grow = np.zeros((cap, S), dtype=np.int32)
                chain_m = np.concatenate([chain_m, grow])
                chain_l = np.concatenate([chain_l, grow])
                cap *= 2
            np.minimum(ma, ng - 1, out=ma)  # clip parked/tail lanes
            ja = prev[ma].astype(np.int64)
            limit = np.where(has, np.minimum(n_i - ma, MAX_MATCH) - MIN_MATCH, 0)
            L = MIN_MATCH + _batch_extend(
                pad, U, ma + MIN_MATCH, ja + MIN_MATCH, limit
            )
            chain_m[t] = ma
            chain_l[t] = L
            steps = np.where(has, t + 1, steps)
            np.copyto(p, ma + L, where=has)
            np.copyto(p, n_i, where=p >= lend)  # park finished lanes
            t += 1
        # a lane still short of its segment end ran out of matches entirely
        tail = p < lend
        if t == 0:  # no lane recorded a token: nothing to splice or index
            continue
        tt, ss = np.nonzero(np.arange(t)[:, None] < steps[None, :])
        # later windows may revisit a match start an earlier window's lane
        # overshot into; greedy parses are memoryless, so both record the
        # same (start, length) token and either chain is a valid entry.
        m2idx[chain_m[tt, ss]] = (base + tt * S + ss).astype(np.int32)
        windows.append((chain_m, chain_l, steps, tail))
        bases.append(base)
        base += t * S

    # --- splice chains into the true parse -------------------------------
    # Indexed by *match start*, not walk position: every position in a
    # literal gap funnels to the same next match (nxt is a step function),
    # so entering any chain token by its match start resyncs immediately.
    from bisect import bisect_right

    buf = data.tobytes()
    parts_m: List[np.ndarray] = []
    parts_l: List[np.ndarray] = []
    pos = 0
    while True:
        m = int(nxt[pos])
        if m >= ng:
            break
        k = int(m2idx[m])
        if k >= 0:
            w = bisect_right(bases, k) - 1
            chain_m, chain_l, steps, tail = windows[w]
            t0, s = divmod(k - bases[w], S)
            t1 = int(steps[s])
            parts_m.append(chain_m[t0:t1, s])
            parts_l.append(chain_l[t0:t1, s])
            if tail[s]:
                break
            pos = int(chain_m[t1 - 1, s]) + int(chain_l[t1 - 1, s])
            continue
        # match start no speculative chain visited: exact scalar token (rare)
        j = int(prev[m])
        L = MIN_MATCH + _extend_scalar(buf, j + MIN_MATCH, m + MIN_MATCH, n)
        L = min(L, MAX_MATCH)
        parts_m.append(np.array([m], dtype=np.int32))
        parts_l.append(np.array([L], dtype=np.int32))
        pos = m + L
    if not parts_m:
        return empty
    M = np.concatenate(parts_m).astype(np.int64)
    L = np.concatenate(parts_l).astype(np.int64)
    D = M - prev[M].astype(np.int64)
    return M, L, D


def _lz77_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("lz77: fixed-width streams only (string_split first)")
    data = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    n = data.size
    with _stage("match_find"):
        M, L, offsets = _find_tokens(data)

    if M.size:
        ends = M + L
        lit_runs = np.empty(M.size + 1, dtype=np.int64)
        lit_runs[0] = M[0]
        lit_runs[1:-1] = M[1:] - ends[:-1]
        lit_runs[-1] = n - ends[-1]
        # gather literal bytes by ragged ranges: O(total literals), not O(n)
        gap_starts = np.concatenate([[0], ends])
        total_lit = int(lit_runs.sum())
        intra = np.arange(total_lit, dtype=np.int64) - np.repeat(
            np.cumsum(lit_runs) - lit_runs, lit_runs
        )
        literals = data[np.repeat(gap_starts, lit_runs) + intra]
    else:
        offsets = np.zeros(0, np.int64)
        lit_runs = np.array([n], dtype=np.int64)
        literals = data

    h = HeaderWriter().u8(int(s.stype)).varint(s.width).varint(n).done()
    return [
        Stream(np.ascontiguousarray(literals), SType.SERIAL, 1),
        numeric_stream(lit_runs.astype(np.uint32)),
        numeric_stream(L.astype(np.uint32)),
        numeric_stream(offsets.astype(np.uint32)),
    ], h


def _lz77_dec(outs, header):
    literals, lit_runs, match_lens, offsets = outs
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    n = r.varint()
    r.expect_end()
    lit = literals.data
    runs = lit_runs.data.astype(np.int64)
    mls = match_lens.data.astype(np.int64)
    offs = offsets.data.astype(np.int64)
    K = min(runs.size, mls.size)  # matches follow all but the final run
    cum_runs = np.zeros(runs.size + 1, dtype=np.int64)
    np.cumsum(runs, out=cum_runs[1:])
    cum_mls = np.zeros(K + 1, dtype=np.int64)
    np.cumsum(mls[:K], out=cum_mls[1:])
    if cum_runs[-1] + cum_mls[-1] != n or cum_runs[-1] != lit.size:
        raise ValueError("lz77: corrupt token streams")
    # literal destinations: run k starts after k runs and min(k, K) matches.
    # Scatter by ragged ranges (the decode twin of the encoder's ragged
    # gather): run starts are strictly increasing cumsums of non-negative
    # lengths, so ranges are disjoint by construction — O(total literals),
    # where the historical coverage-map scatter walked O(n) three times.
    lstart = cum_runs[:-1] + cum_mls[np.minimum(np.arange(runs.size), K)]
    out = np.empty(n, dtype=np.uint8)
    if lit.size:
        intra = np.arange(lit.size, dtype=np.int64) - np.repeat(
            cum_runs[:-1], runs
        )
        out[np.repeat(lstart, runs) + intra] = lit
    # match destinations, replayed in order at memcpy speed
    mstart = (cum_runs[1 : K + 1] + cum_mls[:-1]).tolist()
    if K and (offs[:K] <= 0).any():
        raise ValueError("lz77: corrupt token streams")
    ba = bytearray(out)
    with _stage("match_replay"):
        for mp, length, d in zip(mstart, mls[:K].tolist(), offs[:K].tolist()):
            src = mp - d
            if src < 0:
                raise ValueError("lz77: corrupt token streams")
            if d >= length:
                ba[mp : mp + length] = ba[src : src + length]
            else:  # overlapping copy: replicate the period
                pattern = ba[src:mp]
                reps = -(-length // d)
                ba[mp : mp + length] = (pattern * reps)[:length]
    from repro.core.message import from_wire

    return [from_wire(stype, width, bytes(ba), None)]


register_codec(
    CodecSpec(
        "lz77",
        codec_id=16,
        encode=_lz77_enc,
        decode=_lz77_dec,
        n_outputs=4,
        min_version=2,
        doc="greedy LZ77 -> (literals, lit-runs, match-lens, offsets) streams",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [
                (int(SType.SERIAL), 1),
                (int(SType.NUMERIC), 4),
                (int(SType.NUMERIC), 4),
                (int(SType.NUMERIC), 4),
            ],
            expansion=2.0,
        ),
    )
)


# -------------------------------------------------------------- lzma backend
def _lzma_enc(streams, params):
    import lzma

    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("lzma_backend: fixed-width streams only")
    preset = int(params.get("preset", 6))
    payload = lzma.compress(s.content_bytes(), preset=preset)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _lzma_dec(outs, header):
    import lzma

    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, lzma.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "lzma_backend",
        codec_id=24,
        encode=_lzma_enc,
        decode=_lzma_dec,
        min_version=3,
        doc="stdlib LZMA leaf — the ratio-end generic backend, as OpenZL"
        " embeds zstd-class LZ stages behind its transforms",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [(int(SType.SERIAL), 1)],
            params=(ParamSpec("preset", "int", doc="stdlib compression level"),),
            expansion=1.1,
            packed_outputs=(0,),
        ),
    )
)


# --------------------------------------------------------------- bz2 backend
def _bz2_enc(streams, params):
    import bz2

    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("bz2_backend: fixed-width streams only")
    level = int(params.get("level", 9))
    payload = bz2.compress(s.content_bytes(), level)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _bz2_dec(outs, header):
    import bz2

    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, bz2.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "bz2_backend",
        codec_id=25,
        encode=_bz2_enc,
        decode=_bz2_dec,
        min_version=3,
        doc="stdlib BWT backend (paper §II-B mentions BWT+MTF; block-sorting"
        " is a poor TPU fit so it ships as a host-side leaf only)",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [(int(SType.SERIAL), 1)],
            params=(ParamSpec("level", "int", doc="stdlib compression level"),),
            expansion=1.1,
            packed_outputs=(0,),
        ),
    )
)


# -------------------------------------------------------------- zlib backend
def _zlib_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("zlib_backend: fixed-width streams only (string_split first)")
    level = int(params.get("level", 6))
    payload = zlib.compress(s.content_bytes(), level)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _zlib_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, zlib.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "zlib_backend",
        codec_id=17,
        encode=_zlib_enc,
        decode=_zlib_dec,
        min_version=3,
        doc="stdlib DEFLATE leaf (stands in for OpenZL's optimized C LZ kernels)",
        sig=CodecSig(
            inputs=(InPort(FIXED_STYPES),),
            transfer=lambda atoms, params, n_out: [(int(SType.SERIAL), 1)],
            params=(ParamSpec("level", "int", doc="stdlib compression level"),),
            expansion=1.1,
            packed_outputs=(0,),
        ),
    )
)
