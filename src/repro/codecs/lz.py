"""LZ-family reductive codecs (paper §II-C/D).

``lz77``  — a from-scratch greedy hash-match LZ parser.  Match finding is
vectorized (rolling 4-gram hash + previous-occurrence-by-sort); token
selection is the classic left-to-right greedy walk.  Output follows the
Zstd factoring the paper cites: separate literal / literal-length /
match-length / offset streams — so each stream can take its own backend
(entropy) codec downstream, exactly the graph-model story.

``zlib_backend`` — stdlib DEFLATE as a leaf codec.  OpenZL similarly embeds
battle-tested C kernels for the generic LZ stage; in this offline container
zlib stands in for those (DESIGN.md §6).
"""
from __future__ import annotations

import zlib
from typing import List

import numpy as np

from repro.core.codec import CodecSpec, register_codec
from repro.core.message import Stream, SType

from ._util import HeaderReader, HeaderWriter, numeric_stream

MIN_MATCH = 4
MAX_MATCH = 1 << 16


def _prev_occurrence(data: np.ndarray) -> np.ndarray:
    """For each position i, the most recent j<i with the same 4-gram hash."""
    n = data.size
    if n < MIN_MATCH:
        return np.full(n, -1, dtype=np.int64)
    g = (
        data[:-3].astype(np.uint32)
        | (data[1:-2].astype(np.uint32) << 8)
        | (data[2:-1].astype(np.uint32) << 16)
        | (data[3:].astype(np.uint32) << 24)
    )
    h = (g * np.uint32(2654435761)) >> np.uint32(16)  # Knuth hash -> 16 bits
    order = np.argsort(h, kind="stable")
    prev = np.full(n, -1, dtype=np.int64)
    sh = h[order]
    same = np.zeros(order.size, dtype=bool)
    same[1:] = sh[1:] == sh[:-1]
    prev_sorted = np.where(same, np.concatenate([[0], order[:-1]]), -1)
    prev[order] = prev_sorted
    return prev


def _lz77_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("lz77: fixed-width streams only (string_split first)")
    data = np.frombuffer(s.content_bytes(), dtype=np.uint8)
    n = data.size
    prev = _prev_occurrence(data)
    buf = data.tobytes()

    lit_runs: List[int] = []
    match_lens: List[int] = []
    offsets: List[int] = []
    literals = bytearray()
    i = 0
    lit_start = 0
    while i + MIN_MATCH <= n:
        j = prev[i]
        if j >= 0 and j < i and buf[j : j + MIN_MATCH] == buf[i : i + MIN_MATCH]:
            L = _extend(data, j, i, n)
            lit_runs.append(i - lit_start)
            literals += buf[lit_start:i]
            match_lens.append(L)
            offsets.append(i - j)
            i += L
            lit_start = i
        else:
            i += 1
    lit_runs.append(n - lit_start)
    literals += buf[lit_start:n]

    h = HeaderWriter().u8(int(s.stype)).varint(s.width).varint(n).done()
    return [
        Stream(np.frombuffer(bytes(literals), dtype=np.uint8), SType.SERIAL, 1),
        numeric_stream(np.asarray(lit_runs, dtype=np.uint32)),
        numeric_stream(np.asarray(match_lens, dtype=np.uint32)),
        numeric_stream(np.asarray(offsets, dtype=np.uint32)),
    ], h


def _extend(data: np.ndarray, j: int, i: int, n: int) -> int:
    """Longest common extension of data[i:] vs data[j:] (j < i).

    Overlapping matches (dist < L) are legal in LZ77: the copy source keeps
    reading bytes the copy itself just produced, which for the *extension
    check* is equivalent to comparing data[j+L] vs data[i+L] directly —
    data[] already holds the final bytes on the encode side.  So plain
    chunked comparison is correct regardless of overlap.
    """
    L = 0
    limit = min(n - i, MAX_MATCH)
    while L < limit:
        chunk = min(256, limit - L)
        a = data[j + L : j + L + chunk]
        b = data[i + L : i + L + chunk]
        neq = np.nonzero(a != b)[0]
        if neq.size:
            return L + int(neq[0])
        L += chunk
    return L


def _lz77_dec(outs, header):
    literals, lit_runs, match_lens, offsets = outs
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    n = r.varint()
    r.expect_end()
    out = np.empty(n, dtype=np.uint8)
    lit = literals.data
    runs = lit_runs.data.astype(np.int64)
    mls = match_lens.data.astype(np.int64)
    offs = offsets.data.astype(np.int64)
    pos = 0
    lpos = 0
    for k in range(runs.size):
        rl = int(runs[k])
        if rl:
            out[pos : pos + rl] = lit[lpos : lpos + rl]
            pos += rl
            lpos += rl
        if k < mls.size:
            L = int(mls[k])
            d = int(offs[k])
            src = pos - d
            if d >= L:
                out[pos : pos + L] = out[src : src + L]
            else:  # overlapping copy: replicate the period
                reps = -(-L // d)
                pattern = out[src:pos]
                out[pos : pos + L] = np.tile(pattern, reps)[:L]
            pos += L
    if pos != n:
        raise ValueError("lz77: corrupt token streams")
    from repro.core.message import from_wire

    return [from_wire(stype, width, out.tobytes(), None)]


register_codec(
    CodecSpec(
        "lz77",
        codec_id=16,
        encode=_lz77_enc,
        decode=_lz77_dec,
        n_outputs=4,
        min_version=2,
        doc="greedy LZ77 -> (literals, lit-runs, match-lens, offsets) streams",
    )
)


# -------------------------------------------------------------- lzma backend
def _lzma_enc(streams, params):
    import lzma

    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("lzma_backend: fixed-width streams only")
    preset = int(params.get("preset", 6))
    payload = lzma.compress(s.content_bytes(), preset=preset)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _lzma_dec(outs, header):
    import lzma

    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, lzma.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "lzma_backend",
        codec_id=24,
        encode=_lzma_enc,
        decode=_lzma_dec,
        min_version=3,
        doc="stdlib LZMA leaf — the ratio-end generic backend, as OpenZL"
        " embeds zstd-class LZ stages behind its transforms",
    )
)


# --------------------------------------------------------------- bz2 backend
def _bz2_enc(streams, params):
    import bz2

    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("bz2_backend: fixed-width streams only")
    level = int(params.get("level", 9))
    payload = bz2.compress(s.content_bytes(), level)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _bz2_dec(outs, header):
    import bz2

    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, bz2.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "bz2_backend",
        codec_id=25,
        encode=_bz2_enc,
        decode=_bz2_dec,
        min_version=3,
        doc="stdlib BWT backend (paper §II-B mentions BWT+MTF; block-sorting"
        " is a poor TPU fit so it ships as a host-side leaf only)",
    )
)


# -------------------------------------------------------------- zlib backend
def _zlib_enc(streams, params):
    s = streams[0]
    if s.stype == SType.STRING:
        raise ValueError("zlib_backend: fixed-width streams only (string_split first)")
    level = int(params.get("level", 6))
    payload = zlib.compress(s.content_bytes(), level)
    h = HeaderWriter().u8(int(s.stype)).varint(s.width).done()
    return [Stream(np.frombuffer(payload, dtype=np.uint8), SType.SERIAL, 1)], h


def _zlib_dec(outs, header):
    r = HeaderReader(header)
    stype = SType(r.u8())
    width = r.varint()
    r.expect_end()
    from repro.core.message import from_wire

    return [from_wire(stype, width, zlib.decompress(outs[0].data.tobytes()), None)]


register_codec(
    CodecSpec(
        "zlib_backend",
        codec_id=17,
        encode=_zlib_enc,
        decode=_zlib_dec,
        min_version=3,
        doc="stdlib DEFLATE leaf (stands in for OpenZL's optimized C LZ kernels)",
    )
)
