#!/usr/bin/env python
"""Restartable, fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 300 --reduced --ckpt-dir /tmp/ckpt [--fail-at-step 150]

Production behaviours demonstrated end-to-end on CPU:
  * data from OpenZL-compressed shards (paper §VIII "training data"),
  * straggler-tolerant prefetch (timeout -> skip),
  * OpenZL-compressed checkpoints every --save-interval (paper §VIII
    "PyTorch model checkpoints"), atomic + keep-K,
  * crash/restart: --fail-at-step N simulates a node failure; rerunning the
    same command auto-resumes from the latest checkpoint (params, optimizer,
    data-pipeline cursor),
  * optional compressed gradient collectives (--grad-compress bf16|int8_ef)
    when a 'pod' axis exists,
  * trained checkpoint compressors: --ckpt-plan [DTYPE=]plan.ozp routes
    checkpoint leaves through a `python -m repro train` plan instead of the
    shipped profiles — the paper's train->deploy loop closed inside the
    training job (restore is untouched: frames are self-describing).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import CompressedShardStore, Prefetcher, Straggler
from repro.data.synthetic import zipf_tokens
from repro.distributed import optimizer as opt_lib
from repro.distributed.checkpoint import CheckpointManager
from repro.models import transformer


def make_shards(store: CompressedShardStore, cfg, n_shards: int, batch: int, seq: int):
    if store.shard_ids():
        return
    for i in range(n_shards):
        toks = zipf_tokens((batch * (seq + 1)) * 4, cfg.vocab, seed=i)
        store.write_shard(i, {"tokens": toks})
    stats = store.stats()
    print(
        f"[data] wrote {n_shards} OpenZL-compressed shards:"
        f" {stats['raw_bytes']/1e6:.1f}MB -> {stats['compressed_bytes']/1e6:.1f}MB"
        f" (ratio {stats['ratio']:.2f}x)"
    )


def batches_from_shard(data, batch, seq, rng):
    toks = data["tokens"]
    n = toks.shape[0] - seq - 1
    starts = rng.integers(0, n, size=batch)
    idx = starts[:, None] + np.arange(seq)[None, :]
    return {"tokens": toks[idx], "labels": toks[idx + 1]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size model (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--data-dir", default="/tmp/repro_data")
    ap.add_argument("--save-interval", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--fail-at-step", type=int, default=0, help="simulate a crash")
    ap.add_argument(
        "--ckpt-plan",
        action="append",
        default=[],
        metavar="[DTYPE=]PLAN.ozp",
        help="compress checkpoint leaves with a trained plan (repeatable;"
        " bare PATH applies to all dtypes)",
    )
    ap.add_argument("--straggler-timeout", type=float, default=30.0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    if spec.family != "lm":
        ap.error("train.py drives LM archs; see examples/ for gnn/recsys")

    if args.ckpt_plan:
        from repro.core.serialize import deserialize_plan
        from repro.distributed.checkpoint import set_checkpoint_plan

        for item in args.ckpt_plan:
            dtype_name, _, path = item.rpartition("=")
            dtype_name = dtype_name or "*"
            plan, meta = deserialize_plan(Path(path).read_bytes())
            set_checkpoint_plan(dtype_name, plan)
            print(
                f"[ckpt] trained plan {meta.get('name') or plan.name or path}"
                f" deployed for dtype {dtype_name!r}"
            )
    cfg = spec.reduced_cfg if args.reduced else spec.model_cfg
    cfg = dataclasses.replace(cfg, remat=False) if args.reduced else cfg

    # ---------------------------------------------------------------- data
    store = CompressedShardStore(args.data_dir)
    make_shards(store, cfg, n_shards=4, batch=args.batch, seq=args.seq)
    rng = np.random.default_rng(0)

    # --------------------------------------------------------------- model
    optimizer = opt_lib.adamw(lr=args.lr)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    step_fn = jax.jit(train_step)

    mgr = CheckpointManager(
        args.ckpt_dir,
        save_interval=args.save_interval,
        keep=args.keep,
        async_save=False,
    )
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    start_step = 0
    cursor = 0
    restored = mgr.restore_or_none({"params": params, "opt": opt_state})
    if restored is not None:
        start_step, tree, manifest = restored
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        cursor = int(manifest["metadata"].get("data_cursor", 0))
        print(
            f"[resume] restored step {start_step} from {args.ckpt_dir}"
            f" (compressed ratio {manifest['ratio']:.2f}x), data cursor {cursor}"
        )

    prefetch = Prefetcher(store.read_shard, store.shard_ids(), start_cursor=cursor)
    t0 = time.time()
    losses = []
    try:
        for step in range(start_step + 1, args.steps + 1):
            try:
                item = prefetch.next(timeout=args.straggler_timeout)
            except Straggler as e:
                print(f"[straggler] {e}; skipping a fetch")
                continue
            batch = batches_from_shard(item["data"], args.batch, args.seq, rng)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = step_fn(params, opt_state, batch)
            losses.append(float(loss))
            if step % args.log_every == 0:
                dt = time.time() - t0
                print(
                    f"step {step:5d} loss {np.mean(losses[-args.log_every:]):.4f}"
                    f" ({step - start_step} steps in {dt:.1f}s)",
                    flush=True,
                )
            if args.fail_at_step and step == args.fail_at_step:
                print(f"[failure-sim] crashing at step {step} (before save)")
                prefetch.stop()
                return 42
            if mgr.should_save(step):
                mgr.save(
                    step,
                    {"params": params, "opt": opt_state},
                    metadata={"data_cursor": prefetch.state()["cursor"]},
                )
                print(f"[ckpt] saved step {step}")
        mgr.save(
            args.steps,
            {"params": params, "opt": opt_state},
            metadata={"data_cursor": prefetch.state()["cursor"]},
        )
        print(
            f"[done] {args.steps} steps, final loss"
            f" {np.mean(losses[-10:]):.4f}, initial {losses[0]:.4f}"
        )
    finally:
        prefetch.stop()
        mgr.wait()
    return 0


if __name__ == "__main__":
    sys.exit(main())
