import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, and extract the roofline inputs from the compiled
artifact:

    memory_analysis()  — per-device bytes (proves it fits / doesn't)
    cost_analysis()    — per-device HLO FLOPs + bytes accessed
    compiled HLO text  — collective ops, summed bytes by category

Results cache incrementally as JSON under results/dryrun/ so the sweep is
restartable (usage: python -m repro.launch.dryrun --all [--multi-pod]).
Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system — they land in the JSON with status=error.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import all_archs, get_arch
from repro.core.stream_io import _atomic_sink
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[sufc]\d+|bf16)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{([{}\d,]*)\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)


def _crosses_pods(line: str, half: int) -> bool:
    """True if any replica group mixes devices < half and >= half (the pod
    boundary on the (pod, data, model) mesh with row-major device order)."""
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.strip("{}").split(",") if x]
            if ids and min(ids) < half <= max(ids):
                return True
        return False
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        import numpy as _np

        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        groups = arr.reshape(g, s)
        return bool(((groups < half).any(axis=1) & (groups >= half).any(axis=1)).any())
    return False


def collective_bytes(hlo_text: str, n_devices: int = 0) -> dict:
    """Sum output bytes of every collective op in post-SPMD HLO, by kind,
    plus the cross-pod subtotal (multi-pod meshes)."""
    out = {}
    cross = 0
    half = n_devices // 2 if n_devices >= 512 else 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        if half and _crosses_pods(line, half):
            cross += b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    if half:
        out["cross_pod"] = cross
    return out


def run_cell(
    arch_id: str,
    shape_name: str,
    multi_pod: bool,
    variant: str = "base",
    grad_compress: str = "",
    unroll: bool = False,
    serve_mesh: str = "",
) -> dict:
    if serve_mesh:
        mesh_tag = f"serve{serve_mesh}"
    else:
        mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    spec = get_arch(arch_id)
    shape = spec.shape(shape_name)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_tag,
        "family": spec.family,
        "kind": shape.kind,
        "dims": shape.dims,
        "variant": variant
        + (f"+gc_{grad_compress}" if grad_compress else "")
        + ("+unroll" if unroll else ""),
    }
    if shape.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip
        return rec
    t0 = time.time()
    try:
        if serve_mesh:
            from repro.launch.mesh import make_serving_mesh

            d, m = (int(x) for x in serve_mesh.split("x"))
            mesh = make_serving_mesh(d, m)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(
            arch_id, shape_name, mesh=mesh, variant=variant, unroll=unroll
        )
        with mesh:
            if grad_compress:
                # §Perf/H3: pod-manual shard_map step w/ compressed psum
                import dataclasses as _dc

                from repro.distributed import optimizer as opt_lib
                from repro.distributed.pod_step import (
                    make_ef_state_specs,
                    make_pod_dp_train_step,
                )

                assert multi_pod and shape.kind == "train" and spec.family == "lm"
                cfg = cell.cfg
                if variant == "opt":
                    # inside the pod-manual body only intra-pod axes exist
                    cfg = _dc.replace(
                        cfg,
                        act_dp=("data",),
                        logits_pspec=(("data",), None, "model"),
                    )
                params_sds, opt_sds, batch_sds = cell.abstract_args
                optimizer = opt_lib.for_arch("lm", arch_id)
                step = make_pod_dp_train_step(cfg, optimizer, mesh, grad_compress)
                ef_sds = make_ef_state_specs(params_sds, mesh.shape["pod"])
                jitted = jax.jit(step)
                lowered = jitted.lower(params_sds, opt_sds, ef_sds, batch_sds)
            else:
                jitted = jax.jit(
                    cell.fn,
                    in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                )
                lowered = jitted.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)
        if mem_rec:
            mem_rec["per_device_total"] = (
                mem_rec.get("argument_size_in_bytes", 0)
                + mem_rec.get("output_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0)
                - mem_rec.get("alias_size_in_bytes", 0)
            )
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax returns a 1-list
            cost = cost[0] if cost else {}
        cost_rec = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals") or k.startswith("bytes accessed")
            )
        }
        hlo = compiled.as_text()
        rec.update(
            status="ok",
            n_devices=int(mesh.size),
            lower_seconds=round(t_lower, 2),
            compile_seconds=round(t_compile, 2),
            memory=mem_rec,
            cost=cost_rec,
            collectives=collective_bytes(hlo, int(mesh.size)),
            hlo_lines=hlo.count("\n"),
        )
    except Exception as e:  # a failure here is a bug in our sharding config
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_seconds"] = round(time.time() - t0, 2)
    return rec


def cell_path(
    arch_id: str, shape_name: str, multi_pod: bool, variant: str = "base",
    grad_compress: str = "", unroll: bool = False, serve_mesh: str = "",
) -> Path:
    if serve_mesh:
        mesh_tag = f"serve{serve_mesh}"
    else:
        mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = "" if variant == "base" else f"__{variant}"
    if grad_compress:
        suffix += f"__gc_{grad_compress}"
    if unroll:
        suffix += "__unroll"
    return RESULTS / f"{arch_id}__{shape_name}__{mesh_tag}{suffix}.json"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="single arch id")
    ap.add_argument("--shape", help="single shape name")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument(
        "--grad-compress", default="", choices=["", "none", "bf16", "int8_ef"],
        help="lower the pod-manual compressed-DP step (multi-pod LM train only)",
    )
    ap.add_argument(
        "--unroll", action="store_true",
        help="unroll layer scans (XLA cost_analysis counts loop bodies once)",
    )
    ap.add_argument(
        "--serve-mesh", default="", choices=["", "4x4", "8x8"],
        help="lower on a small serving slice instead (decode cells)",
    )
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch_id, spec in sorted(all_archs().items()):
            for shape in spec.shapes:
                cells.append((arch_id, shape.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    n_err = 0
    for arch_id, shape_name in cells:
        for multi_pod in meshes:
            path = cell_path(
                arch_id, shape_name, multi_pod, args.variant,
                args.grad_compress, args.unroll, args.serve_mesh,
            )
            if path.exists() and not args.force:
                rec = json.loads(path.read_text())
                print(f"[cached] {path.stem}: {rec['status']}")
                continue
            rec = run_cell(
                arch_id, shape_name, multi_pod, args.variant,
                args.grad_compress, args.unroll, args.serve_mesh,
            )
            with _atomic_sink(path) as f:
                f.write(json.dumps(rec, indent=1).encode())
            status = rec["status"]
            n_err += status == "error"
            extra = ""
            if status == "ok":
                mem = rec["memory"].get("per_device_total", 0) / (1 << 30)
                coll = rec["collectives"]["total"] / (1 << 30)
                extra = (
                    f" mem/dev={mem:.2f}GiB coll={coll:.3f}GiB"
                    f" flops/dev={rec['cost'].get('flops', 0):.3g}"
                    f" compile={rec['compile_seconds']}s"
                )
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[{status}] {path.stem}{extra}", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
