#!/usr/bin/env python
"""Batched LM serving driver: prefill + KV-cache decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 8 --prompt-len 32 --gen 32

Loads the latest checkpoint from --ckpt-dir if present (OpenZL frames),
otherwise serves random-init weights.  Reports prefill and decode
throughput.  SWA archs (h2o-danube) serve with a ring-buffer cache of
window size — constant memory however long the generation runs.

Checkpoint leaves decode through the per-worker long-lived codec sessions in
``repro.distributed.checkpoint`` (one DecompressorSession per process): the
universal-decoder thread pool and coder-table scratch are built once and
reused across every leaf and every reload, not per frame.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.distributed.checkpoint import CheckpointManager
from repro.models import transformer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.reduced_cfg if args.reduced else spec.model_cfg
    cfg = dataclasses.replace(cfg, remat=False)

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        from repro.distributed.checkpoint import codec_session_stats

        mgr = CheckpointManager(args.ckpt_dir)
        restored = mgr.restore_or_none({"params": params})
        if restored is not None:
            step, tree, _ = restored
            params = jax.tree.map(jnp.asarray, tree["params"])
            cs = codec_session_stats()
            print(f"[serve] loaded checkpoint step {step}")
            print(
                f"[serve] ozl session: {cs['dec_calls']} leaf frames,"
                f" {cs['dec_bytes_in']/1e6:.1f} MB compressed ->"
                f" {cs['dec_bytes_out']/1e6:.1f} MB (pool+tables reused"
                " across leaves)"
            )

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)

    # ---- prefill: full forward, then write the prompt KV into the cache by
    # replaying tokens through decode_step (simple, cache-layout agnostic)
    decode = jax.jit(
        lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg)
    )
    cache = transformer.init_kv_cache(cfg, B, max_len)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t : t + 1], jnp.int32(t))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # ---- decode
    key = jax.random.PRNGKey(2)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] arch={args.arch} batch={B} prompt={P} gen={G}")
    print(
        f"  prefill: {B*P} tokens in {t_prefill:.2f}s"
        f" ({B*P/max(t_prefill,1e-9):.0f} tok/s, incl. compile)"
    )
    print(
        f"  decode:  {B*(G-1)} tokens in {t_decode:.2f}s"
        f" ({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)"
    )
    print(f"  sample[0,:12] = {np.asarray(out[0, :12]).tolist()}")
    cache_mb = sum(x.nbytes for x in jax.tree.leaves(cache)) / 1e6
    print(f"  kv-cache: {cache_mb:.1f} MB ({'ring/SWA' if cfg.sliding_window else 'linear'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
